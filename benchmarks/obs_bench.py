"""Observability benchmark: measured-vs-modeled accounting → BENCH_obs.json.

Three sections, all driven through the public ``repro.obs`` surface:

* **roofline** — the measured-vs-modeled join the ROADMAP asked for.
  A short IVI run on the Pallas E-step backend records device-synced
  ``train/solve`` spans (`SpanRecorder(device_sync=True)`); their min
  wall time joins against the kernels' structural HBM-byte model
  (`kernel_bench.modeled_estep_hbm_bytes`) under the seed roofline
  hardware table (`repro.obs.roofline.HW`, re-exported by the
  seed harness) via
  ``repro.obs.roofline_from_trace``. On this CPU container the kernels
  run in interpret mode, so the record carries ``proxy_regime: true``
  and the agreement flag is informational; on a TPU the same record is
  the model-validation gate (docs/observability.md §roofline).

* **roofline_csr** — the same join for the flat-token CSR layout
  (``LDA(layout="csr")``): its ``train/solve`` spans are priced by
  ``kernel_bench.modeled_estep_csr_hbm_bytes`` at the engine's
  budget-sized stream shape, so the width-free path carries its own
  measured-vs-modeled record (and ``proxy_regime`` flag) in
  BENCH_obs.json alongside the padded one.

* **overhead** — the telemetry cost contract. The same streaming
  training smoke runs telemetry-off and telemetry-on (default bundle:
  spans + metrics + evaluate-cadence watchdog), min-of-3 each. The CI
  bars: bit-identical final λ (telemetry must not perturb the
  trajectory) and ≤5% wall-clock overhead (CPU wall time is noisy at
  smoke scale, hence min-of-3 and a ≥1s workload).

* **trace_roundtrip** — the roofline run's trace dumps to JSONL,
  re-validates against the schema, and converts to a Chrome trace with a
  count-exact event match.

Run: ``PYTHONPATH=src python -m benchmarks.obs_bench [--json BENCH_obs.json]``
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.kernel_bench import (modeled_estep_csr_hbm_bytes,
                                     modeled_estep_hbm_bytes)
from repro.obs.roofline import HW
from repro.data import PAPER_CORPORA, make_corpus
from repro.lda import LDA
from repro.obs import (SpanRecorder, Telemetry, chrome_trace_from_jsonl,
                       roofline_from_trace, validate_jsonl)

ESTEP_ITERS = 15
BATCH = 32


def _proxy_regime() -> bool:
    """Interpret-mode CPU measurements are Python-time proxies, not the
    HBM-model's hardware — only a real accelerator validates the model."""
    return jax.devices()[0].platform not in ("tpu", "gpu")


def roofline_section(corpus_name: str = "tiny") -> tuple[dict, Telemetry]:
    """Measured train/solve spans joined against the modeled HBM bytes."""
    spec = PAPER_CORPORA[corpus_name]
    corpus = make_corpus(spec, split="train", seed=0)
    tel = Telemetry(trace=SpanRecorder(device_sync=True))
    lda = LDA(num_topics=spec.num_topics, vocab_size=spec.vocab_size,
              estep_max_iters=ESTEP_ITERS, estep_backend="pallas",
              algo="ivi", batch_size=BATCH, seed=0, telemetry=tel)
    lda.fit(corpus, epochs=2)    # epoch 2: every solve is a warm jit entry
    b, v, k, l = (BATCH, spec.vocab_size, spec.num_topics,
                  corpus.max_unique)
    modeled = {
        # the fused Pallas path is what estep_backend="pallas" dispatches
        "train/solve": modeled_estep_hbm_bytes("fused", b, v, k, l,
                                               ESTEP_ITERS),
    }
    check = roofline_from_trace(
        tel.trace.records, modeled, hbm_gbps=HW["hbm_bw"] / 1e9,
        proxy_regime=_proxy_regime())
    check["shape"] = {"B": b, "V": v, "K": k, "L": l,
                      "sweeps": ESTEP_ITERS,
                      "platform": jax.devices()[0].platform}
    return check, tel


def roofline_csr_section(corpus_name: str = "tiny") -> dict:
    """The roofline join for the flat-token CSR layout: a short streaming
    run with ``layout="csr"`` on the Pallas backend, its ``train/solve``
    spans priced by the CSR HBM model at the engine's (token_budget,)
    stream shape — every batch shares ONE compiled entry, so the span
    population is homogeneous by construction."""
    from repro.data.stream import CorpusDocStream

    spec = PAPER_CORPORA[corpus_name]
    corpus = make_corpus(spec, split="train", seed=0)
    tel = Telemetry(trace=SpanRecorder(device_sync=True))
    lda = LDA(num_topics=spec.num_topics, vocab_size=spec.vocab_size,
              estep_max_iters=ESTEP_ITERS, estep_backend="pallas",
              algo="ivi", batch_size=BATCH, layout="csr", seed=0,
              telemetry=tel)
    lda.fit(CorpusDocStream(corpus), epochs=2)   # epoch 2: warm entries
    t = lda.trainer.eng.token_budget             # engine-resolved default
    b, v, k = BATCH, spec.vocab_size, spec.num_topics
    modeled = {
        "train/solve": modeled_estep_csr_hbm_bytes(t, b, v, k,
                                                   ESTEP_ITERS),
    }
    check = roofline_from_trace(
        tel.trace.records, modeled, hbm_gbps=HW["hbm_bw"] / 1e9,
        proxy_regime=_proxy_regime())
    check["shape"] = {"T": t, "B": b, "V": v, "K": k,
                      "sweeps": ESTEP_ITERS,
                      "platform": jax.devices()[0].platform}
    return check


def _timed_stream_fit(telemetry) -> tuple[float, np.ndarray, object]:
    """One streaming training smoke; returns (seconds, final λ, bundle)."""
    from repro.data.stream import CorpusDocStream

    # "small" at a deep E-step: enough device work per batch (~10ms) that
    # the fixed per-batch recorder cost (~0.2ms: 4 spans + a handful of
    # counter updates) amortizes the way it does at production shapes —
    # "tiny" at shallow sweeps would measure Python overhead against
    # nothing and the bar would gate on scheduler noise
    spec = PAPER_CORPORA["small"]
    corpus = make_corpus(spec, split="train", seed=0)
    stream = CorpusDocStream(corpus)
    lda = LDA(num_topics=spec.num_topics, vocab_size=spec.vocab_size,
              estep_max_iters=80, algo="ivi", batch_size=32, seed=0,
              telemetry=telemetry)
    t0 = time.perf_counter()
    lda.fit(stream, epochs=4)
    jax.block_until_ready(lda.lam)
    return time.perf_counter() - t0, np.asarray(lda.lam), lda.telemetry


def overhead_section(repeats: int = 3) -> dict:
    """Telemetry-off vs telemetry-on streaming smoke: bit-equality of the
    trajectory plus the wall-clock overhead bar (min-of-N per arm)."""
    off_s, on_s = [], []
    lam_off = lam_on = None
    tel_stats = None
    for _ in range(repeats):
        s, lam_off, _ = _timed_stream_fit(None)
        off_s.append(s)
        s, lam_on, tel = _timed_stream_fit(True)
        on_s.append(s)
        tel_stats = {
            "span_records": tel.trace.num_records,
            "train_tokens": tel.metrics.total("train.tokens"),
            "pack_batches": tel.metrics.total("pack.batches"),
        }
    t_off, t_on = min(off_s), min(on_s)
    return {
        "repeats": repeats,
        "telemetry_off_s": t_off,
        "telemetry_on_s": t_on,
        "overhead_pct": (t_on - t_off) / t_off * 100.0,
        "lam_bit_identical": bool(np.array_equal(lam_off, lam_on)),
        "telemetry_on_stats": tel_stats,
        "note": ("min-of-N CPU wall time; the ≤5% bar is asserted on the "
                 "min to stay below scheduler noise at smoke scale"),
    }


def trace_roundtrip_section(tel: Telemetry, out_dir: str) -> dict:
    """Dump → validate → Chrome-convert the roofline run's trace."""
    jsonl = os.path.join(out_dir, "obs_trace.jsonl")
    chrome = os.path.join(out_dir, "obs_trace.chrome.json")
    dumped = tel.trace.dump_jsonl(jsonl)
    validated = validate_jsonl(jsonl)
    chrome_events = chrome_trace_from_jsonl(jsonl, chrome)
    return {
        "jsonl": jsonl,
        "chrome": chrome,
        "records_dumped": dumped,
        "records_validated": validated,
        "chrome_events": chrome_events,
        "count_exact": dumped == validated == chrome_events,
    }


def obs_report(json_path: str | None = None, *,
               repeats: int = 3) -> dict:
    roofline, tel = roofline_section()
    record = {
        "roofline": roofline,
        "roofline_csr": roofline_csr_section(),
        "overhead": overhead_section(repeats=repeats),
        "trace_roundtrip": trace_roundtrip_section(
            tel, tempfile.mkdtemp(prefix="obs_bench_")),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_obs.json",
                    help="where to write the observability record")
    ap.add_argument("--repeats", type=int, default=3,
                    help="min-of-N repeats for the overhead arms")
    args = ap.parse_args()
    rec = obs_report(args.json, repeats=args.repeats)
    rl, ov, tr = rec["roofline"], rec["overhead"], rec["trace_roundtrip"]
    rc = rec["roofline_csr"]
    r0, c0 = rl["records"][0], rc["records"][0]
    print(f"BENCH_obs -> {args.json}")
    print(f"  roofline : {rl['n_records']} record(s) on "
          f"{rl['shape']['platform']} (proxy_regime={rl['proxy_regime']}); "
          f"{r0['name']}: measured {r0['measured_s'] * 1e3:.2f}ms vs "
          f"modeled {r0['modeled_s'] * 1e3:.4f}ms "
          f"({r0['measured_vs_modeled']:.1f}x, {r0['verdict']})")
    print(f"  roofline_csr : T={rc['shape']['T']} "
          f"(proxy_regime={rc['proxy_regime']}); "
          f"{c0['name']}: measured {c0['measured_s'] * 1e3:.2f}ms vs "
          f"modeled {c0['modeled_s'] * 1e3:.4f}ms "
          f"({c0['measured_vs_modeled']:.1f}x, {c0['verdict']})")
    print(f"  overhead : off {ov['telemetry_off_s']:.2f}s vs on "
          f"{ov['telemetry_on_s']:.2f}s -> {ov['overhead_pct']:+.2f}% "
          f"(lam bit-identical: {ov['lam_bit_identical']}, "
          f"{ov['telemetry_on_stats']['span_records']} spans)")
    print(f"  trace    : {tr['records_dumped']} records -> "
          f"{tr['chrome_events']} Chrome events "
          f"(count_exact={tr['count_exact']})")
    assert rl["n_records"] >= 1 and not rl["missing_spans"], \
        "roofline join produced no measured-vs-modeled record"
    assert rc["n_records"] >= 1 and not rc["missing_spans"], \
        "CSR roofline join produced no measured-vs-modeled record"
    assert ov["lam_bit_identical"], \
        "telemetry-on run diverged from the telemetry-off trajectory"
    assert ov["overhead_pct"] <= 5.0, \
        f"telemetry overhead {ov['overhead_pct']:.2f}% exceeds the 5% bar"
    assert tr["count_exact"], \
        "trace JSONL -> Chrome conversion lost or invented records"
