"""Table 2 / Figs. 3–4 — D-IVI: LPP and time-per-iteration vs number of
processors and mini-batch size.

Workers are simulated bit-exactly with vmap (repro.dist); the wall-clock
column combines the measured per-round compute time with the paper's
cost structure: a P-worker round processes P mini-batches concurrently, so

    time_per_doc(P) = max_w(estep_time) / (P·B) + comm_bytes / ici_bw

comm is one (V/model, K) correction reduction per round — the same message
the paper's workers send to the master. Speed-up saturates as P grows and
larger mini-batches help, matching the paper's Fig. 3 (bottom right).
"""
from __future__ import annotations

import time
from typing import Dict

from repro.dist import DIVIConfig

# modelled interconnect for the simulated cluster (32-core host in the
# paper; we keep their relative orders of magnitude)
COMM_BW = 2e9          # bytes/s effective reduction bandwidth
COMM_LAT = 2e-3        # per-round latency (s)


def run(corpus_name: str = "small", procs=(1, 2, 4, 8), batches=(16, 64),
        rounds_per_p: int = 64, seed: int = 0) -> Dict:
    from benchmarks.common import paper_setup
    from repro.lda import LDA
    _, train, test, cfg = paper_setup(corpus_name, estep_iters=40, seed=seed)
    results = {}
    for bs in batches:
        for p in procs:
            if train.num_docs // p < bs:
                continue
            lda = LDA(cfg, algo="divi", seed=seed,
                      distributed=DIVIConfig(num_workers=p, batch_size=bs))
            n_rounds = max(rounds_per_p // p, 4)
            t0 = time.perf_counter()
            lda.fit(train, rounds=n_rounds)
            wall = time.perf_counter() - t0
            lpp = lda.score(test)
            # measured per-round compute on ONE worker's batch: the vmap
            # simulation executes all P workers serially on one core, so
            # the per-worker time is wall / (rounds · P)
            t_worker = wall / (n_rounds * p)
            comm = (cfg.vocab_size * cfg.num_topics * 4) / COMM_BW + COMM_LAT
            t_round = t_worker + comm          # workers run concurrently
            docs_per_s = p * bs / t_round
            results[(bs, p)] = {"lpp": lpp, "t_round": t_round,
                                "docs_per_s": docs_per_s,
                                "rounds": n_rounds}
    # speed-ups relative to P=1 at same batch size
    for (bs, p), r in results.items():
        base = results.get((bs, 1))
        r["speedup"] = (r["docs_per_s"] / base["docs_per_s"]) if base else 1.0
    return results


def curves(corpus_name: str = "small", procs=(1, 4, 8), rounds: int = 24,
           seed: int = 0):
    """Fig. 4 — LPP vs documents processed for varying P.

    Paper claim: more processors slow the per-document convergence *rate*
    (staler information per update) while each round covers P× documents.
    """
    from benchmarks.common import paper_setup
    from repro.lda import LDA
    _, train, test, cfg = paper_setup(corpus_name, estep_iters=40, seed=seed)
    out = {}
    for p in procs:
        if train.num_docs // p < 16:
            continue
        lda = LDA(cfg, algo="divi", seed=seed,
                  distributed=DIVIConfig(num_workers=p, batch_size=16))
        lda.partial_fit(train, steps=0)
        docs, lpps = [], []
        for _ in range(max(rounds // p, 3)):
            lda.partial_fit(steps=1)
            docs.append(lda.docs_seen)
            lpps.append(lda.score(test))
        out[p] = {"docs": docs, "lpp": lpps}
    return out


def _lpp_at_docs(curve, budget):
    best = curve["lpp"][0]
    for d, l in zip(curve["docs"], curve["lpp"]):
        if d <= budget:
            best = l
    return best


def rows(corpus_name: str = "small"):
    res = run(corpus_name)
    out = []
    for (bs, p), r in sorted(res.items()):
        out.append((f"table2/{corpus_name}/b{bs}/P{p}",
                    r["t_round"] * 1e6,
                    f"lpp={r['lpp']:.4f} speedup={r['speedup']:.2f}x "
                    f"docs_per_s={r['docs_per_s']:.0f}"))
    # Fig. 4: per-document convergence rate decreases with P
    cv = curves(corpus_name)
    if cv:
        budget = min(c["docs"][-1] for c in cv.values())
        for p, c in sorted(cv.items()):
            out.append((f"fig4/{corpus_name}/P{p}", 0.0,
                        f"lpp@{budget}docs={_lpp_at_docs(c, budget):.4f} "
                        f"final={c['lpp'][-1]:.4f}"))
    return out
