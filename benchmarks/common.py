"""Benchmark helpers: timing, CSV emission, shared LDA setup.

The paper benchmarks build their estimators through the ``repro.lda.LDA``
facade (`make_lda` below) — the same public surface users drive — so a
facade regression shows up in the benchmark numbers, not just in unit
tests.
"""
from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

import jax


def paper_setup(corpus_name: str, *, estep_iters: int = 60, seed: int = 0):
    """(spec, train, test, cfg) with the benchmarks' shared topic sizing."""
    from repro.core import LDAConfig
    from repro.data import PAPER_CORPORA, make_corpus

    spec = PAPER_CORPORA[corpus_name]
    train = make_corpus(spec, split="train", seed=seed)
    test = make_corpus(spec, split="test", seed=seed)
    cfg = LDAConfig(num_topics=min(100, spec.num_topics * 2),
                    vocab_size=spec.vocab_size, estep_max_iters=estep_iters)
    return spec, train, test, cfg


def make_lda(corpus_name: str, *, algo: str = "ivi", batch: int = 32,
             seed: int = 0, estep_iters: int = 60, distributed=None,
             with_test: bool = True) -> Tuple["object", "object", "object"]:
    """(LDA facade, train corpus, test corpus) for one benchmark run."""
    from repro.lda import LDA

    _, train, test, cfg = paper_setup(corpus_name, estep_iters=estep_iters,
                                      seed=seed)
    lda = LDA(cfg, algo=algo, distributed=distributed, batch_size=batch,
              seed=seed)
    lda.partial_fit(train, steps=0,
                    test_corpus=test if with_test else None)
    return lda, train, test


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: Iterable[tuple]) -> List[str]:
    out = []
    for name, us, derived in rows:
        line = f"{name},{us:.1f},{derived}"
        print(line)
        out.append(line)
    return out
