"""Fig. 1 — per-word predictive probability vs documents processed,
MVI vs SVI vs IVI vs S-IVI (paper §6.1).

Acceptance criteria from the paper, checked on synthetic corpora:
  * incremental engines (IVI, S-IVI) converge to a value ≥ the others;
  * IVI reaches MVI's converged LPP after seeing a fraction of the
    documents MVI processed.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import make_lda


def run(corpus_name: str = "small", epochs: int = 6, batch: int = 32,
        seed: int = 0) -> Dict[str, Dict[str, List[float]]]:
    curves: Dict[str, Dict[str, List[float]]] = {}
    for algo in ("mvi", "svi", "ivi", "sivi"):
        lda, train, _ = make_lda(corpus_name, algo=algo, batch=batch,
                                 seed=seed)
        lda.evaluate()
        if algo == "mvi":
            for _ in range(epochs):
                lda.fit(epochs=1)
                lda.evaluate()
        else:
            n_units = epochs * max(train.num_docs // batch, 1)
            for step in range(n_units):
                lda.partial_fit(steps=1)
                if step % 4 == 0:
                    lda.evaluate()
        lda.evaluate()
        curves[algo] = {"docs": list(map(float, lda.history.docs_seen)),
                        "lpp": lda.history.lpp,
                        "wall": lda.history.wall}
    return curves


def _lpp_at(curve, docs: float) -> float:
    """LPP at the evaluation point closest below a docs-processed budget."""
    best = curve["lpp"][0]
    for d, l in zip(curve["docs"], curve["lpp"]):
        if d <= docs:
            best = l
    return best


def rows(corpus_name: str = "small", epochs: int = 4):
    t0 = time.perf_counter()
    curves = run(corpus_name, epochs=epochs)
    total_us = (time.perf_counter() - t0) * 1e6
    out = []
    for algo, c in curves.items():
        out.append((f"fig1/{corpus_name}/{algo}", total_us / 4,
                    f"final_lpp={c['lpp'][-1]:.4f}"))
    # Claim A (Fig. 1, reproduced): at an equal early document budget the
    # incremental engines are ahead of batch MVI — IVI makes progress
    # before a full pass completes.
    budget = max(c["docs"][-1] for c in curves.values()) / max(epochs, 1)
    early = {a: _lpp_at(c, budget) for a, c in curves.items()}
    ok_a = max(early["ivi"], early["sivi"]) >= early["mvi"] - 0.02
    out.append((f"fig1/{corpus_name}/claim_faster_early", 0.0,
                f"ivi@1pass={early['ivi']:.4f} sivi@1pass={early['sivi']:.4f} "
                f"mvi@1pass={early['mvi']:.4f} ok={ok_a}"))
    # Claim B (final quality): on the paper's real corpora IVI matches or
    # beats MVI at convergence; on these *synthetic* corpora (sharply
    # identifiable topics, ≤2k docs) MVI's synchronized passes find a
    # better basin — a documented deviation (EXPERIMENTS.md). We report
    # the measured ordering rather than assert it.
    final = {a: c["lpp"][-1] for a, c in curves.items()}
    out.append((f"fig1/{corpus_name}/final_ordering", 0.0,
                " ".join(f"{a}={final[a]:.4f}"
                         for a in ("mvi", "svi", "ivi", "sivi"))))
    # CVB0 baseline (paper §5's de-facto standard for moderate corpora)
    from benchmarks.common import paper_setup
    from repro.core import CVB0Engine, log_predictive, split_heldout
    _, train, test, cfg = paper_setup(corpus_name, seed=0)
    obs, held = split_heldout(test, seed=0)
    cvb = CVB0Engine(cfg, train, batch_size=32, seed=0)
    for _ in range(epochs):
        cvb.run_epoch()
    out.append((f"fig1/{corpus_name}/cvb0", 0.0,
                f"final_lpp={float(log_predictive(cfg, cvb.lam, obs, held)):.4f}"))
    return out
