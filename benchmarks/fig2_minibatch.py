"""Fig. 2 — effect of IVI mini-batch size (paper §6.1).

Paper claims: smaller mini-batches converge faster (in documents), larger
mini-batches reach a better final value.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import make_lda


def run(corpus_name: str = "small", sizes=(8, 32, 128), budget_docs=3000,
        seed: int = 0) -> Dict[int, List[float]]:
    curves = {}
    for bs in sizes:
        lda, _, _ = make_lda(corpus_name, algo="ivi", batch=bs, seed=seed)
        while lda.docs_seen < budget_docs:
            lda.partial_fit(steps=1)
            if (lda.docs_seen // bs) % 4 == 0:
                lda.evaluate()
        lda.evaluate()
        curves[bs] = {"docs": list(map(float, lda.history.docs_seen)),
                      "lpp": lda.history.lpp}
    return curves


def rows(corpus_name: str = "small"):
    t0 = time.perf_counter()
    curves = run(corpus_name)
    total_us = (time.perf_counter() - t0) * 1e6
    out = []
    for bs, c in curves.items():
        # docs needed to reach within 0.1 of this run's final lpp
        final = c["lpp"][-1]
        hit = next((d for d, l in zip(c["docs"], c["lpp"])
                    if l >= final - 0.1), c["docs"][-1])
        out.append((f"fig2/{corpus_name}/batch{bs}", total_us / len(curves),
                    f"final_lpp={final:.4f} docs_to_converge={hit:.0f}"))
    return out
