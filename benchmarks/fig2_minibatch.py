"""Fig. 2 — effect of IVI mini-batch size (paper §6.1).

Paper claims: smaller mini-batches converge faster (in documents), larger
mini-batches reach a better final value.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import LDAConfig, LDAEngine
from repro.data import PAPER_CORPORA, make_corpus


def run(corpus_name: str = "small", sizes=(8, 32, 128), budget_docs=3000,
        seed: int = 0) -> Dict[int, List[float]]:
    spec = PAPER_CORPORA[corpus_name]
    train = make_corpus(spec, split="train", seed=seed)
    test = make_corpus(spec, split="test", seed=seed)
    cfg = LDAConfig(num_topics=min(100, spec.num_topics * 2),
                    vocab_size=spec.vocab_size, estep_max_iters=60)
    curves = {}
    for bs in sizes:
        eng = LDAEngine(cfg, train, algo="ivi", batch_size=bs, seed=seed,
                        test_corpus=test)
        while eng.docs_seen < budget_docs:
            eng.run_minibatch()
            if (eng.docs_seen // bs) % 4 == 0:
                eng.evaluate()
        eng.evaluate()
        curves[bs] = {"docs": list(map(float, eng.history.docs_seen)),
                      "lpp": eng.history.lpp}
    return curves


def rows(corpus_name: str = "small"):
    t0 = time.perf_counter()
    curves = run(corpus_name)
    total_us = (time.perf_counter() - t0) * 1e6
    out = []
    for bs, c in curves.items():
        # docs needed to reach within 0.1 of this run's final lpp
        final = c["lpp"][-1]
        hit = next((d for d, l in zip(c["docs"], c["lpp"])
                    if l >= final - 0.1), c["docs"][-1])
        out.append((f"fig2/{corpus_name}/batch{bs}", total_us / len(curves),
                    f"final_lpp={final:.4f} docs_to_converge={hit:.0f}"))
    return out
