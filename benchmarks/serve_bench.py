"""Serving-pipeline benchmark: synchronous vs async double-buffered ingest.

``TopicInferencer.posterior_docs`` overlaps host-side request packing with
the device E-step (a producer thread stages batch *t+1* while batch *t*
runs — `docs/streaming.md`). This bench produces ``BENCH_serve.json``:

* a **pipeline check**: both paths run end-to-end on a small shape and
  must return bit-identical γ (the double-buffered path exercises exactly
  the same jit entries — this is the CI guard that keeps it
  lowering-clean);
* a **measured** head-to-head at a CPU-sized shape (docs/s sync vs
  double-buffered) plus the measured per-document packing cost on this
  host — trend tracking only, CPU wall time is not the TPU number;
* a **modeled overlap record at the Arxiv serving shape** (Table 1:
  V=141,952, K=128, serving width 128, B=256) — the CI bar. Like the
  kernel-bench HBM bars, the asserted quantity is a deterministic
  structural model, not a flaky timing:

      t_step = fixed-point stream bytes / HBM_GBPS
               (the `kernel_bench.modeled_estep_hbm_bytes` fixed-point
               term: C and Eφ re-streamed per sweep at this V, bf16)
      t_pack = B · PACK_DOC_US + padded-batch bytes / H2D_GBPS

      sync            serves B docs per (t_pack + t_step)
      double-buffered serves B docs per max(t_pack, t_step)

  The bar: double-buffered ≥ 1.3× sync docs/s at this shape. It holds
  whenever t_pack is a non-trivial fraction of t_step — exactly the
  regime the serving widths produce (host Python packs hundreds of ragged
  docs in the milliseconds the device spends streaming Eφ) — and breaks
  if someone reintroduces a serial pack → run → block loop or makes
  packing quadratically slower.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import LDAConfig

# ---------------------------------------------------------------------------
# model constants (documented in docs/streaming.md §benchmark)
# ---------------------------------------------------------------------------
HBM_GBPS = 1200.0       # TPU-class HBM stream rate the step model divides by
H2D_GBPS = 10.0         # host→device staging rate for the padded batch
PACK_DOC_US = 10.0      # Python-level per-document packing overhead

# Arxiv serving shape (Table 1 padded): the production request profile
ARXIV_SERVE = dict(batch=256, vocab=141_952, topics=128, width=128,
                   iters=50, stream_bytes=2, block_b=128)


def modeled_serve_step_bytes(b: int, v: int, k: int, *, iters: int,
                             stream_bytes: int, block_b: int) -> int:
    """HBM bytes of one serving E-step batch (fixed point only — no memo
    correction at serve time). At Arxiv V the Eφ block cannot stay
    VMEM-resident, so C and Eφ re-stream every sweep; γ round-trips once.
    This is the fixed-point term of `kernel_bench.modeled_estep_hbm_bytes`
    in its nv > 1 regime."""
    nb = -(-b // block_b)
    c_elems = iters * b * v
    eb_elems = iters * nb * v * k
    return (c_elems + eb_elems) * stream_bytes + 3 * b * k * 4


def modeled_arxiv_record() -> dict:
    """The deterministic sync-vs-double-buffered model at ARXIV_SERVE."""
    s = ARXIV_SERVE
    b, w = s["batch"], s["width"]
    step_bytes = modeled_serve_step_bytes(
        b, s["vocab"], s["topics"], iters=s["iters"],
        stream_bytes=s["stream_bytes"], block_b=s["block_b"])
    t_step = step_bytes / (HBM_GBPS * 1e9)
    pack_bytes = b * w * (4 + 4)              # padded int32 ids + fp32 cnts
    t_pack = b * PACK_DOC_US * 1e-6 + pack_bytes / (H2D_GBPS * 1e9)
    sync = b / (t_pack + t_step)
    db = b / max(t_pack, t_step)
    return {
        "shape": {"B": b, "V": s["vocab"], "K": s["topics"], "W": w,
                  "sweeps": s["iters"], "stream_bytes": s["stream_bytes"]},
        "model_constants": {"HBM_GBPS": HBM_GBPS, "H2D_GBPS": H2D_GBPS,
                            "PACK_DOC_US": PACK_DOC_US},
        "step_hbm_bytes": step_bytes,
        "t_step_ms": t_step * 1e3,
        "t_pack_ms": t_pack * 1e3,
        "docs_per_s": {"sync": sync, "double_buffered": db},
        "overlap_ratio": db / sync,
        "meets_1p3x_bar": db / sync >= 1.3,
    }


# ---------------------------------------------------------------------------
# measured sections
# ---------------------------------------------------------------------------

def _make_requests(n_docs: int, vocab: int, seed: int = 0):
    """Ragged (ids, cnts) request docs with matched lengths."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_docs):
        n = int(rng.integers(4, 120))
        ids = np.sort(rng.choice(vocab, size=n, replace=False)).astype(
            np.int32)
        cnts = (rng.poisson(1.0, n) + 1).astype(np.float32)
        out.append((ids, cnts))
    return out


def measured_pack_doc_us(n_docs: int = 2048) -> float:
    """Per-document host packing cost on THIS machine (trend only; the
    Arxiv record uses the documented PACK_DOC_US constant)."""
    from repro.data.stream import BatchPacker

    docs = _make_requests(n_docs, vocab=10_000, seed=1)
    packer = BatchPacker(256)
    t0 = time.perf_counter()
    for i, (ids, cnts) in enumerate(docs):
        packer.add(i, ids, cnts)
    packer.flush()
    return (time.perf_counter() - t0) / n_docs * 1e6


def pipeline_check_and_timing(*, timed: bool, n_docs: int = 2048,
                              vocab: int = 4096, topics: int = 64,
                              batch: int = 128) -> dict:
    """End-to-end sync vs double-buffered through the REAL pipeline.

    Always verifies bit-equality of the two paths (the lowering-clean
    guard); with ``timed`` also measures docs/s for both (CPU proxy).
    """
    import jax

    from repro.lda.infer import TopicInferencer

    cfg = LDAConfig(num_topics=topics, vocab_size=vocab, estep_max_iters=30)
    lam = jax.random.gamma(jax.random.key(0), 100.0, (vocab, topics)) * 0.01
    inf = TopicInferencer(cfg, lam, batch_size=batch)
    docs = _make_requests(min(n_docs, 512 if not timed else n_docs), vocab)

    g_sync = inf.posterior_docs(docs, double_buffer=False)
    g_db = inf.posterior_docs(docs, double_buffer=True)
    equal = bool(np.array_equal(g_sync, g_db))
    out = {
        "shape": {"docs": len(docs), "V": vocab, "K": topics,
                  "batch": batch},
        "sync_equals_double_buffered": equal,
        "jit_widths": inf.cache_info()["compiled_widths"],
    }
    if timed:
        for name, db in (("sync", False), ("double_buffered", True)):
            t0 = time.perf_counter()
            inf.posterior_docs(docs, double_buffer=db)
            out[f"{name}_docs_per_s"] = len(docs) / (time.perf_counter()
                                                     - t0)
        out["measured_ratio"] = (out["double_buffered_docs_per_s"]
                                 / out["sync_docs_per_s"])
        # Honesty flag: at this CPU-proxy shape the measured ratio sits
        # BELOW 1 — host packing (plus the GIL the producer thread shares
        # with the interpreted device loop) costs far more than the
        # device E-step it is meant to hide, so overlapping buys nothing
        # and thread handoff costs a little. That does not contradict the
        # modeled 1.3x Arxiv bar (t_pack comparable to t_step there); it
        # means THIS measurement is a proxy for pipeline overhead, not
        # evidence about the overlap win. Recorded explicitly so the
        # number cannot be quoted as a TPU result.
        out["proxy_regime"] = True
        out["proxy_reason"] = (
            "CPU-proxy shapes: per-batch host pack cost >> interpreted "
            "device E-step cost, so double-buffering cannot win here; "
            "the overlap claim is carried by the modeled arxiv_serve "
            "record, the bit-equality check is what this measurement "
            "guards")
    return out


def serve_report(json_path: str | None = None, *, dryrun: bool = False
                 ) -> dict:
    record = {
        "pipeline": pipeline_check_and_timing(timed=not dryrun),
        "measured_pack_doc_us": measured_pack_doc_us(),
        "arxiv_serve": modeled_arxiv_record(),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="where to write the serving record")
    ap.add_argument("--dryrun", action="store_true",
                    help="CI mode: pipeline equality check + modeled "
                         "record only (no timed loops)")
    args = ap.parse_args()
    rec = serve_report(args.json, dryrun=args.dryrun)
    ax, pl = rec["arxiv_serve"], rec["pipeline"]
    print(f"BENCH_serve -> {args.json}")
    print(f"  pipeline    : {pl['shape']['docs']} ragged docs, "
          f"widths={pl['jit_widths']}, "
          f"sync==double-buffered: {pl['sync_equals_double_buffered']}")
    if "measured_ratio" in pl:
        print(f"  measured    : sync {pl['sync_docs_per_s']:.0f} docs/s, "
              f"double-buffered {pl['double_buffered_docs_per_s']:.0f} "
              f"docs/s ({pl['measured_ratio']:.2f}x, proxy_regime="
              f"{pl['proxy_regime']} — pack cost >> device cost here)")
    print(f"  host packing: {rec['measured_pack_doc_us']:.1f} us/doc "
          f"measured (model constant {PACK_DOC_US:.0f})")
    print(f"  arxiv model : t_pack={ax['t_pack_ms']:.2f}ms "
          f"t_step={ax['t_step_ms']:.2f}ms -> sync "
          f"{ax['docs_per_s']['sync']:.0f} vs double-buffered "
          f"{ax['docs_per_s']['double_buffered']:.0f} docs/s "
          f"({ax['overlap_ratio']:.2f}x)")
    assert pl["sync_equals_double_buffered"], \
        "double-buffered serving diverged from the synchronous path"
    assert ax["meets_1p3x_bar"], \
        "double-buffered serving lost the 1.3x Arxiv docs/s bar"
