"""Kernel micro-benchmarks: the LDA E-step hotspot.

On this CPU container the Pallas kernels run in interpret mode (Python) —
their timings are NOT the TPU numbers. What we measure and report:
  * the pure-jnp dense sweep (the oracle workload XLA:CPU compiles) as the
    throughput reference;
  * the gather-formulation E-step (engine default);
  * kernel-vs-oracle max error, as a guard.

``estep_report`` (also ``python -m benchmarks.kernel_bench --estep-json``)
compares the OLD per-sweep Pallas path (`ops.estep_pallas_sweeps` + jnp
memo correction) against the FUSED path (`ops.memo_correction_pallas`,
fixed-point kernel + segment-sum memo_delta pair) and emits
``BENCH_estep.json``:

  * tokens/s and fixed-point sweep counts for both paths (interpret-mode
    wall time — a CPU proxy, kept for trend tracking only), plus an
    interpret-mode head-to-head of the segment-sum scatter against the
    retired one-hot kernel (`lda_estep.memo_delta_onehot`);
  * kernel-launch structure from the jaxpr (`hlo_analysis.
    pallas_call_sites`): the fused path must show ``under_loop == 0``
    (one pallas_call per fixed point, not one per sweep) and
    ``blk_intermediates == 0`` (no (B, L, K) jnp math);
  * a structural HBM-traffic model (`modeled_estep_hbm_bytes`, documented
    in docs/estep.md): per-sweep block fetches for the old path vs the
    fused pipeline's fetch-once-per-index-change behaviour plus bf16
    streaming — the CI bar is ≥2× fewer modeled bytes per E-step — and a
    transient-HBM model at the Arxiv shape
    (`modeled_scatter_transient_bytes`): the segment-sum scatter must
    allocate ≥4× less transient HBM than the one-hot partial baseline.

``csr_report`` (``--csr-json``) models the flat CSR token path
(`ops.memo_correction_pallas_csr`) against the bucketed padded path at a
Zipf-like long-tail document-length distribution: both packers consume the
SAME document sequence, each emitted batch is priced by its structural HBM
model, and the CI bar asserts the CSR path's modeled tokens/s advantage.
The record merges into BENCH_estep.json under the ``"csr"`` key.

Roofline expectations for the TPU kernel are in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import LDAConfig
from repro.core.estep import estep_dense, estep_gather
from repro.core.math import exp_dirichlet_expectation
from repro.data import PAPER_CORPORA, make_corpus
from repro.kernels import lda_estep, ops, ref
from repro.launch.hlo_analysis import pallas_call_sites


def rows():
    out = []
    rng = np.random.default_rng(0)
    for (b, v, k) in [(64, 4096, 128), (128, 8192, 128)]:
        c = jnp.asarray(rng.poisson(0.05, (b, v)).astype(np.float32))
        et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
        eb = jnp.asarray(rng.gamma(1.0, 1.0, (v, k)).astype(np.float32))
        sweep = jax.jit(lambda c_, e_, b_: ref.estep_sweep_ref(c_, e_, b_, 0.5))
        us = time_call(sweep, c, et, eb)
        flops = 2 * 2 * b * v * k
        out.append((f"kernel/sweep_jnp/B{b}_V{v}_K{k}", us,
                    f"gflops={flops / us / 1e3:.2f}"))
        got = lda_estep.estep_sweep(c, et, eb, 0.5)
        err = float(jnp.abs(got - sweep(c, et, eb)).max())
        out.append((f"kernel/sweep_pallas_interpret_err/B{b}_V{v}_K{k}", 0.0,
                    f"max_err={err:.2e}"))

    spec = PAPER_CORPORA["small"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=64, vocab_size=spec.vocab_size,
                    estep_max_iters=30)
    lam = jax.random.gamma(jax.random.key(0), 100.0,
                           (spec.vocab_size, 64)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    ids, cnts = corpus.token_ids[:64], corpus.counts[:64]
    for name, fn in (("gather", estep_gather), ("dense", estep_dense)):
        us = time_call(lambda: fn(cfg, eb, ids, cnts))
        out.append((f"kernel/estep_{name}/B64", us,
                    f"tokens_per_s={float(cnts.sum()) / (us / 1e6):.0f}"))
    return out + estep_rows()


# ---------------------------------------------------------------------------
# fused vs per-sweep E-step: BENCH_estep.json
# ---------------------------------------------------------------------------

def modeled_estep_hbm_bytes(path: str, b: int, v: int, k: int, l: int,
                            iters: int, *, stream_bytes: int = 4,
                            block_b: int = 128, block_v: int = 512,
                            delta_block_b: int = 32) -> int:
    """Structural HBM traffic of one E-step + memo correction.

    Counts block fetches/stores the way the Pallas TPU pipeline issues
    them — a block is (re-)fetched only when its index-map output changes
    between consecutive grid steps (so with a V-resident layout, nv == 1,
    the fused kernel reads C once per B-tile and Eφ once per call, while
    the per-sweep path re-launches and therefore re-reads both every
    sweep). jnp intermediates count one write + one read each. Worked
    numbers in docs/estep.md.

    ``path``: "sweeps" (per-sweep kernels + jnp correction), "fused"
    (fixed-point kernel + segment-sum memo_delta pair) or "fused_onehot"
    (fixed-point kernel + the retired one-hot-partial memo_delta).
    """
    nb = -(-b // block_b)
    nv = -(-v // block_v)
    bk = b * k * 4
    if path == "sweeps":
        # per sweep: one pallas_call (C + nb·Eφ re-read) + γ out + jnp Eθ
        # recomputation (read γ, write Eθ, kernel reads Eθ)
        per_sweep = (b * v + nb * v * k) * 4 + 4 * bk
        sstats_kernel = (b * v + nb * v * k + v * k) * 4
        # jnp π/correction: ebg write+read×2, π write+read, Δ write+read,
        # old_pi read, scatter out (V, K)
        pi_path = 7 * b * l * k * 4 + 2 * v * k * 4
        return iters * per_sweep + sstats_kernel + pi_path
    if path not in ("fused", "fused_onehot"):
        raise ValueError(path)
    if nv == 1:
        c_elems, eb_elems = b * v, v * k              # fetched once
    else:
        c_elems = iters * b * v                       # re-streamed per sweep
        eb_elems = iters * nb * v * k
    fixed_point = (c_elems + eb_elems) * stream_bytes + 3 * bk
    bp = -(-b // delta_block_b) * delta_block_b       # padded B (ops wrapper)
    cube = bp * l * k * 4
    if path == "fused_onehot":
        # single kernel: ids+cnts+ebtok+old_pi in, π out, and the two
        # one-hot scatters as per-B-tile (nbd, V, K) partials — written
        # once per block by the kernel, then read + reduced to (V, K) by
        # XLA outside it. nbd counts the grid the kernel actually runs
        # (its VMEM guard halves the B-tile for long token axes).
        bb_eff = lda_estep.delta_effective_block_b(bp, l, k,
                                                   block_b=delta_block_b)
        nbd = bp // bb_eff
        delta = (2 * bp * l * 4 + 3 * cube
                 + 2 * (2 * nbd + 1) * v * k * 4 + bk)
        return fixed_point + delta
    # segment-sum pair: token-π kernel reads cnts + the Eφ token cube and
    # writes π once; the scatter re-streams the π/old_pi rows (plus
    # ids/cnts) once per V chunk and writes each (V, K) mass exactly once
    # from VMEM — no partial spills at all.
    vc, _ = lda_estep.segment_scatter_blocks(k, v, True)
    nvc = -(-v // vc)
    delta = (2 * bp * l * 4 + 2 * cube + bk           # token-π kernel
             + nvc * (2 * cube + 2 * bp * l * 4)      # per-chunk re-streams
             + 2 * v * k * 4)                         # S_new/S_old out
    return fixed_point + delta


def modeled_scatter_transient_bytes(path: str, b: int, v: int, k: int,
                                    l: int, *, delta_block_b: int = 32
                                    ) -> int:
    """Peak transient HBM the memo-correction scatter allocates: every
    intermediate between the E-step tensors and the (V, K) results, plus
    those results. The one-hot path's per-B-tile (nb, V, K) partial cubes
    dominate it (~2.3 GB at the Arxiv shape); the segment-sum path holds
    only the row-tile padding remainder — the ≥4× Arxiv bar in
    BENCH_estep.json compares exactly these two numbers.
    """
    bp = -(-b // delta_block_b) * delta_block_b
    vp128 = -(-v // 128) * 128
    results = 2 * vp128 * k * 4                       # S_new + S_old
    if path == "onehot":
        bb_eff = lda_estep.delta_effective_block_b(bp, l, k,
                                                   block_b=delta_block_b)
        nbd = bp // bb_eff
        return 2 * nbd * vp128 * k * 4 + results
    if path == "segment":
        _, bl = lda_estep.pi_tile_shape(bp, l, k, block_b=delta_block_b)
        lp = -(-l // bl) * bl
        _, tb = lda_estep.segment_scatter_blocks(k, v, True)
        rows = bp * lp
        pad_rows = -(-rows // tb) * tb - rows
        return 2 * (bp * (lp - l) + pad_rows) * k * 4 + results
    raise ValueError(path)


def estep_report(json_path: str | None = None):
    """Old per-sweep vs fused Pallas E-step: the BENCH_estep record.

    The shape keeps Eφ V-resident (one V tile) — the regime the fused
    kernel targets; at larger V both paths stream Eφ per sweep and the
    fused win reduces to the removed γ/Eθ round-trips, the removed
    (B, L, K) jnp path and the bf16 streams.
    """
    b, v, k, l = 128, 4096, 128, 64
    block_v = 4096                         # V-resident: Eφ one VMEM block
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
    cnts = jnp.asarray((rng.poisson(1.5, (b, l)) + 1).astype(np.float32))
    lam = jax.random.gamma(jax.random.key(0), 100.0, (v, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    old_pi = jnp.zeros((b, l, k), jnp.float32)
    visited = jnp.zeros((b,), bool)
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=30,
                    estep_backend="pallas")
    cfg_bf16 = dataclasses.replace(cfg, estep_stream_dtype="bfloat16")
    tokens = float(cnts.sum())

    def legacy_correction(cfg_):
        """Pre-fusion path: per-sweep kernel + jnp subtract-old/add-new."""
        from repro.core.estep import scatter_sstats
        res = ops.estep_pallas_sweeps(cfg_, eb, ids, cnts,
                                      block_v=block_v)
        delta = cnts[:, :, None] * (res.pi - old_pi)
        return scatter_sstats(ids, delta, cfg_.vocab_size), res

    def fused_correction(cfg_, pi_dtype="float32"):
        corr, _, res = ops.memo_correction_pallas(cfg_, eb, ids, cnts,
                                                  old_pi, visited,
                                                  pi_dtype=pi_dtype,
                                                  block_v=block_v)
        return corr, res

    def fused_bf16_correction(cfg_):
        # bf16 streams AND the bf16 memo wire (the chunked-store config)
        return fused_correction(cfg_, pi_dtype="bfloat16")

    corr_old, res_old = legacy_correction(cfg)
    corr_new, _ = fused_correction(cfg)
    max_err = float(jnp.abs(corr_old - corr_new).max())

    record = {
        "shape": {"B": b, "V": v, "K": k, "L": l, "block_v": block_v},
        "correction_max_abs_err": max_err,
        "paths": {},
    }
    for name, fn, cfg_, stream in (
            ("sweeps", legacy_correction, cfg, 4),
            ("fused", fused_correction, cfg, 4),
            ("fused_bf16", fused_bf16_correction, cfg_bf16, 2)):
        us = time_call(lambda: fn(cfg_), warmup=1, iters=3)
        sites = pallas_call_sites(lambda: fn(cfg_))
        iters = int(fn(cfg_)[1].iters)      # each config's own convergence
        path_kind = "sweeps" if name == "sweeps" else "fused"
        modeled = modeled_estep_hbm_bytes(
            path_kind, b, v, k, l, iters, stream_bytes=stream,
            block_v=block_v)
        record["paths"][name] = {
            "interpret_us": us,
            "tokens_per_s_interpret": tokens / (us / 1e6),
            "sweeps": iters,
            "kernel_sites": sites,
            "modeled_hbm_bytes": modeled,
        }
    # the retired one-hot memo_delta, modeled at the same shape/sweeps —
    # the baseline the segment-sum scatter is measured against
    record["paths"]["fused_onehot_modeled"] = {
        "modeled_hbm_bytes": modeled_estep_hbm_bytes(
            "fused_onehot", b, v, k, l,
            record["paths"]["fused"]["sweeps"], block_v=block_v),
    }
    base = record["paths"]["sweeps"]["modeled_hbm_bytes"]
    for name in ("fused", "fused_bf16", "fused_onehot_modeled"):
        record["paths"][name]["hbm_ratio_vs_sweeps"] = (
            base / record["paths"][name]["modeled_hbm_bytes"])
    record["meets_2x_hbm_bar"] = (
        record["paths"]["fused"]["hbm_ratio_vs_sweeps"] >= 2.0)
    record["fused_single_launch_ok"] = (
        record["paths"]["fused"]["kernel_sites"]["under_loop"] == 0
        and record["paths"]["fused"]["kernel_sites"]["blk_intermediates"] == 0)

    # interpret-mode head-to-head of the two scatter formulations
    eb_tok = eb[ids]
    et = exp_dirichlet_expectation(res_old.gamma)
    record["scatter_interpret_us"] = {
        "segment": time_call(lambda: lda_estep.memo_delta(
            ids, cnts, eb_tok, et, v, old_pi=old_pi), warmup=1, iters=3),
        "onehot": time_call(lambda: lda_estep.memo_delta_onehot(
            ids, cnts, eb_tok, et, v, old_pi=old_pi), warmup=1, iters=3),
    }

    # transient-HBM model at the Arxiv production shape (Table 1): the
    # one-hot partial cubes vs the segment-sum path — the ≥4× bar
    ax = dict(b=256, v=141_952, k=128, l=128)
    one_t = modeled_scatter_transient_bytes("onehot", **ax)
    seg_t = modeled_scatter_transient_bytes("segment", **ax)
    record["arxiv_scatter"] = {
        "shape": {"B": ax["b"], "V": ax["v"], "K": ax["k"], "L": ax["l"]},
        "onehot_transient_bytes": one_t,
        "segment_transient_bytes": seg_t,
        "transient_ratio": one_t / seg_t,
        "meets_4x_transient_bar": one_t / seg_t >= 4.0,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


# ---------------------------------------------------------------------------
# CSR flat-token path vs bucketed padded path: the "csr" record
# ---------------------------------------------------------------------------

CSR_TOKENS_PER_S_BAR = 3.0


def modeled_estep_csr_hbm_bytes(t: int, b: int, v: int, k: int, iters: int,
                                *, stream_bytes: int = 4,
                                block_t: int = 512) -> int:
    """Structural HBM traffic of one CSR E-step + memo correction
    (`ops.memo_correction_pallas_csr`) on a (T,)-slot flat token stream.

    Same counting rules as ``modeled_estep_hbm_bytes``: a block is
    re-fetched only when its index map moves between consecutive grid
    steps. The CSR path never materializes the dense (B, V) count matrix
    — its variable cost scales with T, and ``ops.csr_effective_block_t``
    decides whether the Eφ token cube is resident (fetched once per call)
    or streamed once per sweep. Terms:

      * Eφ token gather: Eφ read once + ids read + the (T, Kp) cube write;
      * fixed point: cnts/segs + the cube, once or per-sweep, plus the
        γ0-in/γ-out/Eθ-out block triple;
      * memo pair: the token-π kernel (cnts/segs + cube re-read, Eθ in,
        π out) and the segment-sum scatter re-streaming the token rows
        (ids/cnts/π/old_pi) once per V chunk, S_new/S_old written once.
    """
    kp = -(-k // 128) * 128
    bp = -(-b // 8) * 8
    bt = ops.csr_effective_block_t(t, k, stream_bytes, block_t)
    tp = -(-t // bt) * bt
    resident = tp == bt                               # one (T, Kp) tile
    bk = bp * k * 4
    gather = v * k * 4 + tp * 4 + tp * kp * stream_bytes
    tok_fetch = tp * (4 + 4) + tp * kp * stream_bytes
    fixed_point = (1 if resident else iters) * tok_fetch + 3 * bp * kp * 4
    vc, _ = lda_estep.segment_scatter_blocks(k, v, True)
    nvc = -(-v // vc)
    delta = (tp * (4 + 4) + tp * k * stream_bytes + bk + tp * k * 4
             + nvc * (tp * (4 + 4) + 2 * tp * k * 4)  # per-chunk re-streams
             + 2 * v * k * 4)                         # S_new/S_old out
    return gather + fixed_point + delta


def _zipf_docs(rng, num_docs: int, vocab_size: int, cap: int):
    """A Zipf-like long-tail unique-token-length corpus: the regime where
    bucketed padding wastes the most (many tiny docs, a heavy tail)."""
    lengths = np.minimum(rng.zipf(1.35, num_docs), cap).astype(int)
    docs = []
    for n in lengths:
        ids = rng.choice(vocab_size, size=int(n), replace=False)
        cnts = 1.0 + rng.poisson(1.0, int(n))
        docs.append((np.sort(ids).astype(np.int32),
                     cnts.astype(np.float32)))
    return docs, lengths


def _csr_interpret_check():
    """Small-shape interpret-mode guard: the fused CSR kernel pair against
    the jnp segment-sum oracle, warm start and old-π subtraction included."""
    from repro.core.estep import (CSRTokenBatch, estep_csr_ref,
                                  scatter_sstats_flat, warm_start_gamma_flat)
    t, b, v, k = 768, 24, 1024, 32
    rng = np.random.default_rng(3)
    lens = np.minimum(rng.zipf(1.5, b), t // b).astype(int)
    segs_l, ids_l, cnts_l = [], [], []
    for d, n in enumerate(lens):
        segs_l += [d] * int(n)
        ids_l += list(rng.choice(v, size=int(n), replace=False))
        cnts_l += list(1.0 + rng.poisson(1.0, int(n)))
    live = len(ids_l)
    pad = t - live
    ids = jnp.asarray(np.asarray(ids_l + [0] * pad, np.int32))
    cnts = jnp.asarray(np.asarray(cnts_l + [0.0] * pad, np.float32))
    segs = jnp.asarray(np.asarray(segs_l + [0] * pad, np.int32))
    lam = jax.random.gamma(jax.random.key(1), 100.0, (v, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    old_pi = jnp.asarray(rng.dirichlet(np.ones(k), t).astype(np.float32))
    visited = jnp.asarray((np.arange(b) % 2).astype(bool))
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=25,
                    estep_backend="csr")
    corr, _, res = ops.memo_correction_pallas_csr(
        cfg, eb, ids, cnts, segs, old_pi, visited)
    g0 = warm_start_gamma_flat(cfg, CSRTokenBatch(ids, cnts, segs),
                               old_pi, visited)
    ref = estep_csr_ref(cfg, eb, ids, cnts, segs, num_docs=b, gamma0=g0)
    corr_ref = (scatter_sstats_flat(ids, cnts[:, None] * ref.pi, v)
                - scatter_sstats_flat(ids, cnts[:, None] * old_pi, v))
    us = time_call(lambda: ops.memo_correction_pallas_csr(
        cfg, eb, ids, cnts, segs, old_pi, visited), warmup=1, iters=3)
    return {
        "shape": {"T": t, "B": b, "V": v, "K": k, "live_tokens": live},
        "correction_max_abs_err": float(jnp.abs(corr - corr_ref).max()),
        "gamma_max_rel_err": float(
            (jnp.abs(res.gamma - ref.gamma)
             / jnp.abs(ref.gamma)).max()),
        "interpret_us": us,
    }


def csr_report(json_path: str | None = None, *,
               bar: float = CSR_TOKENS_PER_S_BAR) -> dict:
    """CSR flat-token vs bucketed padded E-step at a long-tail length mix.

    Both packers consume the SAME Zipf-drawn document sequence; every
    emitted batch is priced with its path's structural HBM model. The
    asserted comparison runs at the paper's Arxiv production vocabulary
    (Table 1, the ``arxiv_scatter`` shape): there ``V·K·4`` overflows the
    VMEM residency budget, so the padded fixed point re-streams its dense
    (B, V) count matrix AND Eφ once per sweep, while the CSR path gathers
    Eφ once into a budget-sized T-resident token cube and never touches
    (V, K) again until the scatter — the structural win the flat layout
    exists for. A small-vocab entry (V-resident padded kernel, its best
    case) is recorded unasserted for context: zero-padding alone roughly
    breaks even there, which is WHY the bar is pinned to the production
    shape. Modeled tokens/s divides the same live-token total by each
    path's modeled HBM time. Merged into BENCH_estep.json as ``"csr"``.
    """
    from repro.obs.roofline import HW
    from repro.data.stream import BatchPacker

    d, k, batch, cap = 4096, 128, 64, 512
    v_prod, v_small = 141_952, 8192          # Table 1 Arxiv / V-resident
    token_budget = min(batch * 64, 8192)               # engine default
    sweeps = 20                                        # same fixed point
    rng = np.random.default_rng(7)
    docs, lengths = _zipf_docs(rng, d, v_small, cap)

    padded = BatchPacker(batch, max_width=cap, vocab_size=v_small)
    csr = BatchPacker(batch, max_width=cap, vocab_size=v_small,
                      layout="csr", token_budget=token_budget)
    padded_batches, csr_batches = [], []
    for pos, (ids, cnts) in enumerate(docs):
        for pk, out in ((padded, padded_batches), (csr, csr_batches)):
            b = pk.add(pos, ids, cnts)
            if b is not None:
                out.append(b)
    padded_batches += padded.flush()
    csr_batches += csr.flush()

    tokens = int(lengths.sum())                        # live unique slots
    bw = HW["hbm_bw"]

    def _compare(v: int) -> dict:
        # the padded wrapper's own residency promotion (one V tile — Eφ/C
        # fetched once per call — whenever (V, K) fits the budget), asked
        # of the wrapper instead of re-derived here
        _, eff_block_v, v_resident = ops.effective_fixed_point_blocks(
            batch, v, k, block_v=4096)
        padded_bytes = sum(
            modeled_estep_hbm_bytes("fused", pb.token_ids.shape[0], v, k,
                                    pb.width, sweeps, block_v=eff_block_v)
            for pb in padded_batches)
        # the engine pads the CSR doc axis to batch_size; the stream is
        # always exactly token_budget slots
        csr_bytes = sum(
            modeled_estep_csr_hbm_bytes(cb.token_budget, batch, v, k,
                                        sweeps)
            for cb in csr_batches)
        padded_tps = tokens / (padded_bytes / bw)
        csr_tps = tokens / (csr_bytes / bw)
        return {
            "V": v,
            "padded_modeled_hbm_bytes": padded_bytes,
            "csr_modeled_hbm_bytes": csr_bytes,
            "padded_modeled_tokens_per_s": padded_tps,
            "csr_modeled_tokens_per_s": csr_tps,
            "modeled_tokens_per_s_ratio": csr_tps / padded_tps,
            "padded_v_resident": v_resident,
        }

    production = _compare(v_prod)
    record = {
        "shape": {"docs": d, "K": k, "batch_size": batch,
                  "token_budget": token_budget, "sweeps": sweeps,
                  "length_distribution": f"zipf(a=1.35) clipped to {cap}",
                  "live_tokens": tokens},
        "padded": {
            "batches": len(padded_batches),
            "pad_frac": padded.padding_stats()["pad_frac"],
        },
        "csr": {
            "batches": len(csr_batches),
            "pad_frac": csr.padding_stats()["pad_frac"],
            "t_resident": ops.csr_effective_block_t(token_budget, k)
                          >= token_budget,
        },
        "production": production,
        "small_vocab_informational": _compare(v_small),
        "modeled_tokens_per_s_ratio":
            production["modeled_tokens_per_s_ratio"],
        "tokens_per_s_bar": bar,
        "meets_csr_bar":
            production["modeled_tokens_per_s_ratio"] >= bar,
        "interpret_check": _csr_interpret_check(),
    }
    if json_path:
        try:
            with open(json_path) as f:
                full = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            full = {}
        full["csr"] = record
        with open(json_path, "w") as f:
            json.dump(full, f, indent=2)
    return record


def estep_rows():
    rec = estep_report()
    out = []
    for name, p in rec["paths"].items():
        if "interpret_us" not in p:           # modeled-only baselines
            continue
        ratio = p.get("hbm_ratio_vs_sweeps", 1.0)
        out.append((f"kernel/estep_{name}/B128_V4096", p["interpret_us"],
                    f"sweeps={p['sweeps']} hbm_x={ratio:.2f} "
                    f"launches={p['kernel_sites']['total']} "
                    f"under_loop={p['kernel_sites']['under_loop']}"))
    ax = rec["arxiv_scatter"]
    out.append(("kernel/memo_delta_arxiv_transient", 0.0,
                f"onehot={ax['onehot_transient_bytes'] / 1e9:.2f}GB "
                f"segment={ax['segment_transient_bytes'] / 1e9:.2f}GB "
                f"ratio={ax['transient_ratio']:.1f}x"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--estep-json", default="BENCH_estep.json",
                    help="where to write the fused-vs-sweeps record")
    ap.add_argument("--csr-json", default=None, metavar="PATH",
                    help="also run the CSR-vs-bucketed model and merge the "
                         "'csr' record into PATH (usually the same "
                         "BENCH_estep.json)")
    args = ap.parse_args()
    rec = estep_report(args.estep_json)
    f, fb = rec["paths"]["fused"], rec["paths"]["fused_bf16"]
    oh = rec["paths"]["fused_onehot_modeled"]
    ax = rec["arxiv_scatter"]
    print(f"BENCH_estep -> {args.estep_json}")
    print(f"  sweeps path : {rec['paths']['sweeps']['sweeps']} sweeps, "
          f"{rec['paths']['sweeps']['modeled_hbm_bytes'] / 1e6:.1f} MB modeled")
    print(f"  fused (seg) : {f['sweeps']} sweeps, "
          f"{f['modeled_hbm_bytes'] / 1e6:.1f} MB "
          f"({f['hbm_ratio_vs_sweeps']:.2f}x fewer), "
          f"launches={f['kernel_sites']['total']} "
          f"under_loop={f['kernel_sites']['under_loop']} "
          f"blk_jnp={f['kernel_sites']['blk_intermediates']}")
    print(f"  fused bf16  : {fb['hbm_ratio_vs_sweeps']:.2f}x fewer bytes")
    print(f"  one-hot     : {oh['modeled_hbm_bytes'] / 1e6:.1f} MB modeled "
          f"({oh['hbm_ratio_vs_sweeps']:.2f}x vs sweeps, retired baseline)")
    print(f"  arxiv scatter transient: onehot "
          f"{ax['onehot_transient_bytes'] / 1e9:.2f} GB vs segment "
          f"{ax['segment_transient_bytes'] / 1e9:.3f} GB "
          f"({ax['transient_ratio']:.1f}x)")
    print(f"  correction max |Δ| = {rec['correction_max_abs_err']:.2e}")
    assert rec["meets_2x_hbm_bar"], "fused path lost the 2x HBM bar"
    assert rec["fused_single_launch_ok"], "fused path regressed to per-sweep"
    assert ax["meets_4x_transient_bar"], \
        "segment-sum scatter lost the 4x Arxiv transient-HBM bar"

    if args.csr_json:
        crec = csr_report(args.csr_json)
        pd, cs = crec["padded"], crec["csr"]
        pr, sm = crec["production"], crec["small_vocab_informational"]
        chk = crec["interpret_check"]
        print(f"BENCH_estep csr -> {args.csr_json}")
        print(f"  packing : padded {pd['batches']} batches "
              f"(pad_frac={pd['pad_frac']:.3f}) vs csr {cs['batches']} "
              f"batches (pad_frac={cs['pad_frac']:.3f}, "
              f"t_resident={cs['t_resident']})")
        print(f"  arxiv V={pr['V']}: csr "
              f"{pr['csr_modeled_hbm_bytes'] / 1e9:.1f} GB vs padded "
              f"{pr['padded_modeled_hbm_bytes'] / 1e9:.1f} GB modeled -> "
              f"{pr['modeled_tokens_per_s_ratio']:.2f}x tokens/s "
              f"(bar {crec['tokens_per_s_bar']:.1f}x)")
        print(f"  small V={sm['V']} (padded V-resident, informational): "
              f"{sm['modeled_tokens_per_s_ratio']:.2f}x")
        print(f"  interpret check: correction max |Δ| = "
              f"{chk['correction_max_abs_err']:.2e}, "
              f"gamma max rel = {chk['gamma_max_rel_err']:.2e}")
        assert crec["meets_csr_bar"], \
            "CSR flat-token path lost its modeled tokens/s bar vs bucketed"
        assert chk["correction_max_abs_err"] < 1e-2 \
            and chk["gamma_max_rel_err"] < 2e-3, \
            "CSR kernel pair drifted from the segment-sum oracle"
