"""Kernel micro-benchmarks: the LDA E-step hotspot.

On this CPU container the Pallas kernels run in interpret mode (Python) —
their timings are NOT the TPU numbers. What we measure and report:
  * the pure-jnp dense sweep (the oracle workload XLA:CPU compiles) as the
    throughput reference;
  * the gather-formulation E-step (engine default);
  * kernel-vs-oracle max error, as a guard.
Roofline expectations for the TPU kernel are in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import LDAConfig
from repro.core.estep import estep_dense, estep_gather
from repro.core.math import exp_dirichlet_expectation
from repro.data import PAPER_CORPORA, make_corpus
from repro.kernels import lda_estep, ref


def rows():
    out = []
    rng = np.random.default_rng(0)
    for (b, v, k) in [(64, 4096, 128), (128, 8192, 128)]:
        c = jnp.asarray(rng.poisson(0.05, (b, v)).astype(np.float32))
        et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
        eb = jnp.asarray(rng.gamma(1.0, 1.0, (v, k)).astype(np.float32))
        sweep = jax.jit(lambda c_, e_, b_: ref.estep_sweep_ref(c_, e_, b_, 0.5))
        us = time_call(sweep, c, et, eb)
        flops = 2 * 2 * b * v * k
        out.append((f"kernel/sweep_jnp/B{b}_V{v}_K{k}", us,
                    f"gflops={flops / us / 1e3:.2f}"))
        got = lda_estep.estep_sweep(c, et, eb, 0.5)
        err = float(jnp.abs(got - sweep(c, et, eb)).max())
        out.append((f"kernel/sweep_pallas_interpret_err/B{b}_V{v}_K{k}", 0.0,
                    f"max_err={err:.2e}"))

    spec = PAPER_CORPORA["small"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=64, vocab_size=spec.vocab_size,
                    estep_max_iters=30)
    lam = jax.random.gamma(jax.random.key(0), 100.0,
                           (spec.vocab_size, 64)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    ids, cnts = corpus.token_ids[:64], corpus.counts[:64]
    for name, fn in (("gather", estep_gather), ("dense", estep_dense)):
        us = time_call(lambda: fn(cfg, eb, ids, cnts))
        out.append((f"kernel/estep_{name}/B64", us,
                    f"tokens_per_s={float(cnts.sum()) / (us / 1e6):.0f}"))
    return out
