"""Kernel micro-benchmarks: the LDA E-step hotspot.

On this CPU container the Pallas kernels run in interpret mode (Python) —
their timings are NOT the TPU numbers. What we measure and report:
  * the pure-jnp dense sweep (the oracle workload XLA:CPU compiles) as the
    throughput reference;
  * the gather-formulation E-step (engine default);
  * kernel-vs-oracle max error, as a guard.

``estep_report`` (also ``python -m benchmarks.kernel_bench --estep-json``)
compares the OLD per-sweep Pallas path (`ops.estep_pallas_sweeps` + jnp
memo correction) against the FUSED path (`ops.memo_correction_pallas`,
fixed-point kernel + segment-sum memo_delta pair) and emits
``BENCH_estep.json``:

  * tokens/s and fixed-point sweep counts for both paths (interpret-mode
    wall time — a CPU proxy, kept for trend tracking only), plus an
    interpret-mode head-to-head of the segment-sum scatter against the
    retired one-hot kernel (`lda_estep.memo_delta_onehot`);
  * kernel-launch structure from the jaxpr (`hlo_analysis.
    pallas_call_sites`): the fused path must show ``under_loop == 0``
    (one pallas_call per fixed point, not one per sweep) and
    ``blk_intermediates == 0`` (no (B, L, K) jnp math);
  * a structural HBM-traffic model (`modeled_estep_hbm_bytes`, documented
    in docs/estep.md): per-sweep block fetches for the old path vs the
    fused pipeline's fetch-once-per-index-change behaviour plus bf16
    streaming — the CI bar is ≥2× fewer modeled bytes per E-step — and a
    transient-HBM model at the Arxiv shape
    (`modeled_scatter_transient_bytes`): the segment-sum scatter must
    allocate ≥4× less transient HBM than the one-hot partial baseline.

Roofline expectations for the TPU kernel are in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import LDAConfig
from repro.core.estep import estep_dense, estep_gather
from repro.core.math import exp_dirichlet_expectation
from repro.data import PAPER_CORPORA, make_corpus
from repro.kernels import lda_estep, ops, ref
from repro.launch.hlo_analysis import pallas_call_sites


def rows():
    out = []
    rng = np.random.default_rng(0)
    for (b, v, k) in [(64, 4096, 128), (128, 8192, 128)]:
        c = jnp.asarray(rng.poisson(0.05, (b, v)).astype(np.float32))
        et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
        eb = jnp.asarray(rng.gamma(1.0, 1.0, (v, k)).astype(np.float32))
        sweep = jax.jit(lambda c_, e_, b_: ref.estep_sweep_ref(c_, e_, b_, 0.5))
        us = time_call(sweep, c, et, eb)
        flops = 2 * 2 * b * v * k
        out.append((f"kernel/sweep_jnp/B{b}_V{v}_K{k}", us,
                    f"gflops={flops / us / 1e3:.2f}"))
        got = lda_estep.estep_sweep(c, et, eb, 0.5)
        err = float(jnp.abs(got - sweep(c, et, eb)).max())
        out.append((f"kernel/sweep_pallas_interpret_err/B{b}_V{v}_K{k}", 0.0,
                    f"max_err={err:.2e}"))

    spec = PAPER_CORPORA["small"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=64, vocab_size=spec.vocab_size,
                    estep_max_iters=30)
    lam = jax.random.gamma(jax.random.key(0), 100.0,
                           (spec.vocab_size, 64)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    ids, cnts = corpus.token_ids[:64], corpus.counts[:64]
    for name, fn in (("gather", estep_gather), ("dense", estep_dense)):
        us = time_call(lambda: fn(cfg, eb, ids, cnts))
        out.append((f"kernel/estep_{name}/B64", us,
                    f"tokens_per_s={float(cnts.sum()) / (us / 1e6):.0f}"))
    return out + estep_rows()


# ---------------------------------------------------------------------------
# fused vs per-sweep E-step: BENCH_estep.json
# ---------------------------------------------------------------------------

def modeled_estep_hbm_bytes(path: str, b: int, v: int, k: int, l: int,
                            iters: int, *, stream_bytes: int = 4,
                            block_b: int = 128, block_v: int = 512,
                            delta_block_b: int = 32) -> int:
    """Structural HBM traffic of one E-step + memo correction.

    Counts block fetches/stores the way the Pallas TPU pipeline issues
    them — a block is (re-)fetched only when its index-map output changes
    between consecutive grid steps (so with a V-resident layout, nv == 1,
    the fused kernel reads C once per B-tile and Eφ once per call, while
    the per-sweep path re-launches and therefore re-reads both every
    sweep). jnp intermediates count one write + one read each. Worked
    numbers in docs/estep.md.

    ``path``: "sweeps" (per-sweep kernels + jnp correction), "fused"
    (fixed-point kernel + segment-sum memo_delta pair) or "fused_onehot"
    (fixed-point kernel + the retired one-hot-partial memo_delta).
    """
    nb = -(-b // block_b)
    nv = -(-v // block_v)
    bk = b * k * 4
    if path == "sweeps":
        # per sweep: one pallas_call (C + nb·Eφ re-read) + γ out + jnp Eθ
        # recomputation (read γ, write Eθ, kernel reads Eθ)
        per_sweep = (b * v + nb * v * k) * 4 + 4 * bk
        sstats_kernel = (b * v + nb * v * k + v * k) * 4
        # jnp π/correction: ebg write+read×2, π write+read, Δ write+read,
        # old_pi read, scatter out (V, K)
        pi_path = 7 * b * l * k * 4 + 2 * v * k * 4
        return iters * per_sweep + sstats_kernel + pi_path
    if path not in ("fused", "fused_onehot"):
        raise ValueError(path)
    if nv == 1:
        c_elems, eb_elems = b * v, v * k              # fetched once
    else:
        c_elems = iters * b * v                       # re-streamed per sweep
        eb_elems = iters * nb * v * k
    fixed_point = (c_elems + eb_elems) * stream_bytes + 3 * bk
    bp = -(-b // delta_block_b) * delta_block_b       # padded B (ops wrapper)
    cube = bp * l * k * 4
    if path == "fused_onehot":
        # single kernel: ids+cnts+ebtok+old_pi in, π out, and the two
        # one-hot scatters as per-B-tile (nbd, V, K) partials — written
        # once per block by the kernel, then read + reduced to (V, K) by
        # XLA outside it. nbd counts the grid the kernel actually runs
        # (its VMEM guard halves the B-tile for long token axes).
        bb_eff = lda_estep.delta_effective_block_b(bp, l, k,
                                                   block_b=delta_block_b)
        nbd = bp // bb_eff
        delta = (2 * bp * l * 4 + 3 * cube
                 + 2 * (2 * nbd + 1) * v * k * 4 + bk)
        return fixed_point + delta
    # segment-sum pair: token-π kernel reads cnts + the Eφ token cube and
    # writes π once; the scatter re-streams the π/old_pi rows (plus
    # ids/cnts) once per V chunk and writes each (V, K) mass exactly once
    # from VMEM — no partial spills at all.
    vc, _ = lda_estep.segment_scatter_blocks(k, v, True)
    nvc = -(-v // vc)
    delta = (2 * bp * l * 4 + 2 * cube + bk           # token-π kernel
             + nvc * (2 * cube + 2 * bp * l * 4)      # per-chunk re-streams
             + 2 * v * k * 4)                         # S_new/S_old out
    return fixed_point + delta


def modeled_scatter_transient_bytes(path: str, b: int, v: int, k: int,
                                    l: int, *, delta_block_b: int = 32
                                    ) -> int:
    """Peak transient HBM the memo-correction scatter allocates: every
    intermediate between the E-step tensors and the (V, K) results, plus
    those results. The one-hot path's per-B-tile (nb, V, K) partial cubes
    dominate it (~2.3 GB at the Arxiv shape); the segment-sum path holds
    only the row-tile padding remainder — the ≥4× Arxiv bar in
    BENCH_estep.json compares exactly these two numbers.
    """
    bp = -(-b // delta_block_b) * delta_block_b
    vp128 = -(-v // 128) * 128
    results = 2 * vp128 * k * 4                       # S_new + S_old
    if path == "onehot":
        bb_eff = lda_estep.delta_effective_block_b(bp, l, k,
                                                   block_b=delta_block_b)
        nbd = bp // bb_eff
        return 2 * nbd * vp128 * k * 4 + results
    if path == "segment":
        _, bl = lda_estep.pi_tile_shape(bp, l, k, block_b=delta_block_b)
        lp = -(-l // bl) * bl
        _, tb = lda_estep.segment_scatter_blocks(k, v, True)
        rows = bp * lp
        pad_rows = -(-rows // tb) * tb - rows
        return 2 * (bp * (lp - l) + pad_rows) * k * 4 + results
    raise ValueError(path)


def estep_report(json_path: str | None = None):
    """Old per-sweep vs fused Pallas E-step: the BENCH_estep record.

    The shape keeps Eφ V-resident (one V tile) — the regime the fused
    kernel targets; at larger V both paths stream Eφ per sweep and the
    fused win reduces to the removed γ/Eθ round-trips, the removed
    (B, L, K) jnp path and the bf16 streams.
    """
    b, v, k, l = 128, 4096, 128, 64
    block_v = 4096                         # V-resident: Eφ one VMEM block
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
    cnts = jnp.asarray((rng.poisson(1.5, (b, l)) + 1).astype(np.float32))
    lam = jax.random.gamma(jax.random.key(0), 100.0, (v, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    old_pi = jnp.zeros((b, l, k), jnp.float32)
    visited = jnp.zeros((b,), bool)
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=30,
                    estep_backend="pallas")
    cfg_bf16 = dataclasses.replace(cfg, estep_stream_dtype="bfloat16")
    tokens = float(cnts.sum())

    def legacy_correction(cfg_):
        """Pre-fusion path: per-sweep kernel + jnp subtract-old/add-new."""
        from repro.core.estep import scatter_sstats
        res = ops.estep_pallas_sweeps(cfg_, eb, ids, cnts,
                                      block_v=block_v)
        delta = cnts[:, :, None] * (res.pi - old_pi)
        return scatter_sstats(ids, delta, cfg_.vocab_size), res

    def fused_correction(cfg_, pi_dtype="float32"):
        corr, _, res = ops.memo_correction_pallas(cfg_, eb, ids, cnts,
                                                  old_pi, visited,
                                                  pi_dtype=pi_dtype,
                                                  block_v=block_v)
        return corr, res

    def fused_bf16_correction(cfg_):
        # bf16 streams AND the bf16 memo wire (the chunked-store config)
        return fused_correction(cfg_, pi_dtype="bfloat16")

    corr_old, res_old = legacy_correction(cfg)
    corr_new, _ = fused_correction(cfg)
    max_err = float(jnp.abs(corr_old - corr_new).max())

    record = {
        "shape": {"B": b, "V": v, "K": k, "L": l, "block_v": block_v},
        "correction_max_abs_err": max_err,
        "paths": {},
    }
    for name, fn, cfg_, stream in (
            ("sweeps", legacy_correction, cfg, 4),
            ("fused", fused_correction, cfg, 4),
            ("fused_bf16", fused_bf16_correction, cfg_bf16, 2)):
        us = time_call(lambda: fn(cfg_), warmup=1, iters=3)
        sites = pallas_call_sites(lambda: fn(cfg_))
        iters = int(fn(cfg_)[1].iters)      # each config's own convergence
        path_kind = "sweeps" if name == "sweeps" else "fused"
        modeled = modeled_estep_hbm_bytes(
            path_kind, b, v, k, l, iters, stream_bytes=stream,
            block_v=block_v)
        record["paths"][name] = {
            "interpret_us": us,
            "tokens_per_s_interpret": tokens / (us / 1e6),
            "sweeps": iters,
            "kernel_sites": sites,
            "modeled_hbm_bytes": modeled,
        }
    # the retired one-hot memo_delta, modeled at the same shape/sweeps —
    # the baseline the segment-sum scatter is measured against
    record["paths"]["fused_onehot_modeled"] = {
        "modeled_hbm_bytes": modeled_estep_hbm_bytes(
            "fused_onehot", b, v, k, l,
            record["paths"]["fused"]["sweeps"], block_v=block_v),
    }
    base = record["paths"]["sweeps"]["modeled_hbm_bytes"]
    for name in ("fused", "fused_bf16", "fused_onehot_modeled"):
        record["paths"][name]["hbm_ratio_vs_sweeps"] = (
            base / record["paths"][name]["modeled_hbm_bytes"])
    record["meets_2x_hbm_bar"] = (
        record["paths"]["fused"]["hbm_ratio_vs_sweeps"] >= 2.0)
    record["fused_single_launch_ok"] = (
        record["paths"]["fused"]["kernel_sites"]["under_loop"] == 0
        and record["paths"]["fused"]["kernel_sites"]["blk_intermediates"] == 0)

    # interpret-mode head-to-head of the two scatter formulations
    eb_tok = eb[ids]
    et = exp_dirichlet_expectation(res_old.gamma)
    record["scatter_interpret_us"] = {
        "segment": time_call(lambda: lda_estep.memo_delta(
            ids, cnts, eb_tok, et, v, old_pi=old_pi), warmup=1, iters=3),
        "onehot": time_call(lambda: lda_estep.memo_delta_onehot(
            ids, cnts, eb_tok, et, v, old_pi=old_pi), warmup=1, iters=3),
    }

    # transient-HBM model at the Arxiv production shape (Table 1): the
    # one-hot partial cubes vs the segment-sum path — the ≥4× bar
    ax = dict(b=256, v=141_952, k=128, l=128)
    one_t = modeled_scatter_transient_bytes("onehot", **ax)
    seg_t = modeled_scatter_transient_bytes("segment", **ax)
    record["arxiv_scatter"] = {
        "shape": {"B": ax["b"], "V": ax["v"], "K": ax["k"], "L": ax["l"]},
        "onehot_transient_bytes": one_t,
        "segment_transient_bytes": seg_t,
        "transient_ratio": one_t / seg_t,
        "meets_4x_transient_bar": one_t / seg_t >= 4.0,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


def estep_rows():
    rec = estep_report()
    out = []
    for name, p in rec["paths"].items():
        if "interpret_us" not in p:           # modeled-only baselines
            continue
        ratio = p.get("hbm_ratio_vs_sweeps", 1.0)
        out.append((f"kernel/estep_{name}/B128_V4096", p["interpret_us"],
                    f"sweeps={p['sweeps']} hbm_x={ratio:.2f} "
                    f"launches={p['kernel_sites']['total']} "
                    f"under_loop={p['kernel_sites']['under_loop']}"))
    ax = rec["arxiv_scatter"]
    out.append(("kernel/memo_delta_arxiv_transient", 0.0,
                f"onehot={ax['onehot_transient_bytes'] / 1e9:.2f}GB "
                f"segment={ax['segment_transient_bytes'] / 1e9:.2f}GB "
                f"ratio={ax['transient_ratio']:.1f}x"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--estep-json", default="BENCH_estep.json",
                    help="where to write the fused-vs-sweeps record")
    args = ap.parse_args()
    rec = estep_report(args.estep_json)
    f, fb = rec["paths"]["fused"], rec["paths"]["fused_bf16"]
    oh = rec["paths"]["fused_onehot_modeled"]
    ax = rec["arxiv_scatter"]
    print(f"BENCH_estep -> {args.estep_json}")
    print(f"  sweeps path : {rec['paths']['sweeps']['sweeps']} sweeps, "
          f"{rec['paths']['sweeps']['modeled_hbm_bytes'] / 1e6:.1f} MB modeled")
    print(f"  fused (seg) : {f['sweeps']} sweeps, "
          f"{f['modeled_hbm_bytes'] / 1e6:.1f} MB "
          f"({f['hbm_ratio_vs_sweeps']:.2f}x fewer), "
          f"launches={f['kernel_sites']['total']} "
          f"under_loop={f['kernel_sites']['under_loop']} "
          f"blk_jnp={f['kernel_sites']['blk_intermediates']}")
    print(f"  fused bf16  : {fb['hbm_ratio_vs_sweeps']:.2f}x fewer bytes")
    print(f"  one-hot     : {oh['modeled_hbm_bytes'] / 1e6:.1f} MB modeled "
          f"({oh['hbm_ratio_vs_sweeps']:.2f}x vs sweeps, retired baseline)")
    print(f"  arxiv scatter transient: onehot "
          f"{ax['onehot_transient_bytes'] / 1e9:.2f} GB vs segment "
          f"{ax['segment_transient_bytes'] / 1e9:.3f} GB "
          f"({ax['transient_ratio']:.1f}x)")
    print(f"  correction max |Δ| = {rec['correction_max_abs_err']:.2e}")
    assert rec["meets_2x_hbm_bar"], "fused path lost the 2x HBM bar"
    assert rec["fused_single_launch_ok"], "fused path regressed to per-sweep"
    assert ax["meets_4x_transient_bar"], \
        "segment-sum scatter lost the 4x Arxiv transient-HBM bar"
