"""Deliverable (g): roofline table from the dry-run sweep results.

Reads results/dryrun.jsonl (produced by ``python -m repro.launch.dryrun
--all --mesh both --out results/dryrun.jsonl``) and renders the
per-(arch × shape × mesh) roofline terms, dominant bottleneck, MODEL_FLOPS
ratio, and memory fit — the §Roofline content of EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS, get_shape

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}
HBM_GB = 16.0   # v5e


def count_params(cfg) -> float:
    """Analytic parameter count (embedding included once if tied)."""
    import jax
    from repro.models import transformer as T
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
    return float(sum(s.size for s in jax.tree.leaves(
        shapes, is_leaf=lambda x: hasattr(x, "size"))))


def active_params(cfg) -> float:
    """Active parameters per token (MoE: top-k of routed + shared)."""
    total = count_params(cfg)
    if not cfg.num_experts:
        return total
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n_moe = sum(1 for kk in cfg.pattern if kk == "moe")
    expert_p = n_moe * e * 3 * cfg.d_model * cfg.moe_d_ff
    return total - expert_p * (1 - k / e)


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (global)."""
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def load(path: str = "results/dryrun.jsonl") -> List[dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    for line in open(path):
        r = json.loads(line)
        # older rows stored a mesh-shape dict under "mesh"
        if not isinstance(r["mesh"], str):
            r["mesh"] = "multi" if r.get("chips") == 512 else "single"
        seen[(r["arch"], r["shape"], r["mesh"], r.get("seq_shard", False))] = r
    return list(seen.values())


def render(path: str = "results/dryrun.jsonl",
           mesh: str = "single") -> List[str]:
    rows = [r for r in load(path) if r["mesh"] == mesh
            and not r.get("seq_shard")]
    lines = []
    hdr = (f"| arch | shape | ok | compute_s | memory_s | collective_s | "
           f"bottleneck | MODEL_FLOPs/HLO | temp GB (≤{HBM_GB:.0f}) |")
    lines.append(hdr)
    lines.append("|" + "---|" * 9)
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | "
                         f"{r.get('error', '')[:60]} |")
            continue
        rf = r["roofline"]
        terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                 "collective": rf["collective_s"]}
        dom = max(terms, key=terms.get)
        cfg = ARCHS[r["arch"]]
        shape = get_shape(r["shape"])
        mf = model_flops(cfg, shape) / r["chips"]
        ratio = mf / max(r["hlo"]["dot_flops"], 1.0)
        temp = r["memory"]["temp_gb"]
        fit = "✓" if temp <= HBM_GB else "✗"
        lines.append(
            f"| {r['arch']} | {r['shape']} | OK | {terms['compute']:.2e} | "
            f"{terms['memory']:.2e} | {terms['collective']:.2e} | {dom} | "
            f"{ratio:.2f} | {temp:.2f} {fit} |")
    return lines


def rows():
    """CSV rows for benchmarks/run.py."""
    out = []
    for mesh in ("single", "multi"):
        data = [r for r in load() if r["mesh"] == mesh
                and not r.get("seq_shard")]
        ok = sum(1 for r in data if r.get("ok"))
        out.append((f"roofline/dryrun_{mesh}", 0.0,
                    f"pairs_ok={ok}/{len(data)}"))
        for r in data:
            if not r.get("ok"):
                continue
            rf = r["roofline"]
            terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                     "collective": rf["collective_s"]}
            dom = max(terms, key=terms.get)
            out.append((f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                        max(terms.values()) * 1e6,
                        f"bottleneck={dom} temp_gb={r['memory']['temp_gb']:.2f}"))
    return out


if __name__ == "__main__":
    for mesh in ("single", "multi"):
        print(f"\n## Roofline — {mesh} pod\n")
        for line in render(mesh=mesh):
            print(line)
