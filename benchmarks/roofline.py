"""Roofline tables: the dry-run sweep AND the LDA measured-vs-modeled join.

Two sections share ONE hardware table (``repro.obs.roofline.HW`` — this
module re-exports it for the older callers):

* the seed transformer dry-run renderer: reads results/dryrun.jsonl
  (``python -m repro.launch.dryrun --all --mesh both --out
  results/dryrun.jsonl``) and renders per-(arch × shape × mesh) roofline
  terms, dominant bottleneck, MODEL_FLOPS ratio, and memory fit;
* the LDA stack's roofline records: reads ``BENCH_obs.json`` (written by
  ``python -m benchmarks.obs_bench --json BENCH_obs.json``) and renders
  the measured-vs-modeled kernel verdicts of
  ``repro.obs.roofline.roofline_from_trace`` — the join that flags a
  kernel whose modeled HBM bytes say memory-bound but whose measured
  time disagrees (`docs/observability.md`).
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional

from repro.configs import ARCHS, get_shape
from repro.obs.roofline import HBM_GB, HW  # the canonical hardware table

__all__ = ["HW", "HBM_GB", "load", "render", "rows", "render_lda",
           "load_obs", "count_params", "active_params", "model_flops"]


def count_params(cfg) -> float:
    """Analytic parameter count (embedding included once if tied)."""
    import jax
    from repro.models import transformer as T
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
    return float(sum(s.size for s in jax.tree.leaves(
        shapes, is_leaf=lambda x: hasattr(x, "size"))))


def active_params(cfg) -> float:
    """Active parameters per token (MoE: top-k of routed + shared)."""
    total = count_params(cfg)
    if not cfg.num_experts:
        return total
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n_moe = sum(1 for kk in cfg.pattern if kk == "moe")
    expert_p = n_moe * e * 3 * cfg.d_model * cfg.moe_d_ff
    return total - expert_p * (1 - k / e)


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (global)."""
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def load(path: str = "results/dryrun.jsonl") -> List[dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    seen = {}
    for line in open(path):
        r = json.loads(line)
        # older rows stored a mesh-shape dict under "mesh"
        if not isinstance(r["mesh"], str):
            r["mesh"] = "multi" if r.get("chips") == 512 else "single"
        seen[(r["arch"], r["shape"], r["mesh"], r.get("seq_shard", False))] = r
    return list(seen.values())


def render(path: str = "results/dryrun.jsonl",
           mesh: str = "single") -> List[str]:
    rows = [r for r in load(path) if r["mesh"] == mesh
            and not r.get("seq_shard")]
    lines = []
    hdr = (f"| arch | shape | ok | compute_s | memory_s | collective_s | "
           f"bottleneck | MODEL_FLOPs/HLO | temp GB (≤{HBM_GB:.0f}) |")
    lines.append(hdr)
    lines.append("|" + "---|" * 9)
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | "
                         f"{r.get('error', '')[:60]} |")
            continue
        rf = r["roofline"]
        terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                 "collective": rf["collective_s"]}
        dom = max(terms, key=terms.get)
        cfg = ARCHS[r["arch"]]
        shape = get_shape(r["shape"])
        mf = model_flops(cfg, shape) / r["chips"]
        ratio = mf / max(r["hlo"]["dot_flops"], 1.0)
        temp = r["memory"]["temp_gb"]
        fit = "✓" if temp <= HBM_GB else "✗"
        lines.append(
            f"| {r['arch']} | {r['shape']} | OK | {terms['compute']:.2e} | "
            f"{terms['memory']:.2e} | {terms['collective']:.2e} | {dom} | "
            f"{ratio:.2f} | {temp:.2f} {fit} |")
    return lines


def load_obs(path: str = "BENCH_obs.json") -> List[dict]:
    """The LDA stack's roofline-check sections from ``BENCH_obs.json``:
    ``[(section name, roofline_from_trace output)]`` flattened to dicts.
    Empty when the bench has not run (the renderer prints a hint)."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rec = json.load(f)
    out = []
    for section in ("roofline", "roofline_csr"):
        chk = rec.get(section)
        if chk and chk.get("records"):
            out.append({"section": section, **chk})
    return out


def render_lda(path: str = "BENCH_obs.json") -> List[str]:
    """Markdown table of the LDA kernels' measured-vs-modeled verdicts
    (`repro.obs.roofline.roofline_check` output semantics)."""
    checks = load_obs(path)
    if not checks:
        return [f"(no LDA roofline records — run `python -m "
                f"benchmarks.obs_bench --json {path}` first)"]
    lines = ["| section | kernel | measured_s | modeled_s | ratio | "
             "verdict | proxy |", "|" + "---|" * 7]
    for chk in checks:
        proxy = "interpret" if chk.get("proxy_regime") else "device"
        for r in chk["records"]:
            lines.append(
                f"| {chk['section']} | {r['name']} | "
                f"{r['measured_s']:.2e} | {r['modeled_s']:.2e} | "
                f"{r['measured_vs_modeled']:.2f} | {r['verdict']} | "
                f"{proxy} |")
    return lines


def rows():
    """CSV rows for benchmarks/run.py (dry-run sweep + LDA join)."""
    out = []
    for mesh in ("single", "multi"):
        data = [r for r in load() if r["mesh"] == mesh
                and not r.get("seq_shard")]
        ok = sum(1 for r in data if r.get("ok"))
        out.append((f"roofline/dryrun_{mesh}", 0.0,
                    f"pairs_ok={ok}/{len(data)}"))
        for r in data:
            if not r.get("ok"):
                continue
            rf = r["roofline"]
            terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                     "collective": rf["collective_s"]}
            dom = max(terms, key=terms.get)
            out.append((f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                        max(terms.values()) * 1e6,
                        f"bottleneck={dom} temp_gb={r['memory']['temp_gb']:.2f}"))
    for chk in load_obs():
        for r in chk["records"]:
            out.append((f"roofline/lda/{chk['section']}/{r['name']}",
                        r["measured_s"] * 1e6,
                        f"ratio={r['measured_vs_modeled']:.2f} "
                        f"verdict={r['verdict']}"))
    return out


if __name__ == "__main__":
    for mesh in ("single", "multi"):
        print(f"\n## Roofline — {mesh} pod\n")
        for line in render(mesh=mesh):
            print(line)
    print("\n## Roofline — LDA kernels (measured vs modeled)\n")
    for line in render_lda():
        print(line)
