"""Autotuner benchmark: tuned-vs-default kernel policies -> BENCH_tune.json.

Runs the real `repro.tune` search (`tune_shape`) on a panel of problem
shapes and records, for each, the default policy's cost, the tuned
winner's cost and the winner itself. Two invariants are asserted as CI
bars:

* **tuned never loses**: every record has ``tuned_cost <= default_cost``
  (the search falls back to the default when nothing gated cheaper, so a
  regression here means the search itself is broken);
* **honest objective**: off-TPU the objective is the structural HBM
  model and every record carries ``proxy_regime: true`` — interpret-mode
  wall time is never presented as a measurement (docs/tuning.md).

Panel:

* ``padded_small``  — V-resident serving-ish shape (gate cheap enough to
  run everywhere);
* ``padded_arxiv``  — the paper's Table 1 Arxiv shape (B=256, V=141 952,
  K=128): streaming regime, where halving the B-grid via ``block_b=256``
  halves per-sweep Eφ re-streams — the headline modeled win;
* ``csr``           — the flat-token path at the engine's default budget.

``--dryrun`` tunes only the small shape with a minimal budget (the CI
smoke: exercises search + gate + store round-trip in seconds).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.tune.search import (TuneShape, measurement_available,  # noqa: E402
                               tune_shape)
from repro.tune.store import current_device_kind, policy_to_dict  # noqa: E402

PANEL = {
    "padded_small": dict(
        shape=TuneShape(task="padded", b_or_t=64, v=4096, k=128, w=64),
        budget=8, gate_candidates=2),
    "padded_arxiv": dict(
        shape=TuneShape(task="padded", b_or_t=256, v=141_952, k=128, w=128),
        budget=12, gate_candidates=3),
    "csr": dict(
        shape=TuneShape(task="csr", b_or_t=4096, v=8192, k=128, num_docs=64,
                        backend="csr", layout="csr"),
        budget=8, gate_candidates=2),
}

DRYRUN_PANEL = {
    "padded_small": dict(
        shape=PANEL["padded_small"]["shape"], budget=2, gate_candidates=1),
}


def _one(name: str, spec: dict, *, seed: int, iters: int,
         verbose: bool) -> dict:
    shape = spec["shape"]
    res = tune_shape(shape, budget=spec["budget"], seed=seed,
                     gate_candidates=spec["gate_candidates"], iters=iters,
                     verbose=verbose)
    return {
        "name": name,
        "shape": {"task": shape.task, "b_or_t": shape.b_or_t, "v": shape.v,
                  "k": shape.k, "w": shape.w, "num_docs": shape.num_docs,
                  "backend": shape.backend, "layout": shape.layout},
        "objective": res.objective,
        "proxy_regime": res.proxy_regime,
        "default_cost_s": res.default_cost,
        "tuned_cost_s": res.tuned_cost,
        "improvement": res.improvement,
        "trials": res.trials,
        "policy": policy_to_dict(res.policy),
        "tuned_is_default": res.improvement == 1.0,
        "effective": res.effective,
        "equality": res.equality,
    }


def tune_report(json_path=None, *, dryrun: bool = False, seed: int = 0,
                iters: int = 20, verbose: bool = False) -> dict:
    panel = DRYRUN_PANEL if dryrun else PANEL
    measured = measurement_available()
    records = [_one(name, spec, seed=seed, iters=iters, verbose=verbose)
               for name, spec in panel.items()]
    record = {
        "device_kind": current_device_kind(),
        "objective": "measured_seconds" if measured else "modeled_seconds",
        "proxy_regime": not measured,
        "dryrun": dryrun,
        "records": records,
        # the CI bars (asserted under __main__)
        "tuned_never_loses": all(r["tuned_cost_s"] <= r["default_cost_s"]
                                 for r in records),
        "proxy_regime_honest": all(r["proxy_regime"] == (not measured)
                                   for r in records),
    }
    if json_path:
        try:
            with open(json_path) as f:
                full = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            full = {}
        full["tune"] = record
        with open(json_path, "w") as f:
            json.dump(full, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_tune.json",
                    help="where to write the tuned-vs-default records")
    ap.add_argument("--dryrun", action="store_true",
                    help="minimal budget, small shape only (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=20,
                    help="fixed-point sweeps priced by the model")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    rec = tune_report(args.json, dryrun=args.dryrun, seed=args.seed,
                      iters=args.iters, verbose=args.verbose)
    tag = " [proxy_regime]" if rec["proxy_regime"] else ""
    print(f"BENCH_tune -> {args.json} on {rec['device_kind']} "
          f"({rec['objective']}{tag})")
    for r in rec["records"]:
        s = r["shape"]
        win = ("default kept" if r["tuned_is_default"]
               else f"{r['improvement']:.2f}x")
        print(f"  {r['name']:<14} B_or_T={s['b_or_t']} V={s['v']} "
              f"K={s['k']} W={s['w']}: default {r['default_cost_s']:.3e}s "
              f"-> tuned {r['tuned_cost_s']:.3e}s ({win}, "
              f"{r['trials']} trials, gate={r['equality']['mode']} "
              f"err={r['equality']['max_abs_err']:.1e})")
    assert rec["tuned_never_loses"], \
        "a tuned record costs MORE than the default — the search's " \
        "default-fallback guarantee is broken"
    assert rec["proxy_regime_honest"], \
        "proxy_regime tag disagrees with measurement availability — a " \
        "modeled number is masquerading as a measurement"
