"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Budget-friendly defaults (the
paper-scale corpora are sampled down per DESIGN.md §8); pass --full for the
larger synthetic corpora.

Sections:
  fig1   — MVI/SVI/IVI/S-IVI convergence (paper Fig. 1)
  fig2   — IVI mini-batch size sweep (paper Fig. 2)
  table2 — D-IVI LPP + time vs processors × batch (paper Table 2 / Fig. 3)
  fig5   — delay robustness (paper Fig. 5)
  kernel — E-step hotspot micro-benchmarks
  roofline — dry-run roofline summary (reads results/dryrun.jsonl)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections")
    ap.add_argument("--corpus", default="small")
    args = ap.parse_args()

    from benchmarks import (fig1_convergence, fig2_minibatch, fig5_delays,
                            kernel_bench, roofline, table2_divi)
    sections = {
        "fig1": lambda: (fig1_convergence.rows(args.corpus)
                         # K = K* regime (paper-consistent final ordering)
                         + fig1_convergence.rows("tiny", epochs=8)),
        "fig2": lambda: fig2_minibatch.rows(args.corpus),
        "table2": lambda: table2_divi.rows(args.corpus),
        "fig5": lambda: fig5_delays.rows(args.corpus),
        "kernel": kernel_bench.rows,
        "roofline": roofline.rows,
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        try:
            for row in sections[name]():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
