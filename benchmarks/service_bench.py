"""Serving-service benchmark: SLO attainment + online-vs-frozen.

Produces ``BENCH_service.json`` — the evidence record for the `repro.serve`
subsystem (`docs/serving.md`):

* **slo** — the real-time service run under both synthetic traffic shapes
  (seeded Poisson and bursty ON-OFF, `repro.serve.traffic`), each emitting
  a schema-validated ``repro.serve.slo/v1`` report: p50/p95/p99 latency,
  throughput, request conservation (offered == served + shed), and
  attainment against a deliberately generous CPU-proxy target. The
  asserted bars here are the *structural* ones — conservation and
  every-response-versioned — latency magnitudes on a shared CPU runner
  are recorded for trend, not barred.
* **swap_stall** — the measured atomic-snapshot swap window across every
  online publish in the bench (`repro.serve.snapshot`). The CI bar: max
  stall ≤ ``SWAP_STALL_BOUND_MS``. The swap is two reference assignments
  under a lock (the Eφ preprocessing runs *before* the lock), so 50 ms is
  generous by ~3 orders of magnitude — the bar exists to catch anyone
  moving device work back inside the swap.
* **online_vs_frozen** — the paper's headline at serving time: a
  deliberately undertrained frozen model (one pass over a quarter-scale
  corpus) versus the same model after ``OnlineLearner`` trained on the
  served traffic (warm start + IVI passes + drain). Held-out
  log-predictive delta over several seeds with a Student-t 95% CI; the
  bar: the CI lower bound is > 0 — online serving *provably* beats the
  frozen snapshot, not just on average.
* **watchdog** — the ELBO watchdog must have produced ≥ 1 *armed*
  monotonicity reading per run (the drain passes over the quiet window)
  with zero violations: the swaps never served a bound-degrading λ.

``--dryrun`` is the CI smoke: fewer requests/seeds, same asserted bars.
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

# ---------------------------------------------------------------------------
# bench constants (documented in docs/serving.md §benchmark)
# ---------------------------------------------------------------------------
SWAP_STALL_BOUND_MS = 50.0     # generous bound on the atomic swap window
SLO_TARGET_MS = {"p95": 5000.0, "p99": 10000.0}   # CPU-proxy targets
FULL_SEEDS = [0, 1, 2, 3, 4]
DRY_SEEDS = [0, 1, 2]
FROZEN_SCALE = 0.25            # frozen model sees a quarter-scale corpus
SCORE_SPLIT_SEED = 0           # one held-out split shared by every score

# two-sided 95% Student-t critical values by degrees of freedom
_T_CRIT = {1: 12.71, 2: 4.30, 3: 3.18, 4: 2.78, 5: 2.57, 6: 2.45,
           7: 2.36, 8: 2.31, 9: 2.26}


def _base_model(seed: int, *, corpus: str = "tiny", topics: int = 8,
                estep_iters: int = 20):
    """The deliberately *undertrained* serving model: one IVI pass over a
    quarter-scale train corpus. Returns (lda, full train ragged docs,
    test corpus) — the full train split is the traffic the online learner
    gets to see and the frozen model never did."""
    from repro.data import PAPER_CORPORA, make_corpus
    from repro.data.stream import CorpusDocStream
    from repro.lda import LDA

    spec = PAPER_CORPORA[corpus]
    sub = make_corpus(spec, split="train", seed=seed, scale=FROZEN_SCALE)
    lda = LDA(num_topics=topics, vocab_size=spec.vocab_size,
              estep_max_iters=estep_iters, algo="ivi", seed=seed)
    lda.fit(sub, epochs=1)
    train = make_corpus(spec, split="train", seed=seed)
    test = make_corpus(spec, split="test", seed=seed)
    train_docs = list(CorpusDocStream(train).iter_from(0))
    return lda, train_docs, test


def _arrivals(shape: str, n: int, rate: float, seed: int):
    from repro.serve import onoff_arrivals, poisson_arrivals
    if shape == "poisson":
        return poisson_arrivals(n, rate, seed=seed)
    return onoff_arrivals(n, rate, on_s=8.0 / rate, off_s=8.0 / rate,
                          seed=seed)


def _run_service(lda, docs, *, shape: str, rate: float, seed: int,
                 online: bool, batch: int = 16,
                 flush_timeout_s: float = 0.02,
                 cadence_s: float = 0.05):
    """One end-to-end service run; returns (slo report, learner or None)."""
    from repro.serve import (OnlineLearner, ServiceConfig, ServingService,
                             SnapshotStore, requests_from_docs)

    inf = lda.inferencer(batch_size=batch)
    inf.posterior_docs(docs)               # warm every bucket width
    arrivals = _arrivals(shape, len(docs), rate, seed)
    requests = requests_from_docs(docs, arrivals)
    svc = ServingService(inf, config=ServiceConfig(
        flush_timeout_s=flush_timeout_s, slo_ms=dict(SLO_TARGET_MS)))
    learner = None
    if online:
        store = SnapshotStore(inf, metrics=svc.metrics)
        learner = OnlineLearner(lda.cfg, store, lam0=np.asarray(lda.lam),
                                cadence_s=cadence_s, seed=seed)
        svc.learner = learner
        learner.start()
    try:
        svc.run(requests)
    finally:
        if learner is not None:
            learner.stop()
    if learner is not None:
        learner.drain(passes=2)
    return svc.slo_report(), learner


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

def slo_section(*, n_requests: int, rate: float, seed: int = 0) -> dict:
    """Both traffic shapes through the service (no learner — pure serving
    latency), each report schema-validated."""
    from repro.serve import validate_slo_report

    from repro.data.stream import CorpusDocStream

    lda, _, test = _base_model(seed)
    docs = list(CorpusDocStream(test).iter_from(0))[:n_requests]
    out = {}
    for shape in ("poisson", "onoff"):
        t0 = time.perf_counter()
        rep, _ = _run_service(lda, docs, shape=shape, rate=rate, seed=seed,
                              online=False)
        validate_slo_report(rep)
        out[shape] = {
            "traffic": {"shape": shape, "rate_docs_s": rate,
                        "n_requests": len(docs), "seed": seed},
            "wall_s": time.perf_counter() - t0,
            "report": rep,
            "validated": True,
        }
    return out


def online_section(seeds, *, rate: float = 400.0) -> dict:
    """Per-seed online-vs-frozen held-out delta + the swap/watchdog
    evidence each run produces (see module docstring)."""
    per_seed, stalls = [], []
    armed_total, violations_total = 0, 0
    versioned_all = True
    for seed in seeds:
        lda, train_docs, test = _base_model(seed)
        frozen = float(lda.score(test, seed=SCORE_SPLIT_SEED))
        rep, learner = _run_service(lda, train_docs, shape="poisson",
                                    rate=rate, seed=seed, online=True)
        online = float(learner.model.score(test, seed=SCORE_SPLIT_SEED))
        run_stalls = learner.store.swap_stalls_ms()
        stalls.extend(run_stalls)
        armed_total += learner.armed_observations
        violations_total += len(learner.watchdog.violations)
        versioned_all &= bool(rep["every_response_versioned"])
        per_seed.append({
            "seed": seed,
            "frozen_lpp": frozen,
            "online_lpp": online,
            "delta_lpp": online - frozen,
            "online_updates": learner.updates,
            "docs_trained": learner.docs_trained,
            "model_versions_served": rep["model_versions"],
            "served": rep["served"],
            "shed": rep["shed"],
            "armed_observations": learner.armed_observations,
            "watchdog_violations": len(learner.watchdog.violations),
            "swap_stalls_ms": run_stalls,
        })
    deltas = np.array([r["delta_lpp"] for r in per_seed])
    n = len(deltas)
    t_crit = _T_CRIT.get(n - 1, 1.96)
    sem = float(deltas.std(ddof=1) / math.sqrt(n)) if n > 1 else math.inf
    mean = float(deltas.mean())
    return {
        "online_vs_frozen": {
            "seeds": list(seeds),
            "frozen_setup": {"scale": FROZEN_SCALE, "epochs": 1},
            "per_seed": per_seed,
            "mean_delta_lpp": mean,
            "sem_delta_lpp": sem,
            "t_crit_95": t_crit,
            "ci95_lo": mean - t_crit * sem,
            "ci95_hi": mean + t_crit * sem,
            "improves_with_ci": mean - t_crit * sem > 0,
        },
        "swap_stall": {
            "n_swaps": len(stalls),
            "max_ms": max(stalls) if stalls else None,
            "mean_ms": float(np.mean(stalls)) if stalls else None,
            "bound_ms": SWAP_STALL_BOUND_MS,
            "meets_bound": bool(stalls) and max(stalls) <= SWAP_STALL_BOUND_MS,
        },
        "watchdog": {
            "armed_observations": armed_total,
            "violations": violations_total,
            "armed_ok": armed_total >= len(seeds) and violations_total == 0,
        },
        "every_response_versioned": versioned_all,
    }


def service_report(json_path=None, *, dryrun: bool = False) -> dict:
    seeds = DRY_SEEDS if dryrun else FULL_SEEDS
    n_req = 24 if dryrun else 32
    record = {
        "schema": "repro.serve.bench/v1",
        "dryrun": dryrun,
        "slo": slo_section(n_requests=n_req, rate=200.0),
    }
    record.update(online_section(seeds))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_service.json",
                    help="where to write the service record")
    ap.add_argument("--dryrun", action="store_true",
                    help="CI mode: fewer requests/seeds, same bars")
    args = ap.parse_args()
    rec = service_report(args.json, dryrun=args.dryrun)
    print(f"BENCH_service -> {args.json}")
    for shape in ("poisson", "onoff"):
        r = rec["slo"][shape]["report"]
        pct = r["latency_ms"]
        att = all(v["attained"] for v in r["slo"].values())
        print(f"  slo/{shape:7s}: {r['served']}/{r['offered']} served "
              f"p50={pct['p50']:.1f}ms p95={pct['p95']:.1f}ms "
              f"p99={pct['p99']:.1f}ms {r['throughput_docs_s']:.0f} docs/s "
              f"attained={att}")
    ov = rec["online_vs_frozen"]
    print(f"  online vs frozen: Δlpp={ov['mean_delta_lpp']:+.4f} "
          f"95% CI [{ov['ci95_lo']:+.4f}, {ov['ci95_hi']:+.4f}] "
          f"over seeds {ov['seeds']}")
    sw, wd = rec["swap_stall"], rec["watchdog"]
    print(f"  swap stall: max={sw['max_ms']:.3f}ms over {sw['n_swaps']} "
          f"swaps (bound {sw['bound_ms']:.0f}ms)")
    print(f"  watchdog: {wd['armed_observations']} armed readings, "
          f"{wd['violations']} violations")
    for shape in ("poisson", "onoff"):
        assert rec["slo"][shape]["report"]["conservation_ok"], \
            f"{shape}: offered != served + shed"
        assert rec["slo"][shape]["validated"]
    assert rec["every_response_versioned"], \
        "a response was served without a model version"
    assert sw["meets_bound"], \
        f"snapshot swap stalled {sw['max_ms']:.1f}ms > {sw['bound_ms']}ms"
    assert wd["armed_ok"], "watchdog never armed (or a swap broke the bound)"
    assert ov["improves_with_ci"], \
        "online serving did not beat the frozen model at 95% CI"
