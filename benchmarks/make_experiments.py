"""Regenerate the generated tables inside EXPERIMENTS.md from
results/dryrun.jsonl + results/lda_dryrun.jsonl (markers: DRYRUN_TABLE,
ROOFLINE_TABLE)."""
from __future__ import annotations

import json
import os
import re

from benchmarks.roofline import HW, load, render
from repro.configs import ARCHS
from repro.configs.base import INPUT_SHAPES


def dryrun_summary() -> str:
    rows = [r for r in load() if not r.get("seq_shard")
            and r.get("profile", "tp_fsdp") == "tp_fsdp"]
    lines = ["| mesh | pairs OK | median compile s | max compile s | "
             "max temp GB (train) | max temp GB (inference) |",
             "|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        data = [r for r in rows if r["mesh"] == mesh]
        ok = [r for r in data if r.get("ok")]
        cts = sorted(r.get("compile_s", 0) for r in ok)
        tr = [r["memory"]["temp_gb"] for r in ok if r["shape"] == "train_4k"]
        inf = [r["memory"]["temp_gb"] for r in ok if r["shape"] != "train_4k"]
        lines.append(
            f"| {mesh} | {len(ok)}/{len(data)} | "
            f"{cts[len(cts)//2]:.1f} | {cts[-1]:.1f} | "
            f"{max(tr):.1f} | {max(inf):.1f} |")
    # LDA rows
    if os.path.exists("results/lda_dryrun.jsonl"):
        lda = [json.loads(l) for l in open("results/lda_dryrun.jsonl")]
        okl = sum(1 for r in lda if r.get("ok"))
        lines.append(f"| lda-divi (arxiv) | {okl}/{len(lda)} | — | — | — | "
                     f"{max(r['memory']['temp_gb'] for r in lda if r.get('ok')):.2f} |")
    return "\n".join(lines)


def main() -> None:
    path = "EXPERIMENTS.md"
    text = open(path).read()
    dr = dryrun_summary()
    rf = []
    for mesh in ("single", "multi"):
        rf.append(f"### {mesh} pod ({256 if mesh == 'single' else 512} chips)\n")
        rf.extend(render(mesh=mesh))
        rf.append("")
    text = re.sub(r"<!-- DRYRUN_TABLE -->(.|\n)*?(?=\n## §Roofline)",
                  "<!-- DRYRUN_TABLE -->\n" + dr + "\n",
                  text) if "<!-- DRYRUN_TABLE -->" in text else text
    text = re.sub(r"<!-- ROOFLINE_TABLE -->(.|\n)*?(?=\n### Reading)",
                  "<!-- ROOFLINE_TABLE -->\n" + "\n".join(rf) + "\n",
                  text) if "<!-- ROOFLINE_TABLE -->" in text else text
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
