"""Fig. 5 — D-IVI robustness to stale/delayed workers.

Each worker misses a round with probability 0.25/0.5 (the paper's sleep
simulation); larger simulated delays = higher drop probability + higher
staleness S. Claim: convergence slows but does not diverge.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import LDAConfig, log_predictive, split_heldout
from repro.data import PAPER_CORPORA, make_corpus
from repro.dist import DIVIConfig, DIVIEngine


def run(corpus_name: str = "small", rounds: int = 24, seed: int = 0) -> Dict:
    spec = PAPER_CORPORA[corpus_name]
    train = make_corpus(spec, split="train", seed=seed)
    test = make_corpus(spec, split="test", seed=seed)
    cfg = LDAConfig(num_topics=min(100, spec.num_topics * 2),
                    vocab_size=spec.vocab_size, estep_max_iters=40)
    obs, held = split_heldout(test, seed=seed)
    # (delay_prob, staleness) ladders emulate the paper's μ ∈ {2×, 5×, 10×}
    settings = {"none": (0.0, 1), "mu2x": (0.25, 1), "mu5x": (0.25, 3),
                "mu10x": (0.5, 5)}
    out = {}
    for name, (dp, st) in settings.items():
        eng = DIVIEngine(cfg, DIVIConfig(num_workers=4, batch_size=16,
                                         delay_prob=dp, staleness=st),
                         train, seed=seed)
        lpps = [float(log_predictive(cfg, eng.lam, obs, held))]
        for _ in range(rounds):
            eng.run_round()
        lpps.append(float(log_predictive(cfg, eng.lam, obs, held)))
        out[name] = {"first": lpps[0], "last": lpps[-1],
                     "docs_seen": eng.docs_seen}
    return out


def rows(corpus_name: str = "small"):
    res = run(corpus_name)
    out = []
    base = res["none"]["last"]
    for name, r in res.items():
        out.append((f"fig5/{corpus_name}/{name}", 0.0,
                    f"lpp={r['last']:.4f} improved={r['last'] > r['first']} "
                    f"gap_to_no_delay={base - r['last']:.4f}"))
    return out
