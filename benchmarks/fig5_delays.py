"""Fig. 5 — D-IVI robustness to stale/delayed workers.

Each worker misses a round with probability 0.25/0.5 (the paper's sleep
simulation); larger simulated delays = higher drop probability + higher
staleness S. Claim: convergence slows but does not diverge.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import make_lda
from repro.dist import DIVIConfig


def run(corpus_name: str = "small", rounds: int = 24, seed: int = 0) -> Dict:
    # (delay_prob, staleness) ladders emulate the paper's μ ∈ {2×, 5×, 10×}
    settings = {"none": (0.0, 1), "mu2x": (0.25, 1), "mu5x": (0.25, 3),
                "mu10x": (0.5, 5)}
    out = {}
    for name, (dp, st) in settings.items():
        lda, _, test = make_lda(
            corpus_name, algo="divi", seed=seed, estep_iters=40,
            distributed=DIVIConfig(num_workers=4, batch_size=16,
                                   delay_prob=dp, staleness=st))
        first = lda.score(test)
        lda.fit(rounds=rounds)
        out[name] = {"first": first, "last": lda.score(test),
                     "docs_seen": lda.docs_seen}
    return out


def rows(corpus_name: str = "small"):
    res = run(corpus_name)
    out = []
    base = res["none"]["last"]
    for name, r in res.items():
        out.append((f"fig5/{corpus_name}/{name}", 0.0,
                    f"lpp={r['last']:.4f} improved={r['last'] > r['first']} "
                    f"gap_to_no_delay={base - r['last']:.4f}"))
    return out
