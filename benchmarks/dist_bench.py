"""Distributed streaming D-IVI benchmark: shard ingest + scaling record.

The distributed stack feeds workers from ``ShardedDocStream`` views of one
``DocStream`` — no materialize-then-slice step exists any more. This bench
produces ``BENCH_dist.json``:

* a **stream-equality guard**: a stream-fed ``DIVIEngine`` must be
  BIT-equal to a materialized-corpus engine over several rounds, for both
  partitioners, and a mid-run trainer save→restore must continue
  bit-equally — the CI guard that keeps the streaming ingest path honest
  (these are asserted, not just recorded);
* **measured per-worker ingest throughput** on this host: documents and
  tokens per second through ``WorkerIngest.next_batch`` (shard iteration +
  single-rung packing), per partitioner — the host-side cost the round
  must overlap. Trend tracking only; CPU wall time is not a bar;
* a **modeled scaling record at the Arxiv shape** (Table 1 padded:
  V=141,952, K=128, 782k docs). Like the other benches, the asserted
  quantity is a deterministic structural model, not a flaky timing:

      t_estep(W)  = per-worker batch E-step HBM bytes / HBM_GBPS
                    (fixed S·B docs per worker per round — constant in W)
      t_ingest(W) = S·B docs · PULL_DOC_US   (overlapped with compute:
                    the ingest of round r+1 streams while r runs)
      t_psum(W)   = S · 2(W−1)/W · V·K·4 bytes / ICI_BW
                    (one ring all-reduce of the (V, K) correction per
                    sub-round — the protocol's single message)

      docs/s(W)   = W·S·B / (max(t_estep, t_ingest) + t_psum)

  The bar: modeled scaling efficiency docs/s(8) / (8 · docs/s(1)) ≥ 0.7.
  It holds because the psum term approaches a W-independent constant
  (2(W−1)/W → 2) that is small against the per-worker E-step at the Arxiv
  shape, and breaks if someone makes the round's communication grow with
  W (e.g. per-worker λ broadcasts instead of one reduction).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import LDAConfig

# ---------------------------------------------------------------------------
# model constants (documented in docs/divi.md §benchmark)
# ---------------------------------------------------------------------------
HBM_GBPS = 1200.0       # TPU-class HBM stream rate for the E-step model
ICI_BW_GBPS = 50.0      # per-link interconnect rate for the psum ring
PULL_DOC_US = 15.0      # host-side pull+pack cost per ragged document

# Arxiv training shape (Table 1 padded)
ARXIV = dict(vocab=141_952, topics=128, width=128, batch=1024,
             staleness=1, iters=50, stream_bytes=2)


def modeled_estep_bytes(b: int, v: int, k: int, width: int, *, iters: int,
                        stream_bytes: int) -> float:
    """HBM bytes of one worker's (B, L) batch E-step + memo correction:
    the Eφ gather block and counts re-stream every fixed-point sweep
    (VMEM cannot hold them at Arxiv V), γ round-trips per sweep, and the
    (V, K) correction scatter streams once at the end."""
    gather = b * width * k * stream_bytes          # Eφ[ids] block
    counts = b * width * 4
    gamma = b * k * 4
    fixed_point = iters * (gather + counts + 2 * gamma)
    scatter = v * k * 4 + b * width * k * stream_bytes
    return float(fixed_point + scatter)


def modeled_scaling(workers: list[int]) -> dict:
    """docs/s vs W under the structural model above (deterministic)."""
    v, k, width = ARXIV["vocab"], ARXIV["topics"], ARXIV["width"]
    b, s = ARXIV["batch"], ARXIV["staleness"]
    t_estep = s * modeled_estep_bytes(b, v, k, width,
                                      iters=ARXIV["iters"],
                                      stream_bytes=ARXIV["stream_bytes"]) \
        / (HBM_GBPS * 1e9)
    t_ingest = s * b * PULL_DOC_US * 1e-6
    rows = []
    for w in workers:
        t_psum = s * (2 * (w - 1) / w) * v * k * 4 / (ICI_BW_GBPS * 1e9) \
            if w > 1 else 0.0
        t_round = max(t_estep, t_ingest) + t_psum
        rows.append({"workers": w, "t_estep_ms": t_estep * 1e3,
                     "t_ingest_ms": t_ingest * 1e3,
                     "t_psum_ms": t_psum * 1e3,
                     "docs_per_s": w * s * b / t_round})
    base = rows[0]["docs_per_s"]
    for r in rows:
        r["scaling_efficiency"] = r["docs_per_s"] / (r["workers"] * base)
    return {"shape": ARXIV, "per_worker_rows": rows,
            "efficiency_at_8": next(r["scaling_efficiency"] for r in rows
                                    if r["workers"] == 8)}


# ---------------------------------------------------------------------------
# guards + measurement (small corpus, CPU)
# ---------------------------------------------------------------------------

def stream_equality_guard() -> dict:
    """Stream-fed == materialized-fed, bit for bit, both partitioners;
    plus a mid-run save→restore continuation check."""
    import jax.numpy as jnp

    from repro.data import PAPER_CORPORA, make_corpus
    from repro.data.stream import CorpusDocStream
    from repro.dist import DIVIConfig, DIVIEngine
    from repro.lda.trainer import DIVITrainer

    train = make_corpus(PAPER_CORPORA["tiny"])
    cfg = LDAConfig(num_topics=8, vocab_size=250, estep_max_iters=30)
    out: dict = {}
    for part in ("range", "hash"):
        dcfg = DIVIConfig(num_workers=4, batch_size=8, staleness=2,
                          delay_prob=0.25, partitioner=part)
        e1 = DIVIEngine(cfg, dcfg, train, seed=2)
        e2 = DIVIEngine(cfg, dcfg, CorpusDocStream(train), seed=2)
        for _ in range(4):
            e1.run_round()
            e2.run_round()
        out[f"bit_equal_{part}"] = bool(
            jnp.array_equal(e1.lam, e2.lam)
            and jnp.array_equal(e1.shard.pi, e2.shard.pi))

    dcfg = DIVIConfig(num_workers=2, batch_size=7, staleness=2)
    a = DIVITrainer(cfg, dcfg, CorpusDocStream(train), seed=1)
    for _ in range(2):
        a.run_pass()
    meta, arrays = a.capture()
    b = DIVITrainer(cfg, dcfg, CorpusDocStream(train), seed=1)
    b.restore(meta, arrays)
    for _ in range(2):
        a.run_pass()
        b.run_pass()
    out["resume_bit_equal"] = bool(jnp.array_equal(a.state.lam, b.state.lam))
    return out


def measured_ingest(timed: bool = True) -> dict:
    """Per-worker ingest throughput through WorkerIngest.next_batch."""
    from repro.data import PAPER_CORPORA, make_corpus
    from repro.data.stream import CorpusDocStream, ShardedDocStream
    from repro.dist import WorkerIngest

    train = make_corpus(PAPER_CORPORA["medium"])
    stream = CorpusDocStream(train)
    out: dict = {"corpus_docs": int(train.num_docs)}
    for part in ("range", "hash"):
        sharded = ShardedDocStream(stream, 4, partitioner=part)
        ing = WorkerIngest(sharded.shard(0), 64)
        ing.next_batch()                       # warm the iterator
        if not timed:
            out[part] = {"warm_ok": True}
            continue
        t0 = time.perf_counter()
        while ing.docs_pulled < sharded.shard_sizes[0]:
            ing.next_batch()
        dt = time.perf_counter() - t0
        pulled = ing.docs_pulled - 64
        out[part] = {"docs_per_s": pulled / dt,
                     "tokens_per_s": ing.tokens_pulled / dt,
                     "pull_doc_us": dt / pulled * 1e6}
    return out


def dist_report(json_path: str | None, *, dryrun: bool = False) -> dict:
    record = {
        "bench": "dist",
        "stream_guard": stream_equality_guard(),
        "measured_ingest": measured_ingest(timed=not dryrun),
        "arxiv_scaling": modeled_scaling([1, 2, 4, 8, 16]),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_dist.json",
                    help="where to write the distributed record")
    ap.add_argument("--dryrun", action="store_true",
                    help="CI mode: equality guards + modeled record only "
                         "(no timed ingest loop)")
    args = ap.parse_args()
    rec = dist_report(args.json, dryrun=args.dryrun)
    g, sc = rec["stream_guard"], rec["arxiv_scaling"]
    print(f"BENCH_dist -> {args.json}")
    print(f"  stream guard: range={g['bit_equal_range']} "
          f"hash={g['bit_equal_hash']} resume={g['resume_bit_equal']}")
    mi = rec["measured_ingest"]
    if "docs_per_s" in mi.get("range", {}):
        for part in ("range", "hash"):
            m = mi[part]
            print(f"  ingest[{part}]: {m['docs_per_s']:.0f} docs/s, "
                  f"{m['tokens_per_s']:.0f} tokens/s "
                  f"({m['pull_doc_us']:.1f} us/doc)")
    for r in sc["per_worker_rows"]:
        print(f"  arxiv model W={r['workers']:>2}: "
              f"{r['docs_per_s']:>9.0f} docs/s "
              f"(eff {r['scaling_efficiency']:.2f}, "
              f"psum {r['t_psum_ms']:.2f}ms)")
    assert g["bit_equal_range"] and g["bit_equal_hash"], \
        "stream-fed D-IVI diverged from the materialized-corpus reference"
    assert g["resume_bit_equal"], \
        "mid-run save->restore diverged from the uninterrupted run"
    assert sc["efficiency_at_8"] >= 0.7, \
        f"modeled 8-worker scaling efficiency {sc['efficiency_at_8']:.2f} " \
        "fell under the 0.7 bar"
