"""Partition-spec rules: parameter paths → PartitionSpec on (pod,data,model).

Strategy (DESIGN.md §5):
* ``model`` axis — tensor/expert parallel: attention heads, FFN hidden,
  expert dim, vocab dim of embeddings/heads.
* ``fsdp`` = the data axes (("pod","data") or ("data",)) — fully-sharded
  parameters on the *other* matrix dim; XLA all-gathers per layer inside the
  scan, which is what keeps 27B/35B models inside a v5e's HBM.
* every axis is applied **only when the dim is divisible** by the mesh axis
  size — archs with 2/4/8 KV heads simply replicate those dims over
  ``model`` instead of failing to lower.

Stage parameters are stacked (reps, ...); the leading dim is always
replicated (it is scanned over).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """Return ``axes`` if dim divides evenly over them, else None."""
    if isinstance(mesh, _NoModel) and (axes == "model"
                                       or (not isinstance(axes, str)
                                           and axes and "model" in axes)):
        return None
    return axes if axes and dim % _axis_size(mesh, axes) == 0 else None


class _NoModel:
    """Mesh proxy that vetoes the model axis (fsdp_only profile)."""

    def __init__(self, mesh: Mesh):
        self._mesh = mesh

    @property
    def shape(self):
        return self._mesh.shape

    @property
    def axis_names(self):
        return self._mesh.axis_names


def _leaf_spec(mesh: Mesh, path: Tuple[str, ...], shape: Tuple[int, ...],
               lead: int, use_model: bool = True) -> P:
    """Spec for one parameter; ``lead`` = number of stacked leading dims."""
    fs = fsdp_axes(mesh)
    if not use_model:
        # fsdp_only profile: tensor parallelism off — model axis becomes a
        # second pure-data axis (params replicated across it, batch over it)
        mesh = _NoModel(mesh)
    name = path[-1]
    parents = set(path)
    core = shape[lead:]
    nd = len(core)

    def spec(*axes):
        return P(*([None] * lead), *axes)

    if name == "embed":
        if nd == 3:   # audio (C, V, D)
            return spec(None, _fit(mesh, core[1], "model"),
                        _fit(mesh, core[2], fs))
        return spec(_fit(mesh, core[0], "model"), _fit(mesh, core[1], fs))
    if name == "lm_head":
        return spec(_fit(mesh, core[0], fs), _fit(mesh, core[1], "model"))
    if name == "heads":   # audio (C, D, V)
        return spec(None, _fit(mesh, core[1], fs),
                    _fit(mesh, core[2], "model"))
    if name in ("wq", "wk", "wv"):
        if nd == 3:                      # attention (D, H, hd)
            return spec(_fit(mesh, core[0], fs),
                        _fit(mesh, core[1], "model"), None)
        return spec(None, _fit(mesh, core[1], "model"))   # mLSTM (di, di)
    if name == "wo":                     # (H, hd, D)
        return spec(_fit(mesh, core[0], "model"), None,
                    _fit(mesh, core[2], fs))
    if name in ("bq", "bk", "bv"):       # (H, hd)
        return spec(_fit(mesh, core[0], "model"), None)
    if "moe" in parents and name == "router":
        return spec(_fit(mesh, core[0], fs), None)
    if "moe" in parents and name in ("w_gate", "w_up", "w_down") \
            and nd == 3:                 # experts (E, D|F, F|D)
        return spec(_fit(mesh, core[0], "model"), _fit(mesh, core[1], fs),
                    None)
    if name in ("w_gate", "w_up", "w_in"):   # (D, F)
        return spec(_fit(mesh, core[0], fs), _fit(mesh, core[1], "model"))
    if name == "w_down":                 # (F, D)
        return spec(_fit(mesh, core[0], "model"), _fit(mesh, core[1], fs))
    if name == "in_proj":                # (D|2D, X)
        return spec(_fit(mesh, core[0], fs), _fit(mesh, core[1], "model"))
    if name == "out_proj":               # (d_in, D)
        return spec(_fit(mesh, core[0], "model"), _fit(mesh, core[1], fs))
    if name == "conv_w":                 # (K, C)
        return spec(None, _fit(mesh, core[1], "model"))
    if name in ("conv_b", "norm_scale", "skip"):
        return spec(_fit(mesh, core[0], "model"))
    if name == "w_gates":                # mLSTM (d_in, 2H)
        return spec(_fit(mesh, core[0], "model"), None)
    if name in ("dt_bias", "a_log", "d_skip"):
        return spec(_fit(mesh, core[0], "model"))
    if name == "r":                      # sLSTM (4, H, hd, hd)
        # shard the output head_dim: the per-timestep gradient all-reduce
        # of dR (inside the recurrence scan) then moves only 1/model of the
        # bytes per device (§Perf xlstm iteration 2)
        return spec(None, _fit(mesh, core[1], "model"), None,
                    _fit(mesh, core[3], "model")
                    if not _fit(mesh, core[1], "model") else None)
    # norms, biases, small vectors: replicated
    return spec(*([None] * nd))


def _lead_dims(path) -> int:
    """Stage params are nested under (..., 'stages', i, j): stacked reps dim.

    Works for raw params and for optimizer-state trees that mirror them
    (e.g. ('m', 'stages', ...)).
    """
    return 1 if "stages" in path[:-1] else 0


def _walk(mesh: Mesh, tree, path: Tuple, use_model: bool) -> Any:
    if isinstance(tree, dict):
        return {k: _walk(mesh, v, path + (k,), use_model)
                for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(_walk(mesh, v, path + (str(i),), use_model)
                          for i, v in enumerate(tree))
    # leaf: ShapeDtypeStruct or array
    strpath = tuple(p for p in path if not p.isdigit())
    return _leaf_spec(mesh, strpath, tree.shape, _lead_dims(path), use_model)


def param_specs(mesh: Mesh, params_shapes, profile: str = "tp_fsdp") -> Any:
    """PartitionSpec pytree matching ``params_shapes`` (from eval_shape).

    ``profile``: "tp_fsdp" (default) shards over model+fsdp; "fsdp_only"
    drops tensor parallelism (small models where per-layer TP all-reduce
    dwarfs compute — §Perf hillclimb lever).
    """
    return _walk(mesh, params_shapes, (), profile != "fsdp_only")


def named(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(mesh: Mesh, cfg: ModelConfig, shape: InputShape,
                train: bool) -> Dict[str, P]:
    """Input shardings: batch over the data axes when divisible."""
    fs = fsdp_axes(mesh)
    bdim = _fit(mesh, shape.global_batch, fs)
    if train or shape.kind == "prefill":
        specs = {"tokens": P(bdim, None) if cfg.modality != "audio"
                 else P(bdim, None, None)}
        if cfg.modality == "vision":
            specs["vision_embeds"] = P(bdim, None, None)
        if train:
            specs["labels"] = (P(bdim, None) if cfg.modality != "audio"
                               else P(bdim, None, None))
        return specs
    # decode: tokens (B,) (+ (B,C) audio), pos (B,)
    return {"tokens": P(bdim) if cfg.modality != "audio" else P(bdim, None),
            "pos": P(bdim)}


def cache_specs(mesh: Mesh, cfg: ModelConfig, caches) -> Any:
    """Shard caches.

    * batch dim (index 1, after the stacked reps dim) over the data axes;
    * KV-cache tensors (reps, B, W, kv, hd): KV heads over ``model`` when
      divisible, otherwise the cache length W is sharded over ``model`` —
      MHA archs (musicgen kv=24, command-r kv=8) would otherwise replicate
      the entire cache on all 16 model ranks;
    * batch==1 (long-context): the cache length takes the data axes too.
    """
    from repro.models.attention import KVCache

    fs = fsdp_axes(mesh)

    def default_leaf(x):
        shp = x.shape
        if len(shp) < 2:
            return P(*([None] * len(shp)))
        baxis = _fit(mesh, shp[1], fs)
        rest = [None] * (len(shp) - 2)
        if baxis is None and len(shp) >= 3 and _fit(mesh, shp[2], fs):
            rest[0] = fs
        return P(None, baxis, *rest)

    def kv_cache(c: KVCache):
        reps, b, w, kv, hd = c.k.shape
        baxis = _fit(mesh, b, fs)
        waxes = []
        if baxis is None and _fit(mesh, w, fs):
            waxes.append(fs)
        if not _fit(mesh, kv, "model"):
            waxes.append("model")
        kvaxis = "model" if _fit(mesh, kv, "model") else None
        wspec = tuple(a for ws in waxes for a in
                      ((ws,) if isinstance(ws, str) else ws)) or None
        if wspec is not None and w % _axis_size(mesh, wspec) != 0:
            wspec = None
        kspec = P(None, baxis, wspec, kvaxis, None)
        return KVCache(k=kspec, v=kspec, slot_pos=P(None, baxis, wspec))

    def walk(node):
        if isinstance(node, KVCache):
            return kv_cache(node)
        if isinstance(node, tuple) and not hasattr(node, "_fields"):
            return tuple(walk(v) for v in node)
        if hasattr(node, "_fields"):    # other NamedTuple caches
            return type(node)(*(walk(v) for v in node))
        return default_leaf(node)

    return walk(caches)
