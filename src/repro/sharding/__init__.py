from repro.sharding.rules import (param_specs, batch_specs, cache_specs,
                                  named, fsdp_axes)
