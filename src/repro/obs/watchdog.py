"""ELBO-monotonicity watchdog: watch the paper's headline invariant.

IVI's selling point (§3 / Alg. 1) is that every incremental update —
with NO learning rate — monotonically increases the exact memoized ELBO
once every document has been visited. That is a production invariant, not
just a unit-test property: a bound decrease at runtime means a broken
memo (the eq. 4 subtract-old side lost sync), a quantization drift, or a
numerically degenerate E-step. ``ElboWatchdog`` records the per-update
memoized-bound sequence and flags any decrease beyond tolerance:

* ``observe(bound, step=, armed=)`` appends one reading. ``armed`` is
  whether the guarantee is in force — the engines pass
  ``init_frac == 0`` (the random-init mass fully retired, i.e. the first
  full pass is done; before that the bound may legitimately move down as
  random mass is swapped for real statistics). A violation is only ever
  raised between two **armed** readings.
* tolerance: the bound is a sum of ~|bound|-magnitude fp32 terms, so the
  comparison allows ``max(tol, rel_tol · |prev|)`` of rounding slack —
  the same slack the monotonicity property tests use.
* policy: ``"warn"`` emits an ``ElboMonotonicityWarning`` (and keeps
  counting); ``"raise"`` raises ``BoundMonotonicityError``. Either way
  the violation is recorded in ``violations`` and counted in the bundled
  metrics registry (``watchdog.violations``) when one is attached.
* cost: each check reads the **full memoized corpus bound** — an
  O(corpus) chunk read-through, deliberate and exact. ``check_every``
  prices it: the engines evaluate the bound every N-th update (N=1 for
  the paper-faithful per-update record; larger N for production cadence;
  0 = only when a bound is computed anyway, e.g. ``evaluate()``).

SVI has no such guarantee (it needs convergence monitoring instead —
the same ``observe`` stream without arming gives exactly that), so the
engines arm the watchdog on the IVI path only.

``NULL_WATCHDOG`` is the disabled instance the null telemetry carries.
"""
from __future__ import annotations

import math
import warnings
from typing import List, Optional


class BoundMonotonicityError(RuntimeError):
    """An armed IVI update decreased the memoized ELBO beyond tolerance."""


class ElboMonotonicityWarning(UserWarning):
    """Warn-policy counterpart of ``BoundMonotonicityError``."""


class NullElboWatchdog:
    """The disabled watchdog: never checks, never records."""

    enabled = False

    def should_check(self, step: int) -> bool:
        return False

    def observe(self, bound: float, *, step: Optional[int] = None,
                armed: bool = True) -> bool:
        return False

    def status(self) -> dict:
        return {"enabled": False}


NULL_WATCHDOG = NullElboWatchdog()

_POLICIES = ("warn", "raise")


class ElboWatchdog:
    """Monotonicity watchdog over an observed bound sequence (see module
    docstring)."""

    enabled = True

    def __init__(self, *, policy: str = "warn", tol: float = 5e-3,
                 rel_tol: float = 2e-6, check_every: int = 1,
                 metrics=None):
        if policy not in _POLICIES:
            raise ValueError(f"policy must be one of {_POLICIES}, "
                             f"got {policy!r}")
        if check_every < 0:
            raise ValueError("check_every must be >= 0")
        self.policy = policy
        self.tol = tol
        self.rel_tol = rel_tol
        self.check_every = check_every
        self.metrics = metrics
        self.history: List[dict] = []      # every observe() reading
        self.violations: List[dict] = []
        self._prev: Optional[float] = None
        self._prev_armed = False

    def should_check(self, step: int) -> bool:
        """Whether the engines should pay for a bound read at ``step``
        (a 1-based update counter)."""
        return self.check_every > 0 and step % self.check_every == 0

    def observe(self, bound: float, *, step: Optional[int] = None,
                armed: bool = True) -> bool:
        """Record one bound reading; returns True iff it violated.

        ``armed=False`` readings are recorded (they are the convergence
        trace for the non-guaranteed engines) but never enforced.
        """
        bound = float(bound)
        delta = None if self._prev is None else bound - self._prev
        reading = {"step": step, "bound": bound, "delta": delta,
                   "armed": bool(armed)}
        self.history.append(reading)
        violated = False
        if (armed and self._prev_armed and delta is not None
                and not math.isnan(bound)):
            slack = max(self.tol, self.rel_tol * abs(self._prev))
            if delta < -slack:
                violated = True
                self.violations.append(reading)
                if self.metrics is not None:
                    self.metrics.inc("watchdog.violations")
                msg = (f"IVI memoized ELBO decreased: {self._prev:.6f} -> "
                       f"{bound:.6f} (delta={delta:.3e}, slack={slack:.3e}"
                       f"{'' if step is None else f', update {step}'}) — "
                       "the eq. 4 monotonicity guarantee is broken "
                       "(memo out of sync, wire-dtype drift, or a "
                       "degenerate E-step)")
                if self.policy == "raise":
                    self._prev, self._prev_armed = bound, bool(armed)
                    raise BoundMonotonicityError(msg)
                warnings.warn(msg, ElboMonotonicityWarning, stacklevel=2)
        self._prev, self._prev_armed = bound, bool(armed)
        return violated

    # -- introspection ---------------------------------------------------
    @property
    def last_bound(self) -> Optional[float]:
        return self._prev

    def bound_tail(self, n: int = 5) -> List[float]:
        """The last ``n`` observed bounds (oldest first)."""
        return [r["bound"] for r in self.history[-n:]]

    def status(self) -> dict:
        armed_deltas = [r["delta"] for r in self.history
                        if r["armed"] and r["delta"] is not None]
        return {
            "enabled": True,
            "policy": self.policy,
            "checks": len(self.history),
            "armed_checks": sum(1 for r in self.history if r["armed"]),
            "violations": len(self.violations),
            "last_bound": self._prev,
            "min_armed_delta": (min(armed_deltas) if armed_deltas
                                else None),
            "ok": not self.violations,
        }
