"""Span/event tracing: a low-overhead structured run recorder.

``SpanRecorder`` captures nested wall-clock spans and point events from
any thread of the process (training host loop, the serving packer thread,
the D-IVI round driver) into an in-memory buffer of plain dicts:

* **spans** — ``begin(name, **attrs)`` / ``end(token)`` around a phase of
  work, or the ``with recorder.span(name):`` context-manager sugar.
  Nesting is tracked per thread (``depth``), so a trace viewer can
  reconstruct the call tree without parent ids.
* **device sync points** — jax dispatches asynchronously, so a span that
  closes right after a jitted call has measured *dispatch*, not compute.
  ``end(token, sync=arr)`` calls ``jax.block_until_ready(arr)`` before
  taking the end timestamp **iff** the recorder was built with
  ``device_sync=True``; the default leaves the pipeline asynchronous
  (measuring dispatch is the right thing inside the double-buffered
  serving loop, where a sync would serialize the overlap being measured).
* **events** — ``event(name, **attrs)``: zero-duration markers.

Export is JSONL (one record per line, ``dump_jsonl``; schema below) plus
a converter to the Chrome trace-event format, loadable in
``chrome://tracing`` / Perfetto (``to_chrome_trace`` /
``chrome_trace_from_jsonl``).

JSONL schema (``TRACE_SCHEMA``, guarded by ``validate_records``):

    {"type": "meta", "schema": "repro.obs.trace", "version": 1,
     "unix_time": <float>, "device_sync": <bool>}          # first line
    {"type": "span", "name": str, "ts_us": float, "dur_us": float,
     "tid": int, "depth": int, "attrs": {...}}
    {"type": "event", "name": str, "ts_us": float, "tid": int,
     "attrs": {...}}

Timestamps are microseconds relative to the recorder's construction
(``perf_counter_ns`` based — monotonic, immune to wall-clock steps).

The module-level ``NULL_TRACE`` is the disabled recorder: every method is
a no-op, ``span()`` returns one shared context-manager singleton, and no
record is ever allocated — the single-branch null object the instrumented
hot paths check against (``docs/observability.md``).

CLI: ``python -m repro.obs.trace --validate run.jsonl [--chrome out.json]``
validates a trace file against the schema (and optionally writes the
Chrome conversion), exiting non-zero on a malformed file — the CI guard
on the traced quickstart smoke.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

TRACE_SCHEMA = "repro.obs.trace"
TRACE_SCHEMA_VERSION = 1

# (name, attrs, depth, start_ns) — what ``begin`` hands to ``end``
SpanToken = Tuple[str, dict, int, int]


class _NullSpan:
    """Shared no-op context manager (one instance for the whole process)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class NullSpanRecorder:
    """The disabled recorder: true no-ops, zero allocations.

    ``span()`` hands back the process-wide ``NULL_SPAN`` singleton and
    ``begin()`` returns ``None`` — the instrumentation pattern
    ``tok = tel.trace.begin(...) if tel.enabled else None`` therefore
    allocates nothing at all on the disabled path
    (tests/test_obs.py::test_disabled_telemetry_is_noop).
    """

    enabled = False
    device_sync = False

    def span(self, name: str, **attrs) -> _NullSpan:
        return NULL_SPAN

    def begin(self, name: str, **attrs) -> None:
        return None

    def end(self, token, sync=None) -> None:
        pass

    def event(self, name: str, **attrs) -> None:
        pass

    @property
    def num_records(self) -> int:
        return 0

    @property
    def records(self) -> List[dict]:
        return []


NULL_TRACE = NullSpanRecorder()


class _Span:
    """Context-manager wrapper over a live recorder's begin/end pair."""

    __slots__ = ("_rec", "_token", "_sync")

    def __init__(self, rec: "SpanRecorder", token: SpanToken):
        self._rec = rec
        self._token = token
        self._sync = None

    def sync_on(self, arr):
        """Mark ``arr`` as this span's device sync point (see module
        docstring); returns ``arr`` so the call can wrap an expression."""
        self._sync = arr
        return arr

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc) -> bool:
        self._rec.end(self._token, sync=self._sync)
        return False


class SpanRecorder:
    """In-memory span/event recorder (see module docstring).

    Thread safety: records append to one list (atomic under the GIL);
    per-thread nesting depth lives in a ``threading.local``; thread ids
    are mapped to dense small ints under a lock on first sight.
    """

    enabled = True

    def __init__(self, *, device_sync: bool = False):
        self.device_sync = device_sync
        self._t0 = time.perf_counter_ns()
        self._unix0 = time.time()
        self._records: List[dict] = []
        self._tls = threading.local()
        self._tids: Dict[int, int] = {}
        self._lock = threading.Lock()

    # -- recording -------------------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def begin(self, name: str, **attrs) -> SpanToken:
        """Open a span; pass the returned token to ``end``."""
        depth = getattr(self._tls, "depth", 0)
        self._tls.depth = depth + 1
        return (name, attrs, depth, time.perf_counter_ns())

    def end(self, token: SpanToken, sync=None) -> None:
        """Close a span. With ``device_sync`` and a ``sync`` array/pytree,
        blocks until the device work is done before timestamping — the
        optional ``block_until_ready`` sync point."""
        if sync is not None and self.device_sync:
            import jax

            jax.block_until_ready(sync)
        t1 = time.perf_counter_ns()
        name, attrs, depth, t0 = token
        self._tls.depth = depth
        self._records.append({
            "type": "span", "name": name,
            "ts_us": (t0 - self._t0) / 1e3,
            "dur_us": (t1 - t0) / 1e3,
            "tid": self._tid(), "depth": depth, "attrs": attrs,
        })

    def span(self, name: str, **attrs) -> _Span:
        """``with recorder.span("phase"): ...`` sugar over begin/end."""
        return _Span(self, self.begin(name, **attrs))

    def event(self, name: str, **attrs) -> None:
        """A zero-duration point marker."""
        self._records.append({
            "type": "event", "name": name,
            "ts_us": (time.perf_counter_ns() - self._t0) / 1e3,
            "tid": self._tid(), "attrs": attrs,
        })

    # -- introspection / export ------------------------------------------
    @property
    def num_records(self) -> int:
        return len(self._records)

    @property
    def records(self) -> List[dict]:
        return self._records

    def meta(self) -> dict:
        return {"type": "meta", "schema": TRACE_SCHEMA,
                "version": TRACE_SCHEMA_VERSION,
                "unix_time": self._unix0, "device_sync": self.device_sync}

    def dump_jsonl(self, path: str) -> int:
        """Write the meta header + every record as JSONL; returns the
        record count (excluding the header)."""
        records = list(self._records)      # snapshot: threads may append
        with open(path, "w") as f:
            f.write(json.dumps(self.meta()) + "\n")
            for r in records:
                f.write(json.dumps(r) + "\n")
        return len(records)


# ---------------------------------------------------------------------------
# JSONL load / schema validation
# ---------------------------------------------------------------------------

def load_jsonl(path: str) -> Tuple[dict, List[dict]]:
    """Read a trace file → (meta header, records)."""
    with open(path) as f:
        lines = [json.loads(line) for line in f if line.strip()]
    if not lines or lines[0].get("type") != "meta":
        raise ValueError(f"{path!r}: first line is not a trace meta header")
    return lines[0], lines[1:]


_SPAN_KEYS = {"type": str, "name": str, "ts_us": (int, float),
              "dur_us": (int, float), "tid": int, "depth": int,
              "attrs": dict}
_EVENT_KEYS = {"type": str, "name": str, "ts_us": (int, float), "tid": int,
               "attrs": dict}


def validate_records(meta: dict, records: Iterable[dict]) -> int:
    """Schema-check a loaded trace; returns the record count or raises
    ``ValueError`` naming the first offending record."""
    if meta.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"unknown trace schema {meta.get('schema')!r}")
    if meta.get("version") != TRACE_SCHEMA_VERSION:
        raise ValueError(f"unsupported trace schema version "
                         f"{meta.get('version')!r}")
    n = 0
    for i, r in enumerate(records):
        kind = r.get("type")
        keys = {"span": _SPAN_KEYS, "event": _EVENT_KEYS}.get(kind)
        if keys is None:
            raise ValueError(f"record {i}: unknown type {kind!r}")
        for key, typ in keys.items():
            if key not in r:
                raise ValueError(f"record {i} ({kind}): missing {key!r}")
            if not isinstance(r[key], typ):
                raise ValueError(
                    f"record {i} ({kind}): {key}={r[key]!r} is not "
                    f"{typ}")
        if kind == "span" and r["dur_us"] < 0:
            raise ValueError(f"record {i}: negative span duration")
        n += 1
    return n


def validate_jsonl(path: str) -> int:
    """Load + schema-check a trace file; returns the record count."""
    meta, records = load_jsonl(path)
    return validate_records(meta, records)


# ---------------------------------------------------------------------------
# Chrome trace-event conversion (chrome://tracing / Perfetto)
# ---------------------------------------------------------------------------

def to_chrome_trace(records: Iterable[dict],
                    meta: Optional[dict] = None) -> dict:
    """Records → the Chrome trace-event JSON object.

    Spans become complete ("X") events, point events become instants
    ("i"); timestamps are already microseconds, the unit Chrome expects.
    One trace record maps to exactly one ``traceEvents`` entry, so the
    JSONL → Chrome conversion round-trips count-exactly (the CI check).
    """
    events = []
    for r in records:
        if r["type"] == "span":
            events.append({"name": r["name"], "ph": "X", "ts": r["ts_us"],
                           "dur": r["dur_us"], "pid": 0, "tid": r["tid"],
                           "args": dict(r["attrs"], depth=r["depth"])})
        elif r["type"] == "event":
            events.append({"name": r["name"], "ph": "i", "s": "t",
                           "ts": r["ts_us"], "pid": 0, "tid": r["tid"],
                           "args": r["attrs"]})
        else:
            raise ValueError(f"unknown record type {r['type']!r}")
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if meta is not None:
        out["otherData"] = {k: meta[k] for k in ("schema", "version",
                                                 "unix_time", "device_sync")
                            if k in meta}
    return out


def chrome_trace_from_jsonl(src: str, dst: str) -> int:
    """Convert a trace JSONL file to a Chrome trace JSON file; returns
    the event count (== the JSONL record count)."""
    meta, records = load_jsonl(src)
    validate_records(meta, records)
    chrome = to_chrome_trace(records, meta)
    with open(dst, "w") as f:
        json.dump(chrome, f)
    return len(chrome["traceEvents"])


def _main() -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="validate a repro.obs trace JSONL file "
                    "(and optionally convert it to Chrome trace format)")
    ap.add_argument("--validate", required=True, metavar="TRACE_JSONL")
    ap.add_argument("--chrome", default=None, metavar="OUT_JSON",
                    help="also write the chrome://tracing conversion here")
    args = ap.parse_args()
    try:
        n = validate_jsonl(args.validate)
    except (ValueError, OSError) as e:
        print(f"[FAIL] {args.validate}: {e}")
        return 1
    print(f"[OK ] {args.validate}: {n} records, schema "
          f"{TRACE_SCHEMA} v{TRACE_SCHEMA_VERSION}")
    if args.chrome:
        m = chrome_trace_from_jsonl(args.validate, args.chrome)
        if m != n:
            print(f"[FAIL] chrome conversion dropped records "
                  f"({m} events != {n} records)")
            return 1
        print(f"[OK ] {args.chrome}: {m} trace events "
              f"(count-exact round-trip)")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
