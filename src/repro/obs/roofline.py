"""Measured-vs-modeled accounting: the roofline check.

Nearly every perf claim in BENCH_estep.json / BENCH_serve.json is a
*structural model* (HBM bytes counted from the Pallas grid, divided by a
hardware stream rate). A model is only trustworthy while measurement
agrees with it — this module is the join:

* ``spans_by_name`` aggregates a ``SpanRecorder``'s records per span name
  (count, total, min, mean) — the **measured** side. For kernel timings
  the recorder should run with ``device_sync=True`` so a span measures
  compute, not dispatch; ``min_s`` is the aggregate the check uses
  (minimum over repetitions is the standard noise-robust estimator for
  a deterministic workload).
* ``roofline_check`` joins measured seconds against each kernel's modeled
  HBM bytes: ``modeled_s = bytes / bandwidth`` is the memory-bound time,
  and ``measured_vs_modeled = measured_s / modeled_s`` should sit near
  1.0 for a genuinely memory-bound kernel on the modeled hardware. A
  ratio outside ``band`` flags the kernel: **> band** means the kernel is
  slower than its memory traffic explains (it is NOT memory-bound there —
  compute- or overhead-dominated, and the bytes model must not be used to
  claim speedups at that shape); **< band** means the model over-counts
  bytes (the kernel reuses more than the model credits).
* ``proxy_regime``: on this CPU container the Pallas kernels run in
  interpret mode, so measured times are *Python* times and disagree with
  the TPU HBM model by construction. The flag records that the measured
  side is a proxy — the record is still emitted (trend tracking; the join
  machinery is what CI exercises) but ``agrees`` is expected False and is
  **not** a CI bar in that regime. On real TPU hardware the same call
  becomes the model-validation gate.

``benchmarks/obs_bench.py`` drives this against the E-step kernels'
``modeled_estep_hbm_bytes`` and emits ``BENCH_obs.json``. The hardware
table lives HERE (``HW`` / ``HBM_GB`` — v5e figures): this module is the
canonical home the seed roofline harness (``benchmarks/roofline.py``)
now re-exports from, closing the "seed roofline.py is unused" loop — the
seed harness renders the dry-run sweep AND these checks' BENCH_obs.json
records through one table.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# v5e hardware constants — the ONE table every roofline consumer shares
# (the seed dry-run renderer, obs_bench's measured-vs-modeled join, and
# kernel_bench's modeled stream rates all import from here).
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}
HBM_GB = 16.0   # v5e per-chip HBM


def spans_by_name(records: Iterable[dict]) -> Dict[str, dict]:
    """Aggregate trace records per span name → measured-seconds summary.

    Accepts the raw record dicts of a ``SpanRecorder`` (or a loaded trace
    JSONL); non-span records are ignored. Durations convert from the
    trace's microseconds to seconds.
    """
    out: Dict[str, dict] = {}
    for r in records:
        if r.get("type") != "span":
            continue
        agg = out.setdefault(r["name"], {"count": 0, "total_s": 0.0,
                                         "min_s": math.inf})
        dur_s = r["dur_us"] / 1e6
        agg["count"] += 1
        agg["total_s"] += dur_s
        if dur_s < agg["min_s"]:
            agg["min_s"] = dur_s
    for agg in out.values():
        agg["mean_s"] = agg["total_s"] / agg["count"]
    return out


def roofline_check(records: Sequence[dict], *, hbm_gbps: float,
                   band: Tuple[float, float] = (0.5, 2.0),
                   proxy_regime: bool = False) -> dict:
    """Join measured kernel seconds against modeled HBM bytes.

    ``records``: ``[{"name": str, "measured_s": float,
    "modeled_hbm_bytes": int|float, ...}]`` — extra keys pass through.
    Returns a summary dict with per-record verdicts (see module
    docstring for how to read the flags).
    """
    if hbm_gbps <= 0:
        raise ValueError("hbm_gbps must be positive")
    lo, hi = band
    if not (0 < lo < hi):
        raise ValueError(f"band must be 0 < lo < hi, got {band}")
    out: List[dict] = []
    for r in records:
        modeled_s = float(r["modeled_hbm_bytes"]) / (hbm_gbps * 1e9)
        measured_s = float(r["measured_s"])
        ratio = measured_s / modeled_s if modeled_s > 0 else math.inf
        out.append({
            **r,
            "modeled_s": modeled_s,
            "measured_vs_modeled": ratio,
            "agrees_with_memory_bound_model": lo <= ratio <= hi,
            "verdict": ("memory_bound" if lo <= ratio <= hi else
                        "slower_than_memory_model" if ratio > hi else
                        "model_overcounts_bytes"),
        })
    flagged = [r["name"] for r in out
               if not r["agrees_with_memory_bound_model"]]
    return {
        "hbm_gbps": hbm_gbps,
        "band": [lo, hi],
        "proxy_regime": proxy_regime,
        "records": out,
        "n_records": len(out),
        "n_agree": len(out) - len(flagged),
        "flagged": flagged,
    }


def roofline_from_trace(trace_records: Iterable[dict],
                        modeled_bytes: Dict[str, float], *,
                        hbm_gbps: float,
                        band: Tuple[float, float] = (0.5, 2.0),
                        proxy_regime: bool = False,
                        use: str = "min_s") -> dict:
    """``roofline_check`` fed straight from a span trace.

    ``modeled_bytes`` maps span names to their modeled HBM bytes; span
    names absent from the trace are skipped (and listed under
    ``missing_spans`` so a renamed instrumentation point cannot silently
    empty the check).
    """
    agg = spans_by_name(trace_records)
    rows, missing = [], []
    for name, bytes_ in modeled_bytes.items():
        if name not in agg:
            missing.append(name)
            continue
        rows.append({"name": name, "measured_s": agg[name][use],
                     "measured_calls": agg[name]["count"],
                     "modeled_hbm_bytes": bytes_})
    out = roofline_check(rows, hbm_gbps=hbm_gbps, band=band,
                         proxy_regime=proxy_regime)
    out["missing_spans"] = missing
    return out
