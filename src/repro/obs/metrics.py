"""Process-wide metrics registry: counters, gauges, histograms — with labels.

One ``MetricsRegistry`` instance rides inside a ``Telemetry`` bundle and
collects the run's operational numbers from every instrumented layer:

* **counters** (``inc``) — monotone totals: tokens ingested, documents
  trained/served, batches per bucket width, jit-cache hits/misses,
  watchdog violations;
* **gauges** (``set_gauge``) — last-written values: per-bucket pad
  fraction, memo-store resident bytes, effective-topics count;
* **histograms** (``observe``) — full value distributions: request
  latency, per-phase batch timings, double-buffer queue depth. Raw
  observations are kept (bounded by ``max_samples`` per series via
  reservoir-free head-truncation: count/sum/min/max stay exact, the
  percentile basis is the first ``max_samples`` values), so the exported
  percentiles are real percentiles, not bucket interpolations — this is
  what replaced the ad-hoc percentile list in ``serve_lda.py``.

Labels are kwargs: ``reg.inc("serve.batches", width=64)`` — each distinct
label set is its own series. ``snapshot()`` renders everything to a
JSON-able dict (``dump_json`` writes it), with p50/p95/p99 precomputed
for histograms.

``NULL_METRICS`` is the disabled registry: every method is a no-op and
reads return empties/NaN — the null object the hot paths branch on.

Thread safety: every mutation takes the registry lock (mutations are tiny
— a dict lookup and a float add — so the lock is uncontended in
practice; the serving packer thread and the consumer thread both write).
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[str, Tuple[Tuple[str, object], ...]]


def _key(name: str, labels: dict) -> LabelKey:
    return (name, tuple(sorted(labels.items())) if labels else ())


class NullMetrics:
    """The disabled registry: no-op writes, empty reads, no allocations."""

    enabled = False

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        pass

    def set_gauge(self, name: str, value: float, **labels) -> None:
        pass

    def observe(self, name: str, value: float, **labels) -> None:
        pass

    def value(self, name: str, **labels) -> float:
        return 0.0

    def total(self, name: str) -> float:
        return 0.0

    def percentiles(self, name: str, ps: Sequence[int] = (50, 95, 99),
                    **labels) -> Dict[str, float]:
        return {f"p{p}": float("nan") for p in ps}

    def snapshot(self) -> dict:
        return {"counters": [], "gauges": [], "histograms": []}


NULL_METRICS = NullMetrics()


class _Hist:
    __slots__ = ("values", "count", "total", "vmin", "vmax")

    def __init__(self):
        self.values: List[float] = []
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def add(self, v: float, max_samples: int) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if len(self.values) < max_samples:
            self.values.append(v)


class MetricsRegistry:
    """Labelled counters / gauges / histograms (see module docstring)."""

    enabled = True

    def __init__(self, *, max_samples: int = 100_000):
        self.max_samples = max_samples
        self._lock = threading.Lock()
        self._counters: Dict[LabelKey, float] = {}
        self._gauges: Dict[LabelKey, float] = {}
        self._hists: Dict[LabelKey, _Hist] = {}

    # -- writes ----------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = _Hist()
            h.add(float(value), self.max_samples)

    # -- reads -----------------------------------------------------------
    def value(self, name: str, **labels) -> float:
        """One series' counter total or gauge value (0.0 if unwritten)."""
        k = _key(name, labels)
        with self._lock:
            if k in self._counters:
                return self._counters[k]
            return self._gauges.get(k, 0.0)

    def total(self, name: str) -> float:
        """A counter summed across all of its label sets."""
        with self._lock:
            return sum(v for (n, _), v in self._counters.items()
                       if n == name)

    def histogram_values(self, name: str, **labels) -> List[float]:
        with self._lock:
            h = self._hists.get(_key(name, labels))
            return list(h.values) if h is not None else []

    def percentiles(self, name: str, ps: Sequence[int] = (50, 95, 99),
                    **labels) -> Dict[str, float]:
        """Real percentiles over a histogram series; NaNs when the series
        has no observations (callers skip the report row — the
        NaN-on-empty contract ``serve_lda`` relies on)."""
        vals = sorted(self.histogram_values(name, **labels))
        if not vals:
            return {f"p{p}": float("nan") for p in ps}
        out = {}
        for p in ps:
            # linear interpolation between closest ranks (numpy default)
            idx = (len(vals) - 1) * p / 100.0
            lo, hi = int(math.floor(idx)), int(math.ceil(idx))
            frac = idx - lo
            out[f"p{p}"] = vals[lo] * (1 - frac) + vals[hi] * frac
        return out

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Everything, JSON-able: the ``--metrics-json`` payload."""
        with self._lock:
            counters = [{"name": n, "labels": dict(lb), "value": v}
                        for (n, lb), v in sorted(self._counters.items())]
            gauges = [{"name": n, "labels": dict(lb), "value": v}
                      for (n, lb), v in sorted(self._gauges.items())]
            hists = []
            for (n, lb), h in sorted(self._hists.items(),
                                     key=lambda kv: kv[0]):
                hists.append({
                    "name": n, "labels": dict(lb), "count": h.count,
                    "sum": h.total,
                    "min": h.vmin if h.count else float("nan"),
                    "max": h.vmax if h.count else float("nan"),
                    "sampled": len(h.values),
                })
        for rec in hists:
            rec.update(self.percentiles(rec["name"], **rec["labels"]))
        return {"counters": counters, "gauges": gauges, "histograms": hists}

    def dump_json(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=2)
        return snap
