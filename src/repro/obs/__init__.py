"""repro.obs — structured run telemetry for the IVI/LDA stack.

One ``Telemetry`` bundle carries the three observers every instrumented
layer shares:

* ``trace`` — a :class:`~repro.obs.trace.SpanRecorder` (nested spans +
  instant events, JSONL export, Chrome-trace conversion);
* ``metrics`` — a :class:`~repro.obs.metrics.MetricsRegistry`
  (labelled counters / gauges / histograms);
* ``watchdog`` — an :class:`~repro.obs.watchdog.ElboWatchdog`
  (the paper's monotone-memoized-ELBO invariant, enforced at runtime
  on the IVI path).

The disabled state is the **null-object** ``NULL_TELEMETRY`` singleton:
all three components are module-level null objects whose methods are
no-ops, and ``enabled`` is False so hot paths pay exactly one attribute
check + branch (``if tel.enabled: ...``) and allocate nothing. This is
what keeps the PR-3/PR-5 bit-equality and resume guarantees untouched
when telemetry is off — the off path executes the same instructions as
before, modulo that single branch.

``as_telemetry`` is the facade-level coercion::

    as_telemetry(None)       -> NULL_TELEMETRY       (default: off)
    as_telemetry(False)      -> NULL_TELEMETRY
    as_telemetry(True)       -> Telemetry(...)        full live bundle
    as_telemetry(bundle)     -> bundle                (pass-through)

so ``LDA(cfg, telemetry=True)`` turns everything on with defaults while
power users hand in a pre-configured bundle (e.g. a ``raise``-policy
watchdog, or a ``device_sync=True`` recorder for kernel benchmarking).

See ``docs/observability.md`` for the span taxonomy, metric names, the
trace file schema, and how to read the roofline check.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .metrics import NULL_METRICS, MetricsRegistry, NullMetrics
from .roofline import roofline_check, roofline_from_trace, spans_by_name
from .trace import (
    NULL_TRACE,
    NullSpanRecorder,
    SpanRecorder,
    chrome_trace_from_jsonl,
    load_jsonl,
    to_chrome_trace,
    validate_jsonl,
    validate_records,
)
from .watchdog import (
    NULL_WATCHDOG,
    BoundMonotonicityError,
    ElboMonotonicityWarning,
    ElboWatchdog,
    NullElboWatchdog,
)

__all__ = [
    "Telemetry", "NULL_TELEMETRY", "as_telemetry",
    "SpanRecorder", "NullSpanRecorder", "NULL_TRACE",
    "load_jsonl", "validate_records", "validate_jsonl",
    "to_chrome_trace", "chrome_trace_from_jsonl",
    "MetricsRegistry", "NullMetrics", "NULL_METRICS",
    "ElboWatchdog", "NullElboWatchdog", "NULL_WATCHDOG",
    "BoundMonotonicityError", "ElboMonotonicityWarning",
    "roofline_check", "roofline_from_trace", "spans_by_name",
]


@dataclass
class Telemetry:
    """The bundle an instrumented layer receives (see module docstring).

    ``enabled`` is the hot-path gate: instrumentation must branch on it
    once and do nothing when False. The live constructor wires the
    watchdog's violation counter into the bundled registry when both are
    live and the watchdog wasn't given its own.
    """

    trace: object = field(default_factory=SpanRecorder)
    metrics: object = field(default_factory=MetricsRegistry)
    # check_every=0: the default watchdog only observes bounds that are
    # computed anyway (evaluate()) — a per-update check is an O(corpus)
    # memoized-bound read, which the caller must opt into explicitly
    # (ElboWatchdog(check_every=1), the paper-faithful cadence)
    watchdog: object = field(
        default_factory=lambda: ElboWatchdog(check_every=0))
    enabled: bool = True

    def __post_init__(self):
        wd = self.watchdog
        if (getattr(wd, "enabled", False)
                and getattr(wd, "metrics", None) is None
                and getattr(self.metrics, "enabled", False)):
            wd.metrics = self.metrics

    def summary(self) -> dict:
        """A JSON-able roll-up: metrics snapshot + watchdog status +
        trace size — what ``examples/quickstart.py`` prints."""
        return {
            "metrics": self.metrics.snapshot(),
            "watchdog": self.watchdog.status(),
            "trace_records": getattr(self.trace, "num_records", 0),
        }


NULL_TELEMETRY = Telemetry(trace=NULL_TRACE, metrics=NULL_METRICS,
                           watchdog=NULL_WATCHDOG, enabled=False)


def as_telemetry(t) -> Telemetry:
    """Coerce a user-facing ``telemetry=`` argument to a bundle."""
    if t is None or t is False:
        return NULL_TELEMETRY
    if t is True:
        return Telemetry()
    if isinstance(t, Telemetry):
        return t
    raise TypeError(
        "telemetry must be None/False (off), True (defaults), or a "
        f"repro.obs.Telemetry bundle, got {type(t).__name__}")
