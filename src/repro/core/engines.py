"""Single-host inference engines for LDA: MVI, SVI, IVI, S-IVI.

All four consume the E-step through the ``EStepBackend`` contract
(`repro.core.estep`) and the incremental engines access their π memos
through the pluggable ``MemoStore`` (`repro.core.memo`); they differ only
in how the global topic-word parameter λ is updated — exactly the contrast
the paper draws:

* **MVI**  (batch, Blei et al. 2003): λ = β₀ + Σ_d s_d after a full pass.
* **SVI**  (Hoffman et al. 2013, eq. 3): λ ← (1−ρ_t)λ + ρ_t(β₀ + (D/|B|)·s_B).
* **IVI**  (this paper, eq. 4 / Alg. 1): memoize per-document π; maintain the
  exact accumulator ⟨m_vk⟩ by subtract-old/add-new; λ = β₀ + ⟨m_vk⟩.
  No learning rate; monotone in the (memoized) ELBO once every document
  has been visited.
* **S-IVI** (eq. 5): the IVI correction inside a Robbins–Monro average:
  λ ← (1−ρ_t)λ + ρ_t(β₀ + ⟨m_vk⟩⁺). SAG-like; amenable to distribution.

Random-initialisation mass: the paper initialises β randomly (Alg. 1 l.1).
For the incremental engines we carry that mass explicitly (``init_mass``)
and retire each document's pro-rata share the first time it is visited, so
after one full pass ⟨m_vk⟩ == Σ_d s_d exactly and the monotonicity guarantee
is exact (cf. Neal & Hinton 1998 discussion of incremental-EM start-up).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bound import (elbo_collapsed, elbo_collapsed_stream,
                              elbo_memoized_store, elbo_memoized_stream)
from repro.core import estep as estep_mod
from repro.core.estep import BowBatch, CSRTokenBatch, estep, get_backend
from repro.core.math import exp_dirichlet_expectation
from repro.core.memo import MemoStore, make_memo_store
from repro.core.metrics import effective_topics
from repro.core.predictive import log_predictive, split_heldout
from repro.core.types import (Corpus, GlobalState, LDAConfig, Memo,
                              init_global_state)
from repro.obs import NULL_TELEMETRY, as_telemetry

# The canonical global-state constructor set lives in ``repro.core.types``;
# these aliases keep the historical engine-level names working everywhere
# (single-host and ``repro.dist`` both build state through them).
EngineState = GlobalState
init_engine_state = init_global_state


# ---------------------------------------------------------------------------
# MVI — batch coordinate ascent
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(5, 6))
def mvi_scan(cfg: LDAConfig, eb: jax.Array, ids_b: jax.Array,
             cnts_b: jax.Array, doc_idx_b: jax.Array, gamma_buf: jax.Array,
             sstats: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Scan the E-step over stacked batches, accumulating Σ_d s_d.

    ids_b/cnts_b/doc_idx_b: (num_batches, B, ...). γ persists across epochs
    in ``gamma_buf`` (D+1, K): each document's E-step resumes from
    α₀ + Σ_l cnt·π of its previous visit — proper batch coordinate ascent
    in the sense of Neal & Hinton (1998), and the *same* warm-start
    reconstruction the incremental engines use. Without this, a
    ``estep_max_iters``-truncated E-step restarts from scratch every epoch
    while IVI resumes from its memo, and the two full-batch trajectories
    drift apart for reasons that have nothing to do with the incremental
    bookkeeping (see test_fullbatch_ivi_equals_mvi). Row D of ``gamma_buf``
    is the sentinel scratch slot the tail batch's padding writes to.
    """

    def body(carry, batch):
        acc, gbuf = carry
        ids, cnts, idx = batch
        res = estep(cfg, eb, ids, cnts, gbuf[idx])
        gbuf = gbuf.at[idx].set(
            cfg.alpha0 + jnp.einsum("blk,bl->bk", res.pi, cnts))
        return (acc + res.sstats, gbuf), None

    (sstats, gamma_buf), _ = jax.lax.scan(
        body, (sstats, gamma_buf), (ids_b, cnts_b, doc_idx_b))
    return sstats, gamma_buf


# ---------------------------------------------------------------------------
# SVI — stochastic natural gradient (eq. 3)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def svi_step(cfg: LDAConfig, state: EngineState, ids: jax.Array,
             cnts: jax.Array, num_docs_total: jax.Array) -> EngineState:
    eb = exp_dirichlet_expectation(state.lam, axis=0)
    res = estep(cfg, eb, ids, cnts)
    scale = num_docs_total / ids.shape[0]
    lam_hat = cfg.beta0 + scale * res.sstats
    rho = cfg.rho(state.t + 1)
    lam = (1.0 - rho) * state.lam + rho * lam_hat
    return dataclasses.replace(state, lam=lam, t=state.t + 1)


@partial(jax.jit, static_argnames=("cfg", "num_docs"), donate_argnums=(1,))
def svi_step_csr(cfg: LDAConfig, state: EngineState, ids: jax.Array,
                 cnts: jax.Array, segs: jax.Array, batch_docs: jax.Array,
                 num_docs_total: jax.Array, *,
                 num_docs: int) -> EngineState:
    """Eq. 3 on a flat CSR token batch.

    ``num_docs`` is the static segment-id capacity (the engine pads it to
    ``batch_size``, so every batch — full, pre-emit-short or epoch tail —
    hits one compiled entry); ``batch_docs`` is the traced live-document
    count the natural-gradient scale divides by. Phantom padding docs own
    zero tokens, so they contribute exactly nothing to the sstats.
    """
    eb = exp_dirichlet_expectation(state.lam, axis=0)
    res = get_backend(cfg.estep_backend).solve_tokens(
        cfg, eb, CSRTokenBatch(ids, cnts, segs), num_docs=num_docs)
    scale = num_docs_total / batch_docs
    lam_hat = cfg.beta0 + scale * res.sstats
    rho = cfg.rho(state.t + 1)
    lam = (1.0 - rho) * state.lam + rho * lam_hat
    return dataclasses.replace(state, lam=lam, t=state.t + 1)


# ---------------------------------------------------------------------------
# IVI / S-IVI — incremental updates (eqs. 4 & 5)
# ---------------------------------------------------------------------------

def memo_correction(cfg: LDAConfig, eb: jax.Array, ids: jax.Array,
                    cnts: jax.Array, old_pi: jax.Array,
                    visited_rows: jax.Array, pi_dtype: str = "float32"):
    """E-step + subtract-old/add-new core shared by IVI, S-IVI and D-IVI.

    Dispatches to ``cfg.estep_backend``'s ``solve_correction`` — the jnp
    backends scatter the token-aligned delta, the Pallas backend fuses the
    whole thing into its kernels. The distributed engine (``repro.dist``)
    calls this same function for its workers, which is what keeps the
    single-host and distributed paths numerically interchangeable
    (test_divi_single_worker_round_equals_sivi_step).

    Returns (correction (V, K), first-visit word count, EStepResult).
    """
    return get_backend(cfg.estep_backend).solve_correction(
        cfg, eb, BowBatch(ids, cnts), old_pi, visited_rows, pi_dtype)


def retire_init_frac(init_frac: jax.Array, words_first: jax.Array,
                     num_words_total: jax.Array) -> jax.Array:
    """Retire the first-visit words' pro-rata share of the random-init mass.

    Snaps the fp32 subtraction residue to an exact zero once every document
    has been visited, so λ = β₀ + ⟨m_vk⟩ holds exactly afterwards (eq. 4).
    """
    frac = jnp.maximum(init_frac - words_first / num_words_total, 0.0)
    return jnp.where(frac < 1e-6, 0.0, frac)


def sivi_global_update(cfg: LDAConfig, state, corr: jax.Array,
                       frac: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Eq. 5 global step: λ ← (1−ρ_t)λ + ρ_t(β₀ + ⟨m_vk⟩⁺ + frac·init_mass).

    Elementwise in V, so it applies unchanged to the model-sharded rows of
    ``repro.dist`` — keeping the single-host and distributed master updates
    one code path. Returns (λ, ⟨m_vk⟩⁺); the caller bumps ``t``.
    """
    m_vk = state.m_vk + corr
    lam_hat = cfg.beta0 + m_vk + frac * state.init_mass
    rho = cfg.rho(state.t + 1)
    lam = (1.0 - rho) * state.lam + rho * lam_hat
    return lam, m_vk


def _incremental_core(cfg: LDAConfig, averaged: bool, state: EngineState,
                      ids: jax.Array, cnts: jax.Array, old_pi: jax.Array,
                      visited: jax.Array, num_words_total: jax.Array,
                      pi_dtype: str):
    """THE eq. 4 / eq. 5 update — every incremental entry point wraps it."""
    eb = exp_dirichlet_expectation(state.lam, axis=0)
    corr, words_first, res = memo_correction(cfg, eb, ids, cnts, old_pi,
                                             visited, pi_dtype)
    frac = retire_init_frac(state.init_frac, words_first, num_words_total)
    if averaged:
        lam, m_vk = sivi_global_update(cfg, state, corr, frac)
    else:
        m_vk = state.m_vk + corr
        lam = cfg.beta0 + m_vk + frac * state.init_mass
    state = dataclasses.replace(state, lam=lam, m_vk=m_vk, init_frac=frac,
                                t=state.t + 1)
    return state, res, eb


@partial(jax.jit, static_argnames=("cfg", "averaged", "pi_dtype"),
         donate_argnums=(2, 5))
def incremental_update(cfg: LDAConfig, averaged: bool, state: EngineState,
                       ids: jax.Array, cnts: jax.Array, old_pi: jax.Array,
                       visited: jax.Array, num_words_total: jax.Array,
                       pi_dtype: str = "float32"):
    """One IVI (``averaged=False``, eq. 4) or S-IVI (eq. 5) global update.

    Pure in the memo: takes the gathered (π_old, visited) rows from a
    ``MemoStore`` and returns the new π for the host to write back —
    the store itself never crosses the jit boundary, which is what lets
    the bf16-chunked and γ-only stores live in host RAM. ``pi_dtype`` is
    the store's wire dtype: π is rounded through it before the add-new
    scatter so ⟨m_vk⟩ stays bit-consistent with the store's contents.

    Returns (state, π_new (B, L, K), Eφ) — Eφ so γ-only stores can
    snapshot the λ-epoch the E-step ran against.
    """
    state, res, eb = _incremental_core(cfg, averaged, state, ids, cnts,
                                       old_pi, visited, num_words_total,
                                       pi_dtype)
    return state, res.pi, eb


def _csr_gather_flat(old_pi: jax.Array, ix: jax.Array) -> jax.Array:
    """Doc-aligned memo rows (B, W, K) → token-aligned (T, K) via the
    host-built flat index; padding tokens carry the sentinel index B·W,
    which lands on the appended zero row."""
    b, w, k = old_pi.shape
    flat = jnp.concatenate([old_pi.reshape(b * w, k),
                            jnp.zeros((1, k), old_pi.dtype)])
    return flat[ix]


def _csr_scatter_flat(pi: jax.Array, ix: jax.Array, b: int,
                      w: int) -> jax.Array:
    """Inverse of ``_csr_gather_flat``: token-aligned π back onto the
    (B, W, K) memo wire. Padding tokens all target the sentinel row,
    which the slice drops; memo slots no token maps to stay zero —
    inert, since every memo consumer weights π by the (zero) count."""
    k = pi.shape[-1]
    buf = jnp.zeros((b * w + 1, k), pi.dtype)
    return buf.at[ix].set(pi)[: b * w].reshape(b, w, k)


@partial(jax.jit, static_argnames=("cfg", "averaged", "pi_dtype"),
         donate_argnums=(2,))
def incremental_update_csr(cfg: LDAConfig, averaged: bool,
                           state: EngineState, ids: jax.Array,
                           cnts: jax.Array, segs: jax.Array, ix: jax.Array,
                           old_pi: jax.Array, visited: jax.Array,
                           num_words_total: jax.Array,
                           pi_dtype: str = "float32"):
    """``incremental_update`` on a flat CSR token batch.

    Same eq. 4 / eq. 5 algebra, same quantize-then-rescatter memo wire —
    only the (B, L) token axes are replaced by one (T,) stream plus the
    flat index ``ix`` that maps each token slot onto its (doc, position)
    memo cell. The memo stays doc-aligned (B, W, K): old π rows are
    gathered through ``ix`` on the way in and the new π is scattered back
    through it on the way out, so every ``MemoStore`` works unchanged.
    """
    b, w, _ = old_pi.shape
    eb = exp_dirichlet_expectation(state.lam, axis=0)
    old_flat = _csr_gather_flat(old_pi, ix)
    corr, words_first, res = get_backend(
        cfg.estep_backend).solve_correction_tokens(
            cfg, eb, CSRTokenBatch(ids, cnts, segs), old_flat, visited,
            pi_dtype)
    frac = retire_init_frac(state.init_frac, words_first, num_words_total)
    if averaged:
        lam, m_vk = sivi_global_update(cfg, state, corr, frac)
    else:
        m_vk = state.m_vk + corr
        lam = cfg.beta0 + m_vk + frac * state.init_mass
    state = dataclasses.replace(state, lam=lam, m_vk=m_vk, init_frac=frac,
                                t=state.t + 1)
    new_pi = _csr_scatter_flat(res.pi, ix, b, w)
    return state, new_pi, eb


def _raw_memo_step(cfg: LDAConfig, averaged: bool, state: EngineState,
                   memo: Memo, ids: jax.Array, cnts: jax.Array,
                   doc_idx: jax.Array, num_words_total: jax.Array):
    """Raw-``Memo`` convenience wrapper over the same core."""
    state, res, _ = _incremental_core(
        cfg, averaged, state, ids, cnts, memo.pi[doc_idx],
        memo.visited[doc_idx], num_words_total, "float32")
    memo = Memo(pi=memo.pi.at[doc_idx].set(res.pi),
                visited=memo.visited.at[doc_idx].set(True))
    return state, memo


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2))
def ivi_step(cfg: LDAConfig, state: EngineState, memo: Memo, ids: jax.Array,
             cnts: jax.Array, doc_idx: jax.Array,
             num_words_total: jax.Array) -> tuple[EngineState, Memo]:
    """Algorithm 1: partial E-step, then exact incremental M-step (eq. 4)."""
    return _raw_memo_step(cfg, False, state, memo, ids, cnts, doc_idx,
                          num_words_total)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2))
def sivi_step(cfg: LDAConfig, state: EngineState, memo: Memo, ids: jax.Array,
              cnts: jax.Array, doc_idx: jax.Array,
              num_words_total: jax.Array) -> tuple[EngineState, Memo]:
    """Eq. 5: the incremental estimate inside a Robbins–Monro average."""
    return _raw_memo_step(cfg, True, state, memo, ids, cnts, doc_idx,
                          num_words_total)


# ---------------------------------------------------------------------------
# Host-side driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class History:
    docs_seen: List[int] = dataclasses.field(default_factory=list)
    elbo: List[float] = dataclasses.field(default_factory=list)
    lpp: List[float] = dataclasses.field(default_factory=list)
    wall: List[float] = dataclasses.field(default_factory=list)


class LDAEngine:
    """Host driver: shuffling, mini-batching, evaluation, timing.

    ``corpus`` may be a padded ``Corpus`` (the materialized path) or a
    ``repro.data.stream.DocStream`` — ragged documents pulled and packed
    per mini-batch (`repro.data.stream.BatchPacker`), so no ``(D, L)``
    padded corpus is ever resident. One pass over the stream is one epoch
    (stream order — a stream cannot be permuted); packing is
    bit-transparent, so a stream-fed run reproduces the materialized run's
    trajectory exactly under the same batch schedule
    (tests/test_stream_pipeline.py). MVI (full batch) and the γ-only
    store (π reconstructed from resident corpus rows) need the
    materialized corpus.

    ``memo_store`` selects the π-memo representation for the incremental
    engines: ``dense`` (device fp32 oracle), ``chunked`` (bf16 host
    chunks) or ``gamma`` (γ-only reconstruction — S-IVI only, the eq. 4
    exactness needs the true π). ``bucket_by_length=True`` batches each
    epoch inside length buckets (`repro.data.bow.bucket_corpus`), so
    E-step FLOPs and memo traffic scale with each bucket's own padding
    width instead of the corpus-wide maximum; ``bucket_stats`` then holds
    the per-bucket pad fractions (logged once per run by ``train.py``).
    Stream ingest packs by bucket width always.
    """

    def __init__(self, cfg: LDAConfig, corpus, *, algo: str,
                 batch_size: int = 64, seed: int = 0,
                 test_corpus: Optional[Corpus] = None,
                 memo_store: str = "dense", chunk_docs: int = 8192,
                 bucket_by_length: bool = False, layout: str = "padded",
                 token_budget: Optional[int] = None, telemetry=None,
                 tune_store=None):
        assert algo in ("mvi", "svi", "ivi", "sivi")
        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown layout {layout!r} "
                             "(expected 'padded' or 'csr')")
        self.cfg, self.algo = cfg, algo
        self.batch_size = batch_size
        self.layout = layout
        if layout == "csr" and token_budget is None:
            # default budget: enough flat slots that a full batch of
            # median-length documents fits, capped so the token stream
            # stays VMEM-resident in the CSR kernel's T-promotion regime
            token_budget = min(batch_size * 64, 8192)
        self.token_budget = token_budget if layout == "csr" else None
        self.tel = as_telemetry(telemetry)
        self._updates = 0            # host-side global-update counter
        self._doc_tokens = None      # per-doc token totals (telemetry only)
        self.rng = np.random.default_rng(seed)
        self.state = init_engine_state(cfg, jax.random.key(seed))
        self.memo: Optional[MemoStore] = None
        self._gamma_buf = None
        self._buckets = None
        self.bucket_stats: Optional[dict] = None
        self.stream = None
        if isinstance(corpus, Corpus):
            if layout == "csr":
                raise ValueError(
                    "layout='csr' is the flat-token stream path — feed a "
                    "DocStream (data.stream.as_doc_stream(corpus)) instead "
                    "of a padded Corpus")
            self.corpus: Optional[Corpus] = corpus
            self.num_docs = corpus.num_docs
            max_unique = corpus.max_unique
            num_words = float(np.asarray(corpus.counts).sum())
            if self.tel.enabled:
                # per-doc token totals, precomputed once so the hot path's
                # token counter is a host-side fancy-index + sum
                self._doc_tokens = np.asarray(corpus.counts).sum(axis=1)
        else:
            from repro.data.stream import BatchPacker, is_doc_stream
            if not is_doc_stream(corpus):
                raise TypeError(f"corpus must be a Corpus or DocStream, "
                                f"got {type(corpus).__name__}")
            if algo == "mvi":
                raise ValueError(
                    "mvi is full-batch coordinate ascent — it scans the "
                    "materialized corpus every epoch; use "
                    "data.stream.materialize(stream) or a mini-batch algo")
            if memo_store == "gamma":
                raise ValueError(
                    "the γ-only store reconstructs π from resident corpus "
                    "rows — materialize the stream or pick dense/chunked")
            self.stream = corpus
            self.corpus = None
            self.num_docs = corpus.num_docs
            max_unique = corpus.max_unique
            num_words = float(corpus.num_words)
            self._packer = self._make_packer()
            self._stream_cursor = 0          # docs pulled this epoch
            self._stream_iter = None
            self._stream_emitted: List = []  # flushed, not yet processed
        if (tune_store is not None and cfg.kernel_policy is None
                and cfg.estep_backend in ("pallas", "csr")):
            # store-resolved kernel policy, looked up once at construction
            # (the shape key is fully known here). An explicit
            # cfg.kernel_policy always wins over the store; no store (or a
            # miss) leaves the policy None — bit-identical to the built-in
            # defaults. The policy rides on the frozen cfg, which is a jit
            # static arg everywhere, so it keys retraces correctly.
            from repro.tune.resolve import PolicyResolver
            pol = PolicyResolver(tune_store, telemetry=self.tel).resolve(
                backend=cfg.estep_backend, layout=layout,
                b_or_t=(self.token_budget if layout == "csr"
                        else batch_size),
                v=cfg.vocab_size, k=cfg.num_topics,
                w=None if layout == "csr" else max_unique)
            if pol is not None:
                cfg = dataclasses.replace(cfg, kernel_policy=pol)
                self.cfg = cfg
        if algo in ("ivi", "sivi"):
            if memo_store == "gamma" and algo == "ivi":
                raise ValueError(
                    "the γ-only store reconstructs π approximately — it "
                    "breaks IVI's exact eq. 4 accumulator; use it with "
                    "sivi (or divi), or pick dense/chunked for ivi")
            self.memo = make_memo_store(memo_store, cfg, self.num_docs,
                                        max_unique, corpus=self.corpus,
                                        chunk_docs=chunk_docs)
        elif algo == "mvi":
            # per-document warm starts carried across epochs (see mvi_scan);
            # row D is the sentinel slot for the tail batch's padding
            self._gamma_buf = jnp.full((corpus.num_docs + 1, cfg.num_topics),
                                       cfg.alpha0 + 1.0, jnp.float32)
            zrow_i = jnp.zeros((1, corpus.max_unique), jnp.int32)
            zrow_c = jnp.zeros((1, corpus.max_unique), jnp.float32)
            self._mvi_ids = jnp.concatenate([corpus.token_ids, zrow_i])
            self._mvi_cnts = jnp.concatenate([corpus.counts, zrow_c])
        if bucket_by_length and self.stream is None:
            if algo == "mvi":
                raise ValueError("bucket_by_length applies to the "
                                 "mini-batch engines (svi/ivi/sivi)")
            from repro.data.bow import bucket_corpus, bucket_padding_stats
            self._buckets = bucket_corpus(corpus)
            self.bucket_stats = bucket_padding_stats(corpus, self._buckets)
        self.num_words_total = jnp.asarray(num_words)
        self.docs_seen = 0
        self.history = History()
        self._t0 = time.perf_counter()
        if test_corpus is not None:
            self._obs, self._held = split_heldout(test_corpus, seed=seed)
        else:
            self._obs = self._held = None

    def _make_packer(self):
        """A fresh ``BatchPacker`` in this engine's configured layout —
        used at construction and by the Trainer's mid-epoch restore, so
        the two can never drift on packer parameters."""
        from repro.data.stream import BatchPacker
        return BatchPacker(
            self.batch_size, max_width=self.stream.max_unique,
            vocab_size=self.cfg.vocab_size, layout=self.layout,
            token_budget=self.token_budget,
            metrics=self.tel.metrics if self.tel.enabled else None)

    # -- batching ----------------------------------------------------------
    def _epoch_order(self) -> List[np.ndarray]:
        """A full-cover epoch: every document exactly once.

        The ``D % batch_size`` tail documents form a final (smaller) batch
        instead of being dropped — dropping them meant IVI never visited
        them, ``init_frac`` never retired to 0, and the post-pass exactness
        λ = β₀ + ⟨m_vk⟩ (eq. 4) never held.
        """
        d = self.corpus.num_docs
        order = self.rng.permutation(d)
        b = self.batch_size
        if d <= b:
            return [order]
        n = (d // b) * b
        batches = list(order[:n].reshape(-1, b))
        if d % b:
            batches.append(order[n:])
        return batches

    def _bucketed_epoch_order(self) -> List[tuple[np.ndarray, int]]:
        """Per-bucket batches (rows, width), bucket visit order shuffled."""
        out: List[tuple[np.ndarray, int]] = []
        for rows_all, width in zip(self._buckets.doc_idx,
                                   self._buckets.widths):
            order = rows_all[self.rng.permutation(len(rows_all))]
            for lo in range(0, len(order), self.batch_size):
                out.append((order[lo:lo + self.batch_size], width))
        self.rng.shuffle(out)
        return out

    def epoch_batches(self) -> List[tuple[np.ndarray, Optional[int]]]:
        """Draw one epoch's mini-batches: (rows, width|None) pairs.

        This is the exact sequence (and the exact rng consumption)
        ``run_epoch`` processes — exposed so external drivers (the
        ``repro.lda`` Trainer) can step batch-by-batch, persist the
        not-yet-visited remainder mid-epoch, and still be bit-equal to an
        uninterrupted ``run_epoch`` loop.
        """
        if self.algo == "mvi":
            raise ValueError("mvi is full-batch: use run_epoch")
        if self.stream is not None:
            raise ValueError("stream ingest has no materialized epoch "
                             "order: drive it with stream_step/run_epoch")
        if self._buckets is not None:
            return self._bucketed_epoch_order()
        return [(rows, None) for rows in self._epoch_order()]

    # -- steps -------------------------------------------------------------
    def run_epoch(self) -> None:
        if self.stream is not None:
            while self.stream_step():
                pass
            return
        if self.algo == "mvi":
            self._run_mvi_epoch()
            return
        for rows, width in self.epoch_batches():
            self.run_minibatch(rows, width=width)

    def _run_mvi_epoch(self) -> None:
        d = self.corpus.num_docs
        b = min(self.batch_size, d)
        batches = self._epoch_order()
        idx = np.full((len(batches), b), d, np.int64)     # sentinel = row D
        for r, rows in enumerate(batches):
            idx[r, : len(rows)] = rows
        idx = jnp.asarray(idx)
        eb = exp_dirichlet_expectation(self.state.lam, axis=0)
        sstats, self._gamma_buf = mvi_scan(
            self.cfg, eb, self._mvi_ids[idx], self._mvi_cnts[idx], idx,
            self._gamma_buf, jnp.zeros_like(self.state.lam))
        self.state = dataclasses.replace(
            self.state, lam=self.cfg.beta0 + sstats, t=self.state.t + 1)
        self.docs_seen += d

    def run_minibatch(self, rows: Optional[np.ndarray] = None,
                      width: Optional[int] = None) -> None:
        if rows is None:
            rows = self.rng.choice(self.corpus.num_docs, size=self.batch_size,
                                   replace=False)
        idx = jnp.asarray(rows)
        ids, cnts = self.corpus.token_ids[idx], self.corpus.counts[idx]
        if width is not None and width < self.corpus.max_unique:
            ids, cnts = ids[:, :width], cnts[:, :width]
        self._update_batch(rows, ids, cnts)

    def _update_batch(self, rows: np.ndarray, ids: jax.Array,
                      cnts: jax.Array) -> None:
        """One global update on a padded (B', W) batch — the shared core of
        the materialized (`run_minibatch`) and stream (`stream_step`)
        paths; ``W`` is whatever width the batch was packed/sliced to.

        This is the instrumentation hot path: every telemetry touch is
        gated on ``tel.enabled`` (``begin`` returns None otherwise), so
        the disabled run executes the seed instruction sequence modulo
        one branch per site — no recorder allocations, no syncs, and
        therefore bit-identical trajectories (tests/test_obs.py).
        """
        tel = self.tel
        on = tel.enabled
        width = ids.shape[1]
        sp = tel.trace.begin("train/update", algo=self.algo,
                             width=width, docs=len(rows)) if on else None
        if self.algo == "svi":
            self.state = svi_step(self.cfg, self.state, ids, cnts,
                                  jnp.asarray(float(self.num_docs)))
        elif self.algo in ("ivi", "sivi"):
            g = tel.trace.begin("train/memo_gather", width=width) \
                if on else None
            old_pi, visited = self.memo.gather(rows, width=width)
            if g is not None:
                tel.trace.end(g)
            s = tel.trace.begin("train/solve", width=width) if on else None
            self.state, new_pi, eb = incremental_update(
                self.cfg, self.algo == "sivi", self.state, ids, cnts,
                old_pi, visited, self.num_words_total,
                self.memo.pi_wire_dtype)
            if s is not None:
                tel.trace.end(s, sync=self.state.lam)
            u = tel.trace.begin("train/memo_update", width=width) \
                if on else None
            self.memo = self.memo.update(rows, new_pi, exp_elog_beta=eb)
            if u is not None:
                tel.trace.end(u)
        else:
            raise ValueError(self.algo)
        self.docs_seen += len(rows)
        if sp is not None:
            tel.trace.end(sp, sync=self.state.lam)
            self._updates += 1
            m = tel.metrics
            m.inc("train.docs", len(rows))
            m.inc("train.batches", width=width)
            if self._doc_tokens is not None:
                m.inc("train.tokens", float(self._doc_tokens[rows].sum()))
            else:
                m.inc("train.tokens", float(np.asarray(cnts).sum()))
            if self.memo is not None:
                m.set_gauge("train.memo_resident_bytes",
                            self.memo.footprint_bytes())
            wd = tel.watchdog
            if (self.algo in ("ivi", "sivi") and wd.enabled
                    and wd.should_check(self._updates)):
                # O(corpus) memoized-bound read — priced by check_every
                wd.observe(self.full_bound(), step=self._updates,
                           armed=self._watchdog_armed())

    def _watchdog_armed(self) -> bool:
        """Whether the monotone-ELBO guarantee is in force: IVI (eq. 4 —
        S-IVI's averaging forfeits it) after the random-init mass has
        fully retired, i.e. the first complete pass is done."""
        return (self.algo == "ivi"
                and float(jax.device_get(self.state.init_frac)) == 0.0)

    # -- stream ingest -----------------------------------------------------
    def stream_step(self) -> bool:
        """Pull-and-pack until ONE mini-batch emits, then process it.

        Returns True when a batch was processed; False exactly at an epoch
        boundary (the stream is exhausted and every flushed batch has been
        processed — the cursor resets, so the next call starts a new
        pass). Every document of the stream is processed exactly once per
        epoch: the packer's partial buckets flush at exhaustion, the
        streaming analogue of the ``D % batch_size`` epoch-tail batch.
        """
        assert self.stream is not None, "stream_step needs stream ingest"
        if self._stream_emitted:
            self._run_packed(self._stream_emitted.pop(0))
            return True
        if self._stream_iter is None:
            self._stream_iter = self.stream.iter_from(self._stream_cursor)
        for ids, cnts in self._stream_iter:
            pos = self._stream_cursor
            self._stream_cursor += 1
            batch = self._packer.add(pos, ids, cnts)
            if batch is not None:
                self._run_packed(batch)
                return True
        self._stream_emitted = self._packer.flush()
        if self._stream_emitted:
            self._run_packed(self._stream_emitted.pop(0))
            return True
        self._stream_cursor = 0              # epoch boundary: rewind
        self._stream_iter = None
        return False

    def _run_packed(self, batch) -> None:
        from repro.data.stream import CSRBatch
        if isinstance(batch, CSRBatch):
            self._update_batch_csr(batch)
        else:
            self._update_batch(batch.rows, jnp.asarray(batch.token_ids),
                               jnp.asarray(batch.counts))

    def _csr_flat_index(self, batch, width: int) -> np.ndarray:
        """The token-slot → memo-cell map: ``ix[t] = seg_t·W + pos_in_doc``
        for live tokens, sentinel ``B·W`` for padding slots. Host-built
        from the batch's offsets — O(T) numpy, no device work."""
        segs = batch.segments.astype(np.int64)
        ix = segs * width + (np.arange(batch.token_budget, dtype=np.int64)
                             - batch.offsets[segs])
        ix[batch.live_tokens:] = self.batch_size * width
        return ix

    def _update_batch_csr(self, batch) -> None:
        """One global update on a flat CSR batch (`stream_step`, csr
        layout). The jit keys are (token_budget, batch_size, W): the flat
        token arrays are always ``token_budget`` slots and the doc axis is
        padded to ``batch_size`` (phantom docs own no tokens — inert in
        every segment reduction), so W — the ladder rung covering the
        batch's longest document, which sizes the memo wire — is the only
        per-batch shape degree of freedom left.
        """
        tel = self.tel
        on = tel.enabled
        rows = batch.rows
        b_real, b_pad = len(rows), self.batch_size
        width = self._packer.width_for(
            int(batch.doc_lengths.max()) if b_real else 1)
        sp = tel.trace.begin("train/update", algo=self.algo, width=width,
                             docs=b_real) if on else None
        ids = jnp.asarray(batch.token_ids)
        cnts = jnp.asarray(batch.counts)
        segs = jnp.asarray(batch.segments)
        if self.algo == "svi":
            self.state = svi_step_csr(
                self.cfg, self.state, ids, cnts, segs,
                jnp.asarray(float(b_real)),
                jnp.asarray(float(self.num_docs)), num_docs=b_pad)
        elif self.algo in ("ivi", "sivi"):
            # pad the doc axis by re-reading row 0: phantom docs own zero
            # tokens, so their gathered memo rows are never touched and
            # their visited flags contribute 0 to the first-visit count
            rows_pad = np.concatenate(
                [rows, np.zeros(b_pad - b_real, np.int64)])
            g = tel.trace.begin("train/memo_gather", width=width) \
                if on else None
            old_pi, visited = self.memo.gather(rows_pad, width=width)
            if g is not None:
                tel.trace.end(g)
            ix = jnp.asarray(self._csr_flat_index(batch, width))
            s = tel.trace.begin("train/solve", width=width) if on else None
            self.state, new_pi, eb = incremental_update_csr(
                self.cfg, self.algo == "sivi", self.state, ids, cnts, segs,
                ix, old_pi, visited, self.num_words_total,
                self.memo.pi_wire_dtype)
            if s is not None:
                tel.trace.end(s, sync=self.state.lam)
            u = tel.trace.begin("train/memo_update", width=width) \
                if on else None
            self.memo = self.memo.update(rows, new_pi[:b_real],
                                         exp_elog_beta=eb)
            if u is not None:
                tel.trace.end(u)
        else:
            raise ValueError(self.algo)
        self.docs_seen += b_real
        if sp is not None:
            tel.trace.end(sp, sync=self.state.lam)
            self._updates += 1
            m = tel.metrics
            m.inc("train.docs", b_real)
            m.inc("train.batches", width=width)
            m.inc("train.tokens", float(batch.counts.sum()))
            if self.memo is not None:
                m.set_gauge("train.memo_resident_bytes",
                            self.memo.footprint_bytes())
            wd = tel.watchdog
            if (self.algo in ("ivi", "sivi") and wd.enabled
                    and wd.should_check(self._updates)):
                wd.observe(self.full_bound(), step=self._updates,
                           armed=self._watchdog_armed())

    def stream_padding_stats(self) -> dict:
        """Pad-waste accounting of everything packed so far (stream mode)."""
        return self._packer.padding_stats()

    # -- evaluation --------------------------------------------------------
    def evaluate(self) -> Dict[str, float]:
        """Periodic evaluation snapshot.

        With a test corpus: held-out LPP (the paper's §6 metric). Without
        one: the corpus bound (for the incremental engines the *memoized*
        ELBO — the monotone objective — read through the store). Each
        metric is appended to its own ``History`` column only when actually
        computed; ``lpp`` used to be padded with ``nan`` rows whenever no
        test corpus was set, which poisoned any downstream min/mean.
        """
        out: Dict[str, float] = {}
        if self._obs is not None:
            out["lpp"] = float(log_predictive(self.cfg, self.state.lam,
                                              self._obs, self._held))
            self.history.lpp.append(out["lpp"])
        else:
            out["elbo"] = self.full_bound()
            self.history.elbo.append(out["elbo"])
            if (self.tel.enabled and self.tel.watchdog.enabled
                    and self.algo in ("ivi", "sivi")):
                # a bound computed anyway — feed it to the watchdog even
                # at check_every=0 (the free cadence)
                self.tel.watchdog.observe(out["elbo"], step=self._updates,
                                          armed=self._watchdog_armed())
        if self.tel.enabled:
            self.tel.metrics.set_gauge(
                "train.effective_topics",
                float(effective_topics(np.asarray(self.state.lam))))
        self.history.docs_seen.append(self.docs_seen)
        self.history.wall.append(time.perf_counter() - self._t0)
        return out

    def full_bound(self) -> float:
        """Exact corpus ELBO.

        For the incremental engines this is the *memoized* bound — the exact
        objective at (γ(π_memo), π_memo, λ), the quantity IVI monotonically
        increases — read through the ``MemoStore`` chunk by chunk (γ is
        α₀ + Σ_l cnt·π, Alg. 1 line 6, so it is derived from the memo and
        stays consistent with it). For MVI/SVI we report the collapsed
        bound at freshly fitted γ.
        """
        cfg = self.cfg
        if self.stream is not None:
            # stream ingest: chunk-by-chunk read-through, no (D, L) corpus
            if self.memo is not None:
                return float(elbo_memoized_stream(cfg, self.stream,
                                                  self.memo, self.state.lam))
            return float(elbo_collapsed_stream(cfg, self.stream,
                                               self.state.lam))
        if self.memo is not None:
            return float(elbo_memoized_store(cfg, self.corpus, self.memo,
                                             self.state.lam))
        eb = exp_dirichlet_expectation(self.state.lam, axis=0)
        # deliberately the gather backend regardless of cfg.estep_backend:
        # this is a full-corpus E-step, and the dense/pallas formulations
        # would densify all D documents into a (D, V) matrix at once
        res = estep_mod.estep_gather(cfg, eb, self.corpus.token_ids,
                                     self.corpus.counts)
        return float(elbo_collapsed(cfg, self.corpus, res.gamma,
                                    self.state.lam))
