"""Single-host inference engines for LDA: MVI, SVI, IVI, S-IVI.

All four share the batched E-step (`repro.core.estep`); they differ only in
how the global topic-word parameter λ is updated — exactly the contrast the
paper draws:

* **MVI**  (batch, Blei et al. 2003): λ = β₀ + Σ_d s_d after a full pass.
* **SVI**  (Hoffman et al. 2013, eq. 3): λ ← (1−ρ_t)λ + ρ_t(β₀ + (D/|B|)·s_B).
* **IVI**  (this paper, eq. 4 / Alg. 1): memoize per-document π; maintain the
  exact accumulator ⟨m_vk⟩ by subtract-old/add-new; λ = β₀ + ⟨m_vk⟩.
  No learning rate; monotone in the (memoized) ELBO once every document
  has been visited.
* **S-IVI** (eq. 5): the IVI correction inside a Robbins–Monro average:
  λ ← (1−ρ_t)λ + ρ_t(β₀ + ⟨m_vk⟩⁺). SAG-like; amenable to distribution.

Random-initialisation mass: the paper initialises β randomly (Alg. 1 l.1).
For the incremental engines we carry that mass explicitly (``init_mass``)
and retire each document's pro-rata share the first time it is visited, so
after one full pass ⟨m_vk⟩ == Σ_d s_d exactly and the monotonicity guarantee
is exact (cf. Neal & Hinton 1998 discussion of incremental-EM start-up).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estep as estep_mod
from repro.core.bound import elbo_collapsed, elbo_memoized
from repro.core.estep import estep, scatter_sstats
from repro.core.math import exp_dirichlet_expectation
from repro.core.predictive import log_predictive, split_heldout
from repro.core.types import Corpus, LDAConfig, Memo


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    """Variational state for every single-host engine (unused fields zero)."""

    lam: jax.Array         # (V, K) topic-word Dirichlet parameter
    m_vk: jax.Array        # (V, K) incremental accumulator ⟨m_vk⟩
    init_mass: jax.Array   # (V, K) un-attributed random-init mass
    init_frac: jax.Array   # () share of init_mass still live in λ
    t: jax.Array           # () int32 update counter (drives ρ_t)


def init_engine_state(cfg: LDAConfig, key: jax.Array) -> EngineState:
    lam = jax.random.gamma(key, 100.0,
                           (cfg.vocab_size, cfg.num_topics)) * 0.01
    return EngineState(
        lam=lam,
        m_vk=jnp.zeros_like(lam),
        init_mass=lam - cfg.beta0,
        init_frac=jnp.ones(()),
        t=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# MVI — batch coordinate ascent
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 5))
def mvi_epoch(cfg: LDAConfig, state: EngineState, ids_b: jax.Array,
              cnts_b: jax.Array, doc_idx_b: jax.Array,
              gamma_buf: jax.Array
              ) -> tuple[EngineState, jax.Array, jax.Array]:
    """One full batch pass. ids_b/cnts_b/doc_idx_b: (num_batches, B, ...).

    γ persists across epochs in ``gamma_buf`` (D, K): each document's E-step
    resumes from α₀ + Σ_l cnt·π of its previous visit — proper batch
    coordinate ascent in the sense of Neal & Hinton (1998), and the *same*
    warm-start reconstruction the incremental engines use. Without this,
    a ``estep_max_iters``-truncated E-step restarts from scratch every
    epoch while IVI resumes from its memo, and the two full-batch
    trajectories drift apart for reasons that have nothing to do with the
    incremental bookkeeping (see test_fullbatch_ivi_equals_mvi).
    """
    eb = exp_dirichlet_expectation(state.lam, axis=0)

    def body(carry, batch):
        acc, gbuf = carry
        ids, cnts, idx = batch
        res = estep(cfg, eb, ids, cnts, gbuf[idx])
        gbuf = gbuf.at[idx].set(
            cfg.alpha0 + jnp.einsum("blk,bl->bk", res.pi, cnts))
        return (acc + res.sstats, gbuf), res.gamma

    (sstats, gamma_buf), gammas = jax.lax.scan(
        body, (jnp.zeros_like(state.lam), gamma_buf),
        (ids_b, cnts_b, doc_idx_b))
    lam = cfg.beta0 + sstats
    new = dataclasses.replace(state, lam=lam, t=state.t + 1)
    return new, gamma_buf, gammas.reshape(-1, cfg.num_topics)


# ---------------------------------------------------------------------------
# SVI — stochastic natural gradient (eq. 3)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def svi_step(cfg: LDAConfig, state: EngineState, ids: jax.Array,
             cnts: jax.Array, num_docs_total: jax.Array) -> EngineState:
    eb = exp_dirichlet_expectation(state.lam, axis=0)
    res = estep(cfg, eb, ids, cnts)
    scale = num_docs_total / ids.shape[0]
    lam_hat = cfg.beta0 + scale * res.sstats
    rho = cfg.rho(state.t + 1)
    lam = (1.0 - rho) * state.lam + rho * lam_hat
    return dataclasses.replace(state, lam=lam, t=state.t + 1)


# ---------------------------------------------------------------------------
# IVI / S-IVI — incremental updates (eqs. 4 & 5)
# ---------------------------------------------------------------------------

def memo_correction(cfg: LDAConfig, eb: jax.Array, ids: jax.Array,
                    cnts: jax.Array, old_pi: jax.Array,
                    visited_rows: jax.Array):
    """E-step + subtract-old/add-new core shared by IVI, S-IVI and D-IVI.

    The distributed engine (``repro.dist``) calls this same function for its
    workers, which is what keeps the single-host and distributed paths
    numerically interchangeable (test_divi_single_worker_round_equals_sivi_step).

    Returns (correction (V, K), first-visit word count, EStepResult).
    """
    # Warm-start γ from the memo for already-visited documents: coordinate
    # ascent from the memoized point can only improve the bound, which is
    # what makes IVI's monotonicity exact (fresh inits could hop to a worse
    # local optimum of the per-document subproblem).
    gamma_memo = cfg.alpha0 + jnp.einsum("blk,bl->bk", old_pi, cnts)
    fresh = jnp.full_like(gamma_memo, cfg.alpha0 + 1.0)
    gamma0 = jnp.where(visited_rows[:, None], gamma_memo, fresh)
    res = estep(cfg, eb, ids, cnts, gamma0)

    delta = cnts[:, :, None] * (res.pi - old_pi)
    correction = scatter_sstats(ids, delta, cfg.vocab_size)  # (V, K)
    words_first = jnp.sum(jnp.where(~visited_rows, cnts.sum(-1), 0.0))
    return correction, words_first, res


def retire_init_frac(init_frac: jax.Array, words_first: jax.Array,
                     num_words_total: jax.Array) -> jax.Array:
    """Retire the first-visit words' pro-rata share of the random-init mass.

    Snaps the fp32 subtraction residue to an exact zero once every document
    has been visited, so λ = β₀ + ⟨m_vk⟩ holds exactly afterwards (eq. 4).
    """
    frac = jnp.maximum(init_frac - words_first / num_words_total, 0.0)
    return jnp.where(frac < 1e-6, 0.0, frac)


def sivi_global_update(cfg: LDAConfig, state, corr: jax.Array,
                       frac: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Eq. 5 global step: λ ← (1−ρ_t)λ + ρ_t(β₀ + ⟨m_vk⟩⁺ + frac·init_mass).

    Duck-typed over EngineState / the distributed DIVIState (same fields);
    elementwise in V, so it applies unchanged to the model-sharded rows of
    ``repro.dist`` — keeping the single-host and distributed master updates
    one code path. Returns (λ, ⟨m_vk⟩⁺); the caller bumps ``t``.
    """
    m_vk = state.m_vk + corr
    lam_hat = cfg.beta0 + m_vk + frac * state.init_mass
    rho = cfg.rho(state.t + 1)
    lam = (1.0 - rho) * state.lam + rho * lam_hat
    return lam, m_vk


def _incremental_correction(cfg: LDAConfig, state: EngineState, memo: Memo,
                            ids: jax.Array, cnts: jax.Array,
                            doc_idx: jax.Array, num_words_total: jax.Array):
    """Shared E-step + subtract-old/add-new bookkeeping.

    Returns (correction (V,K), new memo, new init_frac, gamma).
    """
    eb = exp_dirichlet_expectation(state.lam, axis=0)
    correction, words_first, res = memo_correction(
        cfg, eb, ids, cnts, memo.pi[doc_idx], memo.visited[doc_idx])
    new_frac = retire_init_frac(state.init_frac, words_first, num_words_total)
    memo = Memo(pi=memo.pi.at[doc_idx].set(res.pi),
                visited=memo.visited.at[doc_idx].set(True))
    return correction, memo, new_frac, res.gamma


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2))
def ivi_step(cfg: LDAConfig, state: EngineState, memo: Memo, ids: jax.Array,
             cnts: jax.Array, doc_idx: jax.Array,
             num_words_total: jax.Array) -> tuple[EngineState, Memo]:
    """Algorithm 1: partial E-step, then exact incremental M-step (eq. 4)."""
    corr, memo, frac, _ = _incremental_correction(
        cfg, state, memo, ids, cnts, doc_idx, num_words_total)
    m_vk = state.m_vk + corr
    lam = cfg.beta0 + m_vk + frac * state.init_mass
    state = dataclasses.replace(state, lam=lam, m_vk=m_vk, init_frac=frac,
                                t=state.t + 1)
    return state, memo


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2))
def sivi_step(cfg: LDAConfig, state: EngineState, memo: Memo, ids: jax.Array,
              cnts: jax.Array, doc_idx: jax.Array,
              num_words_total: jax.Array) -> tuple[EngineState, Memo]:
    """Eq. 5: the incremental estimate inside a Robbins–Monro average."""
    corr, memo, frac, _ = _incremental_correction(
        cfg, state, memo, ids, cnts, doc_idx, num_words_total)
    lam, m_vk = sivi_global_update(cfg, state, corr, frac)
    state = dataclasses.replace(state, lam=lam, m_vk=m_vk, init_frac=frac,
                                t=state.t + 1)
    return state, memo


# ---------------------------------------------------------------------------
# Host-side driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class History:
    docs_seen: List[int] = dataclasses.field(default_factory=list)
    elbo: List[float] = dataclasses.field(default_factory=list)
    lpp: List[float] = dataclasses.field(default_factory=list)
    wall: List[float] = dataclasses.field(default_factory=list)


class LDAEngine:
    """Host driver: shuffling, mini-batching, evaluation, timing."""

    def __init__(self, cfg: LDAConfig, corpus: Corpus, *, algo: str,
                 batch_size: int = 64, seed: int = 0,
                 test_corpus: Optional[Corpus] = None):
        assert algo in ("mvi", "svi", "ivi", "sivi")
        self.cfg, self.corpus, self.algo = cfg, corpus, algo
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.state = init_engine_state(cfg, jax.random.key(seed))
        self.memo = None
        self._gamma_buf = None
        if algo in ("ivi", "sivi"):
            self.memo = Memo(
                pi=jnp.zeros((corpus.num_docs, corpus.max_unique,
                              cfg.num_topics), jnp.float32),
                visited=jnp.zeros((corpus.num_docs,), bool))
        elif algo == "mvi":
            # per-document warm starts carried across epochs (see mvi_epoch)
            self._gamma_buf = jnp.full((corpus.num_docs, cfg.num_topics),
                                       cfg.alpha0 + 1.0, jnp.float32)
        self.num_words_total = jnp.asarray(float(np.asarray(corpus.counts).sum()))
        self.docs_seen = 0
        self.history = History()
        self._t0 = time.perf_counter()
        if test_corpus is not None:
            self._obs, self._held = split_heldout(test_corpus, seed=seed)
        else:
            self._obs = self._held = None

    # -- batching ----------------------------------------------------------
    def _epoch_order(self) -> np.ndarray:
        d = self.corpus.num_docs
        order = self.rng.permutation(d)
        n = (d // self.batch_size) * self.batch_size
        if n == 0:  # corpus smaller than one batch: sample with replacement
            return self.rng.choice(d, size=(1, self.batch_size))
        return order[:n].reshape(-1, self.batch_size)

    # -- steps -------------------------------------------------------------
    def run_epoch(self) -> None:
        batches = self._epoch_order()
        if self.algo == "mvi":
            ids = self.corpus.token_ids[batches]     # (nb, B, L)
            cnts = self.corpus.counts[batches]
            self.state, self._gamma_buf, _ = mvi_epoch(
                self.cfg, self.state, ids, cnts, jnp.asarray(batches),
                self._gamma_buf)
            self.docs_seen += batches.size
            return
        for rows in batches:
            self.run_minibatch(rows)

    def run_minibatch(self, rows: Optional[np.ndarray] = None) -> None:
        if rows is None:
            rows = self.rng.choice(self.corpus.num_docs, size=self.batch_size,
                                   replace=False)
        idx = jnp.asarray(rows)
        ids, cnts = self.corpus.token_ids[idx], self.corpus.counts[idx]
        if self.algo == "svi":
            self.state = svi_step(self.cfg, self.state, ids, cnts,
                                  jnp.asarray(float(self.corpus.num_docs)))
        elif self.algo == "ivi":
            self.state, self.memo = ivi_step(
                self.cfg, self.state, self.memo, ids, cnts, idx,
                self.num_words_total)
        elif self.algo == "sivi":
            self.state, self.memo = sivi_step(
                self.cfg, self.state, self.memo, ids, cnts, idx,
                self.num_words_total)
        else:
            raise ValueError(self.algo)
        self.docs_seen += len(rows)

    # -- evaluation --------------------------------------------------------
    def evaluate(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        if self._obs is not None:
            out["lpp"] = float(log_predictive(self.cfg, self.state.lam,
                                              self._obs, self._held))
        self.history.docs_seen.append(self.docs_seen)
        self.history.lpp.append(out.get("lpp", float("nan")))
        self.history.wall.append(time.perf_counter() - self._t0)
        return out

    def full_bound(self) -> float:
        """Exact corpus ELBO.

        For the incremental engines this is the *memoized* bound — the exact
        objective at (γ(π_memo), π_memo, λ), the quantity IVI monotonically
        increases (γ is α₀ + Σ_l cnt·π, Alg. 1 line 6, so it is derived from
        the memo and stays consistent with it). For MVI/SVI we report the
        collapsed bound at freshly fitted γ.
        """
        cfg = self.cfg
        if self.memo is not None:
            gamma = cfg.alpha0 + jnp.einsum(
                "dlk,dl->dk", self.memo.pi, self.corpus.counts)
            return float(elbo_memoized(cfg, self.corpus, gamma,
                                       self.memo.pi, self.state.lam))
        eb = exp_dirichlet_expectation(self.state.lam, axis=0)
        res = estep_mod.estep_gather(cfg, eb, self.corpus.token_ids,
                                     self.corpus.counts)
        return float(elbo_collapsed(cfg, self.corpus, res.gamma,
                                    self.state.lam))
