"""Core datatypes for incremental variational inference for LDA.

The corpus is held in padded bag-of-words layout: each document is a row of
*unique* token ids plus their counts, padded to the corpus-wide maximum
number of unique tokens per document. This is the layout every engine
(MVI / SVI / IVI / S-IVI / D-IVI) consumes; the Pallas kernels additionally
densify a mini-batch into a count matrix ``C (B, V)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Corpus:
    """Padded bag-of-words corpus.

    Attributes:
      token_ids: ``(D, L)`` int32 — unique token ids per document, padded
        with 0. Padding is disambiguated by ``counts == 0``.
      counts: ``(D, L)`` float32 — occurrence counts; 0 on padding.
    """

    token_ids: jax.Array
    counts: jax.Array

    @property
    def num_docs(self) -> int:
        return self.token_ids.shape[0]

    @property
    def max_unique(self) -> int:
        return self.token_ids.shape[1]

    @property
    def num_words(self) -> jax.Array:
        return self.counts.sum()

    def take(self, idx: jax.Array) -> "Corpus":
        return Corpus(self.token_ids[idx], self.counts[idx])


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Tile/config policy for the Pallas E-step kernels (``repro.tune``).

    Every field defaults to the value the kernels hard-coded before the
    autotuner existed, so ``KernelPolicy()`` — and a ``None`` policy — are
    bit-identical to the historical behavior. The dataclass is frozen and
    hashable because it rides on :class:`LDAConfig` (a jit static arg):
    changing a policy correctly keys a retrace.

    Fields map onto kernel knobs as follows (docs/tuning.md has the table):

    * ``block_b`` / ``block_v`` — fused padded fixed point
      (``ops.estep_pallas``). ``block_v`` is subject to whole-V residency
      promotion; ``ops.effective_fixed_point_blocks`` reports the tile
      actually run.
    * ``delta_block_b`` / ``delta_block_v`` / ``pi_block_l`` /
      ``scatter_block_t`` — the memo_delta π kernel + segment scatter
      (``lda_estep.memo_delta``: ``block_b``/``block_v``/``block_l``/
      ``block_t``).
    * ``block_t`` — CSR flat-token fixed point tile, subject to whole-T
      residency promotion (``ops.csr_effective_block_t``).
    * ``wire_dtype`` — advisory memo wire dtype recorded by the tuner
      (``"float32"``/``"bfloat16"``); the memo *store* kind still decides
      the wire, this records what the search measured as best.
    * ``double_buffer_depth`` — staging queue depth for
      ``TopicInferencer.posterior_docs``.
    """

    block_b: int = 128
    block_v: int = 512
    delta_block_b: int = 32
    delta_block_v: Optional[int] = None
    pi_block_l: int = 512
    scatter_block_t: int = 128
    block_t: int = 512
    wire_dtype: Optional[str] = None
    double_buffer_depth: int = 2


#: The policy in effect when none is configured — today's hard defaults.
DEFAULT_KERNEL_POLICY = KernelPolicy()


@dataclasses.dataclass(frozen=True)
class LDAConfig:
    """Hyper-parameters — defaults are the paper's §6 experimental setup."""

    num_topics: int = 100
    vocab_size: int = 10_000
    alpha0: float = 0.5          # document-topic Dirichlet prior
    beta0: float = 0.05          # topic-word Dirichlet prior
    kappa: float = 0.9           # learning-rate decay (SVI / S-IVI / D-IVI)
    tau: float = 1.0             # learning-rate delay
    estep_max_iters: int = 100   # cap on the local fixed point
    estep_tol: float = 1e-4      # mean-abs-change convergence threshold
    estep_backend: str = "gather"  # "gather" | "dense" | "pallas"
    # dtype the fused Pallas kernel streams C / Eφ in ("float32"|"bfloat16");
    # bf16 halves the dominant HBM terms of the fixed point (docs/estep.md)
    estep_stream_dtype: str = "float32"
    # tuned kernel tile policy (repro.tune); None means the built-in
    # defaults, which are bit-identical to KernelPolicy()
    kernel_policy: Optional[KernelPolicy] = None

    def rho(self, t: jax.Array) -> jax.Array:
        """Robbins–Monro step size ρ_t = (t + τ)^(−κ)."""
        return (t + self.tau) ** (-self.kappa)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GlobalState:
    """Global variational state — THE canonical state for every engine.

    ``lam`` is the (V, K) topic-word Dirichlet parameter β in the paper;
    ``m_vk`` the global sufficient-statistic accumulator ⟨m_vk⟩ (zeros for
    the non-incremental engines); ``t`` counts global updates (drives ρ_t).
    ``init_mass``/``init_frac`` carry the random-initialisation mass of
    Alg. 1 line 1 explicitly: each document's pro-rata share is retired on
    its first visit, so after one full pass λ = β₀ + ⟨m_vk⟩ holds exactly
    (eq. 4; cf. Neal & Hinton 1998 on incremental-EM start-up).

    Single-host engines use this class directly (``engines.EngineState`` is
    an alias) and so does the distributed master (``dist.DIVIState``) — the
    (V, K) leaves there may hold only this device's model-axis rows.
    """

    lam: jax.Array           # (V, K)
    m_vk: jax.Array          # (V, K)
    init_mass: jax.Array     # (V, K) un-attributed random-init mass
    init_frac: jax.Array     # () share of init_mass still live in λ
    t: jax.Array             # () int32

    @property
    def vocab_size(self) -> int:
        return self.lam.shape[0]

    @property
    def num_topics(self) -> int:
        return self.lam.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Memo:
    """IVI per-document memoized responsibilities, token-aligned.

    ``pi`` is ``(D, L, K)``: π_knd for each (document, unique-token) slot.
    Rows of padding carry zeros. The per-document sufficient-statistic
    contribution is ``segment_sum(counts[...,None] * pi, token_ids)``.
    ``visited`` marks documents whose memo is live (contributes to ⟨m_vk⟩).

    This is the raw *device-dense* layout; engines access memos through the
    pluggable ``repro.core.memo.MemoStore`` interface, whose oracle
    implementation wraps exactly this pair of arrays.
    """

    pi: jax.Array            # (D, L, K)
    visited: jax.Array       # (D,) bool


def init_global_state(cfg: LDAConfig, key: jax.Array) -> GlobalState:
    """Random λ initialisation as in the paper (Algorithm 1, line 1).

    Matches the common Gamma(100, 0.01) init of onlineldavb so early
    expectations are well scaled. The single canonical constructor — the
    single-host engines and the distributed master both call it.
    """
    lam = jax.random.gamma(key, 100.0,
                           (cfg.vocab_size, cfg.num_topics)) * 0.01
    return GlobalState(
        lam=lam,
        m_vk=jnp.zeros_like(lam),
        init_mass=lam - cfg.beta0,
        init_frac=jnp.ones(()),
        t=jnp.zeros((), jnp.int32),
    )


def init_memo(cfg: LDAConfig, num_docs: int, max_unique: int) -> Memo:
    """The single canonical raw-memo constructor (zeros, nothing visited)."""
    return Memo(
        pi=jnp.zeros((num_docs, max_unique, cfg.num_topics), jnp.float32),
        visited=jnp.zeros((num_docs,), bool),
    )
