"""Pluggable per-document memo stores — the IVI memory wall, managed.

IVI's defining cost (paper Alg. 1 / eq. 4) is the per-document memo of
token-aligned responsibilities π. Held dense on device in fp32 it is
``D·L·K·4`` bytes — ~51 GB at the Arxiv scale of Table 1 (D=782k, L=128,
K=128) before counting the corpus itself, which is the wall between the
reproduction and the ROADMAP's production-scale target. This module makes
the memo a *pluggable store* behind one contract:

    gather(doc_idx)            -> (π_old (B, L, K) fp32, visited (B,))
    update(doc_idx, π_new, …)  -> store

with three implementations:

* ``DenseMemoStore`` — the oracle: device-resident fp32 ``(D, L, K)``
  (exactly the raw ``types.Memo`` pair). Exact; used by the correctness
  tests and, with a leading worker axis, by the D-IVI worker shards
  (its pure ``gather``/``updated`` trace under vmap/shard_map).
* ``ChunkedMemoStore`` — bf16 storage in host-RAM chunks, fp32 on the
  wire: halves the memo to ``D·L·K·2`` (~25.6 GB at Arxiv scale, under
  the 40 GB single-host budget) and keeps device HBM free of the memo
  entirely; each gather/update round-trips only the touched chunks
  (SCVB0-style compressed statistics, Foulds et al. 2013).
* ``GammaMemoStore`` — γ-only: stores γ (D, K) fp32 plus a per-chunk bf16
  snapshot of Eφ from the chunk's last update, and *recomputes* π_old on
  gather as Eθ(γ)·Eφ_snap/φnorm. ~3.9 GB at Arxiv scale (γ itself is
  0.4 GB; the ⌈D/chunk⌉ ≈ 96 (V, K) bf16 snapshots dominate at 3.5 GB —
  see ``memo_footprint_bytes``). The
  reconstruction is exact only while every document of a chunk was last
  visited under the chunk's snapshot — an approximation intended for the
  S-IVI / D-IVI paths, where the correction enters a Robbins–Monro
  average rather than the exact eq. 4 accumulator.

``gather``/``update`` take an optional ``width`` (≤ L): with the
length-bucketed corpus layout (`repro.data.bow.bucket_corpus`) batches
carry per-bucket padding, so the E-step and the memo traffic shrink to the
bucket width.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:                                      # numpy bf16 dtype (ships with jax)
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:                       # pragma: no cover - jax ships it
    _BF16 = np.dtype(np.float32)

from repro.core.math import exp_dirichlet_expectation
from repro.core.types import Corpus, LDAConfig, init_memo

_EPS = 1e-30


def _chunk_partition(idx: np.ndarray, chunk_docs: int
                     ) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
    """Partition doc indices by chunk: yields (chunk, sel, local) where
    ``idx[sel]`` are the documents landing in ``chunk`` and ``local`` their
    row offsets within it (callers that address whole chunks ignore it)."""
    cid = idx // chunk_docs
    for c in np.unique(cid):
        sel = np.nonzero(cid == c)[0]
        yield int(c), sel, idx[sel] - int(c) * chunk_docs


class MemoStore:
    """One memo contract for every engine (see module docstring)."""

    kind: str = "abstract"
    # wire dtype of the stored π: engines round π through it BEFORE the
    # add-new side of the correction so ⟨m_vk⟩ adds exactly what the store
    # will later subtract (estep.quantize_pi; the Pallas path rounds in
    # its token-π kernel, so the segment-sum scatter already consumes the
    # quantized rows) — the accumulator/memo identity is then an
    # invariant even for low-precision stores
    pi_wire_dtype: str = "float32"
    num_docs: int
    max_unique: int
    num_topics: int

    def gather(self, doc_idx, width: Optional[int] = None
               ) -> Tuple[jax.Array, jax.Array]:
        """Return (π_old (B, width, K) fp32, visited (B,) bool)."""
        raise NotImplementedError

    def update(self, doc_idx, pi: jax.Array, *,
               exp_elog_beta: Optional[jax.Array] = None) -> "MemoStore":
        """Write a batch's new π (B, width, K) and mark it visited.

        CONTRACT: the return value is the only handle valid after the
        call — the pre-update store must be treated as CONSUMED, whichever
        implementation is behind it. The host stores (chunked / γ-only)
        mutate their numpy state in place and return ``self``, so any
        reference kept from before the call aliases the updated state; the
        dense device store returns a new functional value and *donates*
        the old buffers to the scatter, so the old handle's arrays are
        invalidated outright. Callers that need a before/after comparison
        must copy out (``gather``) before updating — holding the old store
        object gives aliased state on one path and a donated-away buffer
        on the other. (``DenseMemoStore.updated`` is the pure, in-jit
        variant with none of this: it leaves ``self`` intact.)

        ``exp_elog_beta`` is the Eφ the E-step ran against — only the
        γ-only store consumes it (chunk snapshot).
        """
        raise NotImplementedError

    def footprint_bytes(self) -> int:
        raise NotImplementedError

    # -- durable state (repro.checkpoint.manifest) ----------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        """The store's full durable state as flat {key: host array}.

        Arrays are returned in the store's OWN storage dtype (bf16 chunks
        stay bf16) so a manifest checkpoint round-trips the memo
        bit-identically — the wire-dtype invariant ⟨m_vk⟩ == Σ scatter(π)
        survives save/restore only if no re-rounding happens here.
        """
        raise NotImplementedError

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> "MemoStore":
        """Restore from ``state_dict`` output. Returns the live handle
        (same consumed-handle contract as ``update``)."""
        raise NotImplementedError

    def iter_chunks(self, batch_docs: int = 512
                    ) -> Iterator[Tuple[np.ndarray, jax.Array, jax.Array]]:
        """Yield (doc_idx, π, visited) over the corpus — the read-through
        path for the memoized ELBO (`repro.core.bound.elbo_memoized_store`)."""
        for lo in range(0, self.num_docs, batch_docs):
            idx = np.arange(lo, min(lo + batch_docs, self.num_docs))
            pi, vis = self.gather(idx)
            yield idx, pi, vis

    def _pad_width(self, pi: jax.Array) -> jax.Array:
        w = pi.shape[1]
        if w == self.max_unique:
            return pi
        return jnp.pad(pi, ((0, 0), (0, self.max_unique - w), (0, 0)))


# ---------------------------------------------------------------------------
# dense device store (oracle)
# ---------------------------------------------------------------------------

@partial(jax.jit, donate_argnums=(0, 1))
def _dense_scatter(pi, visited, idx, new_pi):
    return pi.at[idx].set(new_pi), visited.at[idx].set(True)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DenseMemoStore(MemoStore):
    """Device-resident fp32 memo — the exact oracle.

    A registered pytree: the D-IVI worker shards carry this store (with a
    leading worker axis) straight through vmap / shard_map, using the pure
    ``gather`` / ``updated`` pair. Host engines use ``update``, which
    donates the buffers so the scatter is in-place.
    """

    pi: jax.Array                  # (D, L, K) fp32
    visited: jax.Array             # (D,) bool

    kind = "dense"

    @property
    def num_docs(self) -> int:
        return self.pi.shape[0]

    @property
    def max_unique(self) -> int:
        return self.pi.shape[1]

    @property
    def num_topics(self) -> int:
        return self.pi.shape[2]

    # pure / traceable --------------------------------------------------
    def gather(self, doc_idx, width: Optional[int] = None):
        pi = self.pi[doc_idx]
        if width is not None and width != self.max_unique:
            pi = pi[:, :width]
        return pi, self.visited[doc_idx]

    def updated(self, doc_idx, pi: jax.Array,
                visited_mask: Optional[jax.Array] = None) -> "DenseMemoStore":
        """Functional update (in-jit use; dist workers pass a live mask)."""
        new_vis = (jnp.ones(doc_idx.shape, bool) if visited_mask is None
                   else self.visited[doc_idx] | visited_mask)
        return DenseMemoStore(
            pi=self.pi.at[doc_idx].set(self._pad_width(pi)),
            visited=self.visited.at[doc_idx].set(new_vis))

    # host-side ---------------------------------------------------------
    def update(self, doc_idx, pi, *, exp_elog_beta=None) -> "DenseMemoStore":
        new_pi, new_vis = _dense_scatter(self.pi, self.visited,
                                         jnp.asarray(doc_idx),
                                         self._pad_width(pi))
        return DenseMemoStore(pi=new_pi, visited=new_vis)

    def footprint_bytes(self) -> int:
        return self.pi.size * 4 + self.visited.size

    def state_dict(self) -> Dict[str, np.ndarray]:
        return {"pi": np.asarray(self.pi),
                "visited": np.asarray(self.visited)}

    def load_state_dict(self, state) -> "DenseMemoStore":
        pi = np.asarray(state["pi"])
        if pi.shape != self.pi.shape:
            raise ValueError(f"memo: checkpoint shape {pi.shape} != store "
                             f"{self.pi.shape} — the checkpoint belongs to "
                             "a different corpus/config")
        return DenseMemoStore(pi=jnp.asarray(pi, jnp.float32),
                              visited=jnp.asarray(state["visited"], bool))


# ---------------------------------------------------------------------------
# bf16 chunked host store
# ---------------------------------------------------------------------------

class ChunkedMemoStore(MemoStore):
    """bf16 memo in host-RAM chunks; fp32 only on the device wire.

    Each chunk is an independent ``(chunk_docs, L, K)`` bf16 array, so
    allocation is incremental, updates touch (convert / device_put) only
    the chunks a batch intersects, and a host with ≥ D·L·K·2 bytes of RAM
    holds the Arxiv-scale memo without any device HBM.
    """

    kind = "chunked"
    pi_wire_dtype = "bfloat16"

    def __init__(self, cfg: LDAConfig, num_docs: int, max_unique: int, *,
                 chunk_docs: int = 8192):
        self.num_docs = num_docs
        self.max_unique = max_unique
        self.num_topics = cfg.num_topics
        self.chunk_docs = chunk_docs
        n_chunks = -(-num_docs // chunk_docs)
        self._chunks = [
            np.zeros((min(chunk_docs, num_docs - c * chunk_docs),
                      max_unique, cfg.num_topics), _BF16)
            for c in range(n_chunks)
        ]
        self._visited = np.zeros((num_docs,), bool)

    def gather(self, doc_idx, width: Optional[int] = None):
        idx = np.asarray(doc_idx)
        w = self.max_unique if width is None else width
        out = np.zeros((len(idx), w, self.num_topics), np.float32)
        for c, sel, local in _chunk_partition(idx, self.chunk_docs):
            out[sel] = self._chunks[c][local, :w].astype(np.float32)
        return jnp.asarray(out), jnp.asarray(self._visited[idx])

    def update(self, doc_idx, pi, *, exp_elog_beta=None) -> "ChunkedMemoStore":
        idx = np.asarray(doc_idx)
        w = pi.shape[1]
        vals = np.asarray(pi)                  # device→host, per batch
        for c, sel, local in _chunk_partition(idx, self.chunk_docs):
            self._chunks[c][local, :w] = vals[sel].astype(_BF16)
            if w < self.max_unique:
                self._chunks[c][local, w:] = 0
        self._visited[idx] = True
        return self

    def footprint_bytes(self) -> int:
        return sum(ch.nbytes for ch in self._chunks) + self._visited.nbytes

    def state_dict(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {"visited": self._visited}
        for c, chunk in enumerate(self._chunks):
            out[f"chunk_{c:05d}"] = chunk       # bf16 as stored, no rounding
        return out

    def load_state_dict(self, state) -> "ChunkedMemoStore":
        for c in range(len(self._chunks)):
            chunk = np.asarray(state[f"chunk_{c:05d}"])
            if chunk.shape != self._chunks[c].shape:
                raise ValueError(f"memo chunk {c}: checkpoint shape "
                                 f"{chunk.shape} != store {self._chunks[c].shape}")
            self._chunks[c] = chunk.astype(_BF16, copy=False)
        self._visited[:] = np.asarray(state["visited"], bool)
        return self


# ---------------------------------------------------------------------------
# γ-only store with per-chunk λ-epoch snapshots
# ---------------------------------------------------------------------------

class GammaMemoStore(MemoStore):
    """Store γ, recompute π — for the averaged (S-IVI / D-IVI) paths.

    On update the store keeps γ_memo = α₀ + Σ_l cnt·π (Alg. 1 line 6) per
    document plus ONE bf16 snapshot of Eφ per chunk (the "λ-epoch" of the
    chunk's most recent update). On gather it reconstructs

        π̃ = Eθ(γ_memo) ⊙ Eφ_snap[ids] / φnorm

    which equals the memoized π exactly when every document of the chunk
    was last visited under the snapshot's λ, and is otherwise a bounded
    approximation — acceptable where the correction is folded into the
    Robbins–Monro average (eq. 5), NOT for the exact eq. 4 accumulator.
    """

    kind = "gamma"

    def __init__(self, cfg: LDAConfig, corpus: Corpus, *,
                 chunk_docs: int = 8192):
        self.cfg = cfg
        self.num_docs = corpus.num_docs
        self.max_unique = corpus.max_unique
        self.num_topics = cfg.num_topics
        self.chunk_docs = chunk_docs
        self._ids = np.asarray(corpus.token_ids)
        self._cnts = np.asarray(corpus.counts)
        self._gamma = np.full((self.num_docs, cfg.num_topics),
                              cfg.alpha0, np.float32)
        self._snap: Dict[int, np.ndarray] = {}     # chunk → (V, K) bf16
        self._visited = np.zeros((self.num_docs,), bool)

    def gather(self, doc_idx, width: Optional[int] = None):
        idx = np.asarray(doc_idx)
        w = self.max_unique if width is None else width
        # stage per-chunk reconstructions into ONE host buffer (as the
        # chunked store does) — a functional out.at[sel].set(pi) would copy
        # the whole (B, w, K) output once per touched chunk
        out = np.zeros((len(idx), w, self.num_topics), np.float32)
        vis = self._visited[idx]
        for c, sel, _local in _chunk_partition(idx, self.chunk_docs):
            if c not in self._snap:
                continue
            rows = idx[sel]
            eb = jnp.asarray(self._snap[c].astype(np.float32))
            et = exp_dirichlet_expectation(jnp.asarray(self._gamma[rows]))
            ebg = eb[jnp.asarray(self._ids[rows, :w])]          # (b, w, K)
            p = jnp.einsum("bk,blk->bl", et, ebg) + _EPS
            pi = et[:, None, :] * ebg / p[:, :, None]
            pi = jnp.where(jnp.asarray(self._cnts[rows, :w])[:, :, None] > 0,
                           pi, 0.0)
            pi = jnp.where(jnp.asarray(vis[sel])[:, None, None], pi, 0.0)
            out[sel] = np.asarray(pi)
        return jnp.asarray(out), jnp.asarray(vis)

    def update(self, doc_idx, pi, *, exp_elog_beta=None) -> "GammaMemoStore":
        if exp_elog_beta is None:
            raise ValueError("GammaMemoStore.update needs exp_elog_beta "
                             "(the Eφ the E-step ran against)")
        idx = np.asarray(doc_idx)
        w = pi.shape[1]
        gamma = self.cfg.alpha0 + jnp.einsum(
            "blk,bl->bk", pi, jnp.asarray(self._cnts[idx, :w]))
        self._gamma[idx] = np.asarray(gamma)
        snap = np.asarray(exp_elog_beta).astype(_BF16)
        for c, _sel, _local in _chunk_partition(idx, self.chunk_docs):
            self._snap[c] = snap
        self._visited[idx] = True
        return self

    def footprint_bytes(self) -> int:
        return (self._gamma.nbytes + self._visited.nbytes
                + sum(s.nbytes for s in self._snap.values()))

    def state_dict(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {"gamma": self._gamma,
                                      "visited": self._visited}
        for c, snap in self._snap.items():
            out[f"snap_{c:05d}"] = snap         # the λ-epoch bf16 snapshots
        return out

    def load_state_dict(self, state) -> "GammaMemoStore":
        self._gamma[:] = np.asarray(state["gamma"], np.float32)
        self._visited[:] = np.asarray(state["visited"], bool)
        self._snap = {int(k[len("snap_"):]): np.asarray(v).astype(_BF16,
                                                                  copy=False)
                      for k, v in state.items() if k.startswith("snap_")}
        return self


# ---------------------------------------------------------------------------
# construction + footprint math
# ---------------------------------------------------------------------------

def make_memo_store(kind: str, cfg: LDAConfig, num_docs: int,
                    max_unique: int, *, corpus: Optional[Corpus] = None,
                    chunk_docs: int = 8192) -> MemoStore:
    if kind == "dense":
        raw = init_memo(cfg, num_docs, max_unique)
        return DenseMemoStore(pi=raw.pi, visited=raw.visited)
    if kind == "chunked":
        return ChunkedMemoStore(cfg, num_docs, max_unique,
                                chunk_docs=chunk_docs)
    if kind == "gamma":
        if corpus is None:
            raise ValueError("gamma store needs the corpus (π reconstruction)")
        return GammaMemoStore(cfg, corpus, chunk_docs=chunk_docs)
    raise ValueError(f"unknown memo store kind: {kind!r} "
                     "(have dense | chunked | gamma)")


def memo_footprint_bytes(kind: str, num_docs: int, max_unique: int,
                         num_topics: int, vocab_size: int = 0,
                         chunk_docs: int = 8192) -> int:
    """Footprint math without allocating — used by the dry-run report."""
    if kind == "dense":
        return num_docs * max_unique * num_topics * 4 + num_docs
    if kind == "chunked":
        return num_docs * max_unique * num_topics * 2 + num_docs
    if kind == "gamma":
        n_chunks = -(-num_docs // chunk_docs)
        return (num_docs * num_topics * 4 + num_docs
                + n_chunks * vocab_size * num_topics * 2)
    raise ValueError(f"unknown memo store kind: {kind!r}")
