"""Shared LDA variational math: Dirichlet expectations and bound pieces."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma, gammaln


def dirichlet_expectation(a: jax.Array, axis: int = -1) -> jax.Array:
    """E_q[ln x] for x ~ Dirichlet(a) along ``axis``: ψ(a) − ψ(Σa)."""
    return digamma(a) - digamma(a.sum(axis=axis, keepdims=True))


def exp_dirichlet_expectation(a: jax.Array, axis: int = -1) -> jax.Array:
    return jnp.exp(dirichlet_expectation(a, axis=axis))


def dirichlet_elbo_term(post: jax.Array, prior0: float,
                        elog: jax.Array, axis: int = -1) -> jax.Array:
    """E_q[ln p(x; prior)] − E_q[ln q(x; post)] summed over all Dirichlets.

    ``post`` is the posterior parameter array with the Dirichlet dimension on
    ``axis``; ``elog`` is E_q[ln x] with matching shape; ``prior0`` the
    symmetric prior. Returns a scalar.
    """
    n = post.shape[axis]
    kl = (
        jnp.sum((prior0 - post) * elog)
        + jnp.sum(gammaln(post))
        - jnp.sum(gammaln(post.sum(axis=axis)))
    )
    num = post.size // n
    const = num * (gammaln(n * prior0) - n * gammaln(prior0))
    return kl + const


def safe_normalize(x: jax.Array, axis: int = -1,
                   eps: float = 1e-30) -> jax.Array:
    return x / (x.sum(axis=axis, keepdims=True) + eps)
