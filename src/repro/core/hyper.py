"""Symmetric Dirichlet hyperparameter learning (Minka fixed point).

Real LDA deployments learn α₀ and β₀ rather than hand-setting them; the
paper fixes them (§6) so these updates are OFF by default, exposed through
``LDAEngine``-compatible helpers for the examples/benchmarks.

Fixed-point for a symmetric Dirichlet prior a over dimension K given
posterior parameter rows θ_d ~ Dir(γ_d):

    a ← a · Σ_d Σ_k [ψ(γ_dk) − ψ(a_old)] / (K · Σ_d [ψ(Σ_k γ_dk) − ψ(K a_old)])
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma


def minka_update(a: jax.Array, post: jax.Array, iters: int = 5,
                 floor: float = 1e-4) -> jax.Array:
    """One-or-more Minka fixed-point steps for symmetric prior ``a``.

    post: (N, K) posterior Dirichlet parameters whose prior is a·1_K.
    """
    n, k = post.shape

    def body(a_cur, _):
        num = jnp.sum(digamma(post) - digamma(a_cur))
        den = k * jnp.sum(digamma(post.sum(-1)) - digamma(k * a_cur))
        a_new = a_cur * num / jnp.maximum(den, 1e-12)
        return jnp.maximum(a_new, floor), None

    a_out, _ = jax.lax.scan(body, jnp.asarray(a, jnp.float32), None,
                            length=iters)
    return a_out


def update_alpha0(alpha0: float, gammas: jax.Array, iters: int = 5) -> float:
    """Learn the document-topic prior from fitted γ (D, K)."""
    return float(minka_update(alpha0, gammas, iters))


def update_beta0(beta0: float, lam: jax.Array, iters: int = 5) -> float:
    """Learn the topic-word prior from λ (V, K) — Dirichlets live on V."""
    return float(minka_update(beta0, lam.T, iters))
