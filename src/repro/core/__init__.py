"""The paper's primary contribution: incremental variational inference.

MVI / SVI baselines plus the paper's IVI, S-IVI (single host) and D-IVI
(distributed, in ``repro.dist``) engines for LDA.
"""
from repro.core.types import (Corpus, LDAConfig, GlobalState, Memo,
                              init_global_state, init_memo)
from repro.core.engines import (EngineState, LDAEngine, incremental_update,
                                init_engine_state, ivi_step, memo_correction,
                                mvi_scan, sivi_step, svi_step)
from repro.core.estep import (BowBatch, EStepBackend, EStepResult, estep,
                              estep_dense, estep_gather, get_backend)
from repro.core.memo import (ChunkedMemoStore, DenseMemoStore, GammaMemoStore,
                             MemoStore, make_memo_store, memo_footprint_bytes)
from repro.core.bound import elbo_collapsed, elbo_memoized, elbo_memoized_store
from repro.core.predictive import log_predictive, split_heldout
from repro.core.cvb0 import CVB0Engine, cvb0_step, init_cvb0
from repro.core.metrics import effective_topics, npmi_coherence, top_words
from repro.core.hyper import update_alpha0, update_beta0
