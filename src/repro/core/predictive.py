"""Held-out per-word predictive probability (the paper's §6 metric).

Protocol (Blei et al. 2003, as used in the paper): for each test document,
fit the topic proportions on the first half of its words with the learned
topics frozen, then score the second half under the predictive distribution
p(w) = Σ_k θ̄_k φ̄_wk. Higher is better.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estep import estep_gather
from repro.core.math import safe_normalize
from repro.core.types import Corpus, LDAConfig
from repro.core.math import exp_dirichlet_expectation


def split_heldout(corpus: Corpus, seed: int = 0) -> Tuple[Corpus, Corpus]:
    """Split each document's counts in half (observed / held-out).

    Done on host with numpy: for each unique token, half the occurrences
    (rounded alternately) go to the observed part. Token slots whose count
    splits to zero stay in the layout with count 0 (harmless padding).
    """
    rng = np.random.default_rng(seed)
    cnt = np.asarray(corpus.counts)
    obs = np.floor(cnt / 2.0)
    rem = cnt - 2 * obs
    coin = rng.integers(0, 2, size=cnt.shape).astype(cnt.dtype)
    obs = obs + rem * coin
    held = cnt - obs
    ids = np.asarray(corpus.token_ids)
    return (
        Corpus(jnp.asarray(ids), jnp.asarray(obs.astype(np.float32))),
        Corpus(jnp.asarray(ids), jnp.asarray(held.astype(np.float32))),
    )


@partial(jax.jit, static_argnames=("cfg",))
def log_predictive(cfg: LDAConfig, lam: jax.Array, observed: Corpus,
                   heldout: Corpus) -> jax.Array:
    """Average per-word log predictive probability on held-out halves."""
    exp_elog_beta = exp_dirichlet_expectation(lam, axis=0)   # (V, K)
    res = estep_gather(cfg, exp_elog_beta, observed.token_ids, observed.counts)
    theta_bar = safe_normalize(res.gamma, axis=-1)           # (D, K)
    phi_bar = lam / lam.sum(axis=0, keepdims=True)           # (V, K)
    probs = jnp.einsum("dk,dlk->dl", theta_bar, phi_bar[heldout.token_ids])
    logp = jnp.where(heldout.counts > 0, jnp.log(probs + 1e-30), 0.0)
    total = jnp.sum(heldout.counts * logp)
    return total / jnp.maximum(heldout.counts.sum(), 1.0)
