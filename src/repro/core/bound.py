"""Variational lower bound (ELBO) for LDA.

Two evaluations:

* ``elbo_memoized`` — the exact bound at the current (γ, memoized π, λ).
  This is the objective IVI provably increases monotonically (§3): the
  per-word term uses the *stored* responsibilities, so stale documents
  contribute their memoized statistics exactly as in incremental EM.
* ``elbo_collapsed`` — the bound with π analytically maximised given (γ, λ)
  (Hoffman et al.'s ``approx_bound``); cheaper, used for monitoring MVI/SVI.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.core.math import dirichlet_elbo_term, dirichlet_expectation
from repro.core.types import Corpus, LDAConfig

_EPS = 1e-30


def _topics_term(cfg: LDAConfig, lam: jax.Array) -> jax.Array:
    elog_beta = dirichlet_expectation(lam, axis=0)         # (V, K)
    return dirichlet_elbo_term(lam, cfg.beta0, elog_beta, axis=0)


@partial(jax.jit, static_argnames=("cfg",))
def elbo_memoized(cfg: LDAConfig, corpus: Corpus, gamma: jax.Array,
                  pi: jax.Array, lam: jax.Array) -> jax.Array:
    """Exact ELBO at (γ, π, λ); π token-aligned (D, L, K), zero at padding."""
    elog_theta = dirichlet_expectation(gamma)              # (D, K)
    elog_beta = dirichlet_expectation(lam, axis=0)         # (V, K)
    eb = elog_beta[corpus.token_ids]                       # (D, L, K)
    # Σ_d Σ_l cnt Σ_k π (E[lnθ] + E[lnφ] − ln π)
    inner = pi * (elog_theta[:, None, :] + eb - jnp.log(pi + _EPS))
    words = jnp.sum(corpus.counts[:, :, None] * inner)
    theta_term = dirichlet_elbo_term(gamma, cfg.alpha0, elog_theta, axis=-1)
    return words + theta_term + _topics_term(cfg, lam)


@partial(jax.jit, static_argnames=("cfg",))
def elbo_collapsed(cfg: LDAConfig, corpus: Corpus, gamma: jax.Array,
                   lam: jax.Array) -> jax.Array:
    """ELBO with π at its optimum given (γ, λ)."""
    elog_theta = dirichlet_expectation(gamma)              # (D, K)
    elog_beta = dirichlet_expectation(lam, axis=0)         # (V, K)
    eb = elog_beta[corpus.token_ids]                       # (D, L, K)
    lse = logsumexp(elog_theta[:, None, :] + eb, axis=-1)  # (D, L)
    words = jnp.sum(corpus.counts * lse)
    theta_term = dirichlet_elbo_term(gamma, cfg.alpha0, elog_theta, axis=-1)
    return words + theta_term + _topics_term(cfg, lam)
