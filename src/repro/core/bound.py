"""Variational lower bound (ELBO) for LDA.

Two evaluations:

* ``elbo_memoized`` — the exact bound at the current (γ, memoized π, λ).
  This is the objective IVI provably increases monotonically (§3): the
  per-word term uses the *stored* responsibilities, so stale documents
  contribute their memoized statistics exactly as in incremental EM.
* ``elbo_collapsed`` — the bound with π analytically maximised given (γ, λ)
  (Hoffman et al.'s ``approx_bound``); cheaper, used for monitoring MVI/SVI.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from repro.core.math import dirichlet_elbo_term, dirichlet_expectation
from repro.core.types import Corpus, LDAConfig

_EPS = 1e-30


def _topics_term(cfg: LDAConfig, lam: jax.Array) -> jax.Array:
    elog_beta = dirichlet_expectation(lam, axis=0)         # (V, K)
    return dirichlet_elbo_term(lam, cfg.beta0, elog_beta, axis=0)


@partial(jax.jit, static_argnames=("cfg",))
def _memoized_doc_terms(cfg: LDAConfig, token_ids: jax.Array,
                        counts: jax.Array, gamma: jax.Array, pi: jax.Array,
                        elog_beta: jax.Array) -> jax.Array:
    """Per-document ELBO terms at memoized π: words + θ-Dirichlet pieces."""
    elog_theta = dirichlet_expectation(gamma)              # (B, K)
    eb = elog_beta[token_ids]                              # (B, L, K)
    # Σ_d Σ_l cnt Σ_k π (E[lnθ] + E[lnφ] − ln π)
    inner = pi * (elog_theta[:, None, :] + eb - jnp.log(pi + _EPS))
    words = jnp.sum(counts[:, :, None] * inner)
    return words + dirichlet_elbo_term(gamma, cfg.alpha0, elog_theta, axis=-1)


@partial(jax.jit, static_argnames=("cfg",))
def elbo_memoized(cfg: LDAConfig, corpus: Corpus, gamma: jax.Array,
                  pi: jax.Array, lam: jax.Array) -> jax.Array:
    """Exact ELBO at (γ, π, λ); π token-aligned (D, L, K), zero at padding."""
    doc_terms = _memoized_doc_terms(cfg, corpus.token_ids, corpus.counts,
                                    gamma, pi, dirichlet_expectation(lam,
                                                                     axis=0))
    return doc_terms + _topics_term(cfg, lam)


def elbo_memoized_docs(cfg: LDAConfig, corpus: Corpus, store,
                       elog_beta: jax.Array, *,
                       batch_docs: int = 512) -> jax.Array:
    """Document terms of the memoized ELBO, read through a ``MemoStore``.

    Never materialises the (D, L, K) memo: each store chunk is gathered,
    its γ reconstructed from the memo (γ = α₀ + Σ_l cnt·π, Alg. 1 line 6),
    and its word/θ terms accumulated. The λ-Dirichlet topics term is NOT
    included — that is what makes this the per-shard reduction unit of the
    distributed bound (`DIVITrainer.full_bound`): every worker shard
    contributes its documents' terms independently and the topics term
    enters exactly once at the end, with no all-gather of the memo shards.
    """
    total = jnp.zeros(())
    for idx, pi, _vis in store.iter_chunks(batch_docs):
        ids = corpus.token_ids[jnp.asarray(idx)]
        cnts = corpus.counts[jnp.asarray(idx)]
        gamma = cfg.alpha0 + jnp.einsum("blk,bl->bk", pi, cnts)
        total = total + _memoized_doc_terms(cfg, ids, cnts, gamma, pi,
                                            elog_beta)
    return total


def elbo_memoized_store(cfg: LDAConfig, corpus: Corpus, store,
                        lam: jax.Array, *, batch_docs: int = 512) -> jax.Array:
    """The memoized ELBO read through a ``MemoStore``, chunk by chunk.

    ``elbo_memoized_docs`` plus the topics term. With the dense store this
    equals ``elbo_memoized`` up to fp summation order; with the
    bf16-chunked or γ-only stores the π that enters IS the store's
    (compressed) memo, so the bound reported is the bound of the state the
    engine actually holds.
    """
    docs = elbo_memoized_docs(cfg, corpus, store,
                              dirichlet_expectation(lam, axis=0),
                              batch_docs=batch_docs)
    return docs + _topics_term(cfg, lam)


@partial(jax.jit, static_argnames=("cfg",))
def _collapsed_doc_terms(cfg: LDAConfig, token_ids: jax.Array,
                         counts: jax.Array, gamma: jax.Array,
                         elog_beta: jax.Array) -> jax.Array:
    """Per-document collapsed-π terms: words + θ-Dirichlet pieces."""
    elog_theta = dirichlet_expectation(gamma)              # (B, K)
    eb = elog_beta[token_ids]                              # (B, L, K)
    lse = logsumexp(elog_theta[:, None, :] + eb, axis=-1)  # (B, L)
    words = jnp.sum(counts * lse)
    return words + dirichlet_elbo_term(gamma, cfg.alpha0, elog_theta, axis=-1)


@partial(jax.jit, static_argnames=("cfg",))
def elbo_collapsed(cfg: LDAConfig, corpus: Corpus, gamma: jax.Array,
                   lam: jax.Array) -> jax.Array:
    """ELBO with π at its optimum given (γ, λ)."""
    elog_beta = dirichlet_expectation(lam, axis=0)         # (V, K)
    docs = _collapsed_doc_terms(cfg, corpus.token_ids, corpus.counts,
                                gamma, elog_beta)
    return docs + _topics_term(cfg, lam)


# ---------------------------------------------------------------------------
# stream-fed variants: no (D, L) corpus resident, chunk-by-chunk read-through
# ---------------------------------------------------------------------------

def elbo_memoized_stream(cfg: LDAConfig, stream, store, lam: jax.Array, *,
                         batch_docs: int = 512) -> jax.Array:
    """The memoized ELBO when the corpus is a ``DocStream``.

    The streaming analogue of ``elbo_memoized_store``: documents are pulled
    and padded ``batch_docs`` at a time (`data.stream.iter_padded_chunks`,
    sequential — the same doc order ``MemoStore.iter_chunks`` walks), the
    matching memo rows gathered, and each chunk's word/θ terms accumulated;
    the λ-Dirichlet topics term enters once. Peak resident corpus state is
    one chunk.
    """
    import numpy as np

    from repro.data.stream import iter_padded_chunks

    elog_beta = dirichlet_expectation(lam, axis=0)
    total = jnp.zeros(())
    for start, ids, cnts in iter_padded_chunks(stream, batch_docs,
                                               stream.max_unique):
        pi, _vis = store.gather(np.arange(start, start + ids.shape[0]))
        cnts_j = jnp.asarray(cnts)
        gamma = cfg.alpha0 + jnp.einsum("blk,bl->bk", pi, cnts_j)
        total = total + _memoized_doc_terms(cfg, jnp.asarray(ids), cnts_j,
                                            gamma, pi, elog_beta)
    return total + _topics_term(cfg, lam)


def elbo_collapsed_stream(cfg: LDAConfig, stream, lam: jax.Array, *,
                          batch_docs: int = 512) -> jax.Array:
    """Collapsed corpus bound over a ``DocStream`` (the MVI/SVI monitoring
    path): a fresh token-gather E-step per chunk, doc terms accumulated,
    topics term once — never a full-corpus (D, L, K) intermediate."""
    from repro.core.estep import estep_gather
    from repro.core.math import exp_dirichlet_expectation
    from repro.data.stream import iter_padded_chunks

    elog_beta = dirichlet_expectation(lam, axis=0)
    eb = exp_dirichlet_expectation(lam, axis=0)
    total = jnp.zeros(())
    for _start, ids, cnts in iter_padded_chunks(stream, batch_docs,
                                                stream.max_unique):
        ids_j, cnts_j = jnp.asarray(ids), jnp.asarray(cnts)
        res = estep_gather(cfg, eb, ids_j, cnts_j)
        total = total + _collapsed_doc_terms(cfg, ids_j, cnts_j, res.gamma,
                                             elog_beta)
    return total + _topics_term(cfg, lam)
