"""Batched variational E-step for LDA.

Two interchangeable formulations:

* ``gather`` — token-aligned: gathers rows of exp(E[ln φ]) at the batch's
  token ids, shape (B, L, K). Memory-proportional to batch token count;
  the default on CPU and for the engines' correctness paths.
* ``dense`` — densifies the mini-batch into a count matrix C (B, V) so one
  fixed-point sweep is two MXU matmuls. This is the formulation the Pallas
  kernel (`repro.kernels.lda_estep`) implements; ``dense`` here is its
  pure-jnp twin and oracle.

Both return the converged document-topic parameter γ and the memoized
responsibilities π in token layout (B, L, K) — the quantity IVI stores.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.math import exp_dirichlet_expectation
from repro.core.types import LDAConfig

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


class EStepResult(NamedTuple):
    gamma: jax.Array      # (B, K)
    pi: jax.Array         # (B, L, K) token-aligned responsibilities
    sstats: jax.Array     # (V, K) Σ_d Σ_l cnt·π scattered at token ids
    iters: jax.Array      # () int32 fixed-point iterations used


def _fixed_point(cfg: LDAConfig, update_fn, gamma0: jax.Array):
    """Run γ ← update(γ) until mean |Δγ| < tol or max_iters."""

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta > cfg.estep_tol, it < cfg.estep_max_iters)

    def body(carry):
        gamma, _, it = carry
        gamma_new = update_fn(gamma)
        delta = jnp.abs(gamma_new - gamma).mean()
        return gamma_new, delta, it + 1

    init = (gamma0, jnp.asarray(jnp.inf, gamma0.dtype), jnp.asarray(0, jnp.int32))
    gamma, _, iters = jax.lax.while_loop(cond, body, init)
    return gamma, iters


def scatter_sstats(token_ids: jax.Array, weighted_pi: jax.Array,
                   vocab_size: int) -> jax.Array:
    """Scatter (B, L, K) token-aligned weighted responsibilities into (V, K)."""
    k = weighted_pi.shape[-1]
    flat_ids = token_ids.reshape(-1)
    flat_vals = weighted_pi.reshape(-1, k)
    return jnp.zeros((vocab_size, k), weighted_pi.dtype).at[flat_ids].add(flat_vals)


@partial(jax.jit, static_argnames=("cfg",))
def estep_gather(cfg: LDAConfig, exp_elog_beta: jax.Array,
                 token_ids: jax.Array, counts: jax.Array,
                 gamma0: Optional[jax.Array] = None) -> EStepResult:
    """Token-aligned batched E-step (Algorithm 1, lines 4–7).

    Args:
      exp_elog_beta: (V, K) exp(E[ln φ]).
      token_ids / counts: (B, L) padded unique-token BOW batch.
    """
    b = token_ids.shape[0]
    eb = exp_elog_beta[token_ids]                      # (B, L, K)
    if gamma0 is None:
        gamma0 = jnp.full((b, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)

    def update(gamma):
        etheta = exp_dirichlet_expectation(gamma)      # (B, K)
        p = jnp.einsum("bk,blk->bl", etheta, eb) + _EPS
        return cfg.alpha0 + etheta * jnp.einsum("bl,blk->bk", counts / p, eb)

    gamma, iters = _fixed_point(cfg, update, gamma0)

    etheta = exp_dirichlet_expectation(gamma)
    p = jnp.einsum("bk,blk->bl", etheta, eb) + _EPS
    pi = etheta[:, None, :] * eb / p[:, :, None]       # (B, L, K)
    pi = jnp.where(counts[:, :, None] > 0, pi, 0.0)
    sstats = scatter_sstats(token_ids, counts[:, :, None] * pi,
                            exp_elog_beta.shape[0])
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats, iters=iters)


def densify(token_ids: jax.Array, counts: jax.Array,
            vocab_size: int) -> jax.Array:
    """(B, L) BOW → dense count matrix C (B, V)."""
    b = token_ids.shape[0]
    c = jnp.zeros((b, vocab_size), counts.dtype)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], token_ids.shape)
    return c.at[rows.reshape(-1), token_ids.reshape(-1)].add(counts.reshape(-1))


@partial(jax.jit, static_argnames=("cfg",))
def estep_dense(cfg: LDAConfig, exp_elog_beta: jax.Array,
                token_ids: jax.Array, counts: jax.Array,
                gamma0: Optional[jax.Array] = None) -> EStepResult:
    """Dense-count E-step: one sweep = two (B,V)×(V,K) matmuls.

    The TPU-native formulation (DESIGN.md §2): MXU-friendly, no gathers.
    Matches ``estep_gather`` exactly (same fixed point, same π).
    """
    b = token_ids.shape[0]
    v = exp_elog_beta.shape[0]
    c = densify(token_ids, counts, v)                  # (B, V)
    if gamma0 is None:
        gamma0 = jnp.full((b, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)

    def update(gamma):
        etheta = exp_dirichlet_expectation(gamma)      # (B, K)
        p = etheta @ exp_elog_beta.T + _EPS            # (B, V)
        return cfg.alpha0 + etheta * ((c / p) @ exp_elog_beta)

    gamma, iters = _fixed_point(cfg, update, gamma0)

    etheta = exp_dirichlet_expectation(gamma)
    p = etheta @ exp_elog_beta.T + _EPS
    sstats = exp_elog_beta * ((c / p).T @ etheta)      # (V, K)
    # token-aligned π for the memo, recovered by gathering the dense solution
    eb = exp_elog_beta[token_ids]
    p_tok = jnp.einsum("bk,blk->bl", etheta, eb) + _EPS
    pi = etheta[:, None, :] * eb / p_tok[:, :, None]
    pi = jnp.where(counts[:, :, None] > 0, pi, 0.0)
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats, iters=iters)


def estep(cfg: LDAConfig, exp_elog_beta: jax.Array, token_ids: jax.Array,
          counts: jax.Array, gamma0: Optional[jax.Array] = None) -> EStepResult:
    """Dispatch on ``cfg.estep_backend``."""
    if cfg.estep_backend == "gather":
        return estep_gather(cfg, exp_elog_beta, token_ids, counts, gamma0)
    if cfg.estep_backend == "dense":
        return estep_dense(cfg, exp_elog_beta, token_ids, counts, gamma0)
    if cfg.estep_backend == "pallas":
        from repro.kernels import ops as kops
        return kops.estep_pallas(cfg, exp_elog_beta, token_ids, counts, gamma0)
    raise ValueError(f"unknown estep backend: {cfg.estep_backend}")
