"""Batched variational E-step for LDA, behind one backend contract.

Every engine (MVI / SVI / IVI / S-IVI / D-IVI) consumes the E-step through
``EStepBackend`` — the single protocol all formulations implement:

* ``solve(cfg, exp_elog_beta, batch, gamma0) -> EStepResult`` — run the
  per-document fixed point (Alg. 1 lines 4–7) on a padded BOW mini-batch.
* ``solve_correction(cfg, exp_elog_beta, batch, old_pi, visited)`` — the
  IVI hot path: E-step **plus** the subtract-old/add-new memo correction
  Σ_d cnt·(π_new − π_old) scattered into (V, K), with γ warm-started from
  the memo for visited documents.

Four backends:

* ``gather`` — token-aligned: gathers rows of exp(E[ln φ]) at the batch's
  token ids, shape (B, L, K). Memory-proportional to batch token count;
  the default on CPU and for the engines' correctness paths.
* ``dense`` — densifies the mini-batch into a count matrix C (B, V) so one
  fixed-point sweep is two MXU matmuls: the pure-jnp oracle of the kernels.
* ``pallas`` — the TPU kernels (`repro.kernels.ops`): the whole γ fixed
  point is ONE fused ``pallas_call`` (γ/Eθ resident in VMEM scratch, Eφ
  streamed once per sweep via the V grid, in-kernel convergence flag), and
  ``solve_correction`` emits token-aligned π and the (V, K) correction
  from the segment-sum ``memo_delta`` pair — a token-π kernel tiling
  (B, L) and a V-chunk scatter — with no (B, L, K) jnp intermediates and
  no dense (nb, V, K) scatter partials.
* ``csr`` — the width-free CSR kernels behind the padded contract (a
  (B, L) batch flattens losslessly to a token stream), so the same
  equivalence tests pin them against gather/dense.

Every backend also implements the **flat-token contract**
(``solve_tokens`` / ``solve_correction_tokens`` over a ``CSRTokenBatch``
— a concatenated (T,) token stream with per-token segment ids): the jnp
``segment_sum`` reference by default, the Pallas CSR kernels on the
``pallas``/``csr`` backends. That is the path the CSR stream pipeline and
ragged serving consume — zero padding, one compiled entry for every
document-length mix.

All backends return the converged document-topic parameter γ and the
memoized responsibilities π in token layout — (B, L, K) on the padded
contract, (T, K) on the flat one; both are the quantity IVI stores.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.math import exp_dirichlet_expectation
from repro.core.types import LDAConfig

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


class BowBatch(NamedTuple):
    """A padded unique-token bag-of-words mini-batch (both (B, L))."""

    token_ids: jax.Array
    counts: jax.Array


class CSRTokenBatch(NamedTuple):
    """A flat CSR mini-batch: every document's tokens concatenated.

    ``segments[t]`` is the local document row owning token ``t``; padding
    tokens carry segment 0 with count 0 (inert in every reduction). The
    zero-padding twin of ``BowBatch`` — same fixed point, token layout
    (T,) instead of (B, L)."""

    token_ids: jax.Array  # (T,) int32
    counts: jax.Array     # (T,) float32
    segments: jax.Array   # (T,) int32 in [0, B)


class EStepResult(NamedTuple):
    gamma: jax.Array      # (B, K)
    pi: jax.Array         # (B, L, K) token-aligned responsibilities
                          # (flat-token paths: (T, K))
    sstats: jax.Array     # (V, K) Σ_d Σ_l cnt·π scattered at token ids
    iters: jax.Array      # () int32 fixed-point iterations used


def _fixed_point(cfg: LDAConfig, update_fn, gamma0: jax.Array):
    """Run γ ← update(γ) until mean |Δγ| < tol or max_iters."""

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta > cfg.estep_tol, it < cfg.estep_max_iters)

    def body(carry):
        gamma, _, it = carry
        gamma_new = update_fn(gamma)
        delta = jnp.abs(gamma_new - gamma).mean()
        return gamma_new, delta, it + 1

    init = (gamma0, jnp.asarray(jnp.inf, gamma0.dtype), jnp.asarray(0, jnp.int32))
    gamma, _, iters = jax.lax.while_loop(cond, body, init)
    return gamma, iters


def scatter_sstats(token_ids: jax.Array, weighted_pi: jax.Array,
                   vocab_size: int) -> jax.Array:
    """Scatter (B, L, K) token-aligned weighted responsibilities into (V, K)."""
    k = weighted_pi.shape[-1]
    flat_ids = token_ids.reshape(-1)
    flat_vals = weighted_pi.reshape(-1, k)
    return jnp.zeros((vocab_size, k), weighted_pi.dtype).at[flat_ids].add(flat_vals)


def quantize_pi(pi: jax.Array, pi_dtype: str) -> jax.Array:
    """Round π through the memo store's wire dtype (fp32 result)."""
    if pi_dtype == "float32":
        return pi
    return pi.astype(jnp.dtype(pi_dtype)).astype(jnp.float32)


def warm_start_gamma(cfg: LDAConfig, counts: jax.Array, old_pi: jax.Array,
                     visited: jax.Array) -> jax.Array:
    """Memo-derived γ₀ (Alg. 1 line 6) for visited docs, fresh otherwise.

    Coordinate ascent from the memoized point can only improve the bound,
    which is what makes IVI's monotonicity exact (fresh inits could hop to
    a worse local optimum of the per-document subproblem).
    """
    gamma_memo = cfg.alpha0 + jnp.einsum("blk,bl->bk", old_pi, counts)
    fresh = jnp.full_like(gamma_memo, cfg.alpha0 + 1.0)
    return jnp.where(visited[:, None], gamma_memo, fresh)


# ---------------------------------------------------------------------------
# flat-token (CSR) formulation
# ---------------------------------------------------------------------------

def segment_sum_docs(values: jax.Array, segments: jax.Array,
                     num_docs: int) -> jax.Array:
    """Σ over each document's tokens: (T, ...) → (num_docs, ...)."""
    return jax.ops.segment_sum(values, segments, num_segments=num_docs)


def scatter_sstats_flat(token_ids: jax.Array, weighted_pi: jax.Array,
                        vocab_size: int) -> jax.Array:
    """Scatter (T, K) flat weighted responsibilities into (V, K)."""
    k = weighted_pi.shape[-1]
    return jnp.zeros((vocab_size, k),
                     weighted_pi.dtype).at[token_ids].add(weighted_pi)


def warm_start_gamma_flat(cfg: LDAConfig, tok: CSRTokenBatch,
                          old_pi: jax.Array, visited: jax.Array) -> jax.Array:
    """``warm_start_gamma`` on the flat layout: the memo term is a segment
    sum of cnt·π_old over each document's tokens."""
    num_docs = visited.shape[0]
    gamma_memo = cfg.alpha0 + segment_sum_docs(
        tok.counts[:, None] * old_pi, tok.segments, num_docs)
    fresh = jnp.full_like(gamma_memo, cfg.alpha0 + 1.0)
    return jnp.where(visited[:, None], gamma_memo, fresh)


@partial(jax.jit, static_argnames=("cfg", "num_docs"))
def estep_csr_ref(cfg: LDAConfig, exp_elog_beta: jax.Array,
                  token_ids: jax.Array, counts: jax.Array,
                  segments: jax.Array, num_docs: int,
                  gamma0: Optional[jax.Array] = None) -> EStepResult:
    """jnp ``segment_sum`` reference for the CSR layout — the oracle the
    Pallas CSR kernels are pinned against.

    Same fixed point as ``estep_gather`` with the (B, L) einsums replaced
    by per-token gathers + segment sums over the flat stream; zero-count
    padding tokens (segment 0) are exact no-ops. Returns π in the FLAT
    (T, K) layout.
    """
    eb_tok = exp_elog_beta[token_ids]                  # (T, K)
    if gamma0 is None:
        gamma0 = jnp.full((num_docs, cfg.num_topics), cfg.alpha0 + 1.0,
                          jnp.float32)

    def update(gamma):
        etheta = exp_dirichlet_expectation(gamma)      # (B, K)
        p = (etheta[segments] * eb_tok).sum(-1) + _EPS  # (T,)
        acc = segment_sum_docs((counts / p)[:, None] * eb_tok,
                               segments, num_docs)
        return cfg.alpha0 + etheta * acc

    gamma, iters = _fixed_point(cfg, update, gamma0)

    etheta = exp_dirichlet_expectation(gamma)
    et_tok = etheta[segments]                          # (T, K)
    p = (et_tok * eb_tok).sum(-1) + _EPS
    pi = et_tok * eb_tok / p[:, None]                  # (T, K)
    pi = jnp.where(counts[:, None] > 0, pi, 0.0)
    sstats = scatter_sstats_flat(token_ids, counts[:, None] * pi,
                                 exp_elog_beta.shape[0])
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats, iters=iters)


@partial(jax.jit, static_argnames=("cfg",))
def estep_gather(cfg: LDAConfig, exp_elog_beta: jax.Array,
                 token_ids: jax.Array, counts: jax.Array,
                 gamma0: Optional[jax.Array] = None) -> EStepResult:
    """Token-aligned batched E-step (Algorithm 1, lines 4–7).

    Args:
      exp_elog_beta: (V, K) exp(E[ln φ]).
      token_ids / counts: (B, L) padded unique-token BOW batch.
    """
    b = token_ids.shape[0]
    eb = exp_elog_beta[token_ids]                      # (B, L, K)
    if gamma0 is None:
        gamma0 = jnp.full((b, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)

    def update(gamma):
        etheta = exp_dirichlet_expectation(gamma)      # (B, K)
        p = jnp.einsum("bk,blk->bl", etheta, eb) + _EPS
        return cfg.alpha0 + etheta * jnp.einsum("bl,blk->bk", counts / p, eb)

    gamma, iters = _fixed_point(cfg, update, gamma0)

    etheta = exp_dirichlet_expectation(gamma)
    p = jnp.einsum("bk,blk->bl", etheta, eb) + _EPS
    pi = etheta[:, None, :] * eb / p[:, :, None]       # (B, L, K)
    pi = jnp.where(counts[:, :, None] > 0, pi, 0.0)
    sstats = scatter_sstats(token_ids, counts[:, :, None] * pi,
                            exp_elog_beta.shape[0])
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats, iters=iters)


def densify(token_ids: jax.Array, counts: jax.Array,
            vocab_size: int) -> jax.Array:
    """(B, L) BOW → dense count matrix C (B, V)."""
    b = token_ids.shape[0]
    c = jnp.zeros((b, vocab_size), counts.dtype)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], token_ids.shape)
    return c.at[rows.reshape(-1), token_ids.reshape(-1)].add(counts.reshape(-1))


@partial(jax.jit, static_argnames=("cfg",))
def estep_dense(cfg: LDAConfig, exp_elog_beta: jax.Array,
                token_ids: jax.Array, counts: jax.Array,
                gamma0: Optional[jax.Array] = None) -> EStepResult:
    """Dense-count E-step: one sweep = two (B,V)×(V,K) matmuls.

    The TPU-native formulation (DESIGN.md §2): MXU-friendly, no gathers.
    Matches ``estep_gather`` exactly (same fixed point, same π).
    """
    b = token_ids.shape[0]
    v = exp_elog_beta.shape[0]
    c = densify(token_ids, counts, v)                  # (B, V)
    if gamma0 is None:
        gamma0 = jnp.full((b, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)

    def update(gamma):
        etheta = exp_dirichlet_expectation(gamma)      # (B, K)
        p = etheta @ exp_elog_beta.T + _EPS            # (B, V)
        return cfg.alpha0 + etheta * ((c / p) @ exp_elog_beta)

    gamma, iters = _fixed_point(cfg, update, gamma0)

    etheta = exp_dirichlet_expectation(gamma)
    p = etheta @ exp_elog_beta.T + _EPS
    sstats = exp_elog_beta * ((c / p).T @ etheta)      # (V, K)
    # token-aligned π for the memo, recovered by gathering the dense solution
    eb = exp_elog_beta[token_ids]
    p_tok = jnp.einsum("bk,blk->bl", etheta, eb) + _EPS
    pi = etheta[:, None, :] * eb / p_tok[:, :, None]
    pi = jnp.where(counts[:, :, None] > 0, pi, 0.0)
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats, iters=iters)


# ---------------------------------------------------------------------------
# The backend contract
# ---------------------------------------------------------------------------

class EStepBackend:
    """One E-step contract for all engines.

    Subclasses implement ``solve``; ``solve_correction`` has a default
    jnp implementation in terms of ``solve`` (token-aligned subtract-old/
    add-new) that the Pallas backend overrides with fused kernels.
    """

    name: str = "abstract"

    def solve(self, cfg: LDAConfig, exp_elog_beta: jax.Array,
              batch: BowBatch,
              gamma0: Optional[jax.Array] = None) -> EStepResult:
        raise NotImplementedError

    def solve_correction(
            self, cfg: LDAConfig, exp_elog_beta: jax.Array, batch: BowBatch,
            old_pi: jax.Array, visited: jax.Array,
            pi_dtype: str = "float32",
    ) -> Tuple[jax.Array, jax.Array, EStepResult]:
        """E-step + memo correction: the hot path of IVI / S-IVI / D-IVI.

        ``pi_dtype`` is the memo store's wire dtype: π is rounded to it
        BEFORE the add-new side of the correction, so what ⟨m_vk⟩ adds is
        bit-identical to what the store holds (and will later subtract) —
        the accumulator-vs-memo identity stays an invariant instead of a
        per-visit rounding drift with low-precision stores.

        Returns (correction (V, K), first-visit word count, EStepResult);
        the result's π is the rounded value the caller must store.
        """
        ids, cnts = batch
        gamma0 = warm_start_gamma(cfg, cnts, old_pi, visited)
        res = self.solve(cfg, exp_elog_beta, batch, gamma0)
        pi = quantize_pi(res.pi, pi_dtype)
        # rebuild sstats from the ROUNDED π so every backend returns the
        # same result: the Pallas path scatters the quantized π into its
        # S_new (which doubles as sstats), and the low-precision invariant
        # above must hold for the sstats field too
        snew = scatter_sstats(ids, cnts[:, :, None] * pi, cfg.vocab_size)
        res = res._replace(pi=pi, sstats=snew)
        sold = scatter_sstats(ids, cnts[:, :, None] * old_pi, cfg.vocab_size)
        correction = snew - sold
        words_first = jnp.sum(jnp.where(~visited, cnts.sum(-1), 0.0))
        return correction, words_first, res

    # -- flat-token (CSR) contract --------------------------------------
    def solve_tokens(self, cfg: LDAConfig, exp_elog_beta: jax.Array,
                     tok: CSRTokenBatch, num_docs: int,
                     gamma0: Optional[jax.Array] = None) -> EStepResult:
        """``solve`` on a flat CSR token stream; π comes back (T, K).

        Default: the jnp ``segment_sum`` reference. The Pallas backends
        override with the width-free CSR kernels.
        """
        return estep_csr_ref(cfg, exp_elog_beta, tok.token_ids, tok.counts,
                             tok.segments, num_docs, gamma0)

    def solve_correction_tokens(
            self, cfg: LDAConfig, exp_elog_beta: jax.Array,
            tok: CSRTokenBatch, old_pi: jax.Array, visited: jax.Array,
            pi_dtype: str = "float32",
    ) -> Tuple[jax.Array, jax.Array, EStepResult]:
        """``solve_correction`` on the flat layout (old_pi is (T, K)).

        Identical quantize-then-rescatter discipline as the padded
        contract, with the (B, L) scatters replaced by flat ones.
        """
        num_docs = visited.shape[0]
        gamma0 = warm_start_gamma_flat(cfg, tok, old_pi, visited)
        res = self.solve_tokens(cfg, exp_elog_beta, tok, num_docs, gamma0)
        pi = quantize_pi(res.pi, pi_dtype)
        snew = scatter_sstats_flat(tok.token_ids, tok.counts[:, None] * pi,
                                   cfg.vocab_size)
        res = res._replace(pi=pi, sstats=snew)
        sold = scatter_sstats_flat(tok.token_ids,
                                   tok.counts[:, None] * old_pi,
                                   cfg.vocab_size)
        correction = snew - sold
        doc_words = segment_sum_docs(tok.counts, tok.segments, num_docs)
        words_first = jnp.sum(jnp.where(~visited, doc_words, 0.0))
        return correction, words_first, res


class GatherBackend(EStepBackend):
    name = "gather"

    def solve(self, cfg, exp_elog_beta, batch, gamma0=None):
        return estep_gather(cfg, exp_elog_beta, batch.token_ids,
                            batch.counts, gamma0)


class DenseBackend(EStepBackend):
    name = "dense"

    def solve(self, cfg, exp_elog_beta, batch, gamma0=None):
        return estep_dense(cfg, exp_elog_beta, batch.token_ids,
                           batch.counts, gamma0)


class PallasBackend(EStepBackend):
    """Fused-kernel backend (`repro.kernels.ops`): one pallas_call per
    fixed point, memo correction via the segment-sum ``memo_delta`` pair —
    no (B, L, K) jnp intermediates and no dense (nb, V, K) scatter
    partials.

    ``delta_block_v`` is the scatter's second-level V-chunk size. ``None``
    (the default) defers to the VMEM-budget policy
    (`lda_estep.segment_scatter_blocks`): the largest lane-aligned chunk
    whose selector + accumulators fit the kernel's step budget, capped at
    the vocab so small vocabularies run V-resident in a single chunk. The
    chunk count is the scatter's HBM-traffic knob — the token rows are
    re-streamed once per chunk — so overriding it only makes sense for
    benchmark sweeps.

    ``policy`` (a ``repro.core.types.KernelPolicy``) pins every tile knob
    for instances constructed by the autotuner. The module singletons in
    ``_BACKENDS`` keep ``policy=None`` so the knobs resolve from
    ``cfg.kernel_policy`` (or the built-in defaults) per call — that is
    what lets one shared backend instance serve differently-tuned
    configs without retrace hazards: the policy rides on ``cfg``, which
    is already a jit static argument everywhere.
    """

    name = "pallas"

    def __init__(self, policy=None, delta_block_v: Optional[int] = None):
        self.policy = policy
        self.delta_block_v = delta_block_v  # None → VMEM-budget policy

    def solve(self, cfg, exp_elog_beta, batch, gamma0=None):
        from repro.kernels import ops as kops
        return kops.estep_pallas(cfg, exp_elog_beta, batch.token_ids,
                                 batch.counts, gamma0, policy=self.policy,
                                 delta_block_v=self.delta_block_v)

    def solve_correction(self, cfg, exp_elog_beta, batch, old_pi, visited,
                         pi_dtype="float32"):
        from repro.kernels import ops as kops
        return kops.memo_correction_pallas(cfg, exp_elog_beta,
                                           batch.token_ids, batch.counts,
                                           old_pi, visited,
                                           pi_dtype=pi_dtype,
                                           policy=self.policy,
                                           delta_block_v=self.delta_block_v)

    def solve_tokens(self, cfg, exp_elog_beta, tok, num_docs, gamma0=None):
        from repro.kernels import ops as kops
        return kops.estep_pallas_csr(cfg, exp_elog_beta, tok.token_ids,
                                     tok.counts, tok.segments,
                                     num_docs=num_docs, gamma0=gamma0,
                                     policy=self.policy,
                                     delta_block_v=self.delta_block_v)

    def solve_correction_tokens(self, cfg, exp_elog_beta, tok, old_pi,
                                visited, pi_dtype="float32"):
        from repro.kernels import ops as kops
        return kops.memo_correction_pallas_csr(
            cfg, exp_elog_beta, tok.token_ids, tok.counts, tok.segments,
            old_pi, visited, pi_dtype=pi_dtype, policy=self.policy,
            delta_block_v=self.delta_block_v)


class CSRBackend(PallasBackend):
    """The width-free CSR kernels behind the PADDED ``solve`` /
    ``solve_correction`` contract.

    A (B, L) batch flattens losslessly to a (B·L,) token stream whose
    segment ids are the row indices — so this backend is the bridge that
    lets the existing backend-equivalence tests pin the CSR kernels
    against gather/dense on identical inputs. Flat-token callers (the
    CSR stream path, ragged serving) use the inherited
    ``solve_tokens``/``solve_correction_tokens`` directly.
    """

    name = "csr"

    @staticmethod
    def flatten(batch: BowBatch) -> CSRTokenBatch:
        b, l = batch.token_ids.shape
        segs = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None],
                                (b, l))
        return CSRTokenBatch(batch.token_ids.reshape(-1),
                             batch.counts.reshape(-1), segs.reshape(-1))

    def solve(self, cfg, exp_elog_beta, batch, gamma0=None):
        b, l = batch.token_ids.shape
        res = self.solve_tokens(cfg, exp_elog_beta, self.flatten(batch),
                                num_docs=b, gamma0=gamma0)
        return res._replace(pi=res.pi.reshape(b, l, -1))

    def solve_correction(self, cfg, exp_elog_beta, batch, old_pi, visited,
                         pi_dtype="float32"):
        b, l = batch.token_ids.shape
        corr, words_first, res = self.solve_correction_tokens(
            cfg, exp_elog_beta, self.flatten(batch),
            old_pi.reshape(b * l, -1), visited, pi_dtype=pi_dtype)
        return corr, words_first, res._replace(pi=res.pi.reshape(b, l, -1))


_BACKENDS: Dict[str, EStepBackend] = {
    b.name: b for b in (GatherBackend(), DenseBackend(), PallasBackend(),
                        CSRBackend())
}


def get_backend(name: str) -> EStepBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown estep backend: {name!r} (have {sorted(_BACKENDS)})")


def estep(cfg: LDAConfig, exp_elog_beta: jax.Array, token_ids: jax.Array,
          counts: jax.Array, gamma0: Optional[jax.Array] = None) -> EStepResult:
    """Functional shim: dispatch on ``cfg.estep_backend``."""
    return get_backend(cfg.estep_backend).solve(
        cfg, exp_elog_beta, BowBatch(token_ids, counts), gamma0)
