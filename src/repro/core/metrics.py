"""Topic-model quality metrics beyond held-out likelihood.

* ``top_words`` — per-topic most probable token ids;
* ``npmi_coherence`` — average normalized pointwise mutual information of
  each topic's top-k word pairs under the corpus co-occurrence statistics
  (the standard automatic coherence proxy);
* ``effective_topics`` — exp(entropy) of corpus-level topic usage: detects
  topic death (relevant to the IVI local-optima analysis, EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus


def top_words(lam: jax.Array, k: int = 10) -> np.ndarray:
    """(K, k) token ids of each topic's top-k words."""
    phi = np.asarray(lam / lam.sum(0, keepdims=True))      # (V, K)
    return np.argsort(-phi, axis=0)[:k].T                  # (K, k)


def _doc_presence(corpus: Corpus, vocab_size: int) -> np.ndarray:
    """(D, V) binary token-presence matrix (host side)."""
    d = corpus.num_docs
    out = np.zeros((d, vocab_size), bool)
    ids = np.asarray(corpus.token_ids)
    cnt = np.asarray(corpus.counts)
    rows = np.repeat(np.arange(d), ids.shape[1])
    mask = cnt.reshape(-1) > 0
    out[rows[mask], ids.reshape(-1)[mask]] = True
    return out


def npmi_coherence(lam: jax.Array, corpus: Corpus, k: int = 10,
                   eps: float = 1e-12) -> float:
    """Mean NPMI over all topics' top-k word pairs.

    Vectorized: one ``(D, K·k)`` presence slice and a single matmul give
    every pair's co-document fraction at once — ``sub.T @ sub`` over a
    0/1 float64 matrix is an exact integer count (D < 2⁵³), so this is
    arithmetically identical to the historical per-pair Python loop
    (kept below as ``_npmi_coherence_loop``, the equivalence oracle in
    tests/test_obs.py) while running O(k²·K²/D) fewer interpreter steps.
    """
    v = lam.shape[0]
    tops = top_words(lam, k)                               # (K, k)
    pres = _doc_presence(corpus, v)
    d = pres.shape[0]
    p_w = pres.mean(0)                                     # (V,)
    num_topics, kk = tops.shape
    sub = pres[:, tops.reshape(-1)].astype(np.float64)     # (D, K·k)
    co = (sub.T @ sub) / d                                 # (K·k, K·k)
    # per-topic k×k co-occurrence blocks down the diagonal
    blocks = co.reshape(num_topics, kk, num_topics, kk)[
        np.arange(num_topics), :, np.arange(num_topics), :]  # (K, k, k)
    iu, ju = np.triu_indices(kk, 1)
    p_ij = blocks[:, iu, ju]                               # (K, pairs)
    p_top = p_w[tops]                                      # (K, k)
    pmi = np.log(p_ij / (p_top[:, iu] * p_top[:, ju] + eps) + eps)
    with np.errstate(divide="ignore", invalid="ignore"):
        npmi = np.where(p_ij < eps, -1.0, pmi / -np.log(p_ij + eps))
    return float(npmi.mean(axis=1).mean())


def _npmi_coherence_loop(lam: jax.Array, corpus: Corpus, k: int = 10,
                         eps: float = 1e-12) -> float:
    """The historical O(K·k²) per-pair loop — reference implementation
    the vectorized ``npmi_coherence`` is tested against."""
    v = lam.shape[0]
    tops = top_words(lam, k)
    pres = _doc_presence(corpus, v)
    p_w = pres.mean(0)                                     # (V,)
    scores = []
    for topic in tops:
        s = []
        for i in range(len(topic)):
            for j in range(i + 1, len(topic)):
                wi, wj = topic[i], topic[j]
                p_ij = (pres[:, wi] & pres[:, wj]).mean()
                if p_ij < eps:
                    s.append(-1.0)
                    continue
                pmi = np.log(p_ij / (p_w[wi] * p_w[wj] + eps) + eps)
                s.append(pmi / (-np.log(p_ij + eps)))
        scores.append(np.mean(s))
    return float(np.mean(scores))


def effective_topics(lam: jax.Array) -> float:
    """exp(H[topic usage]) from the topic-word mass."""
    mass = np.asarray(lam.sum(0))                          # (K,)
    p = mass / mass.sum()
    h = -(p * np.log(p + 1e-12)).sum()
    return float(np.exp(h))
