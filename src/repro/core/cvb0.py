"""CVB0 — collapsed variational Bayes (zero-order) for LDA.

Teh et al. (2006) / Asuncion et al. (2009): the paper's §5 names collapsed
variational inference "the de facto standard for corpora of moderate size",
so we ship it as an additional baseline. CVB0 keeps per-token
responsibilities γ and updates them against *collapsed* count statistics
(document-topic N_dk, topic-word N_vk, topic N_k) with self-exclusion:

    γ_dvk ∝ (α₀ + N̂_dk^{−dv}) · (β₀ + N̂_vk^{−dv}) / (V·β₀ + N̂_k^{−dv})

Operates on the padded unique-token layout with count-weighted tokens (the
standard CVB0-with-counts approximation). Batch-incremental like IVI:
visiting a mini-batch replaces its documents' contributions in the global
counts — the same subtract-old/add-new bookkeeping, which is why it slots
into this framework naturally.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estep import scatter_sstats
from repro.core.types import Corpus, LDAConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CVB0State:
    gamma: jax.Array       # (D, L, K) responsibilities (memo)
    n_vk: jax.Array        # (V, K) topic-word expected counts
    visited: jax.Array     # (D,) bool


def init_cvb0(cfg: LDAConfig, corpus: Corpus, key) -> CVB0State:
    d, L = corpus.token_ids.shape
    g = jax.random.gamma(key, 1.0, (d, L, cfg.num_topics)) + 0.1
    g = g / g.sum(-1, keepdims=True)
    g = jnp.where(corpus.counts[:, :, None] > 0, g, 0.0)
    n_vk = scatter_sstats(corpus.token_ids, corpus.counts[:, :, None] * g,
                          cfg.vocab_size)
    return CVB0State(gamma=g, n_vk=n_vk,
                     visited=jnp.ones((d,), bool))


@partial(jax.jit, static_argnames=("cfg", "inner_iters"),
         donate_argnums=(1,))
def cvb0_step(cfg: LDAConfig, state: CVB0State, ids: jax.Array,
              cnts: jax.Array, doc_idx: jax.Array,
              inner_iters: int = 5) -> CVB0State:
    """Visit a mini-batch: refresh its responsibilities against collapsed
    counts, then replace its contribution in N_vk (subtract-old/add-new)."""
    v = cfg.vocab_size
    old_g = state.gamma[doc_idx]                        # (B, L, K)
    old_contrib = scatter_sstats(ids, cnts[:, :, None] * old_g, v)
    n_vk_ext = state.n_vk - old_contrib                 # exclude the batch
    n_k_ext = n_vk_ext.sum(0)                           # (K,)

    def one_iter(g, _):
        # document-topic counts with self-exclusion per token slot
        n_dk = jnp.einsum("blk,bl->bk", g, cnts)        # (B, K)
        n_dk_excl = n_dk[:, None, :] - cnts[:, :, None] * g
        n_vk_tok = n_vk_ext[ids]                        # (B, L, K)
        num = (cfg.alpha0 + n_dk_excl) * (cfg.beta0 + n_vk_tok)
        den = v * cfg.beta0 + n_k_ext
        g_new = num / den
        g_new = g_new / (g_new.sum(-1, keepdims=True) + 1e-30)
        g_new = jnp.where(cnts[:, :, None] > 0, g_new, 0.0)
        return g_new, None

    g, _ = jax.lax.scan(one_iter, old_g, None, length=inner_iters)
    new_contrib = scatter_sstats(ids, cnts[:, :, None] * g, v)
    n_vk = n_vk_ext + new_contrib
    return CVB0State(gamma=state.gamma.at[doc_idx].set(g),
                     n_vk=n_vk,
                     visited=state.visited.at[doc_idx].set(True))


class CVB0Engine:
    """Host driver mirroring LDAEngine (algo-specific state)."""

    def __init__(self, cfg: LDAConfig, corpus: Corpus, *,
                 batch_size: int = 64, seed: int = 0,
                 inner_iters: int = 5):
        self.cfg, self.corpus = cfg, corpus
        self.batch_size = batch_size
        self.inner_iters = inner_iters
        self.rng = np.random.default_rng(seed)
        self.state = init_cvb0(cfg, corpus, jax.random.key(seed))
        self.docs_seen = 0

    @property
    def lam(self) -> jax.Array:
        """Topic-word Dirichlet parameter implied by the collapsed counts."""
        return self.cfg.beta0 + self.state.n_vk

    def run_minibatch(self, rows: Optional[np.ndarray] = None) -> None:
        if rows is None:
            rows = self.rng.choice(self.corpus.num_docs,
                                   size=self.batch_size, replace=False)
        idx = jnp.asarray(rows)
        self.state = cvb0_step(self.cfg, self.state,
                               self.corpus.token_ids[idx],
                               self.corpus.counts[idx], idx,
                               self.inner_iters)
        self.docs_seen += len(rows)

    def run_epoch(self) -> None:
        d = self.corpus.num_docs
        order = self.rng.permutation(d)
        n = (d // self.batch_size) * self.batch_size
        for rows in order[:n].reshape(-1, self.batch_size):
            self.run_minibatch(rows)
