"""Bag-of-words utilities: ragged documents → padded unique-token layout."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus


def corpus_from_docs(docs: Sequence[np.ndarray], vocab_size: int,
                     max_unique: int | None = None) -> Corpus:
    """Build a padded Corpus from ragged arrays of token ids (with repeats)."""
    uniq: List[Tuple[np.ndarray, np.ndarray]] = []
    for doc in docs:
        ids, cnts = np.unique(np.asarray(doc, dtype=np.int64),
                              return_counts=True)
        uniq.append((ids, cnts))
    width = max((len(i) for i, _ in uniq), default=1)
    if max_unique is not None:
        width = min(width, max_unique)
    width = max(width, 1)
    d = len(uniq)
    out_ids = np.zeros((d, width), np.int32)
    out_cnt = np.zeros((d, width), np.float32)
    for r, (ids, cnts) in enumerate(uniq):
        if len(ids) > width:  # keep the most frequent tokens
            top = np.argsort(-cnts)[:width]
            ids, cnts = ids[top], cnts[top]
        out_ids[r, : len(ids)] = ids
        out_cnt[r, : len(ids)] = cnts
    assert out_ids.max(initial=0) < vocab_size
    return Corpus(jnp.asarray(out_ids), jnp.asarray(out_cnt))


def pad_corpus(corpus: Corpus, num_docs: int) -> Corpus:
    """Pad with empty documents so ``num_docs`` divides the batch grid."""
    d = corpus.num_docs
    if d >= num_docs:
        return corpus
    pad = num_docs - d
    ids = jnp.concatenate(
        [corpus.token_ids, jnp.zeros((pad, corpus.max_unique), jnp.int32)])
    cnt = jnp.concatenate(
        [corpus.counts, jnp.zeros((pad, corpus.max_unique), jnp.float32)])
    return Corpus(ids, cnt)
