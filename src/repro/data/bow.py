"""Bag-of-words utilities: ragged documents → padded unique-token layout,
plus the length-bucketed view that shrinks per-batch padding."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus


def corpus_from_docs(docs: Sequence[np.ndarray], vocab_size: int,
                     max_unique: int | None = None) -> Corpus:
    """Build a padded Corpus from ragged arrays of token ids (with repeats)."""
    uniq: List[Tuple[np.ndarray, np.ndarray]] = []
    for doc in docs:
        ids, cnts = np.unique(np.asarray(doc, dtype=np.int64),
                              return_counts=True)
        uniq.append((ids, cnts))
    width = max((len(i) for i, _ in uniq), default=1)
    if max_unique is not None:
        width = min(width, max_unique)
    width = max(width, 1)
    d = len(uniq)
    out_ids = np.zeros((d, width), np.int32)
    out_cnt = np.zeros((d, width), np.float32)
    for r, (ids, cnts) in enumerate(uniq):
        if len(ids) > width:  # keep the most frequent tokens
            top = np.argsort(-cnts)[:width]
            ids, cnts = ids[top], cnts[top]
        out_ids[r, : len(ids)] = ids
        out_cnt[r, : len(ids)] = cnts
    assert out_ids.max(initial=0) < vocab_size
    return Corpus(jnp.asarray(out_ids), jnp.asarray(out_cnt))


@dataclasses.dataclass(frozen=True)
class LengthBuckets:
    """Length-bucketed corpus view: document indices grouped by the padded
    width that covers their unique-token count.

    The corpus arrays stay in the canonical (D, L) layout; a bucket only
    records *which rows* belong to it and *how many leading columns* of
    those rows are live, so a batch drawn from bucket *b* can be sliced to
    ``(B, widths[b])`` — E-step FLOPs and memo gather/update traffic then
    scale with the bucket's own padding, not the corpus-wide maximum L.
    """

    doc_idx: List[np.ndarray]     # per bucket: original corpus row indices
    widths: List[int]             # per bucket: live column count (≤ L)

    @property
    def num_buckets(self) -> int:
        return len(self.widths)


def bucket_corpus(corpus: Corpus,
                  boundaries: Optional[Sequence[int]] = None
                  ) -> LengthBuckets:
    """Group documents into ladder-width buckets.

    A ``LengthBuckets`` view over the ONE bucketing implementation,
    `repro.data.stream.bucket_rows` (keyed on the last live column — equal
    to the unique-token count for this canonical leading-column layout,
    and lossless for any other). Buckets with no documents are dropped;
    the final bucket width is the corpus max L, so every document lands
    somewhere; empty documents join the narrowest bucket.
    """
    from repro.data.stream import WIDTH_BOUNDARIES, bucket_rows
    if boundaries is None:
        boundaries = WIDTH_BOUNDARIES
    buckets = bucket_rows(corpus.counts, boundaries)
    return LengthBuckets(doc_idx=[rows for rows, _ in buckets],
                         widths=[w for _, w in buckets])


def bucket_padding_stats(corpus: Corpus, buckets: LengthBuckets) -> dict:
    """Padding-waste accounting: slots touched per epoch, flat vs bucketed,
    plus the pad fraction inside each bucket (live slots vs padded slots —
    the number that exposes packing regressions)."""
    from repro.data.stream import TOKEN_SLOT_BYTES
    d, l = corpus.num_docs, corpus.max_unique
    cnts = np.asarray(corpus.counts)
    flat = d * l
    per_bucket = []
    bucketed = 0
    live_total = 0
    for rows, w in zip(buckets.doc_idx, buckets.widths):
        slots = len(rows) * w
        live = int((cnts[rows, :w] > 0).sum())
        bucketed += slots
        live_total += live
        per_bucket.append({"width": int(w), "docs": len(rows),
                           "pad_frac": 1.0 - live / max(slots, 1),
                           "wasted_token_bytes":
                               (slots - live) * TOKEN_SLOT_BYTES})
    return {"flat_slots": flat, "bucketed_slots": bucketed,
            "slot_ratio": bucketed / max(flat, 1),
            "wasted_token_bytes":
                (bucketed - live_total) * TOKEN_SLOT_BYTES,
            "per_bucket": per_bucket}


def pad_corpus(corpus: Corpus, num_docs: int) -> Corpus:
    """Pad with empty documents so ``num_docs`` divides the batch grid."""
    d = corpus.num_docs
    if d >= num_docs:
        return corpus
    pad = num_docs - d
    ids = jnp.concatenate(
        [corpus.token_ids, jnp.zeros((pad, corpus.max_unique), jnp.int32)])
    cnt = jnp.concatenate(
        [corpus.counts, jnp.zeros((pad, corpus.max_unique), jnp.float32)])
    return Corpus(ids, cnt)
