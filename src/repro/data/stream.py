"""Ragged token pipeline: ``DocStream`` ingest + packed device batches.

The paper's headline regime — "massive document collections" — never fits
a fully materialized, padded ``(D, L)`` ``Corpus`` in host RAM. This module
is the single ingest contract both training and serving consume:

* ``DocStream`` — an iterator of ragged ``(token_ids, counts)`` documents
  with known ``vocab_size``, resumable via a **cursor** (a document
  position). One pass over the stream is one epoch; a mid-epoch checkpoint
  persists the cursor plus the packer's open buckets, nothing else.
* ``BatchPacker`` — packs ragged documents into the bucketed ``(B, W)``
  padded layouts the engines and the serving E-step consume, under ONE
  width policy (`width_ladder` / `width_for`). It replaces the two
  bucketing implementations that used to exist (`data/bow.py:bucket_corpus`
  for training and the serving-side ``_serving_buckets``): both now route
  through `bucket_rows` / the packer.

**Width policy** (the one policy): a document needs the padded width that
COVERS its last live slot — the smallest rung of the boundary ladder
``(8, 16, 32, 64, 128, 256, 512)`` that is ≥ its live extent, capped at
``max_width`` when the stream declares one (training: the memo's L) and
extended by doubling past the top rung when it does not (serving: unknown
request lengths; the jit cache stays bounded because widths stay on the
ladder). Keying on the *last live column* — not the live-slot count —
keeps the ``[:width]`` slice lossless for any slot layout, including the
interleaved-zero halves ``predictive.split_heldout`` produces; for the
canonical leading-column layout the two keys coincide. Empty documents
(no live slot) ride the smallest rung, where the E-step leaves their γ at
the prior in one sweep.

Packing is **bit-transparent**: a batch packed from ragged docs is
bit-identical to gathering the same rows from a padded ``Corpus`` and
slicing to the bucket width, so a stream-fed training run reproduces the
padded-corpus trajectory exactly (tests/test_stream_pipeline.py).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterator, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import Corpus

# THE width ladder — the single source of truth for both train and serve.
WIDTH_BOUNDARIES = (8, 16, 32, 64, 128, 256, 512)

RaggedDoc = Tuple[np.ndarray, np.ndarray]      # (ids int32, counts float32)


# ---------------------------------------------------------------------------
# width policy
# ---------------------------------------------------------------------------

def width_ladder(max_width: int,
                 boundaries: Sequence[int] = WIDTH_BOUNDARIES) -> List[int]:
    """Bucket widths for documents up to ``max_width`` live slots: every
    ladder rung below it plus ``max_width`` itself as the final rung —
    every document lands somewhere, none is sliced lossily."""
    l = max(int(max_width), 1)
    return sorted({min(b, l) for b in boundaries if b < l} | {l})


def bucket_rows(counts: np.ndarray,
                boundaries: Sequence[int] = WIDTH_BOUNDARIES,
                ) -> List[Tuple[np.ndarray, int]]:
    """Group padded rows by the ladder width covering their LAST live slot.

    The one bucketing implementation (see module docstring): training's
    ``bucket_corpus`` and the serving batcher are both views of this.
    Returns ``[(row_indices int64, width)]`` with ascending widths; every
    row appears in exactly one bucket (empty rows in the first)."""
    counts = np.asarray(counts)
    d, l = counts.shape
    live = counts > 0
    # width needed per doc = index of its last live column + 1 (0 if empty)
    last = np.where(live.any(1), l - np.argmax(live[:, ::-1], axis=1), 0)
    out: List[Tuple[np.ndarray, int]] = []
    lo = -1                   # first rung includes last == 0 (empty docs)
    for w in width_ladder(l, boundaries):
        rows = np.nonzero((last > lo) & (last <= w))[0]
        if len(rows):
            out.append((rows.astype(np.int64), int(w)))
        lo = w
    return out


# ---------------------------------------------------------------------------
# ragged documents
# ---------------------------------------------------------------------------

def as_ragged_doc(doc) -> RaggedDoc:
    """Normalise one request/ingest document to ``(ids int32, cnts fp32)``.

    Accepts a ``(token_ids, counts)`` pair (already unique) or a raw token
    array with repeats (uniquified, ids ascending — the ``corpus_from_docs``
    convention)."""
    if isinstance(doc, tuple) and len(doc) == 2:
        ids, cnts = doc
        return (np.asarray(ids, np.int32).ravel(),
                np.asarray(cnts, np.float32).ravel())
    tokens = np.asarray(doc, np.int64).ravel()
    ids, cnts = np.unique(tokens, return_counts=True)
    return ids.astype(np.int32), cnts.astype(np.float32)


class DocStream:
    """Iterator of ragged documents, resumable via a cursor.

    The ingest contract for training and serving (see module docstring):

    * ``vocab_size`` — token ids are ``< vocab_size``;
    * ``num_docs`` — documents per pass (one pass == one epoch);
    * ``num_words`` — total token count (exact for integer counts) — the
      incremental engines need it up front to retire the random-init mass;
    * ``max_unique`` — an upper bound on any document's live extent (the
      memo width L); implementations may compute it lazily;
    * ``iter_from(cursor)`` — yield documents ``cursor, cursor+1, …`` as
      ``(ids int32, counts float32)`` ragged pairs. ``cursor`` is a plain
      document position, so a mid-epoch checkpoint is just an integer.
    """

    vocab_size: int

    @property
    def num_docs(self) -> int:
        raise NotImplementedError

    @property
    def num_words(self) -> float:
        raise NotImplementedError

    @property
    def max_unique(self) -> int:
        raise NotImplementedError

    def iter_from(self, cursor: int = 0) -> Iterator[RaggedDoc]:
        raise NotImplementedError


class CorpusDocStream(DocStream):
    """A padded ``Corpus`` viewed as a ``DocStream`` (rows trimmed to their
    last live slot). Streaming this is bit-equal to slicing the corpus —
    the bridge the stream-vs-materialized equality tests are built on."""

    def __init__(self, corpus: Corpus, vocab_size: Optional[int] = None):
        self._ids = np.asarray(corpus.token_ids)
        self._cnts = np.asarray(corpus.counts)
        self.vocab_size = (int(self._ids.max(initial=0)) + 1
                           if vocab_size is None else vocab_size)
        live = self._cnts > 0
        l = self._cnts.shape[1]
        self._last = np.where(live.any(1),
                              l - np.argmax(live[:, ::-1], axis=1), 0)

    @property
    def num_docs(self) -> int:
        return self._ids.shape[0]

    @property
    def num_words(self) -> float:
        # same accumulation the corpus-mode engine uses (fp32 numpy sum)
        return float(self._cnts.sum())

    @property
    def max_unique(self) -> int:
        return self._cnts.shape[1]

    def iter_from(self, cursor: int = 0) -> Iterator[RaggedDoc]:
        for d in range(cursor, self._ids.shape[0]):
            n = int(self._last[d])
            yield self._ids[d, :n], self._cnts[d, :n]


class ListDocStream(DocStream):
    """Ragged documents held in host memory (lists / generators already
    drained). The convenience stream the facade wraps around plain doc
    iterables; real mass ingest should use a lazy stream (`data/uci.py`)."""

    def __init__(self, docs, vocab_size: int):
        self._docs = [as_ragged_doc(d) for d in docs]
        self.vocab_size = vocab_size

    @property
    def num_docs(self) -> int:
        return len(self._docs)

    @property
    def num_words(self) -> float:
        return float(sum(float(c.sum()) for _, c in self._docs))

    @property
    def max_unique(self) -> int:
        return max((len(i) for i, _ in self._docs), default=1)

    def iter_from(self, cursor: int = 0) -> Iterator[RaggedDoc]:
        yield from self._docs[cursor:]


class QueueDocStream(DocStream):
    """An append-only request queue behind the ``DocStream`` contract —
    the bridge that lets the incremental engines train on documents a
    serving loop is STILL collecting (`repro.serve.online`).

    The engine contracts want the corpus geometry up front (``num_docs``
    sizes the π memo at construction, ``num_words`` retires the init
    mass); an open request stream has neither. The reconciliation:

    * ``capacity`` plays ``num_docs`` — the memo is sized once for the
      whole online window; ``append`` hands out stable, strictly
      increasing positions below it and returns ``None`` (dropped, see
      ``dropped``) once the window is full. Stable positions are what
      keep IVI's per-doc memo bookkeeping exact when a later pass
      revisits a document appended mid-pass.
    * ``num_words`` / ``max_unique`` report the words appended *so far*
      and the declared per-doc cap — an engine binding the stream reads
      both once, so the learner should bind only after traffic exists
      (``num_words`` underestimating the eventual total just retires the
      init mass early; ``retire_init_frac`` clamps at 0).
    * ``iter_from`` is a lock-free index walk that SEES documents
      appended after the iterator was created — one training pass drains
      everything present by the time it reaches the tail, and the
      engine's epoch-boundary rewind makes the next pass revisit from 0
      (IVI revisits are its own refinement, not double counting).

    Documents longer than ``max_unique`` are clipped to their most
    frequent tokens on append (the ``corpus_from_docs`` rule — the same
    clip the packer would apply, applied early so ``num_words`` counts
    what will actually train). Thread-safe: any number of appenders and
    one training consumer.
    """

    def __init__(self, vocab_size: int, *, capacity: int,
                 max_unique: int = 256):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if max_unique < 1:
            raise ValueError("max_unique must be >= 1")
        self.vocab_size = int(vocab_size)
        self.capacity = int(capacity)
        self._max_unique = int(max_unique)
        self._docs: List[RaggedDoc] = []
        self._words = 0.0
        self._dropped = 0
        self._lock = threading.Lock()

    def append(self, doc) -> Optional[int]:
        """File one document; returns its stable position, or ``None``
        when the window is full (the document is counted in ``dropped``
        and NOT retained). Accepts anything ``as_ragged_doc`` does."""
        ids, cnts = as_ragged_doc(doc)
        if len(ids) and not (0 <= int(ids.min())
                             and int(ids.max()) < self.vocab_size):
            raise ValueError(
                f"token ids in [{ids.min()}, {ids.max()}] fall outside "
                f"the vocabulary [0, {self.vocab_size})")
        if len(ids) > self._max_unique:
            top = np.argsort(-cnts)[: self._max_unique]
            ids, cnts = ids[top], cnts[top]
        with self._lock:
            if len(self._docs) >= self.capacity:
                self._dropped += 1
                return None
            pos = len(self._docs)
            self._docs.append((ids, cnts))
            self._words += float(cnts.sum())
            return pos

    @property
    def num_docs(self) -> int:
        """The CAPACITY (the engine sizes the memo with this — see class
        docstring), not the documents appended so far (``appended``)."""
        return self.capacity

    @property
    def appended(self) -> int:
        return len(self._docs)

    @property
    def dropped(self) -> int:
        return self._dropped

    @property
    def num_words(self) -> float:
        return self._words

    @property
    def max_unique(self) -> int:
        return self._max_unique

    def iter_from(self, cursor: int = 0) -> Iterator[RaggedDoc]:
        i = cursor
        while True:
            # list.append is atomic; reading a stale length only ends the
            # pass a document early — it trains next pass
            if i >= len(self._docs):
                return
            yield self._docs[i]
            i += 1


def is_doc_stream(obj) -> bool:
    """Duck-typed DocStream check (protocol, not inheritance)."""
    return hasattr(obj, "iter_from") and hasattr(obj, "vocab_size")


def as_doc_stream(data, vocab_size: Optional[int] = None) -> DocStream:
    """Coerce: DocStream passthrough, Corpus → view, iterable → list."""
    if is_doc_stream(data):
        return data
    if isinstance(data, Corpus):
        return CorpusDocStream(data, vocab_size)
    if vocab_size is None:
        raise ValueError("wrapping a raw document iterable needs vocab_size")
    return ListDocStream(data, vocab_size)


# ---------------------------------------------------------------------------
# sharding: one stream, P worker views
# ---------------------------------------------------------------------------

_U64 = np.uint64


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer — the stable position hash behind
    ``partitioner='hash'``. Pure integer mixing: no floats, no platform
    dependence, so a shard assignment is reproducible anywhere."""
    with np.errstate(over="ignore"):
        x = (x + _U64(0x9E3779B97F4A7C15)) & _U64(0xFFFFFFFFFFFFFFFF)
        x = (x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)
        return x ^ (x >> _U64(31))


SHARD_PARTITIONERS = ("range", "hash")


class ShardDocStream(DocStream):
    """One worker's view of a partitioned base stream — itself a full
    ``DocStream``: local cursors, its own ``BatchPacker`` (padded or csr via
    ``make_packer``), resumable independently of every sibling shard.

    ``iter_from(local_cursor)`` opens the base stream at the shard's
    ``local_cursor``-th member position and walks forward, yielding only
    member documents — ONE forward pass over the underlying file for both
    partitioners (member positions are kept ascending), so a range shard
    reads a contiguous slice and a hash shard reads-and-skips.
    """

    def __init__(self, base: DocStream, positions: np.ndarray,
                 shard_index: int):
        self.base = base
        self.shard_index = int(shard_index)
        self._positions = np.asarray(positions, np.int64)
        self.vocab_size = base.vocab_size
        self._words: Optional[float] = None

    @property
    def positions(self) -> np.ndarray:
        """Global base-stream positions of this shard's documents
        (ascending; local position i ↔ global ``positions[i]``)."""
        return self._positions

    @property
    def num_docs(self) -> int:
        return len(self._positions)

    @property
    def num_words(self) -> float:
        if self._words is None:
            self._words = sum(float(c.sum()) for _, c in self.iter_from(0))
        return self._words

    @property
    def max_unique(self) -> int:
        return self.base.max_unique

    def iter_from(self, cursor: int = 0) -> Iterator[RaggedDoc]:
        pos = self._positions
        n = len(pos)
        if cursor >= n:
            return
        k = cursor
        g = int(pos[k])                       # global position of next yield
        for doc in self.base.iter_from(g):
            if g == pos[k]:
                yield doc
                k += 1
                if k == n:
                    return
            g += 1

    def make_packer(self, batch_size: int, *, layout: str = "padded",
                    token_budget: Optional[int] = None, boundaries=None,
                    metrics=None) -> "BatchPacker":
        """A ``BatchPacker`` bound to this shard's geometry (ladder capped
        at the base stream's ``max_unique``, vocab checked). ``boundaries``
        defaults to the standard ladder; pass ``()`` for the single-rung
        uniform-width packing the distributed round consumes."""
        return BatchPacker(
            batch_size, max_width=self.base.max_unique,
            boundaries=WIDTH_BOUNDARIES if boundaries is None else boundaries,
            vocab_size=self.vocab_size, layout=layout,
            token_budget=token_budget, metrics=metrics)


class ShardedDocStream:
    """Partition any ``DocStream`` into ``num_shards`` per-worker views.

    The distributed ingest primitive (`docs/divi.md` §streaming shards):
    instead of materializing a corpus and slicing it, the document
    *positions* of the base stream are dealt to shards once, host-side,
    and each worker pulls ragged documents through its own
    ``ShardDocStream`` + packer + cursor.

    Partitioners (both: every document in exactly ONE shard, shard sizes
    balanced to within one document, member positions ascending):

    * ``"range"`` — contiguous position blocks (``np.array_split`` order).
      Workers sharing one file read disjoint byte ranges; with one shard
      the view IS the base stream in order — what keeps the P=1 engine
      comparable to single-host S-IVI.
    * ``"hash"``  — documents dealt round-robin by the rank of their
      splitmix64-hashed position (seeded). Decorrelates shard content
      from file order (e.g. docword files sorted by source or date), at
      the cost of each worker scanning-and-skipping the full file.

    The assignment is a pure function of ``(num_docs, num_shards,
    partitioner, seed)`` — ``signature()`` captures exactly that tuple, and
    a restored manifest refuses a mismatch rather than silently training
    workers on the wrong documents.
    """

    def __init__(self, base: DocStream, num_shards: int, *,
                 partitioner: str = "range", seed: int = 0):
        if partitioner not in SHARD_PARTITIONERS:
            raise ValueError(f"unknown partitioner {partitioner!r} "
                             f"(have {SHARD_PARTITIONERS})")
        d = int(base.num_docs)
        if not 1 <= int(num_shards) <= d:
            raise ValueError(
                f"cannot deal {d} documents to {num_shards} shards — need "
                f"1 <= num_shards <= num_docs (every worker must own at "
                "least one document)")
        self.base = base
        self.num_shards = int(num_shards)
        self.partitioner = partitioner
        self.seed = int(seed)
        if partitioner == "range":
            parts = np.array_split(np.arange(d, dtype=np.int64),
                                   self.num_shards)
        else:
            h = _splitmix64(np.arange(d, dtype=_U64)
                            + _splitmix64(np.asarray(self.seed, _U64)))
            order = np.argsort(h, kind="stable")     # rank by hash, stable
            shard_of = np.empty(d, np.int64)
            shard_of[order] = np.arange(d) % self.num_shards  # deal by rank
            parts = [np.nonzero(shard_of == w)[0].astype(np.int64)
                     for w in range(self.num_shards)]
        self._positions: List[np.ndarray] = parts
        self._shards: Dict[int, ShardDocStream] = {}

    # -- views -----------------------------------------------------------
    @property
    def vocab_size(self) -> int:
        return self.base.vocab_size

    @property
    def num_docs(self) -> int:
        return self.base.num_docs

    @property
    def max_unique(self) -> int:
        return self.base.max_unique

    @property
    def shard_sizes(self) -> List[int]:
        return [len(p) for p in self._positions]

    def positions(self, shard: int) -> np.ndarray:
        """Global positions owned by ``shard`` (ascending)."""
        return self._positions[shard]

    def shard(self, shard: int) -> ShardDocStream:
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"shard {shard} out of range "
                             f"[0, {self.num_shards})")
        if shard not in self._shards:
            self._shards[shard] = ShardDocStream(
                self.base, self._positions[shard], shard)
        return self._shards[shard]

    def shards(self) -> List[ShardDocStream]:
        return [self.shard(w) for w in range(self.num_shards)]

    # -- durable identity -------------------------------------------------
    def signature(self) -> Dict[str, object]:
        """The manifest-persisted identity of this shard assignment. Two
        sharded streams with equal signatures deal every document to the
        same shard at the same local position — the precondition for a
        multi-worker resume to be bit-equal."""
        return {"partitioner": self.partitioner,
                "num_shards": self.num_shards,
                "seed": self.seed,
                "num_docs": int(self.base.num_docs)}

    def check_signature(self, saved: Dict[str, object]) -> None:
        """Refuse (ValueError) when ``saved`` (a manifest's ``sharding``
        meta) does not describe THIS assignment — resuming across a
        mismatch would hand workers the wrong documents with stale memo
        rows, a silent wrong answer."""
        live = self.signature()
        if saved == live:
            return
        if int(saved.get("num_shards", -1)) != live["num_shards"]:
            raise ValueError(
                f"checkpoint was taken with {saved.get('num_shards')} "
                f"worker shards but this run has {live['num_shards']} — "
                "the per-worker cursors/memos only make sense under the "
                "shard count that produced them; resume with "
                f"num_workers={saved.get('num_shards')}")
        diffs = {k: (saved.get(k), live[k]) for k in live
                 if saved.get(k) != live[k]}
        raise ValueError(
            "checkpoint shard assignment does not match this stream's: "
            + ", ".join(f"{k}: saved={s!r} != live={l!r}"
                        for k, (s, l) in sorted(diffs.items()))
            + " — a mismatched partition would hand workers the wrong "
            "documents; rebuild the engine with the saved settings")


# ---------------------------------------------------------------------------
# the packer
# ---------------------------------------------------------------------------

class PackedBatch(NamedTuple):
    """One padded device batch packed from ragged documents."""

    rows: np.ndarray        # (B',) int64 — document positions
    token_ids: np.ndarray   # (B', width) int32, leading-column layout
    counts: np.ndarray      # (B', width) float32
    width: int


class CSRBatch(NamedTuple):
    """One flat CSR device batch: every document's tokens concatenated.

    The zero-padding alternative to ``PackedBatch``: the flat arrays are
    always exactly ``token_budget`` long (tail zero-count padded), so ONE
    jit/kernel entry serves every document-length mix — no width ladder.
    ``segments[t]`` is the local row (index into ``rows``) owning token
    ``t``; padding tokens carry segment 0 with count 0, which every
    segment reduction treats as an exact no-op. ``offsets`` are the
    classic CSR row pointers into the live prefix (``offsets[-1]`` is the
    live token count), kept host-side for unpacking per-doc results."""

    rows: np.ndarray        # (B',) int64 — document positions
    token_ids: np.ndarray   # (T,) int32 flat, zero-padded to token_budget
    counts: np.ndarray      # (T,) float32, 0.0 on padding slots
    segments: np.ndarray    # (T,) int32 — local doc index per token
    offsets: np.ndarray     # (B'+1,) int64 — row offsets, offsets[-1]=live
    token_budget: int

    @property
    def num_docs(self) -> int:
        return len(self.rows)

    @property
    def live_tokens(self) -> int:
        return int(self.offsets[-1])

    @property
    def doc_lengths(self) -> np.ndarray:
        return np.diff(self.offsets)


@dataclasses.dataclass
class _WidthStats:
    docs: int = 0
    live_slots: int = 0
    padded_slots: int = 0


# one staged token slot = int32 id + float32 count
TOKEN_SLOT_BYTES = 8


class BatchPacker:
    """Pack ragged documents into bucketed ``(B, W)`` padded batches.

    Stateful: ``add`` files each document under the ladder width covering
    it and emits a ``PackedBatch`` the moment that bucket holds
    ``batch_size`` documents; ``flush`` emits the partial remainder
    (ascending widths). Emission is a deterministic function of the input
    document sequence — which is what lets a mid-epoch checkpoint persist
    just the not-yet-emitted ``pending_docs`` and the stream cursor.

    ``max_width``: the stream's declared ``max_unique`` (training — caps
    the ladder at the memo width; longer documents are clipped to their
    most frequent tokens, the ``corpus_from_docs`` rule) or ``None``
    (serving — the ladder extends by doubling past its top rung).

    ``vocab_size``: when given, every packed token id is checked against
    it — a jnp gather silently CLAMPS out-of-range indices, so a
    malformed document would otherwise train/serve on token V−1 instead
    of failing (the materialized path asserts this in
    ``corpus_from_docs``; the packer is the streaming equivalent).

    ``metrics``: an optional ``repro.obs`` ``MetricsRegistry``; each
    emitted batch updates ``pack.batches``/``pack.docs``/``pack.tokens``
    counters (labelled by width) and the running per-width
    ``pack.pad_frac`` / ``pack.wasted_token_bytes`` gauges. ``None`` (the
    default) records nothing and adds nothing to the packing path.

    ``layout="csr"`` switches the packer to the flat zero-padding mode:
    documents are concatenated into one ``token_budget``-slot ``CSRBatch``
    (doc boundaries carried via segment ids), emitted when the next
    document would overflow the budget or when ``batch_size`` documents
    are open — so a batch never splits a document and every token is
    packed exactly once. The cursor/pending checkpoint contract is
    identical to the padded mode.
    """

    def __init__(self, batch_size: int, *, max_width: Optional[int] = None,
                 boundaries: Sequence[int] = WIDTH_BOUNDARIES,
                 vocab_size: Optional[int] = None, metrics=None,
                 layout: str = "padded", token_budget: Optional[int] = None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown packer layout {layout!r} "
                             "(expected 'padded' or 'csr')")
        if layout == "csr":
            if token_budget is None:
                raise ValueError("layout='csr' needs a token_budget")
            if token_budget < 1:
                raise ValueError("token_budget must be >= 1")
        self.batch_size = batch_size
        self.max_width = max_width
        self.vocab_size = vocab_size
        self.metrics = metrics
        self.layout = layout
        self.token_budget = int(token_budget) if token_budget else None
        self.boundaries = tuple(boundaries)
        self._widths = (width_ladder(max_width, boundaries)
                        if max_width is not None else sorted(boundaries))
        self._open: Dict[int, List[Tuple[int, np.ndarray, np.ndarray]]] = {}
        self._csr_open: List[Tuple[int, np.ndarray, np.ndarray]] = []
        self._csr_tokens = 0
        self._stats: Dict[int, _WidthStats] = {}

    # -- width policy ----------------------------------------------------
    def width_for(self, n_live: int) -> int:
        """The ladder rung covering a document with ``n_live`` live slots."""
        if self.max_width is not None and n_live > self.max_width:
            n_live = self.max_width
        for w in self._widths:
            if n_live <= w:
                return w
        # unbounded ladder (serving): extend by doubling past the top rung
        w = self._widths[-1]
        while w < n_live:
            w *= 2
            self._widths.append(w)
        return w

    # -- packing ---------------------------------------------------------
    def add(self, pos: int, ids: np.ndarray, cnts: np.ndarray):
        """File one ragged document; emit a batch the moment one fills.

        Padded mode returns ``Optional[PackedBatch]``; CSR mode returns
        ``Optional[CSRBatch]``."""
        ids = np.asarray(ids, np.int32).ravel()
        cnts = np.asarray(cnts, np.float32).ravel()
        if self.vocab_size is not None and len(ids) \
                and not (0 <= int(ids.min())
                         and int(ids.max()) < self.vocab_size):
            raise ValueError(
                f"document {pos}: token ids in [{ids.min()}, {ids.max()}] "
                f"fall outside the vocabulary [0, {self.vocab_size})")
        cap = self.max_width
        if self.layout == "csr":
            cap = (self.token_budget if cap is None
                   else min(cap, self.token_budget))
        if cap is not None and len(ids) > cap:
            # keep the most frequent tokens (the corpus_from_docs rule)
            top = np.argsort(-cnts)[:cap]
            ids, cnts = ids[top], cnts[top]
        if self.layout == "csr":
            return self._add_csr(int(pos), ids, cnts)
        w = self.width_for(len(ids))
        bucket = self._open.setdefault(w, [])
        bucket.append((int(pos), ids, cnts))
        if len(bucket) == self.batch_size:
            return self._emit(w)
        return None

    def _emit(self, width: int) -> PackedBatch:
        docs = self._open.pop(width)
        b = len(docs)
        rows = np.asarray([p for p, _, _ in docs], np.int64)
        out_ids = np.zeros((b, width), np.int32)
        out_cnt = np.zeros((b, width), np.float32)
        st = self._stats.setdefault(width, _WidthStats())
        for r, (_, ids, cnts) in enumerate(docs):
            out_ids[r, : len(ids)] = ids
            out_cnt[r, : len(cnts)] = cnts
            st.live_slots += len(ids)
        st.docs += b
        st.padded_slots += b * width
        if self.metrics is not None:
            m = self.metrics
            m.inc("pack.batches", width=width)
            m.inc("pack.docs", b, width=width)
            m.inc("pack.tokens", float(out_cnt.sum()), width=width)
            m.set_gauge("pack.pad_frac",
                        1.0 - st.live_slots / max(st.padded_slots, 1),
                        width=width)
            m.set_gauge("pack.wasted_token_bytes",
                        (st.padded_slots - st.live_slots) * TOKEN_SLOT_BYTES,
                        width=width)
        return PackedBatch(rows, out_ids, out_cnt, width)

    def _add_csr(self, pos: int, ids: np.ndarray,
                 cnts: np.ndarray) -> Optional[CSRBatch]:
        out = None
        if self._csr_open and \
                self._csr_tokens + len(ids) > self.token_budget:
            # the new doc would overflow the flat budget: close the batch
            # first, so no document ever splits across two batches
            out = self._emit_csr()
        self._csr_open.append((pos, ids, cnts))
        self._csr_tokens += len(ids)
        if len(self._csr_open) == self.batch_size:
            # a pre-emit leaves exactly one open doc, and batch_size == 1
            # never pre-emits (the open list is empty then) — so at most
            # one of the two triggers fires per add
            assert out is None
            out = self._emit_csr()
        return out

    def _emit_csr(self) -> CSRBatch:
        docs = self._csr_open
        self._csr_open, self._csr_tokens = [], 0
        t = self.token_budget
        rows = np.asarray([p for p, _, _ in docs], np.int64)
        out_ids = np.zeros(t, np.int32)
        out_cnt = np.zeros(t, np.float32)
        out_seg = np.zeros(t, np.int32)
        offsets = np.zeros(len(docs) + 1, np.int64)
        cur = 0
        for r, (_, ids, cnts) in enumerate(docs):
            n = len(ids)
            out_ids[cur: cur + n] = ids
            out_cnt[cur: cur + n] = cnts
            out_seg[cur: cur + n] = r
            cur += n
            offsets[r + 1] = cur
        st = self._stats.setdefault(t, _WidthStats())
        st.docs += len(docs)
        st.live_slots += cur
        st.padded_slots += t
        if self.metrics is not None:
            m = self.metrics
            m.inc("pack.batches", width=t)
            m.inc("pack.docs", len(docs), width=t)
            m.inc("pack.tokens", float(out_cnt.sum()), width=t)
            m.set_gauge("pack.pad_frac",
                        1.0 - st.live_slots / max(st.padded_slots, 1),
                        width=t)
            m.set_gauge("pack.wasted_token_bytes",
                        (st.padded_slots - st.live_slots) * TOKEN_SLOT_BYTES,
                        width=t)
        return CSRBatch(rows, out_ids, out_cnt, out_seg, offsets, t)

    def flush(self) -> list:
        """Emit every partially-filled bucket (padded: ascending widths;
        CSR: the single open tail batch)."""
        if self.layout == "csr":
            return [self._emit_csr()] if self._csr_open else []
        return [self._emit(w) for w in sorted(self._open) if self._open[w]]

    # -- checkpointing ---------------------------------------------------
    def pending_docs(self) -> List[Tuple[int, np.ndarray, np.ndarray]]:
        """The open buckets' documents (< num_widths × batch_size of them),
        in an order whose replay through ``add`` reconstructs this exact
        packer state — the mid-epoch checkpoint payload."""
        if self.layout == "csr":
            return list(self._csr_open)
        out: List[Tuple[int, np.ndarray, np.ndarray]] = []
        for w in sorted(self._open):
            out.extend(self._open[w])
        return out

    def load_pending(self,
                     docs: List[Tuple[int, np.ndarray, np.ndarray]]) -> None:
        """Restore ``pending_docs`` output into a fresh packer."""
        if self._open or self._csr_open:
            raise ValueError("load_pending needs a fresh packer")
        for pos, ids, cnts in docs:
            if self.add(pos, ids, cnts) is not None:
                raise ValueError("pending docs overflowed a bucket — the "
                                 "checkpoint does not match this batch_size")

    # -- introspection ---------------------------------------------------
    def padding_stats(self) -> dict:
        """Pad-waste accounting over everything emitted so far: per-width
        document counts, pad fractions and wasted staged bytes, plus the
        overall slot ratio. (CSR mode: one 'width' = the token budget.)"""
        per_width = [
            {"width": w, "docs": st.docs,
             "pad_frac": 1.0 - st.live_slots / max(st.padded_slots, 1),
             "wasted_token_bytes":
                 (st.padded_slots - st.live_slots) * TOKEN_SLOT_BYTES}
            for w, st in sorted(self._stats.items())
        ]
        live = sum(st.live_slots for st in self._stats.values())
        padded = sum(st.padded_slots for st in self._stats.values())
        return {"per_width": per_width,
                "live_slots": live, "padded_slots": padded,
                "pad_frac": 1.0 - live / max(padded, 1),
                "wasted_token_bytes": (padded - live) * TOKEN_SLOT_BYTES}


# ---------------------------------------------------------------------------
# stream utilities
# ---------------------------------------------------------------------------

def materialize(stream: DocStream,
                max_unique: Optional[int] = None) -> Corpus:
    """Drain a stream into the padded ``Corpus`` layout (the inverse of
    ``CorpusDocStream``; over-long docs keep their most frequent tokens)."""
    import jax.numpy as jnp

    docs = [(np.asarray(i, np.int32), np.asarray(c, np.float32))
            for i, c in stream.iter_from(0)]
    width = max((len(i) for i, _ in docs), default=1)
    if max_unique is not None:
        width = min(width, max_unique)
    width = max(width, 1)
    out_ids = np.zeros((len(docs), width), np.int32)
    out_cnt = np.zeros((len(docs), width), np.float32)
    for r, (ids, cnts) in enumerate(docs):
        if len(ids) > width:
            top = np.argsort(-cnts)[:width]
            ids, cnts = ids[top], cnts[top]
        out_ids[r, : len(ids)] = ids
        out_cnt[r, : len(cnts)] = cnts
    assert out_ids.max(initial=0) < stream.vocab_size
    return Corpus(jnp.asarray(out_ids), jnp.asarray(out_cnt))


def iter_padded_chunks(stream: DocStream, batch_docs: int, width: int
                       ) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
    """Yield ``(start, ids (b, width), cnts (b, width))`` sequential chunks
    — the read-through path for the streamed memoized ELBO, mirroring
    ``MemoStore.iter_chunks``'s sequential doc order."""
    buf: List[RaggedDoc] = []
    start = 0
    for doc in stream.iter_from(0):
        buf.append(doc)
        if len(buf) == batch_docs:
            yield start, *_pad_docs(buf, width)
            start += len(buf)
            buf = []
    if buf:
        yield start, *_pad_docs(buf, width)


def _pad_docs(docs: List[RaggedDoc], width: int
              ) -> Tuple[np.ndarray, np.ndarray]:
    out_ids = np.zeros((len(docs), width), np.int32)
    out_cnt = np.zeros((len(docs), width), np.float32)
    for r, (ids, cnts) in enumerate(docs):
        out_ids[r, : len(ids)] = ids
        out_cnt[r, : len(cnts)] = cnts
    return out_ids, out_cnt
