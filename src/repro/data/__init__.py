from repro.data.synthetic import SyntheticSpec, make_corpus, PAPER_CORPORA
from repro.data.bow import (LengthBuckets, bucket_corpus,
                            bucket_padding_stats, corpus_from_docs,
                            pad_corpus)
from repro.data.stream import (SHARD_PARTITIONERS, TOKEN_SLOT_BYTES,
                               WIDTH_BOUNDARIES, BatchPacker, CorpusDocStream,
                               CSRBatch, DocStream, ListDocStream, PackedBatch,
                               ShardDocStream, ShardedDocStream,
                               as_doc_stream, as_ragged_doc, bucket_rows,
                               is_doc_stream, iter_padded_chunks, materialize,
                               width_ladder)
from repro.data.uci import UCIDocStream, load_uci, load_vocab, save_uci
