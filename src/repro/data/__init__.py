from repro.data.synthetic import SyntheticSpec, make_corpus, PAPER_CORPORA
from repro.data.bow import (LengthBuckets, bucket_corpus,
                            bucket_padding_stats, corpus_from_docs,
                            pad_corpus)
from repro.data.uci import load_uci, save_uci
