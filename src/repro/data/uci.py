"""UCI bag-of-words format loader (docword.txt / vocab.txt).

The standard distribution format of the paper's corpora (NYT, Enron, ... on
the UCI repository):

    docword.txt:  D\n W\n NNZ\n  then lines "docID wordID count" (1-based)
    vocab.txt:    one token per line (line i+1 = wordID i+1)

`load_uci` returns (Corpus, vocab list). Files may be gzip-compressed.
No network access is required — benchmarks/tests write synthetic files in
this format to exercise the loader.
"""
from __future__ import annotations

import gzip
import os
from typing import List, Optional, Tuple

import numpy as np

from repro.core.types import Corpus
from repro.data.bow import corpus_from_docs


def _open(path: str):
    return gzip.open(path, "rt") if path.endswith(".gz") else open(path)


def load_uci(docword_path: str, vocab_path: Optional[str] = None,
             max_docs: Optional[int] = None,
             max_unique: Optional[int] = None) -> Tuple[Corpus, List[str]]:
    """Parse UCI bag-of-words files into the padded Corpus layout."""
    with _open(docword_path) as f:
        d = int(f.readline())
        w = int(f.readline())
        nnz = int(f.readline())
        n_docs = min(d, max_docs) if max_docs else d
        ids: List[List[int]] = [[] for _ in range(n_docs)]
        cnts: List[List[int]] = [[] for _ in range(n_docs)]
        for line in f:
            parts = line.split()
            if len(parts) != 3:
                continue
            doc, word, cnt = int(parts[0]) - 1, int(parts[1]) - 1, int(parts[2])
            if doc >= n_docs:
                continue
            ids[doc].append(word)
            cnts[doc].append(cnt)
    docs = [np.repeat(np.asarray(i, np.int64), np.asarray(c, np.int64))
            for i, c in zip(ids, cnts)]
    docs = [dd if len(dd) else np.zeros(1, np.int64) for dd in docs]
    corpus = corpus_from_docs(docs, w, max_unique=max_unique)
    vocab: List[str] = []
    if vocab_path and os.path.exists(vocab_path):
        with _open(vocab_path) as f:
            vocab = [ln.strip() for ln in f]
    return corpus, vocab


def save_uci(corpus: Corpus, docword_path: str) -> None:
    """Write a Corpus back out in UCI format (round-trip / interchange)."""
    ids = np.asarray(corpus.token_ids)
    cnt = np.asarray(corpus.counts).astype(np.int64)
    rows = []
    for d in range(ids.shape[0]):
        live = cnt[d] > 0
        for word, c in zip(ids[d][live], cnt[d][live]):
            rows.append((d + 1, int(word) + 1, int(c)))
    opener = gzip.open(docword_path, "wt") if docword_path.endswith(".gz") \
        else open(docword_path, "w")
    with opener as f:
        f.write(f"{ids.shape[0]}\n{int(ids.max()) + 1}\n{len(rows)}\n")
        for r in rows:
            f.write(f"{r[0]} {r[1]} {r[2]}\n")
