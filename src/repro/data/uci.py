"""UCI bag-of-words format (docword.txt / vocab.txt), lazily streamable.

The standard distribution format of the paper's corpora (NYT, Enron, ... on
the UCI repository):

    docword.txt:  D\n W\n NNZ\n  then lines "docID wordID count" (1-based,
                  grouped by docID)
    vocab.txt:    one token per line (line i+1 = wordID i+1)

``UCIDocStream`` exposes such a file as a `repro.data.stream.DocStream`:
the header is read eagerly (D, W), documents lazily — one per-doc group of
lines at a time — so a corpus streams through training without ever being
materialized as a dense ``(D, L)`` padded array
(``launch/train.py --stream``). ``load_uci`` keeps the old materialized
behaviour, now implemented as ``materialize(UCIDocStream(...))`` so the
parser exists exactly once. Files may be gzip-compressed. No network
access is required — benchmarks/tests write synthetic files in this format
to exercise the loader.
"""
from __future__ import annotations

import bisect
import gzip
import os
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.core.types import Corpus
from repro.data.stream import DocStream, RaggedDoc, materialize


def _open(path: str):
    return gzip.open(path, "rt") if path.endswith(".gz") else open(path)


def _open_binary(path: str):
    """The docword parser reads BINARY lines: ``int()`` accepts bytes, and
    binary ``tell``/``seek`` are cheap positions (text-mode tell is an
    opaque cookie with real per-call cost) — the resume index depends on
    them."""
    return (gzip.open(path, "rb") if path.endswith(".gz")
            else open(path, "rb"))


class UCIDocStream(DocStream):
    """Lazy ``DocStream`` over a UCI docword file (see module docstring).

    Only the 3-line header is read at construction. ``num_words`` and
    ``max_unique`` need one pass over the file; it runs lazily on first
    access and is cached. That same pass records a byte-offset **resume
    index** — the file position of one docID group start every
    ``index_every`` documents — so ``iter_from(cursor)`` seeks to the
    nearest indexed group at or below the cursor and parses O(index_every)
    documents instead of re-reading the whole prefix: a deep mid-epoch
    resume (the distributed-streaming restart path) touches O(1) leading
    bytes of an uncompressed file. (Gzip members still decompress their
    prefix on seek — that is a property of the format, not the parser.)

    The stats scan persists its result to a sidecar ``<path>.idx.npz``
    (atomic tmp+rename, best-effort — a read-only directory just skips the
    cache). N workers sharing one docword file — the ``ShardedDocStream``
    deployment — then pay the O(corpus) scan ONCE: every later stream over
    the same file loads stats + index from the sidecar, which is
    invalidated on any mtime/size mismatch with the docword file (and on a
    differing ``max_docs`` / ``max_unique`` / ``index_every``, which change
    what the scan would have produced). ``use_index_cache=False`` opts out.

    Quirks mirrored from the materialized loader for exact equivalence:
    docIDs absent from the file (empty documents) yield the placeholder
    ``([0], [1.0])`` that ``load_uci`` has always produced for them, and
    ``max_unique``/per-doc clipping keep the most frequent tokens.
    """

    _IDX_VERSION = 1

    def __init__(self, docword_path: str, *, max_docs: Optional[int] = None,
                 max_unique: Optional[int] = None, index_every: int = 1000,
                 use_index_cache: bool = True):
        self.path = docword_path
        self.max_unique_cap = max_unique
        self.index_every = max(1, int(index_every))
        self.use_index_cache = bool(use_index_cache)
        with _open(docword_path) as f:
            d = int(f.readline())
            w = int(f.readline())
            int(f.readline())                     # NNZ, unused
        self.vocab_size = w
        self._num_docs = min(d, max_docs) if max_docs else d
        self._stats: Optional[Tuple[float, int]] = None   # (words, max_uniq)
        self._index: Optional[List[Tuple[int, int]]] = None  # (doc, offset)

    # -- DocStream contract ---------------------------------------------
    @property
    def num_docs(self) -> int:
        return self._num_docs

    @property
    def num_words(self) -> float:
        return self._scan_stats()[0]

    @property
    def max_unique(self) -> int:
        return self._scan_stats()[1]

    def iter_from(self, cursor: int = 0) -> Iterator[RaggedDoc]:
        if cursor <= 0:
            yield from self._iter_docs()
            return
        # the resume index rides the stats scan — which every training
        # run pays anyway (num_words/max_unique) and is cached, so
        # forcing it here keeps deep resumes O(index_every), not O(cursor)
        self._scan_stats()
        start, offset = 0, None
        if self._index:
            i = bisect.bisect_right([d for d, _ in self._index], cursor) - 1
            if i >= 0:
                start, offset = self._index[i]
        it = self._iter_docs(next_doc=start, offset=offset)
        for pos, doc in enumerate(it, start=start):
            if pos >= cursor:
                yield doc

    # -- internals -------------------------------------------------------
    def _iter_docs(self, next_doc: int = 0, offset: Optional[int] = None,
                   track=None) -> Iterator[RaggedDoc]:
        """Documents ``next_doc``..num_docs-1 in order, clipping applied.

        ``offset``: byte position of the first line of docID group
        ``next_doc`` (from the resume index); None starts past the header.
        ``track(doc, cookie)``: called with the byte offset of each docID
        group's first line — the stats scan's hook that builds the index.
        """
        empty = (np.asarray([0], np.int32), np.asarray([1.0], np.float32))
        words: List[int] = []
        cnts: List[int] = []
        with _open_binary(self.path) as f:
            if offset is None:
                for _ in range(3):
                    f.readline()
            else:
                f.seek(offset)
            while True:
                cookie = f.tell() if track is not None else None
                line = f.readline()
                if not line:
                    break
                parts = line.split()
                if len(parts) != 3:
                    continue
                doc, word, cnt = (int(parts[0]) - 1, int(parts[1]) - 1,
                                  int(parts[2]))
                if doc >= self._num_docs:
                    continue
                if doc < next_doc:
                    # a line for an already-emitted document: the file is
                    # not grouped by docID — a lazy reader cannot go back,
                    # so fail loudly instead of emitting phantom documents
                    raise ValueError(
                        f"{self.path!r}: docword lines are not grouped by "
                        f"docID (doc {doc + 1} after doc {next_doc + 1}) — "
                        "sort the file or use the eager load path")
                if doc != next_doc and words:
                    yield self._finish_doc(words, cnts)
                    next_doc += 1
                    words, cnts = [], []
                while next_doc < doc:    # gap in docIDs: empty documents
                    yield empty
                    next_doc += 1
                if track is not None and not words:
                    track(doc, cookie)   # first line of this docID group
                words.append(word)
                cnts.append(cnt)
        if words:
            yield self._finish_doc(words, cnts)
            next_doc += 1
        while next_doc < self._num_docs:
            yield empty
            next_doc += 1

    def _finish_doc(self, words: List[int], cnts: List[int]) -> RaggedDoc:
        """Aggregate one doc's lines: duplicate wordIDs summed, ids
        ascending (the np.unique-of-repeats order ``load_uci`` produced),
        clipped to the most frequent under a ``max_unique`` cap."""
        w = np.asarray(words, np.int64)
        c = np.asarray(cnts, np.int64)
        uw, inv = np.unique(w, return_inverse=True)
        uc = np.zeros(len(uw), np.int64)
        np.add.at(uc, inv, c)
        ids = uw.astype(np.int32)
        out = uc.astype(np.float32)
        cap = self.max_unique_cap
        if cap is not None and len(ids) > cap:
            top = np.argsort(-out)[:cap]
            ids, out = ids[top], out[top]
        return ids, out

    def _scan_stats(self) -> Tuple[float, int]:
        if self._stats is None:
            if self.use_index_cache and self._load_sidecar():
                return self._stats
            words, maxu = 0.0, 1
            index: List[Tuple[int, int]] = []

            def track(doc: int, cookie: int) -> None:
                if not index or doc >= index[-1][0] + self.index_every:
                    index.append((doc, cookie))

            for ids, cnts in self._iter_docs(track=track):
                words += float(cnts.sum())
                maxu = max(maxu, len(ids))
            self._stats = (words, maxu)
            self._index = index
            if self.use_index_cache:
                self._save_sidecar()
        return self._stats

    # -- sidecar stats/index cache ---------------------------------------
    @property
    def index_path(self) -> str:
        return self.path + ".idx.npz"

    def _sidecar_key(self) -> np.ndarray:
        """The validity key: docword identity (mtime ns + size) plus every
        knob that changes what the scan produces."""
        st = os.stat(self.path)
        return np.asarray([self._IDX_VERSION, st.st_mtime_ns, st.st_size,
                           self._num_docs,
                           -1 if self.max_unique_cap is None
                           else self.max_unique_cap,
                           self.index_every], np.int64)

    def _load_sidecar(self) -> bool:
        """True iff a valid sidecar filled ``_stats``/``_index``. A stale
        sidecar (docword rewritten, different knobs) is simply ignored —
        the scan reruns and overwrites it."""
        try:
            with np.load(self.index_path) as z:
                if not np.array_equal(z["key"], self._sidecar_key()):
                    return False
                self._stats = (float(z["words"]), int(z["max_unique"]))
                self._index = [(int(d), int(o)) for d, o in z["index"]]
            return True
        except (OSError, KeyError, ValueError):
            return False

    def _save_sidecar(self) -> None:
        """Best-effort atomic write (tmp + rename); failure to persist —
        read-only dir, races with a sibling worker — never fails the scan
        (the rename makes concurrent writers last-wins, both valid)."""
        tmp = f"{self.index_path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "wb") as f:       # handle, not name: np.savez
                np.savez(f, key=self._sidecar_key(),  # appends .npz to names
                         words=np.asarray(self._stats[0]),
                         max_unique=np.asarray(self._stats[1]),
                         index=np.asarray(self._index or
                                          np.empty((0, 2)), np.int64)
                         .reshape(-1, 2))
            os.replace(tmp, self.index_path)
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass


def load_vocab(vocab_path: Optional[str]) -> List[str]:
    """The vocab.txt side of the format (empty list if absent)."""
    if not (vocab_path and os.path.exists(vocab_path)):
        return []
    with _open(vocab_path) as f:
        return [ln.strip() for ln in f]


def load_uci(docword_path: str, vocab_path: Optional[str] = None,
             max_docs: Optional[int] = None,
             max_unique: Optional[int] = None) -> Tuple[Corpus, List[str]]:
    """Parse UCI bag-of-words files into the padded Corpus layout —
    ``materialize`` over the lazy stream (one parser, two consumers)."""
    stream = UCIDocStream(docword_path, max_docs=max_docs,
                          max_unique=max_unique)
    return materialize(stream, max_unique=max_unique), load_vocab(vocab_path)


def save_uci(corpus: Corpus, docword_path: str) -> None:
    """Write a Corpus back out in UCI format (round-trip / interchange)."""
    ids = np.asarray(corpus.token_ids)
    cnt = np.asarray(corpus.counts).astype(np.int64)
    rows = []
    for d in range(ids.shape[0]):
        live = cnt[d] > 0
        for word, c in zip(ids[d][live], cnt[d][live]):
            rows.append((d + 1, int(word) + 1, int(c)))
    opener = gzip.open(docword_path, "wt") if docword_path.endswith(".gz") \
        else open(docword_path, "w")
    with opener as f:
        f.write(f"{ids.shape[0]}\n{int(ids.max()) + 1}\n{len(rows)}\n")
        for r in rows:
            f.write(f"{r[0]} {r[1]} {r[2]}\n")
