"""Synthetic corpora sampled from the LDA generative model (paper eq. 1).

Real AP / Newsgroup / Wikipedia / Arxiv / Customer-Review / NYT dumps are not
available offline, so we sample corpora *from the model itself* with the
summary statistics of Table 1 (documents, vocabulary, mean length) scaled to
CPU budgets. Trends (convergence order, mini-batch effects, speed-ups) are
reproduced; absolute LPP values are corpus-specific and are not.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.types import Corpus
from repro.data.bow import corpus_from_docs


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    num_train: int
    num_test: int
    mean_len: int
    vocab_size: int
    num_topics: int = 100       # ground-truth topics used to generate
    alpha: float = 0.1          # generative doc-topic concentration
    beta: float = 0.01          # generative topic-word concentration (sparse)


# Table 1 of the paper, scaled ~where needed for CPU execution.
PAPER_CORPORA: Dict[str, SyntheticSpec] = {
    "ap": SyntheticSpec("ap", 1246, 1000, 198, 10473),
    "newsgroup": SyntheticSpec("newsgroup", 13888, 5000, 249, 27059),
    "wikipedia": SyntheticSpec("wikipedia", 39565, 10000, 260, 42419),
    "arxiv": SyntheticSpec("arxiv", 782385, 100000, 116, 141927),
    "customer_review": SyntheticSpec("customer_review", 452944, 100000, 151,
                                     120043),
    "nyt": SyntheticSpec("nyt", 290000, 10000, 232, 102660),
    # CPU-sized variants used by tests/benchmarks
    "tiny": SyntheticSpec("tiny", 96, 32, 40, 250, num_topics=8),
    "small": SyntheticSpec("small", 512, 128, 80, 1200, num_topics=20),
    "medium": SyntheticSpec("medium", 2048, 256, 120, 4000, num_topics=50),
}


def make_corpus(spec: SyntheticSpec, *, split: str = "train",
                seed: int = 0, scale: float = 1.0) -> Corpus:
    """Sample a corpus from the LDA generative model.

    ``scale`` < 1 shrinks document counts (not lengths/vocab) so the paper's
    large corpora can be exercised at CPU scale while keeping their shape.
    """
    assert split in ("train", "test")
    rng = np.random.default_rng(seed + (1_000_003 if split == "test" else 0))
    n_docs = max(int((spec.num_train if split == "train" else spec.num_test)
                     * scale), 8)
    # ground-truth topics, shared across splits via a fixed topic seed.
    # NB: zlib.crc32, not hash() — Python string hashing is salted per
    # process and would make corpora (and every LPP) non-reproducible.
    import zlib
    topic_rng = np.random.default_rng(zlib.crc32(spec.name.encode()))
    phi = topic_rng.dirichlet([spec.beta] * spec.vocab_size, spec.num_topics)
    docs = []
    lengths = np.maximum(rng.poisson(spec.mean_len, n_docs), 4)
    for n in lengths:
        theta = rng.dirichlet([spec.alpha] * spec.num_topics)
        z = rng.choice(spec.num_topics, size=n, p=theta)
        # sample words per unique topic in bulk (much faster than per-word)
        doc = np.empty(n, np.int64)
        for k, cnt in zip(*np.unique(z, return_counts=True)):
            doc[z == k] = rng.choice(spec.vocab_size, size=cnt, p=phi[k])
        docs.append(doc)
    return corpus_from_docs(docs, spec.vocab_size)
