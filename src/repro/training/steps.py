"""Step builders: train_step (loss+grad+optimizer), prefill and serve steps.

These are the functions the launcher jits/lowers; the dry-run lowers exactly
these with production shardings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.moe import MeshCtx
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    ctx: Optional[MeshCtx] = None,
                    clip_norm: float = 1.0,
                    microbatches: int = 1):
    """Returns train_step(state, batch) → (state, metrics).

    ``microbatches`` > 1 enables gradient accumulation: the global batch is
    split on its leading dim and a lax.scan accumulates gradients, dividing
    peak activation memory by the microbatch count with unchanged collective
    volume per sample (§Perf lever for the train_4k shapes).
    """

    def grad_one(params, batch):
        def lfn(p):
            return T.loss_fn(cfg, p, batch, ctx)
        return jax.value_and_grad(lfn, has_aux=True)(params)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = grad_one(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, b_i):
                (_, m), g = grad_one(state.params, b_i)
                return jax.tree.map(jnp.add, acc, (g, m)), None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   state.params)
            # initialise metric accumulator with zeros of the right struct
            zeros_m = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                jax.eval_shape(lambda p, b: grad_one(p, b)[0][1],
                               state.params,
                               jax.tree.map(lambda x: x[0], mb)))
            (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[MeshCtx] = None):
    """Inference forward over full sequences (no grads, no labels).

    Serving-realistic: returns only the **last position's** logits (what the
    decode loop consumes) — materialising (B, S, V) logits for a 32k prefill
    would burn tens of GB per device for no purpose.
    """

    def prefill_step(params, batch):
        hidden, _ = T.forward_hidden(cfg, params, batch, ctx)
        return T._readout(cfg, params, hidden[:, -1:])[:, 0]

    return prefill_step


def make_serve_step(cfg: ModelConfig, ctx: Optional[MeshCtx] = None,
                    greedy: bool = True):
    """One-token decode against a KV/recurrent cache."""

    def serve_step(params, caches, tokens, pos):
        logits, caches = T.decode_step(cfg, params, caches, tokens, pos, ctx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, caches

    return serve_step
