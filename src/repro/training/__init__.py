from repro.training.steps import (make_train_step, make_serve_step,
                                  make_prefill_step, TrainState)
