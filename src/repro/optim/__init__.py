from repro.optim.optimizers import (Optimizer, adamw, sgd, iag,
                                    apply_updates, clip_by_global_norm,
                                    cosine_schedule)
