"""Optimizers (no external deps): AdamW, SGD, and IAG.

IAG — *incremental aggregate gradient* — is the paper's mechanism lifted to
gradient training (DESIGN.md §4): like IVI memoizes per-document statistics
and updates the global accumulator by subtract-old/add-new, IAG memoizes the
last gradient of each data shard and keeps the aggregate gradient exact:

    G ← G − g_shard_old + g_shard_new ;   θ ← θ − η · G / S

(Le Roux et al. 2012's SAG; the paper itself notes S-IVI ≈ SAG.) Memory is
one gradient copy per shard, exactly analogous to IVI's O(K·N) memo.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]   # (grads, state, params) → (upd, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        mh = jax.tree.map(lambda mm: mm / (1 - b1 ** c), m)
        vh = jax.tree.map(lambda vv: vv / (1 - b2 ** c), v)
        step = lr_fn(c)
        upd = jax.tree.map(
            lambda mm, vv, p: -step * (mm / (jnp.sqrt(vv) + eps)
                                       + weight_decay * p.astype(jnp.float32)),
            mh, vh, params)
        return upd, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def sgd(lr, momentum: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        upd = jax.tree.map(lambda m: -lr_fn(c) * m, mu)
        return upd, {"mu": mu, "count": c}

    return Optimizer(init, update)


def iag(lr, num_shards: int) -> Optimizer:
    """Incremental aggregate gradient (SAG). ``update`` needs ``shard=`` id.

    State holds the per-shard gradient memory (num_shards, ...) and the
    aggregate; each call replaces one shard's memoized gradient — the exact
    subtract-old/add-new bookkeeping of IVI eq. (4), applied to gradients.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "memo": jax.tree.map(
                lambda p: jnp.zeros((num_shards,) + p.shape, jnp.float32),
                params),
            "agg": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params),
            "seen": jnp.zeros((num_shards,), bool),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, *, shard):
        c = state["count"] + 1
        old = jax.tree.map(lambda m: m[shard], state["memo"])
        agg = jax.tree.map(lambda a, g, o: a + g.astype(jnp.float32) - o,
                           state["agg"], grads, old)
        memo = jax.tree.map(lambda m, g: m.at[shard].set(g.astype(jnp.float32)),
                            state["memo"], grads)
        seen = state["seen"].at[shard].set(True)
        denom = jnp.maximum(seen.sum().astype(jnp.float32), 1.0)
        upd = jax.tree.map(lambda a: -lr_fn(c) * a / denom, agg)
        return upd, {"memo": memo, "agg": agg, "seen": seen, "count": c}

    return Optimizer(init, update)
