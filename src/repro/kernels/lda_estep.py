"""Pallas TPU kernels for the LDA E-step hotspot.

Production path (`ops.estep_pallas` / `ops.memo_correction_pallas`):

* ``estep_fixed_point`` — the ENTIRE γ fixed point in one ``pallas_call``:
  grid ``(B-tiles, max_iters, V-tiles)`` with γ, Eθ and the sweep
  accumulator resident in VMEM scratch across grid steps. Each sweep
  streams Eφ (and the dense counts C) HBM→VMEM once via the V grid axis;
  a per-B-tile convergence flag in SMEM (mean |Δγ| ≤ tol) predicates the
  remaining sweeps to no-ops, and the sweep counter is emitted per tile.
  Nothing γ-shaped ever round-trips to HBM between sweeps — the old path
  paid one pallas_call per sweep plus a jnp Eθ recomputation per sweep.
* ``memo_delta`` — token-aligned π AND the subtract-old/add-new scatter as
  a **segment-sum** over two kernels: the token-π kernel tiles the (B, L)
  axes (the L grid axis — VMEM no longer bounds the corpus L) and forms
  π = Eθ⊙Eφ_tok/φnorm per tile; the scatter kernel flattens the batch to
  token rows and accumulates cnt·π_new / cnt·π_old into (V, K) over a
  second-level **V-chunk grid axis** — the chunk axis is outermost, so
  each (block_v, K) accumulator is revisited only by grid-consecutive row
  tiles (the revisit pattern Pallas TPU defines) and hits HBM exactly once
  per chunk. No dense (nb, V, K) one-hot partials exist anywhere, and the
  IVI correction still needs **no (B, L, K) jnp intermediates**: the only
  (B, L, K) array XLA sees is the Eφ token gather feeding the kernel.
  The retired one-hot-partial formulation is kept as
  ``memo_delta_onehot`` (benchmark baseline).

Legacy per-sweep path
---------------------
* ``estep_sweep``  — γ' = α₀ + Eθ ⊙ (R·Eφ),  R = C ⊘ (Eθ·Eφᵀ + ε)
* ``sstats``       — S  = Eφ ⊙ (Rᵀ·Eθ)

Tiling (DESIGN.md §7 and docs/estep.md): B-tile × V-tile × K — K is padded
to a multiple of 128 by the wrapper (`ops.py`), V-tiles default to 512 and
B-tiles to 128, so the fused fixed point's VMEM working set is

    C (128·512) + Eφ (512·128) + γ/Eθ/acc (3·128·128)  ≈ 0.8 MB  « 16 MB

and every matmul hits the MXU with ≥128 on both the lane and the
contraction dimension. ``stream_dtype=bfloat16`` streams C and Eφ in bf16
(fp32 accumulation), halving the dominant HBM terms of the fixed point.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


def _default_interpret(interpret):
    return jax.default_backend() != "tpu" if interpret is None else interpret


# ---------------------------------------------------------------------------
# in-kernel Dirichlet expectation
# ---------------------------------------------------------------------------

def _digamma(x):
    """ψ(x) for x > 0, kernel-safe (no lax.digamma lowering dependence).

    Recurrence ψ(x) = ψ(x+1) − 1/x applied 8 times pushes the argument
    above 8, where the asymptotic series ln x − 1/2x − Σ B₂ₙ/(2n·x²ⁿ) is
    accurate to ~1e-7 relative — far inside the E-step tolerance.
    """
    shift = jnp.zeros_like(x)
    for _ in range(8):
        shift += 1.0 / x
        x = x + 1.0
    inv = 1.0 / x
    inv2 = inv * inv
    series = jnp.log(x) - 0.5 * inv - inv2 * (
        1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 / 252.0))
    return series - shift


def _exp_elog_theta(g, k_real: int):
    """exp(E[ln θ]) over the first ``k_real`` topics; padded topics → 0.

    Padded γ columns carry exactly α₀ and a zero Eφ column (see
    ``ops.pad_inputs``); masking them out of the normaliser keeps the real
    topics' expectation identical to the unpadded computation.
    """
    mask = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1) < k_real
    gm = jnp.where(mask, g, 0.0)
    s = gm.sum(-1, keepdims=True)
    et = jnp.exp(_digamma(jnp.maximum(g, 1e-10)) - _digamma(s))
    return jnp.where(mask, et, 0.0)


# ---------------------------------------------------------------------------
# fused fixed-point kernel
# ---------------------------------------------------------------------------

def _fixed_point_kernel(alpha0: float, tol: float, k_real: int,
                        b_real: int, block_b: int, num_t: int, num_v: int,
                        c_ref, eb_ref, g0_ref,
                        gamma_ref, et_ref, iters_ref,
                        gamma_s, et_s, acc_s, flags):
    i = pl.program_id(0)
    t = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((t == 0) & (j == 0))
    def _start():
        gamma_s[...] = g0_ref[...]
        flags[0] = 0                                   # converged flag
        flags[1] = 0                                   # sweeps run

    live = flags[0] == 0

    @pl.when(live & (j == 0))
    def _sweep_start():
        et_s[...] = _exp_elog_theta(gamma_s[...], k_real)
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(live)
    def _accumulate():
        et = et_s[...]                                 # (bB, K)
        eb = eb_ref[...].astype(jnp.float32)           # (bV, K)
        p = jax.lax.dot_general(et, eb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) + _EPS
        r = c_ref[...].astype(jnp.float32) / p         # (bB, bV)
        acc_s[...] += jax.lax.dot(r, eb,
                                  preferred_element_type=jnp.float32)

    @pl.when(live & (j == num_v - 1))
    def _sweep_end():
        g_old = gamma_s[...]
        mask = jax.lax.broadcasted_iota(jnp.int32, g_old.shape, 1) < k_real
        g_new = jnp.where(mask, alpha0 + et_s[...] * acc_s[...], alpha0)
        # mean |Δγ| over the tile's REAL rows/topics only — padding holds
        # γ = α₀ exactly (zero diff) but must not dilute the convergence
        # threshold, or the kernel stops earlier than the jnp backends
        rows_real = jnp.clip(b_real - i * block_b, 1, block_b)
        delta = jnp.abs(g_new - g_old).sum() / (rows_real * k_real)
        gamma_s[...] = g_new
        flags[1] += 1
        flags[0] = jnp.where(delta <= tol, 1, 0).astype(jnp.int32)

    @pl.when((t == num_t - 1) & (j == num_v - 1))
    def _finish():
        g = gamma_s[...]
        gamma_ref[...] = g
        et_ref[...] = _exp_elog_theta(g, k_real)
        iters_ref[0, 0] = flags[1]


def estep_fixed_point(c: jax.Array, eb: jax.Array, gamma0: jax.Array,
                      alpha0: float, tol: float, max_iters: int,
                      k_real: int, b_real: int | None = None, *,
                      block_b: int = 128, block_v: int = 512,
                      interpret: bool | None = None):
    """The whole γ fixed point as ONE pallas_call.

    Shapes: c (B, V), eb (V, K), gamma0 (B, K) → (γ (B, K), Eθ (B, K),
    per-B-tile sweep counts (nb, 1) int32). All dims pre-padded to the
    block grid; ``k_real``/``b_real`` mask the padded topic columns and
    batch rows out of the convergence mean. C/Eφ may be bf16 (fp32
    accumulation).
    """
    b, v = c.shape
    k = gamma0.shape[1]
    b_real = b if b_real is None else b_real
    block_b, block_v = min(block_b, b), min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    interpret = _default_interpret(interpret)
    nb, nv = b // block_b, v // block_v
    grid = (nb, max(int(max_iters), 1), nv)
    gamma, et, iters = pl.pallas_call(
        functools.partial(_fixed_point_kernel, alpha0, tol, k_real,
                          b_real, block_b, grid[1], nv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, t, j: (i, j)),
            pl.BlockSpec((block_v, k), lambda i, t, j: (j, 0)),
            pl.BlockSpec((block_b, k), lambda i, t, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, t, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, t, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, k), jnp.float32),
            pltpu.VMEM((block_b, k), jnp.float32),
            pltpu.VMEM((block_b, k), jnp.float32),
            pltpu.SMEM((2,), jnp.int32),
        ],
        interpret=interpret,
    )(c, eb, gamma0)
    return gamma, et, iters


# ---------------------------------------------------------------------------
# memo correction, production path: token-π kernel + segment-sum scatter
# ---------------------------------------------------------------------------

def _token_pi_kernel(quantize: bool, cnts_ref, ebtok_ref, et_ref, pi_ref):
    """π = Eθ⊙Eφ_tok/φnorm for one (B-tile, L-tile); each block written once.

    The L grid axis is what lifts the old ``L ≤ ~4k`` VMEM cap: the working
    set is two (block_b, block_l, K) cubes regardless of the corpus L.
    """
    et = et_ref[...]                                   # (bB, K)
    ebt = ebtok_ref[...]                               # (bB, bL, K)
    cnts = cnts_ref[...]                               # (bB, bL)
    p = (et[:, None, :] * ebt).sum(-1) + _EPS          # (bB, bL)
    pi = et[:, None, :] * ebt / p[:, :, None]
    pi = jnp.where(cnts[:, :, None] > 0, pi, 0.0)
    if quantize:
        # round through the memo store's wire dtype BEFORE the scatter,
        # so ⟨m_vk⟩ adds exactly what the store will later subtract
        pi = pi.astype(jnp.bfloat16).astype(jnp.float32)
    pi_ref[...] = pi


def _segment_scatter_kernel(has_old: bool, *refs):
    """Segment-sum one tile of token rows into the current V chunk.

    Grid ``(V-chunks, row-tiles)`` with the chunk axis OUTER: for a fixed
    chunk ``j`` the (block_v, K) output block is revisited across the
    grid-consecutive row tiles, which is exactly the revisit pattern Pallas
    TPU defines for in-kernel accumulation — so the (V, K) masses build up
    in VMEM and hit HBM once per chunk, with **no** per-B-tile (nb, V, K)
    partials. Rows are segmented arithmetically: a row contributes to the
    chunk its token id falls in (`iota == ids`, count-scaled), everything
    else multiplies to zero — padded rows carry count 0 and are inert.
    """
    if has_old:
        ids_ref, cnts_ref, wnew_ref, wold_ref, snew_ref, sold_ref = refs
    else:
        ids_ref, cnts_ref, wnew_ref, snew_ref = refs
        wold_ref = sold_ref = None
    j, t = pl.program_id(0), pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        snew_ref[...] = jnp.zeros_like(snew_ref)
        if has_old:
            sold_ref[...] = jnp.zeros_like(sold_ref)

    bv = snew_ref.shape[0]
    tb = ids_ref.shape[1]
    rows = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bv, tb), 0)
    # count-scaled segment selector: (bV, T) — doubles as the MXU scatter
    # operand, so cnt·π never materialises as a separate row array
    weights = jnp.where(rows == ids_ref[...], cnts_ref[...], 0.0)
    snew_ref[...] += jax.lax.dot(weights, wnew_ref[...],
                                 preferred_element_type=jnp.float32)
    if has_old:
        sold_ref[...] += jax.lax.dot(weights, wold_ref[...],
                                     preferred_element_type=jnp.float32)


# VMEM budgets: the token-π step holds two (block_b, block_l, K) fp32 cubes
# (Eφ tokens in, π out); the scatter step holds the (block_v, T) selector
# plus one or two (block_v, K) accumulators and (T, K) row tiles. Both kept
# at half the 16 MB VMEM for the pipeline's double buffering.
_PI_VMEM_BUDGET = 8 * 1024 * 1024
_SEG_VMEM_BUDGET = 8 * 1024 * 1024


def pi_tile_shape(b: int, l: int, k: int, *, block_b: int = 32,
                  block_l: int = 512) -> Tuple[int, int]:
    """(block_b, block_l) for the token-π kernel under the VMEM budget.

    L longer than ``block_l`` is tiled by the L grid axis (the corpus L no
    longer bounds VMEM); the B tile is then halved until the two
    (block_b, block_l, K) cubes fit the step budget.
    """
    bl = l if l <= block_l else block_l
    bb = min(block_b, b)
    while bb > 1 and 2 * bb * bl * k * 4 > _PI_VMEM_BUDGET:
        nxt = bb // 2
        bb = nxt if b % nxt == 0 else 1    # keep the grid exact
    return bb, bl


def segment_scatter_blocks(k: int, vocab_size: int, has_old: bool, *,
                           block_v: int | None = None,
                           block_t: int = 128) -> Tuple[int, int]:
    """(block_v, block_t) for the segment-sum scatter under its budget.

    ``block_v`` is the second-level V-chunk: the largest multiple of 128
    whose selector + accumulators fit ``_SEG_VMEM_BUDGET`` (capped at the
    lane-aligned vocab, so small vocabs run V-resident in one chunk). The
    scatter re-streams the token rows once per chunk, so bigger chunks mean
    fewer re-streams — the chunk count is the path's traffic knob.
    """
    nacc = 2 if has_old else 1

    def _step_bytes(vc):
        return (vc * block_t + nacc * (vc * k + block_t * k)) * 4

    if block_v is None:
        block_v = 8192
        while block_v > 128 and _step_bytes(block_v) > _SEG_VMEM_BUDGET:
            block_v //= 2
    block_v = min(block_v, _round_up(vocab_size, 128))
    return block_v, block_t


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def memo_delta(token_ids: jax.Array, counts: jax.Array, eb_tok: jax.Array,
               etheta: jax.Array, vocab_size: int,
               old_pi: jax.Array | None = None, *,
               quantize: bool = False, block_b: int = 32,
               block_l: int = 512, block_v: int | None = None,
               block_t: int = 128, interpret: bool | None = None):
    """Token-aligned π plus segment-summed new/old masses — two kernels.

    Shapes: token_ids/counts (B, L), eb_tok (B, L, K) = Eφ[token_ids],
    etheta (B, K). Returns (π (B, L, K), S_new (V, K)[, S_old (V, K)]):
    S_new = Σ cnt·π_new and S_old = Σ cnt·π_old accumulated at the token
    ids, so the IVI correction is ``S_new − S_old`` and the batch
    sufficient statistics are ``S_new``.

    Two ``pallas_call``s because the two outputs want opposite grid
    orders: π blocks pin the (B, L) axes as owners (each written once),
    while the (V, K) masses accumulate over ALL rows — which is only
    TPU-safe with the V-chunk axis outermost (grid-consecutive revisits).
    The first kernel tiles (B, L) — the **L grid axis** that removes the
    old L ≤ ~4k VMEM cap — and emits π (quantized through the memo wire
    dtype when asked). The second flattens the rows and segment-sums them
    into (V, K) chunk by chunk: no dense (nb, V, K) one-hot partials
    exist anywhere, the only transient beyond the outputs is the
    row-padding remainder. The retired partial formulation is kept as
    ``memo_delta_onehot`` (benchmark baseline).

    B must divide by the effective B-tile (pad upstream with zero-count
    rows); V and L are padded here (zero-count padding is inert).
    """
    b, l = token_ids.shape
    k = etheta.shape[1]
    has_old = old_pi is not None
    interpret = _default_interpret(interpret)

    # -- kernel 1: token-aligned π over the (B-tiles, L-tiles) grid -----
    bb, bl = pi_tile_shape(b, l, k, block_b=block_b, block_l=block_l)
    assert b % bb == 0, (b, bb)
    lp = _round_up(l, bl)

    def _pad_l(x):
        if lp == l:
            return x
        pad = ((0, 0), (0, lp - l)) + ((0, 0),) * (x.ndim - 2)
        return jnp.pad(x, pad)

    ids_p, cnts_p, ebt_p = _pad_l(token_ids), _pad_l(counts), _pad_l(eb_tok)
    nb, nl = b // bb, lp // bl
    pi_pad = pl.pallas_call(
        functools.partial(_token_pi_kernel, quantize),
        grid=(nb, nl),
        in_specs=[
            pl.BlockSpec((bb, bl), lambda i, li: (i, li)),
            pl.BlockSpec((bb, bl, k), lambda i, li: (i, li, 0)),
            pl.BlockSpec((bb, k), lambda i, li: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bl, k), lambda i, li: (i, li, 0)),
        out_shape=jax.ShapeDtypeStruct((b, lp, k), jnp.float32),
        interpret=interpret,
    )(cnts_p, ebt_p, etheta)

    # -- kernel 2: segment-sum scatter over the V-chunk grid ------------
    vc, tb = segment_scatter_blocks(k, vocab_size, has_old,
                                    block_v=block_v, block_t=block_t)
    rows = b * lp
    tb = min(tb, rows)
    rows_p = _round_up(rows, tb)
    nt = rows_p // tb

    def _flat_rows(x, width):
        flat = x.reshape(rows, *((width,) if width else ()))
        if rows_p == rows:
            return flat
        pad = ((0, rows_p - rows),) + ((0, 0),) * (flat.ndim - 1)
        return jnp.pad(flat, pad)

    ids2 = _flat_rows(ids_p, None).reshape(nt, tb)
    cnts2 = _flat_rows(cnts_p, None).reshape(nt, tb)
    wnew = _flat_rows(pi_pad, k)
    inputs = [ids2, cnts2, wnew]
    if has_old:
        inputs.append(_flat_rows(_pad_l(old_pi), k))

    vp = _round_up(vocab_size, vc)
    row_spec = pl.BlockSpec((1, tb), lambda j, t: (t, 0))
    w_spec = pl.BlockSpec((tb, k), lambda j, t: (t, 0))
    acc_spec = pl.BlockSpec((vc, k), lambda j, t: (j, 0))
    n_out = 2 if has_old else 1
    outs = pl.pallas_call(
        functools.partial(_segment_scatter_kernel, has_old),
        grid=(vp // vc, nt),
        in_specs=[row_spec, row_spec, w_spec] + [w_spec] * (n_out - 1),
        out_specs=[acc_spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((vp, k), jnp.float32)] * n_out,
        interpret=interpret,
    )(*inputs)

    pi = pi_pad if lp == l else pi_pad[:, :l]
    snew = outs[0][:vocab_size]
    if has_old:
        return pi, snew, outs[1][:vocab_size]
    return pi, snew


# ---------------------------------------------------------------------------
# CSR ragged E-step: the γ fixed point over a FLAT token stream
# ---------------------------------------------------------------------------
#
# The padded fixed point streams a dense (B, V) count matrix; the CSR
# kernels stream only the live tokens. A batch is the flat triplet
# (counts (T,), segment ids (T,), Eφ token rows (T, K)) — doc boundaries
# are carried arithmetically by the segment ids, exactly the PR-4 scatter
# trick run in reverse: a (B, block_t) selector `iota == segs` is both the
# per-token Eθ gather (selᵀ·Eθ on the MXU) and the segment-reduced γ
# accumulator (sel·weights · Eφ_tok). One compiled kernel therefore serves
# every document-length distribution: no (B, W) padding, no width ladder.

def _csr_fixed_point_kernel(alpha0: float, tol: float, k_real: int,
                            b_real: int, num_t: int, num_j: int,
                            cnts_ref, segs_ref, ebtok_ref, g0_ref,
                            gamma_ref, et_ref, iters_ref,
                            gamma_s, et_s, acc_s, flags):
    t = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((t == 0) & (j == 0))
    def _start():
        gamma_s[...] = g0_ref[...]
        flags[0] = 0                                   # converged flag
        flags[1] = 0                                   # sweeps run

    live = flags[0] == 0

    @pl.when(live & (j == 0))
    def _sweep_start():
        et_s[...] = _exp_elog_theta(gamma_s[...], k_real)
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(live)
    def _accumulate():
        et = et_s[...]                                 # (Bp, K)
        ebt = ebtok_ref[...].astype(jnp.float32)       # (bT, K)
        segs = segs_ref[...]                           # (1, bT)
        cnts = cnts_ref[...].astype(jnp.float32)       # (1, bT)
        bp = et.shape[0]
        bt = ebt.shape[0]
        rows = jax.lax.broadcasted_iota(jnp.int32, (bp, bt), 0)
        sel = rows == segs                             # owner-doc selector
        # φnorm per token = the selected row of Eθ·Eφ_tokᵀ — computed for
        # every (doc, token) pair on the MXU and masked down, which keeps
        # the kernel gather-free (the trade for zero padding)
        p = jax.lax.dot_general(et, ebt, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pnorm = jnp.where(sel, p, 0.0).sum(0, keepdims=True) + _EPS
        w = jnp.where(sel, cnts / pnorm, 0.0)          # (Bp, bT)
        acc_s[...] += jax.lax.dot(w, ebt,
                                  preferred_element_type=jnp.float32)

    @pl.when(live & (j == num_j - 1))
    def _sweep_end():
        g_old = gamma_s[...]
        mask = jax.lax.broadcasted_iota(jnp.int32, g_old.shape, 1) < k_real
        g_new = jnp.where(mask, alpha0 + et_s[...] * acc_s[...], alpha0)
        # token-free rows (doc padding) hold γ = α₀ exactly; mask them out
        # of the convergence mean like the fused kernel masks padded rows
        delta = jnp.abs(g_new - g_old).sum() / (b_real * k_real)
        gamma_s[...] = g_new
        flags[1] += 1
        flags[0] = jnp.where(delta <= tol, 1, 0).astype(jnp.int32)

    @pl.when((t == num_t - 1) & (j == num_j - 1))
    def _finish():
        g = gamma_s[...]
        gamma_ref[...] = g
        et_ref[...] = _exp_elog_theta(g, k_real)
        iters_ref[0, 0] = flags[1]


def estep_fixed_point_csr(cnts: jax.Array, segs: jax.Array,
                          eb_tok: jax.Array, gamma0: jax.Array,
                          alpha0: float, tol: float, max_iters: int,
                          k_real: int, b_real: int | None = None, *,
                          block_t: int = 512,
                          interpret: bool | None = None):
    """The whole CSR γ fixed point as ONE pallas_call.

    Shapes: cnts/segs (T,) flat token stream, eb_tok (T, K) = Eφ gathered
    at the flat token ids, gamma0 (B, K) → (γ (B, K), Eθ (B, K), sweep
    count (1, 1) int32). γ/Eθ and the sweep accumulator stay resident in
    VMEM for the whole batch (no B tiling — a CSR batch's doc count is
    bounded by ``batch_size``); the token axis is the inner grid axis, so
    eb_tok streams HBM→VMEM once per sweep, or exactly once when the
    wrapper promotes ``block_t`` to the whole (budget-sized) stream.
    K is pre-padded to a lane multiple by the wrapper; T is padded here
    (zero-count tail tokens are inert in every reduction); padding tokens
    must carry segment 0 and count 0. eb_tok may be bf16 (fp32 accum).
    """
    b, k = gamma0.shape
    t = cnts.shape[0]
    b_real = b if b_real is None else b_real
    interpret = _default_interpret(interpret)
    block_t = min(block_t, _round_up(t, 128))
    tp = _round_up(t, block_t)
    if tp != t:
        cnts = jnp.pad(cnts, (0, tp - t))
        segs = jnp.pad(segs, (0, tp - t))
        eb_tok = jnp.pad(eb_tok, ((0, tp - t), (0, 0)))
    nj = tp // block_t
    cnts2 = cnts.reshape(nj, block_t)
    segs2 = segs.reshape(nj, block_t)
    grid = (max(int(max_iters), 1), nj)
    gamma, et, iters = pl.pallas_call(
        functools.partial(_csr_fixed_point_kernel, alpha0, tol, k_real,
                          b_real, grid[0], nj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t), lambda t, j: (j, 0)),
            pl.BlockSpec((1, block_t), lambda t, j: (j, 0)),
            pl.BlockSpec((block_t, k), lambda t, j: (j, 0)),
            pl.BlockSpec((b, k), lambda t, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda t, j: (0, 0)),
            pl.BlockSpec((b, k), lambda t, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda t, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.SMEM((2,), jnp.int32),
        ],
        interpret=interpret,
    )(cnts2, segs2, eb_tok, gamma0)
    return gamma, et, iters


def _csr_token_pi_kernel(quantize: bool, cnts_ref, segs_ref, ebtok_ref,
                         et_ref, pi_ref):
    """π = Eθ[seg]⊙Eφ_tok/φnorm for one flat token tile, gather-free.

    The per-token Eθ gather is the selector matmul selᵀ·Eθ folded into the
    count/φnorm weighting, so the whole tile is two MXU matmuls.
    """
    et = et_ref[...]                                   # (Bp, K)
    ebt = ebtok_ref[...].astype(jnp.float32)           # (bT, K)
    segs = segs_ref[...]                               # (1, bT)
    cnts = cnts_ref[...]                               # (1, bT)
    bp = et.shape[0]
    bt = ebt.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bp, bt), 0)
    sel = rows == segs
    p = jax.lax.dot_general(et, ebt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    pnorm = jnp.where(sel, p, 0.0).sum(0, keepdims=True) + _EPS
    selw = jnp.where(sel & (cnts > 0), 1.0 / pnorm, 0.0)
    pi = jax.lax.dot_general(selw, et, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * ebt
    if quantize:
        # round through the memo wire dtype BEFORE the scatter, so ⟨m_vk⟩
        # adds exactly what the store will later subtract
        pi = pi.astype(jnp.bfloat16).astype(jnp.float32)
    pi_ref[...] = pi


def memo_delta_csr(token_ids: jax.Array, counts: jax.Array,
                   segs: jax.Array, eb_tok: jax.Array, etheta: jax.Array,
                   vocab_size: int, old_pi: jax.Array | None = None, *,
                   quantize: bool = False, block_t_pi: int = 512,
                   block_v: int | None = None, block_t: int = 128,
                   interpret: bool | None = None):
    """Flat-token π plus segment-summed new/old masses — two kernels.

    The CSR twin of ``memo_delta``: token_ids/counts/segs are the flat
    (T,) stream, eb_tok (T, K) the Eφ token gather, old_pi the memoized π
    in the SAME flat layout. Returns (π (T, K), S_new (V, K)[, S_old]).
    The scatter is the unchanged ``_segment_scatter_kernel`` — it always
    operated on flattened token rows, so the CSR layout is its native
    input and the (B, L) reshape simply disappears.
    """
    t = token_ids.shape[0]
    k = etheta.shape[1]
    has_old = old_pi is not None
    interpret = _default_interpret(interpret)

    # -- kernel 1: token-aligned π over the flat token grid -------------
    bt = min(block_t_pi, _round_up(t, 128))
    tp = _round_up(t, bt)

    def _pad_t(x):
        if tp == t:
            return x
        pad = ((0, tp - t),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, pad)

    ids_p, cnts_p = _pad_t(token_ids), _pad_t(counts)
    segs_p, ebt_p = _pad_t(segs), _pad_t(eb_tok)
    nj = tp // bt
    pi_pad = pl.pallas_call(
        functools.partial(_csr_token_pi_kernel, quantize),
        grid=(nj,),
        in_specs=[
            pl.BlockSpec((1, bt), lambda j: (j, 0)),
            pl.BlockSpec((1, bt), lambda j: (j, 0)),
            pl.BlockSpec((bt, k), lambda j: (j, 0)),
            pl.BlockSpec(etheta.shape, lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, k), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, k), jnp.float32),
        interpret=interpret,
    )(cnts_p.reshape(nj, bt), segs_p.reshape(nj, bt), ebt_p, etheta)

    # -- kernel 2: the SAME segment-sum scatter as the padded path ------
    vc, tb = segment_scatter_blocks(k, vocab_size, has_old,
                                    block_v=block_v, block_t=block_t)
    tb = min(tb, tp)
    rows_p = _round_up(tp, tb)

    def _scatter_rows(x):
        if rows_p == tp:
            return x
        pad = ((0, rows_p - tp),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, pad)

    nt = rows_p // tb
    ids2 = _scatter_rows(ids_p).reshape(nt, tb)
    cnts2 = _scatter_rows(cnts_p).reshape(nt, tb)
    inputs = [ids2, cnts2, _scatter_rows(pi_pad)]
    if has_old:
        inputs.append(_scatter_rows(_pad_t(old_pi)))

    vp = _round_up(vocab_size, vc)
    row_spec = pl.BlockSpec((1, tb), lambda j, t: (t, 0))
    w_spec = pl.BlockSpec((tb, k), lambda j, t: (t, 0))
    acc_spec = pl.BlockSpec((vc, k), lambda j, t: (j, 0))
    n_out = 2 if has_old else 1
    outs = pl.pallas_call(
        functools.partial(_segment_scatter_kernel, has_old),
        grid=(vp // vc, nt),
        in_specs=[row_spec, row_spec, w_spec] + [w_spec] * (n_out - 1),
        out_specs=[acc_spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((vp, k), jnp.float32)] * n_out,
        interpret=interpret,
    )(*inputs)

    pi = pi_pad if tp == t else pi_pad[:t]
    snew = outs[0][:vocab_size]
    if has_old:
        return pi, snew, outs[1][:vocab_size]
    return pi, snew


# ---------------------------------------------------------------------------
# legacy one-hot memo-correction kernel (benchmark baseline)
# ---------------------------------------------------------------------------

def _memo_delta_onehot_kernel(block_v: int, has_old: bool, quantize: bool,
                              *refs):
    if has_old:
        (ids_ref, cnts_ref, ebtok_ref, oldpi_ref, et_ref,
         pi_ref, snew_ref, sold_ref) = refs
    else:
        ids_ref, cnts_ref, ebtok_ref, et_ref, pi_ref, snew_ref = refs
        oldpi_ref = sold_ref = None
    j = pl.program_id(1)
    cnts = cnts_ref[...]                               # (bB, L)

    @pl.when(j == 0)
    def _pi():
        et = et_ref[...]                               # (bB, K)
        ebt = ebtok_ref[...]                           # (bB, L, K)
        p = (et[:, None, :] * ebt).sum(-1) + _EPS      # (bB, L)
        pi = et[:, None, :] * ebt / p[:, :, None]
        pi = jnp.where(cnts[:, :, None] > 0, pi, 0.0)
        if quantize:
            # round through the memo store's wire dtype BEFORE scattering,
            # so ⟨m_vk⟩ adds exactly what the store will later subtract
            pi = pi.astype(jnp.bfloat16).astype(jnp.float32)
        pi_ref[...] = pi

    bb, ll, kk = pi_ref.shape
    ids_flat = ids_ref[...].reshape(1, bb * ll)
    rows = j * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_v, bb * ll), 0)
    onehot = (rows == ids_flat).astype(jnp.float32)    # (bV, bB·L)

    # Each (nb, V-tile) partial block is visited exactly once, so a plain
    # write is safe on TPU — accumulating (V, K) blocks across B-tiles is
    # not, because the B axis is the OUTER grid axis here (π pins it) and
    # Pallas only defines revisited output blocks for consecutive revisits.
    w_new = (cnts[:, :, None] * pi_ref[...]).reshape(bb * ll, kk)
    snew_ref[...] = jax.lax.dot(onehot, w_new,
                                preferred_element_type=jnp.float32)[None]

    if has_old:
        w_old = (cnts[:, :, None] * oldpi_ref[...]).reshape(bb * ll, kk)
        sold_ref[...] = jax.lax.dot(onehot, w_old,
                                    preferred_element_type=jnp.float32)[None]


# VMEM budget for one one-hot memo_delta grid step (≈4 (block_b, L, K) fp32
# cubes plus the (block_v, block_b·L) one-hot), kept at half of the 16 MB
# VMEM to leave room for the pipeline's double buffering. The wrapper
# halves block_b until the step fits; the L axis is NOT tiled here, which
# is the L ≤ ~4k cap the segment-sum path removes.
_DELTA_VMEM_BUDGET = 8 * 1024 * 1024


def delta_effective_block_b(b: int, l: int, k: int, *, block_b: int = 32,
                            block_v: int = 128, has_old: bool = True) -> int:
    """The B-tile ``memo_delta_onehot`` actually runs after the VMEM guard.

    Larger B-tiles mean fewer (nb, V, K) partial blocks to spill and
    reduce, so the default starts at 32 and is halved until the per-step
    working set fits ``_DELTA_VMEM_BUDGET`` (e.g. L=128, K=128 lands on
    16; L=512 on 4). Exposed so the BENCH_estep HBM model can count the
    same grid the kernel uses.
    """
    block_b = min(block_b, b)
    ncubes = 4 if has_old else 3

    def _step_bytes(bb):
        return (ncubes * bb * l * k + block_v * bb * l) * 4

    while block_b > 1 and _step_bytes(block_b) > _DELTA_VMEM_BUDGET:
        nxt = block_b // 2
        block_b = nxt if b % nxt == 0 else 1   # keep the grid exact
    return block_b


def memo_delta_onehot(token_ids: jax.Array, counts: jax.Array,
                      eb_tok: jax.Array, etheta: jax.Array, vocab_size: int,
                      old_pi: jax.Array | None = None, *,
                      quantize: bool = False, block_b: int = 32,
                      block_v: int = 128, interpret: bool | None = None):
    """RETIRED production path, kept as the benchmark baseline.

    Same contract as ``memo_delta``, via the dense one-hot formulation: one
    kernel forms π and scatters cnt·π_new / cnt·π_old with a one-hot MXU
    matmul into per-B-tile (nb, V, K) partials (each output block written
    exactly once — the TPU-safe revisit discipline), reduced over nb in
    jnp here. Those partials are the cost the segment-sum path removes:
    ~2·nb·V·K fp32 of transient HBM per batch (~2.5 GB at Arxiv V=142k),
    and with the L axis untiled the VMEM guard caps L at ~4k (K=128).

    B must divide by ``block_b`` (pad upstream; ``block_b`` is halved
    automatically until the VMEM step budget holds, see
    ``_DELTA_VMEM_BUDGET``); V is padded here (ids are always < V so the
    padded rows are zero and stripped).
    """
    b, l = token_ids.shape
    k = etheta.shape[1]
    has_old = old_pi is not None
    block_b = delta_effective_block_b(b, l, k, block_b=block_b,
                                      block_v=block_v, has_old=has_old)
    assert b % block_b == 0, (b, block_b)
    interpret = _default_interpret(interpret)
    vp = ((vocab_size + block_v - 1) // block_v) * block_v
    nb, nv = b // block_b, vp // block_v

    row_spec = pl.BlockSpec((block_b, l), lambda i, j: (i, 0))
    cube_spec = pl.BlockSpec((block_b, l, k), lambda i, j: (i, 0, 0))
    part_spec = pl.BlockSpec((1, block_v, k), lambda i, j: (i, j, 0))
    in_specs = [row_spec, row_spec, cube_spec]
    inputs = [token_ids, counts, eb_tok]
    if has_old:
        in_specs.append(cube_spec)
        inputs.append(old_pi)
    in_specs.append(pl.BlockSpec((block_b, k), lambda i, j: (i, 0)))
    inputs.append(etheta)
    out_specs = [cube_spec, part_spec]
    out_shape = [jax.ShapeDtypeStruct((b, l, k), jnp.float32),
                 jax.ShapeDtypeStruct((nb, vp, k), jnp.float32)]
    if has_old:
        out_specs.append(part_spec)
        out_shape.append(jax.ShapeDtypeStruct((nb, vp, k), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(_memo_delta_onehot_kernel, block_v, has_old,
                          quantize),
        grid=(nb, nv),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    pi, snew = outs[0], outs[1].sum(0)[:vocab_size]
    if has_old:
        return pi, snew, outs[2].sum(0)[:vocab_size]
    return pi, snew


# ---------------------------------------------------------------------------
# legacy γ-sweep kernel (one pallas_call per sweep)
# ---------------------------------------------------------------------------

def _sweep_kernel(alpha0: float, num_v_tiles: int,
                  c_ref, et_ref, eb_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    et = et_ref[...]                                       # (bB, K)
    eb = eb_ref[...]                                       # (bV, K)
    p = jax.lax.dot_general(et, eb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + _EPS
    r = c_ref[...] / p                                     # (bB, bV)
    out_ref[...] += jax.lax.dot(r, eb,
                                preferred_element_type=jnp.float32)

    @pl.when(j == num_v_tiles - 1)
    def _fin():
        out_ref[...] = alpha0 + et * out_ref[...]


def estep_sweep(c: jax.Array, etheta: jax.Array, eb: jax.Array,
                alpha0: float, *, block_b: int = 128, block_v: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """One fixed-point sweep γ' = α₀ + Eθ ⊙ ((C ⊘ Eθ·Eφᵀ)·Eφ).

    Shapes: c (B, V), etheta (B, K), eb (V, K) → (B, K).
    B, V, K must already be padded to the block grid (see ops.py).
    """
    b, v = c.shape
    k = etheta.shape[1]
    block_b, block_v = min(block_b, b), min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    interpret = _default_interpret(interpret)
    grid = (b // block_b, v // block_v)
    return pl.pallas_call(
        functools.partial(_sweep_kernel, alpha0, grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_v, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(c, etheta, eb)


# ---------------------------------------------------------------------------
# sufficient-statistics kernel
# ---------------------------------------------------------------------------

def _sstats_kernel(num_b_tiles: int, c_ref, et_ref, eb_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    et = et_ref[...]                                       # (bB, K)
    eb = eb_ref[...]                                       # (bV, K)
    p = jax.lax.dot_general(et, eb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + _EPS
    r = c_ref[...] / p                                     # (bB, bV)
    out_ref[...] += jax.lax.dot_general(
        r, et, (((0,), (0,)), ((), ())),                   # Rᵀ·Eθ → (bV, K)
        preferred_element_type=jnp.float32)

    @pl.when(j == num_b_tiles - 1)
    def _fin():
        out_ref[...] *= eb


def sstats(c: jax.Array, etheta: jax.Array, eb: jax.Array, *,
           block_b: int = 128, block_v: int = 512,
           interpret: bool | None = None) -> jax.Array:
    """Expected topic-word counts S = Eφ ⊙ (Rᵀ·Eθ) → (V, K)."""
    b, v = c.shape
    k = etheta.shape[1]
    block_b, block_v = min(block_b, b), min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    interpret = _default_interpret(interpret)
    grid = (v // block_v, b // block_b)                    # B-axis innermost
    return pl.pallas_call(
        functools.partial(_sstats_kernel, grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (j, i)),
            pl.BlockSpec((block_b, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_v, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, k), jnp.float32),
        interpret=interpret,
    )(c, etheta, eb)
