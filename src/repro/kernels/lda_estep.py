"""Pallas TPU kernels for the LDA E-step hotspot.

Two kernels, both tiling the vocabulary dimension so that the topic matrix
Eφ (V, K) streams HBM→VMEM once and the (B, V) intermediates (phinorm P and
ratio R) live only in VMEM tile-by-tile:

* ``estep_sweep``  — γ' = α₀ + Eθ ⊙ (R·Eφ),  R = C ⊘ (Eθ·Eφᵀ + ε)
* ``sstats``       — S  = Eφ ⊙ (Rᵀ·Eθ)

Tiling (DESIGN.md §7): B-tile × V-tile × K — K is padded to a multiple of
128 by the wrapper (`ops.py`), V-tiles default to 512 and B-tiles to 128,
so the per-step VMEM working set is

    C (128·512) + Eφ (512·128) + Eθ/out (128·128)  ≈ 0.6 MB  « 16 MB VMEM

and every matmul hits the MXU with ≥128 on both the lane and the
contraction dimension. The reduction over V-tiles uses the classic
revisited-output-block accumulator pattern (the V grid axis is innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


# ---------------------------------------------------------------------------
# γ-sweep kernel
# ---------------------------------------------------------------------------

def _sweep_kernel(alpha0: float, num_v_tiles: int,
                  c_ref, et_ref, eb_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    et = et_ref[...]                                       # (bB, K)
    eb = eb_ref[...]                                       # (bV, K)
    p = jax.lax.dot_general(et, eb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + _EPS
    r = c_ref[...] / p                                     # (bB, bV)
    out_ref[...] += jax.lax.dot(r, eb,
                                preferred_element_type=jnp.float32)

    @pl.when(j == num_v_tiles - 1)
    def _fin():
        out_ref[...] = alpha0 + et * out_ref[...]


def estep_sweep(c: jax.Array, etheta: jax.Array, eb: jax.Array,
                alpha0: float, *, block_b: int = 128, block_v: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """One fixed-point sweep γ' = α₀ + Eθ ⊙ ((C ⊘ Eθ·Eφᵀ)·Eφ).

    Shapes: c (B, V), etheta (B, K), eb (V, K) → (B, K).
    B, V, K must already be padded to the block grid (see ops.py).
    """
    b, v = c.shape
    k = etheta.shape[1]
    block_b, block_v = min(block_b, b), min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (b // block_b, v // block_v)
    return pl.pallas_call(
        functools.partial(_sweep_kernel, alpha0, grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_v, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(c, etheta, eb)


# ---------------------------------------------------------------------------
# sufficient-statistics kernel
# ---------------------------------------------------------------------------

def _sstats_kernel(num_b_tiles: int, c_ref, et_ref, eb_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    et = et_ref[...]                                       # (bB, K)
    eb = eb_ref[...]                                       # (bV, K)
    p = jax.lax.dot_general(et, eb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + _EPS
    r = c_ref[...] / p                                     # (bB, bV)
    out_ref[...] += jax.lax.dot_general(
        r, et, (((0,), (0,)), ((), ())),                   # Rᵀ·Eθ → (bV, K)
        preferred_element_type=jnp.float32)

    @pl.when(j == num_b_tiles - 1)
    def _fin():
        out_ref[...] *= eb


def sstats(c: jax.Array, etheta: jax.Array, eb: jax.Array, *,
           block_b: int = 128, block_v: int = 512,
           interpret: bool | None = None) -> jax.Array:
    """Expected topic-word counts S = Eφ ⊙ (Rᵀ·Eθ) → (V, K)."""
    b, v = c.shape
    k = etheta.shape[1]
    block_b, block_v = min(block_b, b), min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (v // block_v, b // block_b)                    # B-axis innermost
    return pl.pallas_call(
        functools.partial(_sstats_kernel, grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (j, i)),
            pl.BlockSpec((block_b, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_v, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, k), jnp.float32),
        interpret=interpret,
    )(c, etheta, eb)
