"""Pure-jnp oracles for the LDA Pallas kernels.

The E-step hotspot in the dense TPU formulation (DESIGN.md §2 & §7):

  P = Eθ · Eφᵀ              (B, V)   "phinorm"
  R = C ⊘ (P + ε)           (B, V)
  sweep:  γ' = α₀ + Eθ ⊙ (R · Eφ)            — one fixed-point iteration
  sstats: S  = Eφ ⊙ (Rᵀ · Eθ)                — Σ_d cnt·π scattered to (V, K)

Everything is two (B,V)×(V,K)-shaped MXU matmuls plus elementwise work;
the kernels tile over V so Eφ streams HBM→VMEM exactly once per call and
the (B, V) intermediates never materialise in HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


def estep_sweep_ref(c: jax.Array, etheta: jax.Array, eb: jax.Array,
                    alpha0: float) -> jax.Array:
    """One dense fixed-point sweep: γ' (B, K)."""
    p = etheta @ eb.T + _EPS                   # (B, V)
    return alpha0 + etheta * ((c / p) @ eb)


def sstats_ref(c: jax.Array, etheta: jax.Array, eb: jax.Array) -> jax.Array:
    """Expected topic-word counts for the batch: S (V, K)."""
    p = etheta @ eb.T + _EPS                   # (B, V)
    return eb * ((c / p).T @ etheta)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
            scale: float | None = None) -> jax.Array:
    """Oracle for the flash-attention kernel. q,k,v: (BH, S, hd)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)
