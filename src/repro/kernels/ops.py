"""Jitted wrappers around the LDA Pallas kernels.

``estep_pallas`` is the fused drop-in replacement for
``repro.core.estep.estep_dense`` (select with
``LDAConfig(estep_backend="pallas")``): it pads (B, V, K) to the kernel
block grid, runs the WHOLE γ fixed point in one ``pallas_call``
(`lda_estep.estep_fixed_point`), and recovers token-aligned π and the
sufficient statistics with the segment-sum ``memo_delta`` pair (token-π
kernel + V-chunk scatter) — three kernel launches per E-step, none of
them inside a ``while`` loop, no (B, L, K) jnp intermediates beyond the
Eφ token gather that feeds the kernels, and no dense (nb, V, K) scatter
partials.

``memo_correction_pallas`` is the IVI hot path behind
``core.estep.PallasBackend.solve_correction``: the same three launches
also emit the subtract-old/add-new correction ``S_new − S_old`` directly.

``estep_pallas_sweeps`` keeps the pre-fusion formulation (one
``pallas_call`` per sweep inside ``lax.while_loop`` + a separate sstats
kernel + jnp π recovery) as the benchmark baseline — see
``benchmarks/kernel_bench.py`` and BENCH_estep.json.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.estep import (CSRTokenBatch, EStepResult, densify,
                              segment_sum_docs, warm_start_gamma,
                              warm_start_gamma_flat)
from repro.core.math import exp_dirichlet_expectation
from repro.core.types import DEFAULT_KERNEL_POLICY, KernelPolicy, LDAConfig
from repro.kernels import lda_estep
from repro.kernels.flash_attention import flash_attention

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_policy(cfg: LDAConfig,
                   policy: Optional[KernelPolicy] = None) -> KernelPolicy:
    """The :class:`KernelPolicy` in effect for a kernel call.

    Precedence: an explicit ``policy`` argument wins, then
    ``cfg.kernel_policy`` (the store-resolved policy threaded through the
    engines), then the built-in defaults — which are bit-identical to the
    pre-autotune hard-coded knobs. Per-knob keyword arguments on the ops
    entry points override whatever this returns.
    """
    if policy is not None:
        return policy
    if cfg.kernel_policy is not None:
        return cfg.kernel_policy
    return DEFAULT_KERNEL_POLICY


def pad_inputs(c: jax.Array, eb: jax.Array, block_b: int, block_v: int,
               block_k: int = 128):
    """Pad C (B,V) and Eφ (V,K) to the kernel grid.

    Padding values keep the math exact: padded documents have zero counts
    (contribute nothing), padded vocabulary rows of Eφ are 1.0 so their
    phinorm contribution is harmless (their C is 0), padded topics get
    Eφ = 0 so they never win responsibilities — and padded γ columns are
    stripped before returning.
    """
    b, v = c.shape
    k = eb.shape[1]
    bp, vp, kp = (_round_up(b, block_b), _round_up(v, block_v),
                  _round_up(k, block_k))
    c = jnp.pad(c, ((0, bp - b), (0, vp - v)))
    # padded vocab rows get Eφ = 1.0 (NOT 0: a zero row makes the phinorm
    # P exactly 0 on that tile — the fp32 epsilon underflows — and C/P
    # would be 0/0); their C is 0 so they contribute nothing either way.
    eb = jnp.pad(eb, ((0, vp - v), (0, 0)), constant_values=1.0)
    eb = jnp.pad(eb, ((0, 0), (0, kp - k)))       # padded topics stay 0
    return c, eb, (b, v, k)


def _stream_cast(cfg: LDAConfig, x: jax.Array) -> jax.Array:
    """Cast a streamed kernel input to ``cfg.estep_stream_dtype``.

    bf16 halves the dominant HBM terms (C and Eφ) of the fixed point;
    accumulation stays fp32 in-kernel. Counts are exact in bf16 up to 256
    occurrences of a token in one document.
    """
    if cfg.estep_stream_dtype == "float32":
        return x
    if cfg.estep_stream_dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    raise ValueError(f"unknown estep_stream_dtype: {cfg.estep_stream_dtype}")


def _pad_rows(x: jax.Array, rows: int) -> jax.Array:
    pad = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


# Eφ blocks at or under this size are made V-resident: one V tile, so the
# Pallas pipeline fetches Eφ once per call and C once per B-tile instead of
# re-streaming both every sweep (the block index never changes across the
# sweep axis). Chosen well under the 16 MB VMEM with the fp32 working set.
_V_RESIDENT_BYTES = 6 * 1024 * 1024


def effective_fixed_point_blocks(b: int, v: int, k: int, *,
                                 block_b: int = 128, block_v: int = 512,
                                 stream_bytes: int = 4
                                 ) -> Tuple[int, int, bool]:
    """The (block_b, block_v) grid the fused fixed point actually runs.

    ``_run_fixed_point`` promotes ``block_v`` to whole-V whenever the
    lane-aligned Eφ block fits the resident budget — one V tile means the
    pipeline fetches Eφ once per call instead of once per sweep. The
    promotion used to be silent; this mirror of ``csr_effective_block_t``
    exposes it so tune records, the roofline HBM model, and telemetry
    report the tile that ran, never a requested-but-ignored ``block_v``.

    Returns ``(block_b, block_v, v_resident)``.
    """
    del b  # B only pads the row grid; it never changes the tile choice
    v_aligned = _round_up(v, 128)
    kp = _round_up(k, 128)
    if v_aligned * kp * stream_bytes <= _V_RESIDENT_BYTES:
        return block_b, max(block_v, v_aligned), True
    return block_b, block_v, False


def _run_fixed_point(cfg: LDAConfig, exp_elog_beta: jax.Array,
                     token_ids: jax.Array, counts: jax.Array,
                     gamma0: Optional[jax.Array], block_b: int, block_v: int):
    """densify → pad → fused fixed-point kernel. Returns real-shape γ/Eθ."""
    bsz = token_ids.shape[0]
    v = exp_elog_beta.shape[0]
    stream_bytes = 2 if cfg.estep_stream_dtype == "bfloat16" else 4
    # the resident tile must stay lane-aligned: a raw (unrounded) V as the
    # C lane / Eφ sublane dimension breaks the TPU (8, 128) tiling when V
    # is not a multiple of 128 — pad_inputs pads V up to this block size
    block_b, block_v, _ = effective_fixed_point_blocks(
        bsz, v, exp_elog_beta.shape[1], block_b=block_b, block_v=block_v,
        stream_bytes=stream_bytes)
    c = densify(token_ids, counts, v)
    cpad, ebpad, (b, _, k) = pad_inputs(c, exp_elog_beta, block_b, block_v)
    if gamma0 is None:
        gamma0 = jnp.full((bsz, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)
    # pad γ topics/rows with α₀ (they stay exactly α₀: zero Eφ column and
    # zero counts respectively, so their update is a no-op)
    gpad = jnp.pad(gamma0, ((0, cpad.shape[0] - b), (0, ebpad.shape[1] - k)),
                   constant_values=cfg.alpha0)
    gamma, et, iters = lda_estep.estep_fixed_point(
        _stream_cast(cfg, cpad), _stream_cast(cfg, ebpad), gpad,
        cfg.alpha0, cfg.estep_tol, cfg.estep_max_iters, k_real=k,
        b_real=bsz, block_b=block_b, block_v=block_v)
    return gamma[:bsz, :k], et[:bsz, :k], iters.max()


@partial(jax.jit, static_argnames=("cfg", "policy", "block_b", "block_v",
                                   "delta_block_b", "delta_block_v"))
def estep_pallas(cfg: LDAConfig, exp_elog_beta: jax.Array,
                 token_ids: jax.Array, counts: jax.Array,
                 gamma0: Optional[jax.Array] = None, *,
                 policy: Optional[KernelPolicy] = None,
                 block_b: Optional[int] = None,
                 block_v: Optional[int] = None,
                 delta_block_b: Optional[int] = None,
                 delta_block_v: Optional[int] = None) -> EStepResult:
    """Fused batched E-step: fixed-point kernel + memo_delta pair.

    Tile knobs resolve per ``resolve_policy`` (explicit kwarg > ``policy``
    > ``cfg.kernel_policy`` > defaults). ``delta_block_v`` is the
    scatter's V-chunk (None → the VMEM-budget policy
    ``lda_estep.segment_scatter_blocks``).
    """
    pol = resolve_policy(cfg, policy)
    block_b = pol.block_b if block_b is None else block_b
    block_v = pol.block_v if block_v is None else block_v
    delta_block_b = pol.delta_block_b if delta_block_b is None else delta_block_b
    delta_block_v = pol.delta_block_v if delta_block_v is None else delta_block_v
    bsz = token_ids.shape[0]
    gamma, et, iters = _run_fixed_point(cfg, exp_elog_beta, token_ids,
                                        counts, gamma0, block_b, block_v)
    eb_tok = exp_elog_beta[token_ids]                  # (B, L, K) kernel feed
    bp = _round_up(bsz, delta_block_b)
    pi, snew = lda_estep.memo_delta(
        _pad_rows(token_ids, bp), _pad_rows(counts, bp),
        _pad_rows(eb_tok, bp), _pad_rows(et, bp), exp_elog_beta.shape[0],
        block_b=delta_block_b, block_l=pol.pi_block_l,
        block_v=delta_block_v, block_t=pol.scatter_block_t)
    return EStepResult(gamma=gamma, pi=pi[:bsz], sstats=snew, iters=iters)


@partial(jax.jit, static_argnames=("cfg", "pi_dtype", "policy", "block_b",
                                   "block_v", "delta_block_b",
                                   "delta_block_v"))
def memo_correction_pallas(cfg: LDAConfig, exp_elog_beta: jax.Array,
                           token_ids: jax.Array, counts: jax.Array,
                           old_pi: jax.Array, visited: jax.Array, *,
                           pi_dtype: str = "float32",
                           policy: Optional[KernelPolicy] = None,
                           block_b: Optional[int] = None,
                           block_v: Optional[int] = None,
                           delta_block_b: Optional[int] = None,
                           delta_block_v: Optional[int] = None
                           ) -> Tuple[jax.Array, jax.Array, EStepResult]:
    """Fused IVI hot path: E-step + subtract-old/add-new correction.

    Returns (correction (V, K), first-visit word count, EStepResult) —
    exactly the `EStepBackend.solve_correction` contract. The correction
    is ``S_new − S_old`` from the segment-sum scatters of the
    ``memo_delta`` pair; the only (B, L, K) jnp array in the jaxpr is the
    Eφ token gather feeding the kernels (old_pi is an *input*, not an
    intermediate), and no (nb, V, K) scatter partials exist.
    ``delta_block_v`` is the scatter's V-chunk (None → the VMEM-budget
    policy ``lda_estep.segment_scatter_blocks``).
    """
    if pi_dtype not in ("float32", "bfloat16"):
        # the in-kernel quantize only implements the bf16 wire; refuse
        # rather than silently skip the round-trip and drift ⟨m_vk⟩
        raise ValueError(f"pallas memo correction supports pi_dtype "
                         f"float32|bfloat16, got {pi_dtype!r}")
    pol = resolve_policy(cfg, policy)
    block_b = pol.block_b if block_b is None else block_b
    block_v = pol.block_v if block_v is None else block_v
    delta_block_b = pol.delta_block_b if delta_block_b is None else delta_block_b
    delta_block_v = pol.delta_block_v if delta_block_v is None else delta_block_v
    bsz = token_ids.shape[0]
    gamma0 = warm_start_gamma(cfg, counts, old_pi, visited)
    gamma, et, iters = _run_fixed_point(cfg, exp_elog_beta, token_ids,
                                        counts, gamma0, block_b, block_v)
    eb_tok = exp_elog_beta[token_ids]                  # (B, L, K) kernel feed
    bp = _round_up(bsz, delta_block_b)
    pi, snew, sold = lda_estep.memo_delta(
        _pad_rows(token_ids, bp), _pad_rows(counts, bp),
        _pad_rows(eb_tok, bp), _pad_rows(et, bp), exp_elog_beta.shape[0],
        old_pi=_pad_rows(old_pi, bp), quantize=(pi_dtype == "bfloat16"),
        block_b=delta_block_b, block_l=pol.pi_block_l,
        block_v=delta_block_v, block_t=pol.scatter_block_t)
    correction = snew - sold
    words_first = jnp.sum(jnp.where(~visited, counts.sum(-1), 0.0))
    res = EStepResult(gamma=gamma, pi=pi[:bsz], sstats=snew, iters=iters)
    return correction, words_first, res


# ---------------------------------------------------------------------------
# CSR ragged path: the width-free flat-token E-step
# ---------------------------------------------------------------------------

def csr_effective_block_t(t: int, k: int, stream_bytes: int = 4,
                          block_t: int = 512) -> int:
    """The token tile the CSR fixed point actually runs.

    Mirrors the ``_V_RESIDENT_BYTES`` promotion of the dense path: when
    the whole (T, K) Eφ token stream fits the resident budget it becomes
    ONE tile, so the pipeline fetches it once per call instead of once
    per sweep — the default token budgets are chosen to sit inside this
    regime. Exposed so the BENCH_estep HBM model counts the same grid.
    """
    t_aligned = _round_up(t, 128)
    kp = _round_up(k, 128)
    if t_aligned * kp * stream_bytes <= _V_RESIDENT_BYTES:
        return t_aligned
    return min(block_t, t_aligned)


def _run_fixed_point_csr(cfg: LDAConfig, exp_elog_beta: jax.Array,
                         token_ids: jax.Array, counts: jax.Array,
                         segments: jax.Array, num_docs: int,
                         gamma0: Optional[jax.Array], block_t: int):
    """K-pad → Eφ token gather → fused CSR kernel. Returns real-shape γ/Eθ
    plus the (T, Kp) Eφ token gather the memo pair re-uses."""
    k = exp_elog_beta.shape[1]
    kp = _round_up(k, 128)
    t = token_ids.shape[0]
    stream_bytes = 2 if cfg.estep_stream_dtype == "bfloat16" else 4
    block_t = csr_effective_block_t(t, k, stream_bytes, block_t)
    ebp = jnp.pad(exp_elog_beta, ((0, 0), (0, kp - k)))  # padded topics → 0
    eb_tok = ebp[token_ids]                              # (T, Kp) kernel feed
    if gamma0 is None:
        gamma0 = jnp.full((num_docs, cfg.num_topics), cfg.alpha0 + 1.0,
                          jnp.float32)
    bp = _round_up(num_docs, 8)
    # pad γ topics/rows with α₀: token-free rows and zero-Eφ topics keep
    # exactly α₀ through every sweep (their update is a no-op)
    gpad = jnp.pad(gamma0, ((0, bp - num_docs), (0, kp - k)),
                   constant_values=cfg.alpha0)
    gamma, et, iters = lda_estep.estep_fixed_point_csr(
        counts, segments, _stream_cast(cfg, eb_tok), gpad,
        cfg.alpha0, cfg.estep_tol, cfg.estep_max_iters, k_real=k,
        b_real=num_docs, block_t=block_t)
    return gamma[:num_docs, :k], et[:num_docs, :k], eb_tok, iters.max()


@partial(jax.jit, static_argnames=("cfg", "num_docs", "policy", "block_t",
                                   "delta_block_v"))
def estep_pallas_csr(cfg: LDAConfig, exp_elog_beta: jax.Array,
                     token_ids: jax.Array, counts: jax.Array,
                     segments: jax.Array,
                     gamma0: Optional[jax.Array] = None, *,
                     num_docs: int,
                     policy: Optional[KernelPolicy] = None,
                     block_t: Optional[int] = None,
                     delta_block_v: Optional[int] = None) -> EStepResult:
    """Width-free flat-token E-step: CSR fixed point + CSR memo_delta.

    token_ids/counts/segments are the flat (T,) stream (zero-count
    padding tokens carry segment 0); π comes back in the same flat
    (T, K) layout. One compiled entry serves every document-length mix
    with the same (T, B) shape — no width in the jit key.
    """
    pol = resolve_policy(cfg, policy)
    block_t = pol.block_t if block_t is None else block_t
    delta_block_v = pol.delta_block_v if delta_block_v is None else delta_block_v
    gamma, et, eb_tok, iters = _run_fixed_point_csr(
        cfg, exp_elog_beta, token_ids, counts, segments, num_docs,
        gamma0, block_t)
    k = exp_elog_beta.shape[1]
    pi, snew = lda_estep.memo_delta_csr(
        token_ids, counts, segments, eb_tok[:, :k], et,
        exp_elog_beta.shape[0], block_t_pi=pol.pi_block_l,
        block_v=delta_block_v, block_t=pol.scatter_block_t)
    return EStepResult(gamma=gamma, pi=pi, sstats=snew, iters=iters)


@partial(jax.jit, static_argnames=("cfg", "pi_dtype", "policy", "block_t",
                                   "delta_block_v"))
def memo_correction_pallas_csr(cfg: LDAConfig, exp_elog_beta: jax.Array,
                               token_ids: jax.Array, counts: jax.Array,
                               segments: jax.Array, old_pi: jax.Array,
                               visited: jax.Array, *,
                               pi_dtype: str = "float32",
                               policy: Optional[KernelPolicy] = None,
                               block_t: Optional[int] = None,
                               delta_block_v: Optional[int] = None
                               ) -> Tuple[jax.Array, jax.Array, EStepResult]:
    """Fused CSR IVI hot path: flat E-step + subtract-old/add-new.

    The flat twin of ``memo_correction_pallas``: old_pi is (T, K) in the
    SAME flat token layout, and the correction comes from the unchanged
    ``_segment_scatter_kernel`` — flat token rows are its native input.
    """
    if pi_dtype not in ("float32", "bfloat16"):
        # the in-kernel quantize only implements the bf16 wire; refuse
        # rather than silently skip the round-trip and drift ⟨m_vk⟩
        raise ValueError(f"pallas memo correction supports pi_dtype "
                         f"float32|bfloat16, got {pi_dtype!r}")
    pol = resolve_policy(cfg, policy)
    block_t = pol.block_t if block_t is None else block_t
    delta_block_v = pol.delta_block_v if delta_block_v is None else delta_block_v
    num_docs = visited.shape[0]
    tok = CSRTokenBatch(token_ids, counts, segments)
    gamma0 = warm_start_gamma_flat(cfg, tok, old_pi, visited)
    gamma, et, eb_tok, iters = _run_fixed_point_csr(
        cfg, exp_elog_beta, token_ids, counts, segments, num_docs,
        gamma0, block_t)
    k = exp_elog_beta.shape[1]
    pi, snew, sold = lda_estep.memo_delta_csr(
        token_ids, counts, segments, eb_tok[:, :k], et,
        exp_elog_beta.shape[0], old_pi=old_pi,
        quantize=(pi_dtype == "bfloat16"), block_t_pi=pol.pi_block_l,
        block_v=delta_block_v, block_t=pol.scatter_block_t)
    correction = snew - sold
    doc_words = segment_sum_docs(counts, segments, num_docs)
    words_first = jnp.sum(jnp.where(~visited, doc_words, 0.0))
    res = EStepResult(gamma=gamma, pi=pi, sstats=snew, iters=iters)
    return correction, words_first, res


# ---------------------------------------------------------------------------
# legacy per-sweep path (benchmark baseline)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "block_b", "block_v"))
def estep_pallas_sweeps(cfg: LDAConfig, exp_elog_beta: jax.Array,
                        token_ids: jax.Array, counts: jax.Array,
                        gamma0: Optional[jax.Array] = None, *,
                        block_b: int = 128, block_v: int = 512) -> EStepResult:
    """Pre-fusion E-step: one ``pallas_call`` per sweep inside a
    ``lax.while_loop``, jnp Eθ recomputation between sweeps, separate
    sstats kernel, jnp token-π recovery. Kept as the BENCH_estep baseline."""
    bsz = token_ids.shape[0]
    v = exp_elog_beta.shape[0]
    c = densify(token_ids, counts, v)
    cpad, ebpad, (b, _, k) = pad_inputs(c, exp_elog_beta, block_b, block_v)
    if gamma0 is None:
        gamma0 = jnp.full((bsz, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)
    gpad = jnp.pad(gamma0, ((0, cpad.shape[0] - b), (0, ebpad.shape[1] - k)),
                   constant_values=cfg.alpha0)

    def elog_theta_exp(g):
        # digamma expectation over the *real* topics only; padded topics
        # carry exactly α₀ and a zero Eφ column, set their Eθ to 0.
        real = jnp.arange(g.shape[1]) < k
        gm = jnp.where(real, g, 0.0)
        s = gm.sum(-1, keepdims=True)
        et = jnp.exp(jax.scipy.special.digamma(jnp.maximum(g, 1e-10))
                     - jax.scipy.special.digamma(s))
        return jnp.where(real, et, 0.0)

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta > cfg.estep_tol,
                               it < cfg.estep_max_iters)

    def body(carry):
        g, _, it = carry
        et = elog_theta_exp(g)
        g_new = lda_estep.estep_sweep(cpad, et, ebpad, cfg.alpha0,
                                      block_b=block_b, block_v=block_v)
        real = jnp.arange(g.shape[1]) < k
        g_new = jnp.where(real, g_new, cfg.alpha0)
        delta = jnp.abs(g_new - g).mean()
        return g_new, delta, it + 1

    init = (gpad, jnp.asarray(jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32))
    gpad, _, iters = jax.lax.while_loop(cond, body, init)

    et = elog_theta_exp(gpad)
    spad = lda_estep.sstats(cpad, et, ebpad, block_b=block_b, block_v=block_v)
    gamma = gpad[:bsz, :k]
    sstats_out = spad[:v, :k]

    # token-aligned π for the IVI memo (identical to estep_dense)
    etheta = et[:bsz, :k]
    ebg = exp_elog_beta[token_ids]
    p_tok = jnp.einsum("bk,blk->bl", etheta, ebg) + _EPS
    pi = etheta[:, None, :] * ebg / p_tok[:, :, None]
    pi = jnp.where(counts[:, :, None] > 0, pi, 0.0)
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats_out, iters=iters)


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              scale: Optional[float] = None) -> jax.Array:
    """GQA-aware wrapper: q (B, S, H, hd), k/v (B, S, KV, hd) → (B, S, H, hd).

    Repeats KV heads to the query-head count, flattens (B, H) and pads S to
    the 128-block grid before invoking the flash kernel.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    blk = 128 if s >= 128 else s
    s_pad = ((s + blk - 1) // blk) * blk
    qf, kf, vf = flat(q), flat(kf), flat(vf)
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        qf, kf, vf = jnp.pad(qf, pad), jnp.pad(kf, pad), jnp.pad(vf, pad)
    out = flash_attention(qf, kf, vf, causal=causal, scale=scale,
                          block_q=blk, block_k=blk)
    out = out[:, :s].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out
