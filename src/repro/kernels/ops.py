"""Jitted wrappers around the LDA Pallas kernels.

``estep_pallas`` is a drop-in replacement for ``repro.core.estep.estep_dense``
(select with ``LDAConfig(estep_backend="pallas")``): it pads (B, V, K) to the
kernel block grid, runs the fixed point with the fused sweep kernel, and
produces the same ``EStepResult`` (γ, token-aligned π, sufficient stats).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.estep import EStepResult, densify
from repro.core.math import exp_dirichlet_expectation
from repro.core.types import LDAConfig
from repro.kernels import lda_estep
from repro.kernels.flash_attention import flash_attention

_EPS = 1e-30  # fp32-safe (1e-100 underflows to 0)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_inputs(c: jax.Array, eb: jax.Array, block_b: int, block_v: int,
               block_k: int = 128):
    """Pad C (B,V) and Eφ (V,K) to the kernel grid.

    Padding values keep the math exact: padded documents have zero counts
    (contribute nothing), padded vocabulary rows of Eφ are 1.0 so their
    phinorm contribution is harmless (their C is 0), padded topics get
    Eφ = 0 so they never win responsibilities — and padded γ columns are
    stripped before returning.
    """
    b, v = c.shape
    k = eb.shape[1]
    bp, vp, kp = (_round_up(b, block_b), _round_up(v, block_v),
                  _round_up(k, block_k))
    c = jnp.pad(c, ((0, bp - b), (0, vp - v)))
    # padded vocab rows get Eφ = 1.0 (NOT 0: a zero row makes the phinorm
    # P exactly 0 on that tile — the fp32 epsilon underflows — and C/P
    # would be 0/0); their C is 0 so they contribute nothing either way.
    eb = jnp.pad(eb, ((0, vp - v), (0, 0)), constant_values=1.0)
    eb = jnp.pad(eb, ((0, 0), (0, kp - k)))       # padded topics stay 0
    return c, eb, (b, v, k)


@partial(jax.jit, static_argnames=("cfg", "block_b", "block_v"))
def estep_pallas(cfg: LDAConfig, exp_elog_beta: jax.Array,
                 token_ids: jax.Array, counts: jax.Array,
                 gamma0: Optional[jax.Array] = None, *,
                 block_b: int = 128, block_v: int = 512) -> EStepResult:
    """Full batched E-step using the Pallas kernels (dense formulation)."""
    bsz = token_ids.shape[0]
    v = exp_elog_beta.shape[0]
    c = densify(token_ids, counts, v)
    cpad, ebpad, (b, _, k) = pad_inputs(c, exp_elog_beta, block_b, block_v)
    if gamma0 is None:
        gamma0 = jnp.full((bsz, cfg.num_topics), cfg.alpha0 + 1.0, jnp.float32)
    # pad γ topics with α₀ (they stay exactly α₀: padded Eφ column is zero)
    gpad = jnp.pad(gamma0, ((0, cpad.shape[0] - b), (0, ebpad.shape[1] - k)),
                   constant_values=cfg.alpha0)

    def elog_theta_exp(g):
        # digamma expectation over the *real* topics only; padded topics
        # carry exactly α₀ and a zero Eφ column, set their Eθ to 0.
        real = jnp.arange(g.shape[1]) < k
        gm = jnp.where(real, g, 0.0)
        s = gm.sum(-1, keepdims=True)
        et = jnp.exp(jax.scipy.special.digamma(jnp.maximum(g, 1e-10))
                     - jax.scipy.special.digamma(s))
        return jnp.where(real, et, 0.0)

    def cond(carry):
        _, delta, it = carry
        return jnp.logical_and(delta > cfg.estep_tol,
                               it < cfg.estep_max_iters)

    def body(carry):
        g, _, it = carry
        et = elog_theta_exp(g)
        g_new = lda_estep.estep_sweep(cpad, et, ebpad, cfg.alpha0,
                                      block_b=block_b, block_v=block_v)
        real = jnp.arange(g.shape[1]) < k
        g_new = jnp.where(real, g_new, cfg.alpha0)
        delta = jnp.abs(g_new - g).mean()
        return g_new, delta, it + 1

    init = (gpad, jnp.asarray(jnp.inf, jnp.float32), jnp.asarray(0, jnp.int32))
    gpad, _, iters = jax.lax.while_loop(cond, body, init)

    et = elog_theta_exp(gpad)
    spad = lda_estep.sstats(cpad, et, ebpad, block_b=block_b, block_v=block_v)
    gamma = gpad[:bsz, :k]
    sstats_out = spad[:v, :k]

    # token-aligned π for the IVI memo (identical to estep_dense)
    etheta = et[:bsz, :k]
    ebg = exp_elog_beta[token_ids]
    p_tok = jnp.einsum("bk,blk->bl", etheta, ebg) + _EPS
    pi = etheta[:, None, :] * ebg / p_tok[:, :, None]
    pi = jnp.where(counts[:, :, None] > 0, pi, 0.0)
    return EStepResult(gamma=gamma, pi=pi, sstats=sstats_out, iters=iters)


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              scale: Optional[float] = None) -> jax.Array:
    """GQA-aware wrapper: q (B, S, H, hd), k/v (B, S, KV, hd) → (B, S, H, hd).

    Repeats KV heads to the query-head count, flattens (B, H) and pads S to
    the 128-block grid before invoking the flash kernel.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    blk = 128 if s >= 128 else s
    s_pad = ((s + blk - 1) // blk) * blk
    qf, kf, vf = flat(q), flat(kf), flat(vf)
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        qf, kf, vf = jnp.pad(qf, pad), jnp.pad(kf, pad), jnp.pad(vf, pad)
    out = flash_attention(qf, kf, vf, causal=causal, scale=scale,
                          block_q=blk, block_k=blk)
    out = out[:, :s].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out
