"""Pallas TPU kernels for the paper's compute hotspot (the LDA E-step).

Layout per repo convention: ``lda_estep.py`` holds the ``pl.pallas_call``
kernels with explicit BlockSpec VMEM tiling, ``ops.py`` the jitted wrappers
and ``ref.py`` the pure-jnp oracles.
"""
from repro.kernels import flash_attention, lda_estep, ops, ref
