"""Flash-attention Pallas TPU kernel (online-softmax tiling).

The roofline table (EXPERIMENTS.md) shows the big dense archs
(command-r-35b, gemma2-27b) compute-bound on attention-score FLOPs for the
prefill/train shapes; this kernel is the TPU-native answer: q-block × kv-
block tiling with running (max, sum) statistics in VMEM scratch so the
(S, S) score matrix never leaves VMEM tiles.

Grid: (batch·heads, q_blocks, kv_blocks) with the kv axis innermost —
output blocks are revisited across kv steps and finalised on the last one.
Causal masking skips fully-masked kv blocks via ``pl.when``. Matches the
pure-jnp oracle (`ref.mha_ref`) to fp32 tolerance in interpret mode; on a
real TPU the same code lowers to Mosaic.

Sizing: bq=bk=128 tiles with hd ≤ 256 keep
(q 128·hd + k/v 2·128·hd + scores 128·128 + acc 128·hd) ≈ 0.7 MB « VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(scale: float, causal: bool, num_kv: int, block_q: int,
                  block_k: int,
                  q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: kv block strictly after the q block is fully masked — skip
    run = True
    if causal:
        run = kj * block_k <= (qi + 1) * block_q - 1

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale         # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        s = q @ k.T                                      # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + p @ v_ref[0].astype(jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == num_kv - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q, k, v: (BH, S, hd) — batch·heads flattened. Returns (BH, S, hd).

    S must divide by the blocks (pad upstream); GQA callers repeat/flatten
    heads before the call (see ops.flash_mha).
    """
    bh, s, hd = q.shape
    block_q, block_k = min(block_q, s), min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    if scale is None:
        scale = hd ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (bh, s // block_q, s // block_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale, causal, grid[2], block_q,
                          block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
