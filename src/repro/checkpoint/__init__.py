from repro.checkpoint.io import save_checkpoint, restore_checkpoint
from repro.checkpoint.manifest import (MANIFEST_VERSION, is_manifest_checkpoint,
                                       load_manifest, save_manifest)
