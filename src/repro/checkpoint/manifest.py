"""Versioned directory checkpoints: a JSON manifest + named npz groups.

The flat-npz ``io.save_checkpoint`` serialises one pytree of arrays and
nothing else — which is exactly why ``train.py`` used to drop the memo and
the epoch bookkeeping of an IVI run on save (ISSUE 3 satellite). A manifest
checkpoint is a *directory*:

    <path>/
      manifest.json        version, free-form meta, per-group dtype tags
      <group>.npz          one npz per named array group

and restores three things npz alone cannot:

* **wire dtypes** — npz round-trips ml_dtypes arrays (bf16 memo chunks,
  λ-epoch snapshots) as raw void bytes, silently losing the dtype. The
  manifest stores such arrays as unsigned views and records the true dtype
  per key, so a bf16 chunk comes back bit-identical *as bf16*.
* **structure** — groups keep logically distinct state (global λ-state,
  memo chunks, pending epoch batches) separately addressable instead of
  flattened into one namespace.
* **meta** — JSON-able host state (rng bit-generator state, histories,
  constructor kwargs) that has no array representation.

``save_manifest`` / ``load_manifest`` are generic; the LDA-specific schema
on top lives in ``repro.lda.ckpt``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import numpy as np

MANIFEST_VERSION = 1
MANIFEST_NAME = "manifest.json"

# dtypes np.savez/np.load round-trip natively; anything else (ml_dtypes
# bf16/fp8, ...) is stored as a same-width unsigned view + a dtype tag
_NATIVE_KINDS = frozenset("biufcSU")


def _encode(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    arr = np.asarray(arr)
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr, ""
    view = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
    return view, arr.dtype.name


def _decode(arr: np.ndarray, tag: str) -> np.ndarray:
    if not tag:
        return arr
    import ml_dtypes  # registers the extension dtypes with numpy

    del ml_dtypes
    return arr.view(np.dtype(tag))


def is_manifest_checkpoint(path: str) -> bool:
    return os.path.isfile(os.path.join(path, MANIFEST_NAME))


def save_manifest(path: str, meta: Dict[str, Any],
                  arrays: Dict[str, Dict[str, np.ndarray]]) -> str:
    """Write ``meta`` + named array groups under directory ``path``.

    ``arrays`` maps group name → {key: array}; each group becomes one
    ``<group>.npz``. Returns ``path``.
    """
    os.makedirs(path, exist_ok=True)
    # invalidate any previous checkpoint at this path BEFORE touching its
    # group files: a save interrupted mid-way must read as "no checkpoint",
    # never as a silent mix of old and new generations
    manifest_path = os.path.join(path, MANIFEST_NAME)
    if os.path.exists(manifest_path):
        os.remove(manifest_path)
    dtype_tags: Dict[str, Dict[str, str]] = {}
    for group, kv in arrays.items():
        encoded, tags = {}, {}
        for key, arr in kv.items():
            encoded[key], tag = _encode(arr)
            if tag:
                tags[key] = tag
        np.savez(os.path.join(path, f"{group}.npz"), **encoded)
        dtype_tags[group] = tags
    doc = {"manifest_version": MANIFEST_VERSION,
           "groups": sorted(arrays),
           "dtype_tags": dtype_tags,
           "meta": meta}
    tmp = manifest_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    # the manifest is written last and atomically: a directory with no
    # manifest.json is an interrupted save, never a corrupt checkpoint
    os.replace(tmp, manifest_path)
    return path


def load_manifest(path: str) -> Tuple[Dict[str, Any],
                                      Dict[str, Dict[str, np.ndarray]]]:
    """Read back (meta, arrays) written by ``save_manifest``."""
    with open(os.path.join(path, MANIFEST_NAME)) as f:
        doc = json.load(f)
    version = doc.get("manifest_version")
    if version != MANIFEST_VERSION:
        raise ValueError(f"unsupported manifest version {version!r} "
                         f"(this build reads version {MANIFEST_VERSION})")
    arrays: Dict[str, Dict[str, np.ndarray]] = {}
    for group in doc["groups"]:
        tags = doc["dtype_tags"].get(group, {})
        with np.load(os.path.join(path, f"{group}.npz")) as data:
            arrays[group] = {k: _decode(data[k], tags.get(k, ""))
                             for k in data.files}
    return doc["meta"], arrays
