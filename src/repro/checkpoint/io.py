"""Host-gather npz checkpointing.

Arrays are fetched to host (gathering shards if needed), flattened by
pytree path and written to a single .npz; restore rebuilds the pytree and
(optionally) re-places it with a target sharding tree. Deliberately simple
— no async, no per-shard files — but correct for both LDA engine states and
transformer TrainStates.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(path: str, tree: Any, step: Optional[int] = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)
    return path


def restore_checkpoint(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays/structs)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    new_leaves = []
    for (path_keys, leaf) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
