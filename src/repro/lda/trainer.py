"""The Trainer protocol: one training contract over both engine families.

``LDAEngine`` (single host: MVI/SVI/IVI/S-IVI) and ``DIVIEngine``
(distributed D-IVI) expose different driving surfaces (epochs + minibatches
vs rounds) and different durable state (π ``MemoStore`` + epoch remainder
vs worker memo shards). The facade (`repro.lda.api.LDA`) never touches
either engine directly — it drives a ``Trainer``:

* ``run_pass()``  — one full unit of cover: an epoch / a global round;
* ``run_step()``  — the smallest resumable unit: one mini-batch / round;
* ``capture()`` / ``restore()`` — the trainer's FULL durable state as
  (json-able meta, named array groups) for `repro.checkpoint.manifest`.

``capture`` is the piece ``train.py``'s old ``save_checkpoint(eng.state)``
got wrong: an incremental run's state is not just λ — it is (λ, t,
init_frac, ⟨m_vk⟩), the π memo in its wire dtype, the host rng stream and
the not-yet-visited remainder of the current epoch. All of it round-trips
here, which is what makes save → load → resume bit-equal to an
uninterrupted run (tests/test_lda_api.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import History, LDAEngine
from repro.core.predictive import log_predictive, split_heldout
from repro.core.types import Corpus, GlobalState, LDAConfig
from repro.dist.engine import DIVIEngine
from repro.dist.protocol import DIVIConfig

_STATE_FIELDS = ("lam", "m_vk", "init_mass", "init_frac", "t")


def _capture_state(state: GlobalState) -> Dict[str, np.ndarray]:
    return {f: np.asarray(jax.device_get(getattr(state, f)))
            for f in _STATE_FIELDS}


def _restore_state(arrays: Dict[str, np.ndarray],
                   like: GlobalState) -> GlobalState:
    """Rebuild a GlobalState, re-placing each leaf on its current sharding
    (the D-IVI mesh path keeps the (V, K) leaves model-sharded)."""
    leaves = {}
    for f in _STATE_FIELDS:
        ref = getattr(like, f)
        arr = jnp.asarray(arrays[f], ref.dtype)
        if arr.shape != ref.shape:
            raise ValueError(
                f"state leaf {f!r}: checkpoint shape {arr.shape} != live "
                f"{tuple(ref.shape)} — the checkpoint belongs to a "
                "different corpus/config")
        leaves[f] = jax.device_put(arr, ref.sharding)
    return GlobalState(**leaves)


class Trainer:
    """Abstract training contract (see module docstring)."""

    kind: str = "abstract"
    algo: str
    history: History

    # -- views ----------------------------------------------------------
    @property
    def state(self) -> GlobalState:
        raise NotImplementedError

    @property
    def lam(self) -> jax.Array:
        return self.state.lam

    @property
    def docs_seen(self) -> int:
        raise NotImplementedError

    # -- stepping -------------------------------------------------------
    def run_pass(self) -> None:
        """One full unit of cover: an epoch (single host) / a round (D-IVI)."""
        raise NotImplementedError

    def run_step(self) -> None:
        """The smallest resumable unit: one mini-batch / one round."""
        raise NotImplementedError

    def evaluate(self) -> Dict[str, float]:
        raise NotImplementedError

    def set_test_corpus(self, corpus: Corpus, *, seed: int = 0) -> None:
        """(Re)bind the held-out evaluation split ``evaluate`` scores."""
        raise NotImplementedError

    def full_bound(self) -> float:
        raise NotImplementedError

    # -- durable state --------------------------------------------------
    def capture(self) -> Tuple[Dict[str, Any],
                               Dict[str, Dict[str, np.ndarray]]]:
        """Snapshot ALL durable state: (json-able meta, array groups)."""
        raise NotImplementedError

    def restore(self, meta: Dict[str, Any],
                arrays: Dict[str, Dict[str, np.ndarray]]) -> None:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# single host: MVI / SVI / IVI / S-IVI
# ---------------------------------------------------------------------------

class SingleHostTrainer(Trainer):
    """``LDAEngine`` behind the Trainer contract, with a resumable epoch.

    Materialized path: the trainer materialises each epoch's batch
    sequence up front (the exact sequence — and the exact rng consumption
    — ``run_epoch`` uses, via ``LDAEngine.epoch_batches``) and steps
    through it, so a checkpoint taken mid-epoch persists the unvisited
    remainder and the resumed run finishes the same epoch with the same
    batches.

    Stream path (``corpus`` is a ``DocStream``): no batch sequence exists
    up front — documents are pulled and packed per mini-batch. A
    mid-epoch checkpoint persists the **epoch cursor** (documents pulled),
    the packer's open buckets (ragged — bounded by
    num_widths × batch_size documents) and any flushed-but-unprocessed
    batches; ``restore`` re-seats the stream at the cursor, so
    save → load → resume stays bit-equal to an uninterrupted run.
    """

    kind = "single"

    def __init__(self, cfg: LDAConfig, corpus, *, algo: str,
                 batch_size: int = 64, seed: int = 0,
                 test_corpus: Optional[Corpus] = None,
                 memo_store: str = "dense", chunk_docs: int = 8192,
                 bucket_by_length: bool = False, layout: str = "padded",
                 token_budget: Optional[int] = None, telemetry=None,
                 tune_store=None):
        self.eng = LDAEngine(cfg, corpus, algo=algo, batch_size=batch_size,
                             seed=seed, test_corpus=test_corpus,
                             memo_store=memo_store, chunk_docs=chunk_docs,
                             bucket_by_length=bucket_by_length,
                             layout=layout, token_budget=token_budget,
                             telemetry=telemetry, tune_store=tune_store)
        self.algo = algo
        self._streamed = self.eng.stream is not None
        self._pending: List[Tuple[np.ndarray, Optional[int]]] = []

    # -- views ----------------------------------------------------------
    @property
    def state(self) -> GlobalState:
        return self.eng.state

    @property
    def docs_seen(self) -> int:
        return self.eng.docs_seen

    @property
    def history(self) -> History:
        return self.eng.history

    @property
    def pending_batches(self) -> int:
        """Batches of the current epoch not yet visited (0 ≡ epoch
        boundary). Stream mode: flushed-but-unprocessed batches only —
        ``stream_cursor`` is the mid-epoch indicator there."""
        if self._streamed:
            return len(self.eng._stream_emitted)
        return len(self._pending)

    @property
    def stream_cursor(self) -> int:
        """Documents pulled from the stream this epoch (stream mode)."""
        return self.eng._stream_cursor if self._streamed else 0

    # -- stepping -------------------------------------------------------
    def run_step(self) -> None:
        if self.algo == "mvi":
            raise ValueError("mvi is full-batch coordinate ascent — it has "
                             "no mini-batch step; use run_pass()")
        if self._streamed:
            if not self.eng.stream_step():
                # exactly at an epoch boundary: start the next pass
                self.eng.stream_step()
            return
        if not self._pending:
            self._pending = list(self.eng.epoch_batches())
        rows, width = self._pending.pop(0)
        self.eng.run_minibatch(rows, width=width)

    def run_pass(self) -> None:
        if self._streamed:
            while self.eng.stream_step():
                pass
            return
        if self.algo == "mvi":
            self.eng.run_epoch()
            return
        if not self._pending:
            self._pending = list(self.eng.epoch_batches())
        while self._pending:
            self.run_step()

    def evaluate(self) -> Dict[str, float]:
        return self.eng.evaluate()

    def set_test_corpus(self, corpus: Corpus, *, seed: int = 0) -> None:
        self.eng._obs, self.eng._held = split_heldout(corpus, seed=seed)

    def full_bound(self) -> float:
        return self.eng.full_bound()

    # -- durable state --------------------------------------------------
    def capture(self):
        eng = self.eng
        meta: Dict[str, Any] = {
            "kind": self.kind,
            "algo": self.algo,
            "docs_seen": eng.docs_seen,
            "rng": eng.rng.bit_generator.state,
            "history": dataclasses.asdict(eng.history),
            "wall_elapsed": time.perf_counter() - eng._t0,
            "pending_widths": [None if w is None else int(w)
                               for _, w in self._pending],
            "streamed": self._streamed,
        }
        arrays: Dict[str, Dict[str, np.ndarray]] = {
            "state": _capture_state(eng.state),
            "pending": {f"batch_{i:05d}": np.asarray(rows, np.int64)
                        for i, (rows, _) in enumerate(self._pending)},
        }
        if self._streamed:
            # the epoch cursor + the packer's open buckets + any flushed
            # batches not yet processed — the full mid-epoch stream state
            pend = eng._packer.pending_docs()
            meta["stream_cursor"] = int(eng._stream_cursor)
            meta["stream_layout"] = eng.layout
            meta["stream_pending_pos"] = [int(p) for p, _, _ in pend]
            # per-batch shape key: padded width, or the CSR token budget
            meta["stream_emitted_widths"] = [
                int(b.token_budget if eng.layout == "csr" else b.width)
                for b in eng._stream_emitted]
            grp: Dict[str, np.ndarray] = {}
            for i, (_pos, ids, cnts) in enumerate(pend):
                grp[f"pend_{i:05d}_ids"] = np.asarray(ids, np.int32)
                grp[f"pend_{i:05d}_cnts"] = np.asarray(cnts, np.float32)
            for i, b in enumerate(eng._stream_emitted):
                grp[f"emit_{i:05d}_rows"] = np.asarray(b.rows, np.int64)
                grp[f"emit_{i:05d}_ids"] = np.asarray(b.token_ids)
                grp[f"emit_{i:05d}_cnts"] = np.asarray(b.counts)
                if eng.layout == "csr":
                    grp[f"emit_{i:05d}_segs"] = np.asarray(b.segments)
                    grp[f"emit_{i:05d}_offs"] = np.asarray(b.offsets)
            arrays["stream"] = grp
        if eng.memo is not None:
            meta["memo_kind"] = eng.memo.kind
            arrays["memo"] = eng.memo.state_dict()
        if eng._gamma_buf is not None:
            arrays["mvi"] = {"gamma_buf": np.asarray(eng._gamma_buf)}
        return meta, arrays

    def restore(self, meta, arrays) -> None:
        if meta["algo"] != self.algo:
            raise ValueError(f"checkpoint algo {meta['algo']!r} != "
                             f"trainer algo {self.algo!r}")
        if bool(meta.get("streamed", False)) != self._streamed:
            kind = "stream-fed" if meta.get("streamed") else "materialized"
            raise ValueError(
                f"checkpoint belongs to a {kind} run — resume it with a "
                "matching data source (DocStream vs padded Corpus); the "
                "epoch bookkeeping of the two ingest paths is not "
                "interchangeable")
        eng = self.eng
        eng.state = _restore_state(arrays["state"], eng.state)
        if eng.memo is not None:
            if meta.get("memo_kind") != eng.memo.kind:
                raise ValueError(
                    f"checkpoint memo store {meta.get('memo_kind')!r} != "
                    f"configured {eng.memo.kind!r} — the memo is part of "
                    "the algorithm state and cannot be converted on load")
            eng.memo = eng.memo.load_state_dict(arrays["memo"])
        if eng._gamma_buf is not None:
            eng._gamma_buf = jnp.asarray(arrays["mvi"]["gamma_buf"])
        eng.rng.bit_generator.state = meta["rng"]
        eng.docs_seen = int(meta["docs_seen"])
        eng.history = History(**meta["history"])
        eng._t0 = time.perf_counter() - float(meta["wall_elapsed"])
        widths = meta["pending_widths"]
        self._pending = [
            (arrays["pending"][f"batch_{i:05d}"],
             None if w is None else int(w))
            for i, w in enumerate(widths)]
        if self._streamed:
            from repro.data.stream import CSRBatch, PackedBatch
            ck_layout = meta.get("stream_layout", "padded")
            if ck_layout != eng.layout:
                raise ValueError(
                    f"checkpoint packs the stream in {ck_layout!r} layout "
                    f"!= configured {eng.layout!r} — the emission schedule "
                    "differs between layouts, so a mid-epoch resume cannot "
                    "switch them")
            grp = arrays.get("stream", {})
            packer = eng._make_packer()
            packer.load_pending([
                (pos, grp[f"pend_{i:05d}_ids"], grp[f"pend_{i:05d}_cnts"])
                for i, pos in enumerate(meta["stream_pending_pos"])])
            eng._packer = packer
            eng._stream_cursor = int(meta["stream_cursor"])
            eng._stream_iter = None          # re-seated lazily at the cursor
            if eng.layout == "csr":
                eng._stream_emitted = [
                    CSRBatch(grp[f"emit_{i:05d}_rows"],
                             grp[f"emit_{i:05d}_ids"],
                             grp[f"emit_{i:05d}_cnts"],
                             grp[f"emit_{i:05d}_segs"],
                             grp[f"emit_{i:05d}_offs"], int(w))
                    for i, w in enumerate(meta["stream_emitted_widths"])]
            else:
                eng._stream_emitted = [
                    PackedBatch(grp[f"emit_{i:05d}_rows"],
                                grp[f"emit_{i:05d}_ids"],
                                grp[f"emit_{i:05d}_cnts"], int(w))
                    for i, w in enumerate(meta["stream_emitted_widths"])]


# ---------------------------------------------------------------------------
# distributed: D-IVI
# ---------------------------------------------------------------------------

class DIVITrainer(Trainer):
    """``DIVIEngine`` behind the Trainer contract.

    One pass == one global round (``staleness`` sub-rounds of P concurrent
    worker batches). ``data`` is anything the engine accepts: a padded
    ``Corpus``, any ``DocStream``, or a pre-built ``ShardedDocStream``.
    The durable state adds the per-worker memo shards AND every worker's
    stream-ingest cursor (position in its shard, pass count, packer's open
    partial batch) to the global (λ, ⟨m_vk⟩, …) leaves, so a multi-worker
    save mid-round resumes bit-equal; on the mesh path ``restore``
    re-places every leaf with the sharding the live engine already carries.
    ``restore`` refuses a checkpoint whose shard assignment (worker count,
    partitioner, partition seed, corpus size) differs from the live
    engine's — resuming P-worker state onto Q≠P workers would scatter
    memos onto the wrong documents.
    """

    kind = "divi"

    def __init__(self, cfg: LDAConfig, dcfg: DIVIConfig, data, *,
                 seed: int = 0, test_corpus: Optional[Corpus] = None,
                 mesh=None, data_axes=None, telemetry=None):
        self.cfg, self.dcfg = cfg, dcfg
        self.algo = "sivi"          # D-IVI is the eq. 5 protocol distributed
        self.eng = DIVIEngine(cfg, dcfg, data, seed=seed, mesh=mesh,
                              data_axes=data_axes, telemetry=telemetry)
        self.history = History()
        self._t0 = time.perf_counter()
        if test_corpus is not None:
            self._obs, self._held = split_heldout(test_corpus, seed=seed)
        else:
            self._obs = self._held = None

    # -- views ----------------------------------------------------------
    @property
    def state(self) -> GlobalState:
        return self.eng.state

    @property
    def docs_seen(self) -> int:
        return self.eng.docs_seen

    # -- stepping -------------------------------------------------------
    def run_step(self) -> None:
        self.eng.run_round()

    run_pass = run_step

    def evaluate(self) -> Dict[str, float]:
        """Periodic evaluation snapshot — mirrors ``LDAEngine.evaluate``:
        held-out LPP with a test corpus, otherwise the memoized corpus
        bound (``full_bound``) so distributed runs report ``elbo`` too."""
        out: Dict[str, float] = {}
        if self._obs is not None:
            out["lpp"] = float(log_predictive(self.cfg, self.eng.lam,
                                              self._obs, self._held))
            self.history.lpp.append(out["lpp"])
        else:
            out["elbo"] = self.full_bound()
            self.history.elbo.append(out["elbo"])
        self.history.docs_seen.append(self.docs_seen)
        self.history.wall.append(time.perf_counter() - self._t0)
        return out

    def set_test_corpus(self, corpus: Corpus, *, seed: int = 0) -> None:
        self._obs, self._held = split_heldout(corpus, seed=seed)

    def full_bound(self) -> float:
        """Memoized corpus ELBO over the sharded worker memos.

        An all-gather-free per-shard reduction: each worker's slice of the
        (W, D_w, L, K) memo is viewed as its own ``DenseMemoStore`` and its
        documents are streamed back through the worker's shard view in
        chunks (`data.stream.iter_padded_chunks` — the same read-through
        the single-host stream bound uses), contributing their word/θ
        terms; the λ-Dirichlet topics term enters once at the end. Neither
        the memo nor the corpus is ever materialised in one piece — peak
        extra resident state is one chunk of one shard. Every document
        lands in exactly one shard, so the bound covers the FULL corpus
        (no ``D % P`` tail is dropped anywhere).
        """
        from repro.core.bound import _memoized_doc_terms, _topics_term
        from repro.core.math import dirichlet_expectation
        from repro.core.memo import DenseMemoStore
        from repro.data.stream import iter_padded_chunks

        eng = self.eng
        lam = eng.state.lam
        elog_beta = dirichlet_expectation(lam, axis=0)
        total = 0.0
        for w in range(self.dcfg.num_workers):
            store_w = DenseMemoStore(pi=eng.shard.pi[w],
                                     visited=eng.shard.visited[w])
            stream_w = eng.ingest[w].stream
            for start, ids, cnts in iter_padded_chunks(stream_w, 512,
                                                       eng.max_unique):
                pi, _vis = store_w.gather(np.arange(start,
                                                    start + ids.shape[0]))
                cnts_j = jnp.asarray(cnts)
                gamma = self.cfg.alpha0 + jnp.einsum("blk,bl->bk", pi, cnts_j)
                total += float(_memoized_doc_terms(self.cfg, jnp.asarray(ids),
                                                   cnts_j, gamma, pi,
                                                   elog_beta))
        return total + float(_topics_term(self.cfg, lam))

    # -- durable state --------------------------------------------------
    def capture(self):
        eng = self.eng
        ingest_meta, ingest_arrays = [], {}
        for w, ing in enumerate(eng.ingest):
            m, arrs = ing.capture()
            ingest_meta.append(m)
            for k, v in arrs.items():
                ingest_arrays[f"w{w:03d}_{k}"] = v
        meta: Dict[str, Any] = {
            "kind": self.kind,
            "algo": "divi",
            "docs_seen": eng.docs_seen,
            "rng": eng.rng.bit_generator.state,
            "history": dataclasses.asdict(self.history),
            "wall_elapsed": time.perf_counter() - self._t0,
            # the shard assignment this state belongs to — restore refuses
            # any mismatch (satellite: no silent re-deal of memos)
            "sharding": eng.sharded.signature(),
            "ingest": ingest_meta,
        }
        arrays = {
            "state": _capture_state(eng.state),
            "memo": {"pi": np.asarray(jax.device_get(eng.shard.memo.pi)),
                     "visited": np.asarray(jax.device_get(
                         eng.shard.memo.visited))},
            "ingest": ingest_arrays,
        }
        return meta, arrays

    def restore(self, meta, arrays) -> None:
        if meta["algo"] != "divi":
            raise ValueError(f"checkpoint algo {meta['algo']!r} is not a "
                             "D-IVI checkpoint")
        eng = self.eng
        if "sharding" not in meta:
            raise ValueError(
                "D-IVI checkpoint predates streaming shards (no shard "
                "assignment recorded) — it cannot be resumed by this "
                "version; retrain or restore with the version that wrote it")
        eng.sharded.check_signature(meta["sharding"])
        for w, (ing, m) in enumerate(zip(eng.ingest, meta["ingest"])):
            prefix = f"w{w:03d}_"
            ing.restore(m, {k[len(prefix):]: v
                            for k, v in arrays.get("ingest", {}).items()
                            if k.startswith(prefix)})
        eng.state = _restore_state(arrays["state"], eng.state)
        memo = eng.shard.memo
        from repro.core.memo import DenseMemoStore
        eng.shard = dataclasses.replace(eng.shard, memo=DenseMemoStore(
            pi=jax.device_put(jnp.asarray(arrays["memo"]["pi"]),
                              memo.pi.sharding),
            visited=jax.device_put(jnp.asarray(arrays["memo"]["visited"]),
                                   memo.visited.sharding)))
        eng.rng.bit_generator.state = meta["rng"]
        eng.docs_seen = int(meta["docs_seen"])
        self.history = History(**meta["history"])
        self._t0 = time.perf_counter() - float(meta["wall_elapsed"])


def make_trainer(cfg: LDAConfig, corpus, *, algo: str,
                 distributed: Optional[DIVIConfig] = None,
                 batch_size: int = 64, seed: int = 0,
                 test_corpus: Optional[Corpus] = None,
                 memo_store: str = "dense", chunk_docs: int = 8192,
                 bucket_by_length: bool = False, layout: str = "padded",
                 token_budget: Optional[int] = None, mesh=None,
                 data_axes=None, telemetry=None, tune_store=None) -> Trainer:
    """Bind a corpus (or ``DocStream``) to the right Trainer.

    Every data source works on every path: D-IVI shards a ``DocStream``
    into per-worker views (a padded ``Corpus`` is wrapped on the way in),
    so stream ingest is distributed-ready too. ``tune_store`` is a
    ``repro.tune`` policy store (path or ``PolicyStore``) consulted once
    at engine construction for a tuned kernel policy.
    """
    if distributed is not None:
        if layout != "padded":
            raise ValueError("distributed training packs padded worker "
                             "batches; layout='csr' is single-host only")
        if tune_store is not None and cfg.kernel_policy is None \
                and cfg.estep_backend in ("pallas", "csr"):
            # D-IVI workers all run the same per-worker batch shape; one
            # facade-level lookup covers them (per-worker width = the
            # stream's max_unique, the padded packer width)
            from repro.tune.resolve import PolicyResolver
            pol = PolicyResolver(tune_store, telemetry=telemetry).resolve(
                backend=cfg.estep_backend, layout="padded",
                b_or_t=distributed.batch_size, v=cfg.vocab_size,
                k=cfg.num_topics, w=getattr(corpus, "max_unique", None))
            if pol is not None:
                cfg = dataclasses.replace(cfg, kernel_policy=pol)
        return DIVITrainer(cfg, distributed, corpus, seed=seed,
                           test_corpus=test_corpus, mesh=mesh,
                           data_axes=data_axes, telemetry=telemetry)
    return SingleHostTrainer(cfg, corpus, algo=algo, batch_size=batch_size,
                             seed=seed, test_corpus=test_corpus,
                             memo_store=memo_store, chunk_docs=chunk_docs,
                             bucket_by_length=bucket_by_length,
                             layout=layout, token_budget=token_budget,
                             telemetry=telemetry, tune_store=tune_store)
