"""Durable LDA checkpoints: the facade schema over `repro.checkpoint.manifest`.

A saved ``LDA`` is one manifest directory:

    meta.constructor   — everything needed to rebuild the facade: the
                         LDAConfig fields, algo, DIVIConfig (or null),
                         batch size, seed, memo-store kind, bucketing;
    meta.trainer       — the Trainer's runtime meta: rng bit-generator
                         state, docs_seen, histories, pending-epoch widths;
    state.npz          — λ, ⟨m_vk⟩, init_mass, init_frac, t;
    memo.npz           — the MemoStore's chunks in their WIRE dtype (bf16
                         chunks stay bf16; γ-only stores include their
                         λ-epoch snapshots), or the D-IVI worker shards;
    pending.npz / mvi.npz — mid-epoch batch remainder / MVI warm-start γ;
    stream.npz         — stream-fed runs: the packer's open-bucket ragged
                         docs and flushed-but-unprocessed batches (the
                         epoch cursor itself lives in meta.trainer) —
                         `docs/streaming.md`.

``load_lda_checkpoint`` also accepts the legacy flat ``.npz`` that
``train.py`` used to write via ``save_checkpoint(eng.state)``. Those
checkpoints silently dropped the memo, rng and epoch bookkeeping — an
IVI/S-IVI run restored from one cannot actually continue (the eq. 4
subtract-old side is gone). Loading one emits a ``DeprecationWarning`` and
returns a serve-only estimator: ``transform``/``top_words``/``score`` work,
``resume`` refuses.
"""
from __future__ import annotations

import dataclasses
import os
import warnings

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manifest import (is_manifest_checkpoint, load_manifest,
                                       save_manifest)
from repro.core.types import GlobalState, LDAConfig
from repro.dist.protocol import DIVIConfig

SCHEMA_FORMAT = "repro.lda"
SCHEMA_VERSION = 1


def save_lda_checkpoint(path: str, lda) -> str:
    """Persist the facade + its Trainer's full durable state at ``path``."""
    trainer = lda._require_trainer()
    trainer_meta, arrays = trainer.capture()
    meta = {
        "format": SCHEMA_FORMAT,
        "schema_version": SCHEMA_VERSION,
        "constructor": {
            "cfg": dataclasses.asdict(lda.cfg),
            "algo": lda.algo,
            "distributed": (dataclasses.asdict(lda.distributed)
                            if lda.distributed is not None else None),
            "batch_size": lda.batch_size,
            "seed": lda.seed,
            "memo_store": lda.memo_store,
            "chunk_docs": lda.chunk_docs,
            "bucket_by_length": lda.bucket_by_length,
            "layout": lda.layout,
            "token_budget": lda.token_budget,
        },
        "trainer": trainer_meta,
    }
    return save_manifest(path, meta, arrays)


def _state_view(arrays: dict) -> GlobalState:
    st = arrays["state"]
    return GlobalState(
        lam=jnp.asarray(st["lam"], jnp.float32),
        m_vk=jnp.asarray(st["m_vk"], jnp.float32),
        init_mass=jnp.asarray(st["init_mass"], jnp.float32),
        init_frac=jnp.asarray(st["init_frac"], jnp.float32),
        t=jnp.asarray(st["t"], jnp.int32))


def load_lda_checkpoint(path: str):
    """Load a manifest checkpoint (or a legacy bare-λ ``.npz``) → ``LDA``."""
    from repro.lda.api import LDA

    if not is_manifest_checkpoint(path):
        return _load_legacy(path)
    meta, arrays = load_manifest(path)
    if meta.get("format") != SCHEMA_FORMAT:
        raise ValueError(f"{path!r} is a manifest checkpoint but not an LDA "
                         f"one (format={meta.get('format')!r})")
    if meta.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(f"unsupported LDA checkpoint schema "
                         f"{meta.get('schema_version')!r}")
    ctor = meta["constructor"]
    dist = (DIVIConfig(**ctor["distributed"])
            if ctor["distributed"] is not None else None)
    cfg_fields = dict(ctor["cfg"])
    if cfg_fields.get("kernel_policy") is not None:
        # dataclasses.asdict flattened the nested KernelPolicy to a plain
        # dict on save; rebuild it so the restored cfg stays hashable (it
        # is a jit static arg) and the run replays its tuned trajectory
        from repro.tune.store import policy_from_dict
        cfg_fields["kernel_policy"] = \
            policy_from_dict(cfg_fields["kernel_policy"])
    lda = LDA(LDAConfig(**cfg_fields), algo=ctor["algo"], distributed=dist,
              batch_size=ctor["batch_size"], seed=ctor["seed"],
              memo_store=ctor["memo_store"], chunk_docs=ctor["chunk_docs"],
              bucket_by_length=ctor["bucket_by_length"],
              layout=ctor.get("layout", "padded"),
              token_budget=ctor.get("token_budget"))
    lda._state_view = _state_view(arrays)
    lda._pending_restore = (meta["trainer"], arrays)
    return lda


def _load_legacy(path: str):
    """Legacy flat-npz (``save_checkpoint(eng.state)``) → serve-only LDA."""
    from repro.lda.api import LDA

    npz = path if path.endswith(".npz") else path + ".npz"
    if not os.path.isfile(npz):
        raise FileNotFoundError(
            f"{path!r} is neither a manifest checkpoint directory nor a "
            "legacy .npz state file")
    warnings.warn(
        f"{path!r} is a legacy bare-λ checkpoint (train.py used to save "
        "eng.state only). It carries none of the incremental state — no "
        "memo, no rng, no epoch remainder — so training CANNOT resume from "
        "it; the estimator is serve-only. Re-save through LDA.save() for a "
        "resumable manifest checkpoint.", DeprecationWarning, stacklevel=3)
    with np.load(npz) as data:
        # io._flatten keys GlobalState leaves as ".lam", ".m_vk", ...
        flat = {k.lstrip("."): np.asarray(v) for k, v in data.items()}
    if "lam" not in flat:
        raise ValueError(f"{npz!r} holds no 'lam' leaf — not an LDA state "
                         f"checkpoint (keys: {sorted(flat)})")
    lam = flat["lam"].astype(np.float32)
    v, k = lam.shape
    # flat legacy files may carry the other GlobalState leaves; default the
    # missing ones to the post-first-pass fixed point (init mass retired)
    st = {"lam": lam,
          "m_vk": flat.get("m_vk", np.zeros_like(lam)),
          "init_mass": flat.get("init_mass", np.zeros_like(lam)),
          "init_frac": flat.get("init_frac", np.zeros(())),
          "t": flat.get("t", np.zeros((), np.int32))}
    lda = LDA(num_topics=k, vocab_size=v)
    lda._state_view = _state_view({"state": st})
    lda._pending_restore = None          # serve-only: resume() will refuse
    lda._serve_only = True               # ...and so will fit/partial_fit
    return lda
