"""Serving-side inference: topic posteriors for unseen documents.

Training owns λ; serving only needs the per-document E-step against frozen
topics (the same fixed point `predictive.log_predictive` runs before
scoring). This module packages that E-step for request traffic:

* documents are grouped into **length buckets** (the training ladder of
  `repro.data.bow.bucket_corpus`, but keyed on the last LIVE column so
  arbitrary request layouts slice losslessly — ``_serving_buckets``) and
  each bucket sliced to its own width, so E-step FLOPs scale with a
  request's actual length, not the corpus-wide maximum;
* every bucket batch is padded to one fixed ``batch_size``, so the jit
  cache holds exactly **one compiled executable per bucket width** — a
  bounded, enumerable cache (``TopicInferencer.cache_info``) instead of
  one recompile per request shape;
* the E-step dispatches through ``cfg.estep_backend`` — with ``pallas``
  this is the fused fixed-point kernel (`docs/estep.md`), the production
  serving configuration.

``TopicInferencer`` is the reusable handle (λ is preprocessed to
exp(E[ln φ]) once); ``topic_posterior`` is the one-shot convenience the
``LDA.transform`` facade method wraps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estep import estep
from repro.core.math import exp_dirichlet_expectation, safe_normalize
from repro.core.types import Corpus, LDAConfig

# the same width ladder repro.data.bow.bucket_corpus uses for training
_WIDTH_BOUNDARIES = (8, 16, 32, 64, 128, 256, 512)


def _serving_buckets(counts: np.ndarray, boundaries=_WIDTH_BOUNDARIES):
    """Group documents by the padded width that COVERS their live slots.

    Unlike training-side ``bucket_corpus`` (which buckets by the number of
    live slots, valid for the canonical leading-column layout), serving
    traffic may carry zero-count slots interspersed with live ones — e.g.
    the observed halves ``predictive.split_heldout`` produces. Bucketing
    by the LAST live column keeps the ``[:width]`` slice lossless for any
    layout; interior zero-count slots are harmless (the E-step masks them).

    EMPTY documents (no live slot at all, ``last == 0``) are real serving
    traffic — requests whose every token fell outside the vocabulary —
    and must not fall through the bucket ladder: a dropped row would leave
    its γ all-zero in ``posterior`` and ``transform`` would then normalise
    a zero vector. They ride the smallest bucket (the ``last <= w`` test
    of the first rung, whose lower bound is inclusive at 0), where the
    E-step leaves their γ at the prior α₀ in one sweep, i.e. the prior
    posterior. Every document lands in exactly one bucket — ``posterior``
    asserts the cover.
    """
    d, l = counts.shape
    live = counts > 0
    # width needed per doc = index of its last live column + 1 (0 if empty)
    last = np.where(live.any(1), l - np.argmax(live[:, ::-1], axis=1), 0)
    widths = sorted({min(b, l) for b in boundaries if b < l} | {l})
    out = []
    lo = -1                   # first rung includes last == 0 (empty docs)
    for w in widths:
        rows = np.nonzero((last > lo) & (last <= w))[0]
        if len(rows):
            out.append((rows.astype(np.int64), int(w)))
        lo = w
    return out


@partial(jax.jit, static_argnames=("cfg",))
def _posterior_batch(cfg: LDAConfig, exp_elog_beta: jax.Array,
                     token_ids: jax.Array, counts: jax.Array) -> jax.Array:
    """γ for one padded (B, width) batch via the configured backend."""
    return estep(cfg, exp_elog_beta, token_ids, counts).gamma


class TopicInferencer:
    """Frozen-topics E-step server (see module docstring).

    Args:
      cfg: training config; ``backend`` overrides ``cfg.estep_backend``
        for serving (e.g. train with ``gather``, serve with ``pallas``).
      lam: (V, K) topic-word parameter — from a live ``LDA`` facade, a
        checkpoint, or any λ with the right shape.
      batch_size: fixed request batch; shorter batches are padded with
        empty documents (zero counts — they converge to the γ prior in
        one sweep and are dropped before returning).
    """

    def __init__(self, cfg: LDAConfig, lam: jax.Array, *,
                 backend: Optional[str] = None, batch_size: int = 256):
        if backend is not None and backend != cfg.estep_backend:
            cfg = dataclasses.replace(cfg, estep_backend=backend)
        self.cfg = cfg
        self.batch_size = batch_size
        self.exp_elog_beta = exp_dirichlet_expectation(jnp.asarray(lam),
                                                       axis=0)
        self._compiled_widths: Dict[int, int] = {}    # width → batches run

    # -- core -----------------------------------------------------------
    def posterior(self, corpus: Corpus) -> np.ndarray:
        """γ (D, K) for every document, bucketed + fixed-batch padded.

        Empty documents (all-zero counts) come back at the prior γ = α₀ —
        see ``_serving_buckets`` — so no row of the result can be the
        all-zero vector ``transform`` would fail to normalise.
        """
        d = corpus.num_docs
        out = np.zeros((d, self.cfg.num_topics), np.float32)
        ids_all = np.asarray(corpus.token_ids)
        cnts_all = np.asarray(corpus.counts)
        b = self.batch_size
        buckets = _serving_buckets(cnts_all)
        covered = sum(len(rows) for rows, _ in buckets)
        assert covered == d, (covered, d)     # every doc in exactly one bucket
        for rows_all, width in buckets:
            for lo in range(0, len(rows_all), b):
                rows = rows_all[lo:lo + b]
                ids = np.zeros((b, width), np.int32)
                cnts = np.zeros((b, width), np.float32)
                ids[: len(rows)] = ids_all[rows, :width]
                cnts[: len(rows)] = cnts_all[rows, :width]
                gamma = _posterior_batch(self.cfg, self.exp_elog_beta,
                                         jnp.asarray(ids), jnp.asarray(cnts))
                out[rows] = np.asarray(gamma[: len(rows)])
                self._compiled_widths[width] = \
                    self._compiled_widths.get(width, 0) + 1
        return out

    def transform(self, corpus: Corpus) -> np.ndarray:
        """θ̄ (D, K): the normalised topic posterior (matches the θ̄ that
        ``predictive.log_predictive`` scores held-out words with)."""
        gamma = self.posterior(corpus)
        return np.asarray(safe_normalize(jnp.asarray(gamma), axis=-1))

    # -- introspection ---------------------------------------------------
    def cache_info(self) -> Dict[str, object]:
        """Serving-cache introspection — counters and compilations apart.

        ``_compiled_widths`` counts *batches served* per width, NOT jit
        entries (a width served twice still holds one compiled
        executable), so the two quantities are reported separately:

        * ``batches_per_width`` — {bucket width: batches served through
          it}, a traffic histogram;
        * ``compiled_widths``   — the sorted set of widths that have
          compiled an executable (the keys above);
        * ``jit_entries``       — its size: the number of compiled
          executables the fixed ``batch_size`` bounds.
        """
        return {
            "batches_per_width": dict(self._compiled_widths),
            "compiled_widths": sorted(self._compiled_widths),
            "jit_entries": len(self._compiled_widths),
        }


def topic_posterior(cfg: LDAConfig, lam: jax.Array, corpus: Corpus, *,
                    backend: Optional[str] = None, batch_size: int = 256
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """One-shot (γ, θ̄) for ``corpus`` under frozen topics ``lam``."""
    inf = TopicInferencer(cfg, lam, backend=backend, batch_size=batch_size)
    gamma = inf.posterior(corpus)
    theta = np.asarray(safe_normalize(jnp.asarray(gamma), axis=-1))
    return gamma, theta
