"""Serving-side inference: topic posteriors for unseen documents.

Training owns λ; serving only needs the per-document E-step against frozen
topics (the same fixed point `predictive.log_predictive` runs before
scoring). This module packages that E-step for request traffic:

* documents are grouped into **length buckets** under the ONE width
  policy of the ragged token pipeline (`repro.data.stream`: the ladder
  rung covering the last live slot — lossless for any slot layout,
  including ``split_heldout`` halves) and each bucket sliced/packed to its
  own width, so E-step FLOPs scale with a request's actual length, not
  the corpus-wide maximum;
* every bucket batch is padded to one fixed ``batch_size``, so the jit
  cache holds exactly **one compiled executable per bucket width** — a
  bounded, enumerable cache (``TopicInferencer.cache_info``) instead of
  one recompile per request shape;
* ragged requests need no padded ``Corpus`` at all: ``posterior_docs``
  consumes a ``DocStream`` / iterable of ragged documents through a
  ``BatchPacker`` and — by default — an **async double-buffered
  pipeline**: a host thread packs and stages request batch *t+1* while
  the device runs the E-step on batch *t* (`docs/streaming.md`;
  throughput record in ``BENCH_serve.json`` via
  ``benchmarks/serve_bench.py``);
* the E-step dispatches through ``cfg.estep_backend`` — with ``pallas``
  this is the fused fixed-point kernel (`docs/estep.md`), the production
  serving configuration;
* topics are held as an atomic **versioned model snapshot**: a single
  ``(version, exp_elog_beta)`` tuple attribute. ``swap_model`` publishes
  a new λ with one reference assignment, every dispatched batch reads the
  tuple exactly once, so an online learner can republish topics under
  live traffic with no torn reads — an in-flight batch completes entirely
  on the snapshot it started with (`docs/serving.md`).

``TopicInferencer`` is the reusable handle (λ is preprocessed to
exp(E[ln φ]) once); ``topic_posterior`` is the one-shot convenience the
``LDA.transform`` facade method wraps.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from functools import partial
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estep import CSRTokenBatch, estep, get_backend
from repro.core.math import exp_dirichlet_expectation, safe_normalize
from repro.core.types import Corpus, LDAConfig
from repro.data.stream import BatchPacker, as_ragged_doc, bucket_rows
from repro.obs import as_telemetry


@partial(jax.jit, static_argnames=("cfg",))
def _posterior_batch(cfg: LDAConfig, exp_elog_beta: jax.Array,
                     token_ids: jax.Array, counts: jax.Array) -> jax.Array:
    """γ for one padded (B, width) batch via the configured backend."""
    return estep(cfg, exp_elog_beta, token_ids, counts).gamma


@partial(jax.jit, static_argnames=("cfg", "num_docs"))
def _posterior_batch_csr(cfg: LDAConfig, exp_elog_beta: jax.Array,
                         token_ids: jax.Array, counts: jax.Array,
                         segments: jax.Array, *,
                         num_docs: int) -> jax.Array:
    """γ for one flat CSR token batch — every request length distribution
    shares this single (token_budget,)-shaped entry."""
    return get_backend(cfg.estep_backend).solve_tokens(
        cfg, exp_elog_beta, CSRTokenBatch(token_ids, counts, segments),
        num_docs=num_docs).gamma


# one staged request batch: (request positions, device ids, device counts,
# bucket width — padded — or device segments — csr —, live row count)
_Staged = Tuple[np.ndarray, jax.Array, jax.Array, object, int]

# one dispatched result: (request positions, device γ, live rows, the
# model version whose snapshot solved the batch)
_Result = Tuple[np.ndarray, jax.Array, int, int]


class TopicInferencer:
    """Frozen-topics E-step server (see module docstring).

    Args:
      cfg: training config; ``backend`` overrides ``cfg.estep_backend``
        for serving (e.g. train with ``gather``, serve with ``pallas``).
      lam: (V, K) topic-word parameter — from a live ``LDA`` facade, a
        checkpoint, or any λ with the right shape.
      batch_size: fixed request batch; shorter batches are padded with
        empty documents (zero counts — they converge to the γ prior in
        one sweep and are dropped before returning).
      telemetry: a ``repro.obs`` bundle (None/False = off). Serving spans
        (``serve/stage``, ``serve/solve``) never device-sync by default,
        so tracing does not serialise the double-buffer overlap; counters
        record docs/batches served, jit-cache hits vs misses per width,
        and the double-buffer queue depth histogram.
      tune_store: a ``repro.tune`` policy store (path or ``PolicyStore``)
        of autotuned kernel policies (`docs/tuning.md`). Padded serving
        resolves a policy PER BUCKET WIDTH, lazily, the first time a
        width is dispatched (each width is its own kernel shape, so each
        can carry its own winner — the per-width cfg variants mirror the
        one-jit-entry-per-width cache). CSR serving resolves once at
        construction (one shape total). A tuned
        ``double_buffer_depth`` sizes ``posterior_docs``'s staging queue.
        An explicit ``cfg.kernel_policy`` always wins; no store (or a
        miss) is bit-identical to the built-in defaults.
    """

    def __init__(self, cfg: LDAConfig, lam: jax.Array, *,
                 backend: Optional[str] = None, batch_size: int = 256,
                 layout: str = "padded", token_budget: Optional[int] = None,
                 telemetry=None, tune_store=None):
        if backend is not None and backend != cfg.estep_backend:
            cfg = dataclasses.replace(cfg, estep_backend=backend)
        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown layout {layout!r} "
                             "(expected 'padded' or 'csr')")
        self.cfg = cfg
        self.batch_size = batch_size
        self.layout = layout
        if layout == "csr" and token_budget is None:
            token_budget = min(batch_size * 64, 8192)
        self.token_budget = token_budget if layout == "csr" else None
        self.tel = as_telemetry(telemetry)
        # the model snapshot is ONE tuple attribute: readers take a local
        # reference once per batch, swap_model replaces the whole tuple in
        # a single assignment — no lock on the read path, no torn
        # (version, topics) pairs under concurrent republish
        self._model: Tuple[int, jax.Array] = (
            0, exp_dirichlet_expectation(jnp.asarray(lam), axis=0))
        self._swap_lock = threading.Lock()
        self._compiled_widths: Dict[int, int] = {}    # width → batches run
        self._live_slots = 0          # staged token slots actually live
        self._padded_slots = 0        # staged token slots incl. padding
        # tuned-policy resolution (docs/tuning.md): per-width cfg variants
        # for padded serving, a one-shot construction-time lookup for csr
        self._resolver = None
        self._cfg_by_width: Dict[int, LDAConfig] = {}
        if (tune_store is not None and self.cfg.kernel_policy is None
                and self.cfg.estep_backend in ("pallas", "csr")):
            from repro.tune.resolve import PolicyResolver
            self._resolver = PolicyResolver(tune_store, telemetry=self.tel)
            if layout == "csr":
                pol = self._resolver.resolve(
                    backend=self.cfg.estep_backend, layout="csr",
                    b_or_t=self.token_budget, v=self.cfg.vocab_size,
                    k=self.cfg.num_topics, w=None)
                if pol is not None:
                    self.cfg = dataclasses.replace(self.cfg,
                                                   kernel_policy=pol)

    def _cfg_for_width(self, width: int) -> LDAConfig:
        """The serving cfg for one bucket width — carrying that width's
        tuned kernel policy when the store has one (padded layout only;
        csr resolved its single shape at construction). Cached so each
        width's lookup — and its ``tune.cache`` hit/miss — happens once,
        like its jit compile."""
        if self._resolver is None or self.layout == "csr":
            return self.cfg
        cfg = self._cfg_by_width.get(width)
        if cfg is None:
            pol = self._resolver.resolve(
                backend=self.cfg.estep_backend, layout="padded",
                b_or_t=self.batch_size, v=self.cfg.vocab_size,
                k=self.cfg.num_topics, w=width)
            cfg = (self.cfg if pol is None
                   else dataclasses.replace(self.cfg, kernel_policy=pol))
            self._cfg_by_width[width] = cfg
        return cfg

    def _buffer_depth(self) -> int:
        """``posterior_docs``'s staging-queue size: the active kernel
        policy's ``double_buffer_depth`` (tuned or explicit), else the
        classic 2 (one in flight + one staged)."""
        pol = self.cfg.kernel_policy
        return pol.double_buffer_depth if pol is not None else 2

    # -- model snapshot ---------------------------------------------------
    @property
    def exp_elog_beta(self) -> jax.Array:
        """The current snapshot's exp(E[ln φ]) (V, K)."""
        return self._model[1]

    @property
    def model_version(self) -> int:
        """Monotone counter of published snapshots (0 = the constructor's)."""
        return self._model[0]

    def swap_model(self, lam: Optional[jax.Array] = None, *,
                   exp_elog_beta: Optional[jax.Array] = None,
                   version: Optional[int] = None) -> int:
        """Atomically publish new topics; returns the new version.

        Thread-safe against concurrent requests AND concurrent swappers:
        the expensive exp(E[ln φ]) preprocessing runs outside the lock
        (on the caller's thread — an online learner pays it, serving does
        not), and the critical section is a single tuple assignment. A
        batch dispatched before the swap completes on the OLD snapshot —
        ``_dispatch`` reads the tuple exactly once — and its response
        reports the old version; the next batch serves the new one.

        Pass ``lam`` (a (V, K) topic-word parameter, preprocessed here) or
        a precomputed ``exp_elog_beta`` directly. ``version`` overrides
        the auto-incremented counter (it must advance monotonically).
        """
        if (lam is None) == (exp_elog_beta is None):
            raise ValueError("pass exactly one of lam / exp_elog_beta")
        eb = (exp_dirichlet_expectation(jnp.asarray(lam), axis=0)
              if lam is not None else jnp.asarray(exp_elog_beta))
        if eb.shape != self._model[1].shape:
            raise ValueError(
                f"snapshot shape {tuple(eb.shape)} != serving "
                f"{tuple(self._model[1].shape)} — a swap cannot change "
                "the (V, K) geometry")
        with self._swap_lock:
            cur = self._model[0]
            v = cur + 1 if version is None else int(version)
            if v <= cur:
                raise ValueError(f"version must advance: {v} <= {cur}")
            self._model = (v, eb)
        if self.tel.enabled:
            self.tel.metrics.inc("serve.model_swaps")
            self.tel.metrics.set_gauge("serve.model_version", v)
        return v

    # -- padded-corpus requests -----------------------------------------
    def posterior(self, corpus: Corpus) -> np.ndarray:
        """γ (D, K) for every document, bucketed + fixed-batch padded.

        Empty documents (all-zero counts) come back at the prior γ = α₀ —
        they ride the smallest bucket (`repro.data.stream.bucket_rows`
        keeps ``last == 0`` rows on the first rung), so no row of the
        result can be the all-zero vector ``transform`` would fail to
        normalise.
        """
        if self.layout == "csr":
            # the flat layout has no width buckets: route padded-corpus
            # requests through the same single-entry ragged path
            from repro.data.stream import CorpusDocStream
            return self.posterior_docs(CorpusDocStream(corpus))
        d = corpus.num_docs
        out = np.zeros((d, self.cfg.num_topics), np.float32)
        ids_all = np.asarray(corpus.token_ids)
        cnts_all = np.asarray(corpus.counts)
        b = self.batch_size
        buckets = bucket_rows(cnts_all)
        covered = sum(len(rows) for rows, _ in buckets)
        assert covered == d, (covered, d)     # every doc in exactly one bucket
        for rows_all, width in buckets:
            for lo in range(0, len(rows_all), b):
                rows = rows_all[lo:lo + b]
                ids = np.zeros((b, width), np.int32)
                cnts = np.zeros((b, width), np.float32)
                ids[: len(rows)] = ids_all[rows, :width]
                cnts[: len(rows)] = cnts_all[rows, :width]
                self._note_padding(int((cnts > 0).sum()), cnts.size)
                gamma = _posterior_batch(self._cfg_for_width(width),
                                         self.exp_elog_beta,
                                         jnp.asarray(ids), jnp.asarray(cnts))
                out[rows] = np.asarray(gamma[: len(rows)])
                self._note_width(width, len(rows))
        return out

    def _note_width(self, width: int, docs: int) -> None:
        """Per-width serving bookkeeping; a width seen for the first time
        is the batch that paid a jit compile (the cache holds one
        executable per width — `cache_info`)."""
        miss = width not in self._compiled_widths
        self._compiled_widths[width] = \
            self._compiled_widths.get(width, 0) + 1
        if self.tel.enabled:
            m = self.tel.metrics
            m.inc("serve.jit_cache_misses" if miss
                  else "serve.jit_cache_hits", width=width)
            m.inc("serve.docs", docs)
            m.inc("serve.batches", width=width)

    def transform(self, corpus: Corpus) -> np.ndarray:
        """θ̄ (D, K): the normalised topic posterior (matches the θ̄ that
        ``predictive.log_predictive`` scores held-out words with)."""
        gamma = self.posterior(corpus)
        return np.asarray(safe_normalize(jnp.asarray(gamma), axis=-1))

    # -- ragged requests -------------------------------------------------
    def _stage(self, batch) -> _Staged:
        """Pad a packed batch to the fixed ``batch_size`` and put it on
        device — the host half of the pipeline (runs on the packer
        thread when double-buffered — the recorder is thread-safe and
        tags spans with a per-thread tid)."""
        tel = self.tel
        n = len(batch.rows)
        if self.layout == "csr":
            # flat arrays are already exactly token_budget slots — nothing
            # to pad; phantom docs exist only as unused segment ids
            sp = tel.trace.begin("serve/stage", width=batch.token_budget,
                                 docs=n) if tel.enabled else None
            self._note_padding(batch.live_tokens, batch.token_budget)
            staged = (batch.rows, jnp.asarray(batch.token_ids),
                      jnp.asarray(batch.counts),
                      jnp.asarray(batch.segments), n)
            if sp is not None:
                tel.trace.end(sp)
            return staged
        sp = tel.trace.begin("serve/stage", width=batch.width,
                             docs=len(batch.rows)) if tel.enabled else None
        ids = np.zeros((self.batch_size, batch.width), np.int32)
        cnts = np.zeros((self.batch_size, batch.width), np.float32)
        ids[:n] = batch.token_ids
        cnts[:n] = batch.counts
        self._note_padding(int((cnts > 0).sum()), cnts.size)
        staged = (batch.rows, jnp.asarray(ids), jnp.asarray(cnts),
                  batch.width, n)
        if sp is not None:
            tel.trace.end(sp)
        return staged

    def _staged_batches(self, docs) -> Iterator[_Staged]:
        """Pack a ragged request iterable into staged device batches.

        The serving packer runs the SAME width policy as training but with
        an open-ended ladder (requests of unseen lengths extend it by
        doubling) — the jit cache stays one executable per width.
        """
        it = (docs.iter_from(0) if hasattr(docs, "iter_from")
              else (as_ragged_doc(d) for d in docs))
        packer = BatchPacker(
            self.batch_size, vocab_size=self.cfg.vocab_size,
            layout=self.layout, token_budget=self.token_budget,
            metrics=self.tel.metrics if self.tel.enabled else None)
        pos = 0
        for ids, cnts in it:
            batch = packer.add(pos, ids, cnts)
            pos += 1
            if batch is not None:
                yield self._stage(batch)
        for batch in packer.flush():
            yield self._stage(batch)

    def posterior_docs(self, docs, *,
                       double_buffer: bool = True) -> np.ndarray:
        """γ (N, K) for RAGGED request documents — no padded ``Corpus``.

        ``docs``: a ``DocStream`` or any iterable of documents (raw token
        arrays with repeats, or unique ``(ids, counts)`` pairs; empty
        documents return the prior γ = α₀). Results come back in request
        order.

        ``double_buffer=True`` (default) overlaps ingest with compute: a
        host thread packs, pads and stages batch *t+1* while the device
        runs the E-step on batch *t* (the consumer dispatches without
        blocking — jax's async dispatch keeps the device queue full — and
        only converts γ to host arrays once every batch is in flight).
        ``double_buffer=False`` is the synchronous reference path: pack →
        run → block, one batch at a time (the baseline
        ``benchmarks/serve_bench.py`` measures the pipelining win
        against). Both paths run identical batches through the same jit
        entries, so their results are bit-identical.
        """
        results: List[_Result] = []
        if double_buffer:
            q: "queue.Queue" = queue.Queue(maxsize=self._buffer_depth())
            abort = threading.Event()
            err: List[BaseException] = []

            def put(item) -> bool:
                # bounded put that gives up once the consumer aborts, so a
                # consumer-side exception can never leave this thread (and
                # its staged device buffers) blocked on a full queue
                while not abort.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        return True
                    except queue.Full:
                        continue
                return False

            def produce():
                try:
                    for staged in self._staged_batches(docs):
                        if not put(staged):
                            return
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    err.append(e)
                finally:
                    put(None)

            t = threading.Thread(target=produce, name="serve-packer",
                                 daemon=True)
            t.start()
            try:
                while True:
                    staged = q.get()
                    if staged is None:
                        break
                    if self.tel.enabled:
                        # depth AFTER the take: 0 = consumer starved (pack
                        # is the bottleneck), maxsize−1 = producer ahead
                        self.tel.metrics.observe("serve.queue_depth",
                                                 q.qsize())
                    results.append(self._dispatch(staged))
            finally:
                abort.set()
                t.join()
            if err:
                raise err[0]
        else:
            for staged in self._staged_batches(docs):
                res = self._dispatch(staged)
                res[1].block_until_ready()    # the synchronous baseline
                results.append(res)
        total = sum(n for _, _, n, _ in results)
        out = np.zeros((total, self.cfg.num_topics), np.float32)
        for rows, gamma, n, _ in results:
            out[rows] = np.asarray(gamma[:n])
        return out

    def _dispatch(self, staged: _Staged) -> _Result:
        tel = self.tel
        rows, ids, cnts, aux, n = staged
        # ONE read of the snapshot tuple: the whole batch — and the version
        # its response reports — belongs to a single published model even
        # if swap_model lands mid-dispatch
        version, eb = self._model
        # serve/solve is never device-synced: syncing here would serialise
        # the double-buffer overlap the pipeline exists for, so the span
        # measures dispatch (+ compile on a width's first batch)
        if self.layout == "csr":
            width = self.token_budget
            sp = tel.trace.begin("serve/solve", width=width, docs=n) \
                if tel.enabled else None
            gamma = _posterior_batch_csr(self.cfg, eb, ids, cnts, aux,
                                         num_docs=self.batch_size)
        else:
            width = aux
            sp = tel.trace.begin("serve/solve", width=width, docs=n) \
                if tel.enabled else None
            gamma = _posterior_batch(self._cfg_for_width(width), eb, ids,
                                     cnts)
        if sp is not None:
            tel.trace.end(sp)
        self._note_width(width, n)
        return rows, gamma, n, version

    def posterior_packed(self, batch) -> _Result:
        """γ for ONE pre-packed batch — the serving-service entry point.

        ``batch``: a ``PackedBatch``/``CSRBatch`` from a ``BatchPacker``
        configured like this inferencer (`repro.serve.admission` builds
        one from ``packer_kwargs``). Returns ``(rows, gamma_device, n,
        model_version)`` — γ stays on device (callers block when they
        need honest latency), rows are the packer positions, and the
        version identifies the snapshot that solved the batch. Packing
        and staging are identical to ``posterior_docs``'s, so the served
        γ is bit-equal to the offline path on the same document sequence.
        """
        return self._dispatch(self._stage(batch))

    def packer_kwargs(self) -> Dict[str, object]:
        """The ``BatchPacker`` construction kwargs matching this
        inferencer's serving configuration — external batch formation
        (the admission controller) must pack exactly like
        ``_staged_batches`` to stay bit-equal with ``posterior_docs``."""
        return dict(batch_size=self.batch_size,
                    vocab_size=self.cfg.vocab_size, layout=self.layout,
                    token_budget=self.token_budget)

    def transform_docs(self, docs, *, double_buffer: bool = True
                       ) -> np.ndarray:
        """θ̄ (N, K) for ragged request documents (``posterior_docs``
        normalised)."""
        gamma = self.posterior_docs(docs, double_buffer=double_buffer)
        return np.asarray(safe_normalize(jnp.asarray(gamma), axis=-1))

    def _note_padding(self, live: int, padded: int) -> None:
        self._live_slots += int(live)
        self._padded_slots += int(padded)

    def padding_stats(self) -> Dict[str, object]:
        """Pad-waste accounting of everything staged so far: live vs
        total staged token slots and the bytes the padding cost on the
        host→device wire (`repro.data.stream.TOKEN_SLOT_BYTES` per slot).
        Under ``layout='csr'`` the only padding left is the flat batch
        tail below ``token_budget``."""
        from repro.data.stream import TOKEN_SLOT_BYTES
        wasted = self._padded_slots - self._live_slots
        return {"live_slots": self._live_slots,
                "padded_slots": self._padded_slots,
                "pad_frac": 1.0 - self._live_slots
                    / max(self._padded_slots, 1),
                "wasted_token_bytes": wasted * TOKEN_SLOT_BYTES}

    # -- introspection ---------------------------------------------------
    def cache_info(self) -> Dict[str, object]:
        """Serving-cache introspection — counters and compilations apart.

        ``_compiled_widths`` counts *batches served* per width, NOT jit
        entries (a width served twice still holds one compiled
        executable), so the two quantities are reported separately:

        * ``batches_per_width`` — {bucket width: batches served through
          it}, a traffic histogram;
        * ``compiled_widths``   — the sorted set of widths that have
          compiled an executable (the keys above);
        * ``jit_entries``       — its size: the number of compiled
          executables the fixed ``batch_size`` bounds.
        """
        return {
            "batches_per_width": dict(self._compiled_widths),
            "compiled_widths": sorted(self._compiled_widths),
            "jit_entries": len(self._compiled_widths),
        }


def topic_posterior(cfg: LDAConfig, lam: jax.Array, corpus: Corpus, *,
                    backend: Optional[str] = None, batch_size: int = 256
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """One-shot (γ, θ̄) for ``corpus`` under frozen topics ``lam``."""
    inf = TopicInferencer(cfg, lam, backend=backend, batch_size=batch_size)
    gamma = inf.posterior(corpus)
    theta = np.asarray(safe_normalize(jnp.asarray(gamma), axis=-1))
    return gamma, theta
