"""``repro.lda`` — the public estimator API for the paper's system.

One facade (``LDA``) for train / resume / serve over every engine the
reproduction implements (MVI / SVI / IVI / S-IVI single host, D-IVI
distributed), with durable incremental-state checkpoints. See
``docs/api.md`` for the reference and the migration table from the raw
``LDAEngine`` / ``DIVIEngine`` constructors (which remain available and
unchanged under ``repro.core`` / ``repro.dist``).

``__all__`` is the public surface and is guarded by
``tests/test_lda_api.py::test_public_api_surface`` — additions are fine,
removals and renames are breaking.
"""
from repro.lda.api import LDA
from repro.lda.ckpt import (SCHEMA_VERSION, load_lda_checkpoint,
                            save_lda_checkpoint)
from repro.lda.infer import TopicInferencer, topic_posterior
from repro.lda.trainer import (DIVITrainer, SingleHostTrainer, Trainer,
                               make_trainer)

__all__ = [
    "LDA",
    "Trainer",
    "SingleHostTrainer",
    "DIVITrainer",
    "make_trainer",
    "TopicInferencer",
    "topic_posterior",
    "save_lda_checkpoint",
    "load_lda_checkpoint",
    "SCHEMA_VERSION",
]
