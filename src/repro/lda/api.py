"""``LDA`` — one estimator facade for train / resume / serve.

The paper's selling points (no learning rate, monotone bound, resumable
incremental state, the distributed variant) are all in the engines, but
reaching them used to mean hand-wiring ``LDAEngine`` / ``DIVIEngine``,
``MemoStore`` kinds, E-step backends and length buckets. The facade puts
every knob on one constructor and makes the three lifecycle verbs
first-class:

    lda = LDA(num_topics=100, vocab_size=10_000, algo="ivi",
              backend="pallas", memo_store="chunked", bucket_by_length=True)
    lda.fit(train, epochs=5, test_corpus=test, eval_every=1)    # train
    lda.save("ckpt/run1")
    ...
    lda = LDA.load("ckpt/run1").resume(train)                   # resume
    lda.partial_fit(steps=2)        # bit-equal to never having stopped
    theta = lda.transform(unseen)                               # serve

Training is delegated to a ``Trainer`` (`repro.lda.trainer`) — the one
contract over both engine families — so a facade run is bit-equal to
driving the engines directly with the same seed. Serving goes through
``repro.lda.infer`` (bucketed batching, per-width jit cache, fused Pallas
E-step). Checkpoints are versioned manifests (`repro.lda.ckpt`) carrying
the FULL incremental state, not just λ.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.engines import History
from repro.core.metrics import top_words as _top_words
from repro.core.predictive import log_predictive, split_heldout
from repro.core.types import Corpus, GlobalState, LDAConfig
from repro.dist.protocol import DIVIConfig
from repro.lda.infer import TopicInferencer
from repro.lda.trainer import Trainer, make_trainer
from repro.obs import as_telemetry

_ALGOS = ("mvi", "svi", "ivi", "sivi", "divi")


class LDA:
    """Latent Dirichlet Allocation estimator (see module docstring).

    Args:
      cfg: an ``LDAConfig``; alternatively pass its fields as keyword
        arguments (``num_topics=…, vocab_size=…``) and leave ``cfg`` unset.
      algo: ``"mvi" | "svi" | "ivi" | "sivi"`` — the update rule — or
        ``"divi"``, shorthand for S-IVI under the distributed protocol
        (equivalent to ``algo="sivi", distributed=DIVIConfig(...)``).
      distributed: a ``DIVIConfig`` to train with the asynchronous
        master/worker protocol (paper §4); None = single host.
      backend: E-step backend override (``gather | dense | pallas``);
        equivalent to setting ``cfg.estep_backend``.
      memo_store / chunk_docs: π-memo representation for the incremental
        engines (``dense | chunked | gamma`` — `repro.core.memo`).
      bucket_by_length: length-bucketed epoch batching (`repro.data.bow`).
      mesh / data_axes: optional production mesh for the distributed path.
      telemetry: run observability (`repro.obs`, `docs/observability.md`):
        ``None``/``False`` = off (the default — a true no-op on the hot
        paths), ``True`` = a default ``Telemetry`` bundle (span recorder +
        metrics registry + evaluate-cadence ELBO watchdog), or a
        pre-configured ``repro.obs.Telemetry``. Threaded through the
        trainer, both engines, the batch packer and (by default) every
        inferencer this estimator creates.
      tune_store: a ``repro.tune`` policy store (path or ``PolicyStore``)
        of autotuned kernel policies (`docs/tuning.md`). Consulted once
        when the corpus is bound: a hit is written onto ``cfg`` (so
        checkpoints record the active policy and a resumed run reproduces
        its trajectory with or without the store); a miss — or no store —
        leaves the built-in defaults, bit-identical to not tuning. An
        explicit ``cfg.kernel_policy`` always wins over the store.
    """

    def __init__(self, cfg: Optional[LDAConfig] = None, *,
                 algo: str = "ivi",
                 distributed: Optional[DIVIConfig] = None,
                 batch_size: int = 64, seed: int = 0,
                 memo_store: str = "dense", chunk_docs: int = 8192,
                 bucket_by_length: bool = False,
                 backend: Optional[str] = None, layout: str = "padded",
                 token_budget: Optional[int] = None,
                 mesh=None, data_axes=None, telemetry=None,
                 tune_store=None, **cfg_kwargs):
        if cfg is None:
            cfg = LDAConfig(**cfg_kwargs)
        elif cfg_kwargs:
            raise TypeError("pass either a full LDAConfig or LDAConfig "
                            f"fields as kwargs, not both: {sorted(cfg_kwargs)}")
        if backend is not None and backend != cfg.estep_backend:
            cfg = dataclasses.replace(cfg, estep_backend=backend)
        if algo not in _ALGOS:
            raise ValueError(f"unknown algo {algo!r} (have {_ALGOS})")
        if layout not in ("padded", "csr"):
            raise ValueError(f"unknown layout {layout!r} "
                             "(expected 'padded' or 'csr')")
        if layout == "csr" and bucket_by_length:
            raise ValueError("bucket_by_length is the padded layout's "
                             "padding mitigation; layout='csr' has no "
                             "width buckets to begin with")
        if algo == "divi" and distributed is None:
            distributed = DIVIConfig()
        if distributed is not None and algo not in ("sivi", "divi"):
            raise ValueError(
                f"distributed training runs the S-IVI update (eq. 5) — "
                f"algo={algo!r} is incompatible; use algo='sivi' or 'divi'")
        self.cfg = cfg
        self.algo = algo
        self.distributed = distributed
        self.batch_size = batch_size
        self.seed = seed
        self.memo_store = memo_store
        self.chunk_docs = chunk_docs
        self.bucket_by_length = bucket_by_length
        self.layout = layout
        self.token_budget = token_budget if layout == "csr" else None
        self.telemetry = as_telemetry(telemetry)
        self.tune_store = tune_store
        self._cfg_pre_tune = None     # cfg before store resolution, if any
        self._mesh, self._data_axes = mesh, data_axes
        self.trainer: Optional[Trainer] = None
        self._corpus = None           # coerced Corpus | DocStream
        self._corpus_raw = None       # object the caller actually passed
        # set by LDA.load(): a state view for serve-without-resume, plus
        # the full trainer payload resume() restores; legacy bare-λ loads
        # set _serve_only (no payload to resume, training refused)
        self._state_view: Optional[GlobalState] = None
        self._pending_restore = None
        self._serve_only = False

    # ------------------------------------------------------------------
    # lifecycle: fit / partial_fit / resume
    # ------------------------------------------------------------------

    def _coerce_data(self, data):
        """Normalise fit/resume input: padded ``Corpus`` (materialized
        path), ``DocStream`` (ragged stream ingest — no (D, L) corpus ever
        resident), a pre-dealt ``ShardedDocStream`` (distributed path) or
        any plain iterable of documents (token arrays or ``(ids, counts)``
        pairs — wrapped as a host-resident stream)."""
        if data is None:
            return data
        if isinstance(data, Corpus):
            if self.layout == "csr":
                # the flat layout trains through stream ingest: wrap the
                # padded corpus as a resident stream (zero-copy row views)
                from repro.data.stream import CorpusDocStream
                return CorpusDocStream(data)
            return data
        from repro.data.stream import (ListDocStream, ShardedDocStream,
                                       is_doc_stream)
        if isinstance(data, ShardedDocStream):
            # already dealt into worker views — the distributed engine
            # consumes it as-is (it is NOT itself a DocStream: no cursor)
            if self.distributed is None:
                raise ValueError(
                    "a ShardedDocStream is the distributed ingest form; "
                    "single-host training takes the base DocStream (pass "
                    "sharded.base, or set distributed=DIVIConfig(...))")
            if data.vocab_size > self.cfg.vocab_size:
                raise ValueError(
                    f"stream vocab_size {data.vocab_size} exceeds the "
                    f"model's {self.cfg.vocab_size}")
            return data
        if is_doc_stream(data):
            if data.vocab_size > self.cfg.vocab_size:
                raise ValueError(
                    f"stream vocab_size {data.vocab_size} exceeds the "
                    f"model's {self.cfg.vocab_size}")
            return data
        return ListDocStream(data, vocab_size=self.cfg.vocab_size)

    def _bind(self, corpus,
              test_corpus: Optional[Corpus] = None) -> Trainer:
        raw = corpus
        if raw is not None and raw is self._corpus_raw:
            # the same data object the trainer is bound to: re-use the
            # coerced form (coercing again would wrap plain iterables in a
            # fresh ListDocStream and defeat the identity check below)
            corpus = self._corpus
        else:
            corpus = self._coerce_data(corpus)
        if self._pending_restore is not None:
            # loaded-but-not-resumed: building a fresh trainer here would
            # silently discard the checkpoint and train from scratch
            raise ValueError(
                "this estimator holds an unrestored checkpoint — call "
                "resume(corpus) to continue the checkpointed run (fit/"
                "partial_fit on it would silently retrain from scratch)")
        if self._serve_only:
            # legacy bare-λ load: serve-only — training would throw the
            # loaded topics away and start from the seed
            raise ValueError(
                "this estimator was loaded from a legacy bare-λ checkpoint "
                "and is serve-only (transform/score/top_words); training "
                "it would discard the loaded topics — build a fresh "
                "LDA(...) instead")
        if self.trainer is not None:
            if corpus is not None and corpus is not self._corpus:
                raise ValueError(
                    "this estimator is already bound to a corpus; build a "
                    "new LDA(...) to train on different data")
            if test_corpus is not None:
                self.trainer.set_test_corpus(test_corpus, seed=self.seed)
            return self.trainer
        if corpus is None:
            raise ValueError("first fit/partial_fit call must pass a corpus"
                             + (" (or call resume(corpus) on a loaded "
                                "checkpoint)" if self._pending_restore
                                else ""))
        self._resolve_tuned_policy(corpus)
        self.trainer = make_trainer(
            self.cfg, corpus, algo=self.algo, distributed=self.distributed,
            batch_size=self.batch_size, seed=self.seed,
            test_corpus=test_corpus, memo_store=self.memo_store,
            chunk_docs=self.chunk_docs,
            bucket_by_length=self.bucket_by_length, layout=self.layout,
            token_budget=self.token_budget, mesh=self._mesh,
            data_axes=self._data_axes, telemetry=self.telemetry,
            tune_store=self.tune_store)
        self._corpus = corpus
        self._corpus_raw = raw
        return self.trainer

    def _resolve_tuned_policy(self, corpus) -> None:
        """Look up a tuned ``KernelPolicy`` for the bound training shape.

        Resolving at the FACADE (not just inside the engine) writes the
        winner onto ``self.cfg`` — the object checkpoints serialize — so
        a resumed run reproduces the tuned trajectory even when the store
        is absent at resume time. A miss, no store, or an explicit
        ``cfg.kernel_policy`` changes nothing.
        """
        cfg = self.cfg
        if (self.tune_store is None or cfg.kernel_policy is not None
                or cfg.estep_backend not in ("pallas", "csr")):
            return
        from repro.tune.resolve import PolicyResolver
        if self.layout == "csr":
            # the engine's token-budget default, mirrored so the lookup
            # key matches the shape the engine will actually run
            b_or_t = (self.token_budget if self.token_budget is not None
                      else min(self.batch_size * 64, 8192))
            w = None
        else:
            b_or_t = (self.distributed.batch_size
                      if self.distributed is not None else self.batch_size)
            w = getattr(corpus, "max_unique", None)
        pol = PolicyResolver(self.tune_store,
                             telemetry=self.telemetry).resolve(
            backend=cfg.estep_backend, layout=self.layout,
            b_or_t=b_or_t, v=cfg.vocab_size, k=cfg.num_topics, w=w)
        if pol is not None:
            self._cfg_pre_tune = cfg
            self.cfg = dataclasses.replace(cfg, kernel_policy=pol)

    def fit(self, corpus=None, *, epochs: int = 1,
            rounds: Optional[int] = None,
            test_corpus: Optional[Corpus] = None, eval_every: int = 0,
            verbose: bool = False) -> "LDA":
        """Train: ``epochs`` full passes (single host) / ``rounds`` global
        rounds (distributed; defaults to ``epochs`` if unset). Repeated
        calls continue training the same bound corpus. ``corpus`` may be a
        padded ``Corpus``, a ``DocStream`` (ragged streaming ingest — one
        pass over the stream per epoch, `docs/streaming.md`) or a plain
        document iterable."""
        tr = self._bind(corpus, test_corpus)
        if rounds is not None and self.distributed is None:
            raise ValueError("rounds= applies to distributed training; "
                             "single-host engines take epochs=")
        n = (rounds if rounds is not None else epochs) \
            if self.distributed is not None else epochs
        for i in range(n):
            tr.run_pass()
            if eval_every and (i + 1) % eval_every == 0:
                ev = tr.evaluate()
                if verbose:
                    unit = "round" if self.distributed is not None else "epoch"
                    metrics = " ".join(f"{k}={v:.4f}"
                                       for k, v in sorted(ev.items()))
                    print(f"{unit}={i + 1} docs={tr.docs_seen} {metrics}")
        return self

    def partial_fit(self, corpus=None, *, steps: int = 1,
                    test_corpus: Optional[Corpus] = None) -> "LDA":
        """Run ``steps`` smallest resumable units (mini-batches / rounds)."""
        tr = self._bind(corpus, test_corpus)
        for _ in range(steps):
            tr.run_step()
        return self

    def warm_start(self, lam) -> "LDA":
        """Seed an UNTRAINED bound trainer's topics from a pretrained λ.

        The paper's Alg. 1 line 1 structure, with the pretrained model
        playing the random initialisation's role: λ ← λ₀ with the carried
        mass booked as ``init_mass = λ₀ − β₀`` at ``init_frac = 1`` and an
        EMPTY accumulator (⟨m_vk⟩ = 0, t = 0). Each document's pro-rata
        share of the carried mass retires on its first visit — exactly how
        the random init retires — so after one full pass λ = β₀ + ⟨m_vk⟩
        holds and the memoized bound is monotone from then on. (The
        alternative — folding λ₀ − β₀ into ⟨m_vk⟩ — would break eq. 4's
        coordinate-ascent argmax and with it the monotone bound.)

        This is the online-learning handoff (`repro.serve.online`): a
        frozen serving model warm-starts a learner over live traffic.
        Bind a corpus first without training: ``lda.fit(stream, epochs=0)``.
        """
        tr = self._require_trainer()
        eng = getattr(tr, "eng", None)
        if tr.kind != "single" or eng is None:
            raise ValueError("warm_start drives the single-host incremental "
                             "engines; seed a distributed run by "
                             "checkpointing instead")
        if int(jax.device_get(tr.state.t)) != 0 or tr.docs_seen:
            raise ValueError(
                "warm_start needs an untrained estimator — this one has "
                f"already run {tr.docs_seen} docs (t="
                f"{int(jax.device_get(tr.state.t))}); its memo/accumulator "
                "bookkeeping would no longer match the swapped λ")
        import jax.numpy as jnp
        lam0 = jnp.asarray(lam, jnp.float32)
        if lam0.shape != tr.state.lam.shape:
            raise ValueError(f"λ shape {tuple(lam0.shape)} != model "
                             f"{tuple(tr.state.lam.shape)}")
        eng.state = dataclasses.replace(
            eng.state, lam=lam0, m_vk=jnp.zeros_like(lam0),
            init_mass=lam0 - self.cfg.beta0,
            init_frac=jnp.ones(()), t=jnp.zeros((), jnp.int32))
        return self

    def resume(self, corpus, *,
               test_corpus: Optional[Corpus] = None, mesh=None,
               data_axes=None) -> "LDA":
        """Rebind the corpus (or ``DocStream``) and restore the
        checkpointed trainer state.

        The corpus is data, not state — it is not persisted in the
        checkpoint and must be supplied again. Everything else (λ-state,
        memo, rng stream, mid-epoch remainder — for stream ingest the
        epoch cursor and the packer's open buckets) comes from the
        manifest: continuing is bit-equal to a run that never stopped.
        """
        if self._pending_restore is None:
            raise ValueError(
                "nothing to resume: this estimator was not produced by "
                "LDA.load(), or resume() already ran (legacy bare-λ "
                "checkpoints restore λ only and cannot resume — retrain "
                "or re-save through LDA.save)")
        if mesh is not None:
            self._mesh, self._data_axes = mesh, data_axes
        meta, arrays = self._pending_restore
        self._pending_restore = None         # consume BEFORE _bind's guard
        try:
            tr = self._bind(corpus, test_corpus)
            tr.restore(meta, arrays)
        except Exception:
            self._pending_restore = (meta, arrays)
            raise
        self._state_view = None
        return self

    # ------------------------------------------------------------------
    # serve: transform / posterior / score
    # ------------------------------------------------------------------

    def inferencer(self, *, backend: Optional[str] = None,
                   batch_size: int = 256, layout: Optional[str] = None,
                   token_budget: Optional[int] = None,
                   telemetry=None, tune_store=None) -> TopicInferencer:
        """A reusable serving handle over the current topics (λ is
        preprocessed once; one jit entry per bucket width — or exactly ONE
        entry total under ``layout='csr'``). Layout defaults to the
        estimator's training layout; telemetry and the tuned-policy store
        to its own (serving resolves per-width policies lazily —
        `docs/tuning.md`)."""
        layout = self.layout if layout is None else layout
        if token_budget is None and layout == self.layout:
            token_budget = self.token_budget
        # a TRAIN-shape store policy must not ride into serving's shapes:
        # hand the inferencer the pre-resolution cfg so it does its own
        # per-width lookups (a user-explicit cfg.kernel_policy still wins
        # — _cfg_pre_tune is only set when the store supplied the policy)
        cfg = self.cfg if self._cfg_pre_tune is None else self._cfg_pre_tune
        return TopicInferencer(
            cfg, self.lam, backend=backend, batch_size=batch_size,
            layout=layout, token_budget=token_budget,
            telemetry=self.telemetry if telemetry is None else telemetry,
            tune_store=self.tune_store if tune_store is None else tune_store)

    def transform(self, corpus: Corpus, *, backend: Optional[str] = None,
                  batch_size: int = 256) -> np.ndarray:
        """θ̄ (D, K): normalised topic posterior of (unseen) documents."""
        return self.inferencer(backend=backend,
                               batch_size=batch_size).transform(corpus)

    def posterior(self, corpus: Corpus, *, backend: Optional[str] = None,
                  batch_size: int = 256) -> np.ndarray:
        """γ (D, K): unnormalised Dirichlet posterior parameters."""
        return self.inferencer(backend=backend,
                               batch_size=batch_size).posterior(corpus)

    def posterior_docs(self, docs, *, backend: Optional[str] = None,
                       batch_size: int = 256,
                       double_buffer: bool = True) -> np.ndarray:
        """γ (N, K) for RAGGED request documents — no padded ``Corpus``
        required. ``docs`` is a ``DocStream`` or any iterable of documents
        (token arrays or ``(ids, counts)`` pairs); with ``double_buffer``
        the host packs batch t+1 while the device runs the E-step on
        batch t (`docs/streaming.md`)."""
        return self.inferencer(backend=backend,
                               batch_size=batch_size).posterior_docs(
                                   docs, double_buffer=double_buffer)

    def score(self, corpus: Corpus, *, seed: Optional[int] = None) -> float:
        """Held-out per-word log predictive probability (paper §6 metric):
        fit θ on half of each document's words, score the other half."""
        obs, held = split_heldout(corpus, seed=self.seed if seed is None
                                  else seed)
        return float(log_predictive(self.cfg, self.lam, obs, held))

    def perplexity(self, corpus: Corpus, *,
                   seed: Optional[int] = None) -> float:
        """exp(−lpp) on held-out halves. Lower is better."""
        return float(np.exp(-self.score(corpus, seed=seed)))

    def top_words(self, k: int = 10) -> np.ndarray:
        """(K, k) token ids of each topic's most probable words."""
        return _top_words(self.lam, k)

    def coherence(self, corpus: Corpus, *, k: int = 10) -> float:
        """Mean NPMI topic coherence of the top-``k`` words per topic
        under ``corpus``'s co-occurrence statistics
        (`repro.core.metrics.npmi_coherence`, vectorized)."""
        from repro.core.metrics import npmi_coherence
        return npmi_coherence(self.lam, corpus, k=k)

    def effective_topics(self) -> float:
        """exp(entropy) of corpus-level topic usage — the topic-death
        diagnostic the telemetry gauge ``train.effective_topics`` tracks."""
        from repro.core.metrics import effective_topics
        return effective_topics(self.lam)

    def bound(self) -> float:
        """Exact corpus ELBO (incremental engines: the memoized bound —
        the objective IVI increases monotonically).

        A bound computed here was paid for anyway, so — like
        ``evaluate()`` — it feeds the telemetry watchdog even at
        ``check_every=0`` (the free cadence, `docs/observability.md`).
        The distributed trainer skips this: D-IVI averages away the
        guarantee, so its readings would never be armed.
        """
        tr = self._require_trainer()
        b = tr.full_bound()
        eng = getattr(tr, "eng", None)
        if (eng is not None and eng.tel.enabled and eng.tel.watchdog.enabled
                and eng.algo in ("ivi", "sivi")):
            eng.tel.watchdog.observe(b, step=eng._updates,
                                     armed=eng._watchdog_armed())
        return b

    def evaluate(self) -> Dict[str, float]:
        """One History row: held-out LPP if a test corpus is bound, the
        corpus bound otherwise."""
        return self._require_trainer().evaluate()

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------

    def save(self, path: str) -> str:
        """Write a versioned manifest checkpoint of the FULL state."""
        from repro.lda.ckpt import save_lda_checkpoint
        return save_lda_checkpoint(path, self)

    @classmethod
    def load(cls, path: str, *, telemetry=None) -> "LDA":
        """Load a checkpoint. Serving (``transform`` / ``top_words`` /
        ``score``) works immediately; call ``resume(corpus)`` before
        continuing training. ``telemetry`` attaches an observability
        bundle to the loaded estimator (checkpoints never persist
        telemetry — it is process state, not model state)."""
        from repro.lda.ckpt import load_lda_checkpoint
        lda = load_lda_checkpoint(path)
        lda.telemetry = as_telemetry(telemetry)
        return lda

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    def _require_trainer(self) -> Trainer:
        if self.trainer is None:
            raise ValueError("not fitted: call fit()/partial_fit() first"
                             + (" or resume(corpus)"
                                if self._pending_restore else ""))
        return self.trainer

    @property
    def state(self) -> GlobalState:
        if self.trainer is not None:
            return self.trainer.state
        if self._state_view is not None:
            return self._state_view
        raise ValueError("not fitted and no checkpoint state loaded")

    @property
    def lam(self) -> jax.Array:
        return self.state.lam

    @property
    def docs_seen(self) -> int:
        return self._require_trainer().docs_seen

    @property
    def history(self) -> History:
        return self._require_trainer().history
