import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape), build the production step function
(train_step / prefill_step / serve_step), lower it with production shardings
on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh, ``compile()``
it, and record memory analysis, cost analysis and the HLO-derived roofline
inputs. The two XLA_FLAGS lines above MUST precede any jax import — jax
locks the device count on first initialisation.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both          # 40 pairs × 2
  python -m repro.launch.dryrun --all --mesh single --out results/d.jsonl
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config, get_shape
from repro.configs.base import InputShape, ModelConfig, shape_variant
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.moe import MeshCtx
from repro.optim import adamw
from repro.sharding import batch_specs, cache_specs, fsdp_axes, param_specs
from repro.training import TrainState, make_prefill_step, make_serve_step, \
    make_train_step

# TPU v5e hardware constants (roofline denominators)
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def make_ctx(mesh: Mesh, seq_shard: bool = False,
             profile: str = "tp_fsdp") -> MeshCtx:
    data_axes = fsdp_axes(mesh)
    if profile == "fsdp_only":
        # no tensor parallelism: the model axis carries batch/data too.
        # (Not valid for MoE archs — their expert shard_map needs the model
        # axis; the dryrun rejects that combination.)
        data_axes = data_axes + ("model",)
    return MeshCtx(mesh=mesh, data_axes=data_axes, model_axis="model",
                   seq_shard=seq_shard)


def _sds(tree_shapes, spec_tree, mesh: Mesh):
    """ShapeDtypeStructs carrying NamedShardings (for .lower())."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree_shapes, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                profile: str = "tp_fsdp") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    fs = fsdp_axes(mesh)
    if profile == "fsdp_only":
        # no tensor parallelism: the model axis carries batch too
        allax = fs + ("model",)
        bspec = allax if b % _size(mesh, allax) == 0 else (
            fs if b % _size(mesh, fs) == 0 else None)
    else:
        bspec = fs if b % _size(mesh, fs) == 0 else None
    sd = lambda shp, dt, sp: jax.ShapeDtypeStruct(
        shp, dt, sharding=NamedSharding(mesh, sp))
    if shape.kind in ("train", "prefill"):
        if cfg.modality == "audio":
            toks = sd((b, s, cfg.num_codebooks), jnp.int32,
                      P(bspec, None, None))
            labs = sd((b, s, cfg.num_codebooks), jnp.int32,
                      P(bspec, None, None))
        elif cfg.modality == "vision":
            toks = sd((b, s - cfg.num_patches), jnp.int32, P(bspec, None))
            labs = sd((b, s), jnp.int32, P(bspec, None))
        else:
            toks = sd((b, s), jnp.int32, P(bspec, None))
            labs = sd((b, s), jnp.int32, P(bspec, None))
        batch = {"tokens": toks}
        if cfg.modality == "vision":
            batch["vision_embeds"] = sd((b, cfg.num_patches, cfg.d_model),
                                        jnp.bfloat16, P(bspec, None, None))
        if shape.kind == "train":
            batch["labels"] = labs
        return batch
    # decode
    tok_shape = (b, cfg.num_codebooks) if cfg.modality == "audio" else (b,)
    return {
        "tokens": sd(tok_shape, jnp.int32,
                     P(bspec, None) if cfg.modality == "audio" else P(bspec)),
        "pos": sd((b,), jnp.int32, P(bspec)),
    }


def _size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def build_and_lower(arch: str, shape_name: str, mesh: Mesh,
                    donate: bool = True, seq_shard: bool = False,
                    profile: str = "tp_fsdp", microbatches: int = 1,
                    cfg_override: Optional[ModelConfig] = None):
    """Returns (lowered, meta) for the production step of this pair."""
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    cfg, note = shape_variant(cfg, shape)
    if profile == "fsdp_only" and cfg.num_experts:
        raise ValueError("fsdp_only profile is incompatible with MoE archs")
    ctx = make_ctx(mesh, seq_shard=seq_shard, profile=profile)

    params_shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.key(0)))
    pspecs = param_specs(mesh, params_shapes, profile=profile)
    meta = {"arch": arch, "shape": shape_name, "variant_note": note,
            "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape))}

    if shape.kind == "train":
        opt = adamw(3e-4)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        ospecs = param_specs(mesh, opt_shapes, profile=profile)
        state_sds = TrainState(
            params=_sds(params_shapes, pspecs, mesh),
            opt_state=_sds(opt_shapes, ospecs, mesh),
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())))
        step = make_train_step(cfg, opt, ctx, microbatches=microbatches)
        jitted = jax.jit(step, donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_sds,
                               input_specs(cfg, shape, mesh, profile))
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx)
        jitted = jax.jit(step)
        lowered = jitted.lower(_sds(params_shapes, pspecs, mesh),
                               input_specs(cfg, shape, mesh, profile))
    else:  # decode
        cache_shapes = jax.eval_shape(
            partial(T.init_caches, cfg, shape.global_batch, shape.seq_len))
        cspecs = cache_specs(mesh, cfg, cache_shapes)
        step = make_serve_step(cfg, ctx)
        jitted = jax.jit(step, donate_argnums=(1,) if donate else ())
        ins = input_specs(cfg, shape, mesh)
        lowered = jitted.lower(_sds(params_shapes, pspecs, mesh),
                               _sds(cache_shapes, cspecs, mesh),
                               ins["tokens"], ins["pos"])
    return lowered, meta


def run_pair(arch: str, shape_name: str, mesh_kind: str,
             seq_shard: bool = False, profile: str = "tp_fsdp",
             microbatches: int = 1) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "chips": n_chips,
                           "seq_shard": seq_shard, "profile": profile,
                           "microbatches": microbatches}
    try:
        lowered, meta = build_and_lower(arch, shape_name, mesh,
                                        seq_shard=seq_shard, profile=profile,
                                        microbatches=microbatches)
        out.update(meta)
        out["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # jax<=0.4.x returns [dict]
            ca = ca[0] if ca else {}
        out["cost_analysis"] = {
            "flops_once": float(ca.get("flops", 0.0)),
            "bytes_once": float(ca.get("bytes accessed", 0.0)),
        }
        hlo = hlo_analysis.analyze(compiled.as_text())
        out["hlo"] = hlo
        # roofline terms (per device, seconds)
        out["roofline"] = {
            "compute_s": hlo["dot_flops"] / HW["peak_flops"],
            "memory_s": max(hlo["dot_bytes"], hlo["param_bytes"])
            / HW["hbm_bw"],
            "collective_s": hlo["collective_bytes"] / HW["ici_bw"],
        }
        out["ok"] = True
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        out["ok"] = False
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-2000:]
    out["total_s"] = round(time.time() - t0, 1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel residual stream (hillclimb lever)")
    ap.add_argument("--profile", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp_only"],
                    help="parallelism profile (hillclimb lever)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches (train shapes)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        # isolate each pair in a subprocess: keeps host RAM bounded and one
        # failure cannot poison the rest of the sweep
        from repro.configs.base import INPUT_SHAPES
        for arch in sorted(ARCHS):
            for shape in INPUT_SHAPES:
                for mk in meshes:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk]
                    if args.seq_shard:
                        cmd.append("--seq-shard")
                    if args.out:
                        cmd += ["--out", args.out]
                    subprocess.run(cmd, check=False)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    for mk in meshes:
        res = run_pair(args.arch, args.shape, mk, seq_shard=args.seq_shard,
                       profile=args.profile, microbatches=args.microbatches)
        line = json.dumps(res)
        status = "OK " if res["ok"] else "FAIL"
        print(f"[{status}] {args.arch} × {args.shape} × {mk}  "
              f"compile={res.get('compile_s', '-')}s  "
              f"temp={res.get('memory', {}).get('temp_gb', float('nan')):.3f}GB"
              if res["ok"] else
              f"[{status}] {args.arch} × {args.shape} × {mk}: "
              f"{res.get('error', '')[:300]}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")


if __name__ == "__main__":
    main()
