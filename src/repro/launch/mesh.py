"""Production mesh construction.

Functions (not module-level constants) so importing never touches jax
device state. Single pod: 256 chips as (data=16, model=16); multi-pod:
2 pods × 256 chips as (pod=2, data=16, model=16).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_abstract_mesh(shape, axes):
    """Version-compatible ``jax.sharding.AbstractMesh``.

    jax >= 0.5 takes ``(axis_sizes, axis_names)``; jax 0.4.x takes a single
    ``shape_tuple`` of ``(name, size)`` pairs. AbstractMesh carries only
    shape/axis metadata, so constructing it never touches device state.
    """
    from jax.sharding import AbstractMesh
    shape, axes = tuple(shape), tuple(axes)
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
