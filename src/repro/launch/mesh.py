"""Production mesh construction.

Functions (not module-level constants) so importing never touches jax
device state. Single pod: 256 chips as (data=16, model=16); multi-pod:
2 pods × 256 chips as (pod=2, data=16, model=16).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))
