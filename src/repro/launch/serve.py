"""Serving launcher: batched autoregressive decode for any assigned arch.

Reduced configs run real decode on CPU; full configs are exercised via the
dry-run (use ``repro.launch.dryrun --shape decode_32k``).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-moe-16b \
      --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import transformer as T
from repro.training import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(seq_len_hint=args.prompt_len)
    params = T.init_params(cfg, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    b = args.batch
    cache_len = args.prompt_len + args.new_tokens
    caches = T.init_caches(cfg, b, cache_len, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))

    tok_shape = ((b, args.prompt_len, cfg.num_codebooks)
                 if cfg.modality == "audio" else (b, args.prompt_len))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, tok_shape))
    cur = prompt[:, 0]
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        cur, logits, caches = serve(params, caches, prompt[:, t],
                                    jnp.full((b,), t, jnp.int32))
    gen = []
    for t in range(args.prompt_len, cache_len):
        cur, logits, caches = serve(params, caches, cur,
                                    jnp.full((b,), t, jnp.int32))
        gen.append(np.asarray(cur))
    dt = time.perf_counter() - t0
    total = b * cache_len
    print(f"arch={cfg.name} decoded {args.new_tokens}×{b} tokens "
          f"({total / dt:.1f} tok/s incl. prefill)")
    print("sample:", np.stack(gen, 1)[0].tolist()[:12])


if __name__ == "__main__":
    main()
