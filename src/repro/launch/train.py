"""Training launcher.

Two modes:
  * ``lda``  — the paper's system: train LDA with MVI/SVI/IVI/S-IVI/D-IVI
    on a synthetic paper-shaped corpus, periodic held-out LPP evaluation,
    checkpointing.
  * ``lm``   — transformer training: any assigned arch (reduced or full),
    synthetic token stream, AdamW or IAG, optional mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train lda --algo ivi --corpus small
  PYTHONPATH=src python -m repro.launch.train lda --algo divi --workers 4
  PYTHONPATH=src python -m repro.launch.train lda --algo divi --workers 4 \
      --stream                     # D-IVI straight off a UCI DocStream
  PYTHONPATH=src python -m repro.launch.train lm --arch yi-9b --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main_lda(args) -> None:
    """LDA training through the ``repro.lda.LDA`` facade.

    The historical flags are thin aliases onto facade kwargs (``--algo
    divi`` ≡ ``distributed=DIVIConfig(...)``, ``--memo-store`` ≡
    ``memo_store=``, …); ``--ckpt`` now writes a versioned manifest
    directory carrying the FULL incremental state (λ-state, memo, rng,
    epoch remainder — `repro.lda.ckpt`), and ``--resume`` continues such a
    run bit-equally. The old ``save_checkpoint(eng.state)`` flat-npz files
    load too, but serve-only (DeprecationWarning: their memo was dropped
    on save, so an IVI/S-IVI run cannot actually continue from them).
    """
    from repro.core import LDAConfig
    from repro.data import PAPER_CORPORA, UCIDocStream, make_corpus, save_uci
    from repro.dist import DIVIConfig
    from repro.lda import LDA

    tel = _build_telemetry(args)

    spec = PAPER_CORPORA[args.corpus]
    test = make_corpus(spec, split="test", seed=args.seed, scale=args.scale)
    if args.stream:
        # ragged streaming ingest: train from a lazily-read UCI docword
        # file through a DocStream — no (D, L) padded corpus resident.
        # With --docword an existing file is streamed; otherwise the
        # synthetic corpus is written out in UCI format once and then
        # streamed back, exercising the exact production ingest path.
        # Works single-host AND distributed (--algo divi shards the stream
        # into per-worker views); only full-batch mvi needs a materialized
        # corpus.
        if args.algo == "mvi":
            raise SystemExit("--stream needs a mini-batch engine; mvi is "
                             "full-batch coordinate ascent")
        docword = args.docword
        if docword is None:
            import tempfile
            mat = make_corpus(spec, split="train", seed=args.seed,
                              scale=args.scale)
            docword = os.path.join(tempfile.mkdtemp(prefix="lda_stream_"),
                                   "docword.txt.gz")
            save_uci(mat, docword)
        train = UCIDocStream(docword)
        print(f"stream={docword} docs={train.num_docs} "
              f"words={train.num_words:.0f} K={args.topics}")
    elif args.docword:
        raise SystemExit("--docword goes with --stream")
    else:
        train = make_corpus(spec, split="train", seed=args.seed,
                            scale=args.scale)
        print(f"corpus={args.corpus} docs={train.num_docs} "
              f"words={float(train.num_words):.0f} K={args.topics}")
    cfg = LDAConfig(num_topics=args.topics, vocab_size=spec.vocab_size,
                    estep_max_iters=args.estep_iters,
                    estep_backend=args.backend)

    if args.resume:
        lda = LDA.load(args.resume, telemetry=tel).resume(train,
                                                          test_corpus=test)
        print(f"resumed {args.resume}: algo={lda.algo} "
              f"docs_seen={lda.docs_seen}")
    elif args.algo == "divi":
        lda = LDA(cfg, algo="divi",
                  distributed=DIVIConfig(num_workers=args.workers,
                                         batch_size=args.batch,
                                         staleness=args.staleness,
                                         delay_prob=args.delay_prob),
                  seed=args.seed, telemetry=tel,
                  tune_store=args.tune_store)
    else:
        lda = LDA(cfg, algo=args.algo, batch_size=args.batch,
                  seed=args.seed, memo_store=args.memo_store,
                  chunk_docs=args.chunk_docs,
                  bucket_by_length=args.bucketed, telemetry=tel,
                  tune_store=args.tune_store)

    # bind the corpus without stepping so the memo footprint is reportable
    lda.partial_fit(train, steps=0, test_corpus=test)
    if lda.cfg.kernel_policy is not None:
        # a tuned (or explicit) policy is part of the run's identity —
        # log it so the trajectory is attributable (docs/tuning.md)
        print(f"kernel_policy={lda.cfg.kernel_policy}")
    memo = (lda.trainer.eng.memo if lda.trainer.kind == "single" else None)
    if memo is not None:
        print(f"memo_store={memo.kind} "
              f"footprint={memo.footprint_bytes() / 1e6:.2f}MB")
    # pad-waste visibility: log the per-bucket pad fractions once per run
    # so a packing/bucketing regression shows up in the training log
    stats = (lda.trainer.eng.bucket_stats
             if lda.trainer.kind == "single" else None)
    if stats is not None:
        per = " ".join(f"w{b['width']}:{b['docs']}d/{b['pad_frac']:.0%}"
                       for b in stats["per_bucket"])
        print(f"bucket_padding_stats slot_ratio={stats['slot_ratio']:.3f} "
              f"[{per}]")

    if lda.distributed is not None:
        lda.fit(rounds=args.rounds, eval_every=args.eval_every,
                verbose=True)
    else:
        lda.fit(epochs=args.epochs, eval_every=1, verbose=True)
        if args.stream:
            st = lda.trainer.eng.stream_padding_stats()
            per = " ".join(f"w{b['width']}:{b['docs']}d/{b['pad_frac']:.0%}"
                           for b in st["per_width"])
            print(f"stream_padding_stats pad_frac={st['pad_frac']:.3f} "
                  f"[{per}]")
        if args.bound:
            print("final exact bound:", lda.bound())
    if tel is not None:
        _report_telemetry(tel, args)
    if args.ckpt:
        print("saved", lda.save(args.ckpt))


def _build_telemetry(args):
    """Construct the run's ``repro.obs`` bundle from the CLI flags
    (None when no telemetry flag is set — the true-no-op path)."""
    if not (args.trace or args.metrics_json or args.watchdog != "off"):
        return None
    from repro.obs import ElboWatchdog, Telemetry
    if args.watchdog != "off":
        return Telemetry(watchdog=ElboWatchdog(
            policy=args.watchdog, check_every=args.watchdog_every))
    return Telemetry()


def _report_telemetry(tel, args) -> None:
    """End-of-run telemetry summary + the --trace/--metrics-json dumps."""
    m, wd = tel.metrics, tel.watchdog
    tokens = m.total("train.tokens")
    wall = sum(r["dur_us"] for r in tel.trace.records
               if r["type"] == "span" and r["name"] == "train/update") / 1e6
    rate = f"{tokens / wall:,.0f} tok/s" if wall > 0 else "n/a"
    st = wd.status()
    wd_line = ("off" if not st["enabled"] else
               f"{st['policy']} checks={st['checks']} "
               f"violations={st['violations']} "
               f"{'OK' if st['ok'] else 'VIOLATED'}")
    print(f"telemetry: tokens={tokens:,.0f} update_time={wall:.2f}s "
          f"({rate}) spans={tel.trace.num_records} watchdog={wd_line}")
    if args.trace:
        n = tel.trace.dump_jsonl(args.trace)
        print(f"trace: wrote {n} records to {args.trace}")
    if args.metrics_json:
        m.dump_json(args.metrics_json)
        print(f"metrics: wrote {args.metrics_json}")


def main_lm(args) -> None:
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.optim import adamw, cosine_schedule, iag
    from repro.training import TrainState, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(seq_len_hint=args.seq)
    rng = np.random.default_rng(args.seed)
    params = T.init_params(cfg, jax.random.key(args.seed))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n / 1e6:.2f}M")
    if args.optimizer == "iag":
        opt = iag(args.lr, num_shards=args.iag_shards)
    else:
        opt = adamw(cosine_schedule(args.lr, 10, args.steps))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    if args.optimizer == "iag":
        def step_fn(state, batch, shard):
            def lfn(p):
                return T.loss_fn(cfg, p, batch)
            (loss, m), g = jax.value_and_grad(lfn, has_aux=True)(state.params)
            upd, os_ = opt.update(g, state.opt_state, state.params,
                                  shard=shard)
            from repro.optim import apply_updates
            return TrainState(apply_updates(state.params, upd), os_,
                              state.step + 1), m
        step = jax.jit(step_fn)
    else:
        step = jax.jit(make_train_step(cfg, opt))

    def sample_batch():
        shape = ((args.batch, args.seq, cfg.num_codebooks)
                 if cfg.modality == "audio" else (args.batch, args.seq))
        toks = rng.integers(0, cfg.vocab_size, shape)
        batch = {"tokens": jnp.asarray(toks)}
        lab_len = args.seq + (cfg.num_patches if cfg.modality == "vision"
                              else 0)
        lab_shape = ((args.batch, lab_len, cfg.num_codebooks)
                     if cfg.modality == "audio" else (args.batch, lab_len))
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   lab_shape))
        if cfg.modality == "vision":
            batch["vision_embeds"] = jnp.asarray(rng.normal(
                0, 1, (args.batch, cfg.num_patches, cfg.d_model))
                .astype(np.float32))
        return batch

    t0 = time.perf_counter()
    for s in range(args.steps):
        batch = sample_batch()
        if args.optimizer == "iag":
            state, metrics = step(state, batch,
                                  jnp.asarray(s % args.iag_shards))
        else:
            state, metrics = step(state, batch)
        if (s + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(f"step={s + 1} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"steps_per_s={(s + 1) / dt:.2f}")
    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, state.params, step=args.steps)
        print("saved", args.ckpt)


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    lda = sub.add_parser("lda")
    lda.add_argument("--algo", default="ivi",
                     choices=["mvi", "svi", "ivi", "sivi", "divi"])
    lda.add_argument("--corpus", default="small")
    lda.add_argument("--scale", type=float, default=1.0)
    lda.add_argument("--topics", type=int, default=50)
    lda.add_argument("--batch", type=int, default=32)
    lda.add_argument("--epochs", type=int, default=5)
    lda.add_argument("--rounds", type=int, default=50)
    lda.add_argument("--workers", type=int, default=4)
    lda.add_argument("--staleness", type=int, default=1)
    lda.add_argument("--delay-prob", type=float, default=0.0)
    lda.add_argument("--estep-iters", type=int, default=60)
    lda.add_argument("--backend", default="gather",
                     choices=["gather", "dense", "pallas"])
    lda.add_argument("--memo-store", default="dense",
                     choices=["dense", "chunked", "gamma"],
                     help="π-memo representation for ivi/sivi "
                          "(docs/estep.md)")
    lda.add_argument("--chunk-docs", type=int, default=8192,
                     help="documents per host-store chunk")
    lda.add_argument("--bucketed", action="store_true",
                     help="length-bucketed epoch batching (svi/ivi/sivi)")
    lda.add_argument("--stream", action="store_true",
                     help="ragged streaming ingest through a UCI DocStream "
                          "(no padded corpus resident; docs/streaming.md)")
    lda.add_argument("--docword", default=None,
                     help="existing UCI docword(.gz) file to stream "
                          "(default: write the synthetic corpus out once)")
    lda.add_argument("--eval-every", type=int, default=5)
    lda.add_argument("--bound", action="store_true")
    lda.add_argument("--seed", type=int, default=0)
    lda.add_argument("--ckpt", default=None,
                     help="save a manifest checkpoint directory here "
                          "(full incremental state; repro.lda.ckpt)")
    lda.add_argument("--tune-store", default=None, metavar="PATH",
                     help="repro.tune policy store of autotuned kernel "
                          "policies (docs/tuning.md); a hit replaces the "
                          "built-in tile defaults, a miss changes nothing")
    lda.add_argument("--resume", default=None,
                     help="resume from a --ckpt manifest (bit-equal "
                          "continuation); algo/store flags then come from "
                          "the checkpoint")
    lda.add_argument("--trace", default=None, metavar="PATH",
                     help="record a repro.obs span trace and write it as "
                          "JSONL here (docs/observability.md)")
    lda.add_argument("--metrics-json", default=None, metavar="PATH",
                     help="write the run's metrics-registry snapshot here")
    lda.add_argument("--watchdog", default="off",
                     choices=["off", "warn", "raise"],
                     help="ELBO-monotonicity watchdog policy on the "
                          "incremental path (armed once init mass retires)")
    lda.add_argument("--watchdog-every", type=int, default=0,
                     help="check the memoized bound every N updates "
                          "(O(corpus) each; 0 = only at evaluations)")

    lm = sub.add_parser("lm")
    lm.add_argument("--arch", required=True)
    lm.add_argument("--reduced", action="store_true")
    lm.add_argument("--steps", type=int, default=100)
    lm.add_argument("--batch", type=int, default=4)
    lm.add_argument("--seq", type=int, default=128)
    lm.add_argument("--lr", type=float, default=3e-4)
    lm.add_argument("--optimizer", default="adamw", choices=["adamw", "iag"])
    lm.add_argument("--iag-shards", type=int, default=8)
    lm.add_argument("--log-every", type=int, default=10)
    lm.add_argument("--seed", type=int, default=0)
    lm.add_argument("--ckpt", default=None)

    args = ap.parse_args()
    if args.mode == "lda":
        main_lda(args)
    else:
        main_lm(args)


if __name__ == "__main__":
    main()
