"""LDA serving launcher — a thin client of the ``repro.serve`` service.

Drives the ``ServingService`` (`docs/serving.md`) with scheduled request
traffic: admission control forms batches over the serving width ladder /
CSR token budget, partial batches flush on timeout, every response
records the model version that served it, and the latency report comes
from the service's SLO accounting (``repro.serve.slo/v1``).

Traffic shapes (``--traffic``): ``replay`` (the legacy fixed-replay mode
as a schedule — ``--requests × --batch`` single-document requests, all at
t=0, or spaced at ``--rate``), ``poisson`` and ``onoff`` (the synthetic
open-stream generators, seeded). ``--online`` runs the background
incremental learner on the served documents and publishes λ through the
atomic snapshot swap.

Legacy flags: ``--requests``/``--batch`` keep their old meaning as the
replay volume (N requests of B docs ⇒ N·B single-doc requests);
``--ragged`` and ``--no-double-buffer`` are DEPRECATED no-ops — the
service always consumes ragged requests through the admission packer.

Examples:
  PYTHONPATH=src python -m repro.launch.serve_lda --corpus small \
      --requests 64 --batch 32 --backend gather
  PYTHONPATH=src python -m repro.launch.serve_lda --corpus small \
      --traffic poisson --rate 200 --requests 16 --online
  # Arxiv-scale serving dry-run (lowering + memory, no weights needed):
  PYTHONPATH=src python -m repro.launch.serve_lda --dryrun
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

# Arxiv (Table 1): V=141,927 padded /16, K=100 → 128 lanes.
ARXIV = dict(vocab=141_952, topics=128)
ARXIV_WIDTHS = (32, 64, 128)            # serving bucket widths at L=128


def run_serve_dryrun(batch: int = 256, widths=ARXIV_WIDTHS,
                     backend: str = "pallas") -> dict:
    """Lower the per-bucket serving step at Arxiv scale, per width.

    No weights are materialised (ShapeDtypeStructs only): this checks the
    serving program compiles at the production shape and reports its
    device-memory needs — the serving analogue of ``dryrun_lda --mode ivi``.
    """
    from repro.core.types import LDAConfig
    from repro.lda.infer import _posterior_batch

    v, k = ARXIV["vocab"], ARXIV["topics"]
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=50,
                    estep_backend=backend, estep_stream_dtype="bfloat16")
    out = {"arch": "lda-serve-arxiv", "mode": "serve", "backend": backend,
           "shape": f"b{batch}", "widths": list(widths)}
    t0 = time.time()
    try:
        sds = jax.ShapeDtypeStruct
        per_width = {}
        for w in widths:
            lowered = _posterior_batch.lower(
                cfg, sds((v, k), jnp.float32),
                sds((batch, w), jnp.int32), sds((batch, w), jnp.float32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            per_width[w] = {
                "temp_gb": mem.temp_size_in_bytes / 1e9,
                "argument_gb": mem.argument_size_in_bytes / 1e9,
            }
        out["compile_s"] = round(time.time() - t0, 1)
        out["memory"] = per_width
        out["jit_cache_entries"] = len(widths)
        out["ok"] = True
    except Exception as e:  # noqa: BLE001
        out["ok"] = False
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-1500:]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="LDA checkpoint (manifest dir or legacy .npz); "
                         "omit to train a quick model on --corpus")
    ap.add_argument("--corpus", default="small")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--topics", type=int, default=50)
    ap.add_argument("--estep-iters", type=int, default=50)
    ap.add_argument("--backend", default=None,
                    choices=[None, "gather", "dense", "pallas"],
                    help="serving E-step backend (default: the config's)")
    ap.add_argument("--batch", type=int, default=32,
                    help="request batch size (also the jit pad width)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--layout", default=None, choices=[None, "padded", "csr"],
                    help="serving batch layout: padded width buckets or "
                         "the flat CSR token stream (one jit entry total); "
                         "default: the estimator's training layout")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="with --layout csr: flat slots per batch")
    ap.add_argument("--ragged", action="store_true",
                    help="DEPRECATED no-op: the service always serves "
                         "ragged requests through the admission packer")
    ap.add_argument("--no-double-buffer", action="store_true",
                    help="DEPRECATED no-op: batching/overlap policy now "
                         "lives in the service loop")
    ap.add_argument("--traffic", default="replay",
                    choices=["replay", "poisson", "onoff"],
                    help="arrival schedule: replay (--requests×--batch "
                         "docs, burst or --rate-spaced), poisson, or "
                         "bursty ON-OFF")
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate, docs/s (replay: None = all at "
                         "t=0; poisson/onoff default 200)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget; admission sheds "
                         "requests that already blew it (default: none)")
    ap.add_argument("--flush-timeout-ms", type=float, default=20.0,
                    help="partial-batch flush timeout")
    ap.add_argument("--slo-p95-ms", type=float, default=None,
                    help="p95 latency SLO target for the report")
    ap.add_argument("--online", action="store_true",
                    help="train the background incremental learner on "
                         "served documents and publish λ via atomic "
                         "snapshot swaps")
    ap.add_argument("--cadence-s", type=float, default=0.25,
                    help="with --online: background update period")
    ap.add_argument("--warm-epochs", type=int, default=1,
                    help="quick-train epochs when no --ckpt is given")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dryrun", action="store_true",
                    help="Arxiv-scale serving lowering, no weights")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a repro.obs span trace of the serving run "
                         "and write it as JSONL here (docs/observability.md)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the serving metrics-registry snapshot here")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.dryrun:
        res = run_serve_dryrun(batch=args.batch,
                               backend=args.backend or "pallas")
        if res["ok"]:
            worst = max(m["temp_gb"] for m in res["memory"].values())
            print(f"[OK ] lda-serve arxiv  compile={res['compile_s']}s "
                  f"widths={res['widths']} max_temp={worst:.2f}GB "
                  f"jit_entries={res['jit_cache_entries']}")
        else:
            print(f"[FAIL] lda-serve: {res['error'][:200]}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
        return

    from repro.data import PAPER_CORPORA, make_corpus
    from repro.lda import LDA
    from repro.obs import MetricsRegistry, Telemetry

    # the metrics registry IS the latency accounting now (real histogram
    # percentiles replaced the old ad-hoc list); the full bundle (with a
    # span recorder) is only built when a telemetry flag asks for it
    tel = Telemetry() if (args.trace or args.metrics_json) else None
    reg = tel.metrics if tel is not None else MetricsRegistry()

    spec = PAPER_CORPORA[args.corpus]
    test = make_corpus(spec, split="test", seed=args.seed, scale=args.scale)
    if args.ckpt:
        lda = LDA.load(args.ckpt)
        print(f"topics from {args.ckpt}: V={lda.cfg.vocab_size} "
              f"K={lda.cfg.num_topics}")
    else:
        train = make_corpus(spec, split="train", seed=args.seed,
                            scale=args.scale)
        lda = LDA(num_topics=args.topics, vocab_size=spec.vocab_size,
                  estep_max_iters=args.estep_iters, algo="ivi",
                  seed=args.seed)
        lda.fit(train, epochs=args.warm_epochs)
        print(f"quick-trained ivi on {args.corpus}: "
              f"{args.warm_epochs} epoch(s), docs_seen={lda.docs_seen}")

    if args.ragged or args.no_double_buffer:
        print("note: --ragged/--no-double-buffer are deprecated no-ops — "
              "the service always serves ragged requests through the "
              "admission packer (docs/serving.md)")

    from repro.data.stream import CorpusDocStream
    from repro.serve import (OnlineLearner, ServiceConfig, ServingService,
                             SnapshotStore, onoff_arrivals, poisson_arrivals,
                             replay_arrivals, requests_from_docs)

    inf = lda.inferencer(backend=args.backend, batch_size=args.batch,
                         layout=args.layout, token_budget=args.token_budget,
                         telemetry=tel)
    ragged_docs = list(CorpusDocStream(test).iter_from(0))

    # warmup: serve the whole test corpus once — every bucket width
    # compiles here, so the service run measures steady-state latency
    if args.requests:
        inf.posterior_docs(ragged_docs)

    n = args.requests * args.batch        # legacy volume: N requests × B
    rng = np.random.default_rng(args.seed)
    doc_order = [ragged_docs[i] for i in
                 rng.choice(len(ragged_docs), size=max(n, 1))]
    if args.traffic == "poisson":
        arrivals = poisson_arrivals(n, args.rate or 200.0, seed=args.seed)
    elif args.traffic == "onoff":
        r = args.rate or 200.0
        arrivals = onoff_arrivals(n, r, on_s=max(8.0 / r, 1e-3),
                                  off_s=max(8.0 / r, 1e-3), seed=args.seed)
    else:
        arrivals = replay_arrivals(n, args.rate)
    deadline = (args.deadline_ms / 1e3 if args.deadline_ms is not None
                else float("inf"))
    requests = requests_from_docs(doc_order, arrivals, deadline_s=deadline)

    slo = {"p95": args.slo_p95_ms} if args.slo_p95_ms else None
    svc = ServingService(inf, config=ServiceConfig(
        flush_timeout_s=args.flush_timeout_ms / 1e3,
        slo_ms=slo), telemetry=tel)
    learner = None
    if args.online:
        store = SnapshotStore(inf, metrics=svc.metrics)
        learner = OnlineLearner(lda.cfg, store, lam0=np.asarray(lda.lam),
                                cadence_s=args.cadence_s, seed=args.seed)
        svc.learner = learner
        learner.start()
    t0 = time.perf_counter()
    try:
        svc.run(requests)
    finally:
        if learner is not None:
            learner.stop()
    if learner is not None:
        learner.drain()
    wall = time.perf_counter() - t0

    rep = svc.slo_report()
    pct = rep["latency_ms"]
    mode = f"{inf.layout}/service/{args.traffic}"
    if rep["served"]:
        print(f"served {rep['served']}/{rep['offered']} docs "
              f"({rep['shed']} shed) backend={inf.cfg.estep_backend} "
              f"[{mode}]: {rep['throughput_docs_s']:.1f} docs/s "
              f"(wall {wall:.2f}s)")
        print(f"latency ms: p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
              f"p99={pct['p99']:.1f} max={pct['max']:.1f}")
        print(f"model versions served: {rep['model_versions']}"
              + (f" ({learner.updates} online updates)" if learner else ""))
        pad = inf.padding_stats()
        print(f"padding: frac={pad['pad_frac']:.3f} "
              f"wasted={pad['wasted_token_bytes'] / 1e3:.1f}kB staged "
              f"({pad['padded_slots'] - pad['live_slots']} of "
              f"{pad['padded_slots']} slots dead)")
    else:
        print("served 0 requests — skipping the latency report")
    for name, s in rep["slo"].items():
        print(f"SLO {name}: target {s['target_ms']:.0f}ms observed "
              f"{s['observed_ms']:.1f}ms -> "
              f"{'ATTAINED' if s['attained'] else 'MISSED'}")
    cache = inf.cache_info()
    print(f"jit cache: {cache['jit_entries']} compiled widths "
          f"{cache['compiled_widths']} "
          f"(batches per width: {cache['batches_per_width']})")
    if args.trace:
        n_rec = tel.trace.dump_jsonl(args.trace)
        print(f"trace: wrote {n_rec} records to {args.trace}")
    if args.metrics_json:
        reg.dump_json(args.metrics_json)
        print(f"metrics: wrote {args.metrics_json}")
    if args.out:
        rec = {"mode": "serve", "backend": inf.cfg.estep_backend,
               "serve_mode": mode, "traffic": args.traffic,
               "batch": args.batch, "requests": args.requests,
               "docs_per_s": rep["throughput_docs_s"],
               "latency_ms": pct,
               "slo_report": rep,
               "jit_widths": cache["compiled_widths"],
               "batches_per_width": cache["batches_per_width"],
               "layout": inf.layout,
               "online": bool(learner),
               "padding": inf.padding_stats(), "ok": True}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
