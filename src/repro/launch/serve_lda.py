"""LDA serving launcher: batched topic-posterior requests, latency report.

Serves ``LDA.transform``-style traffic through `repro.lda.infer`: each
request is a batch of unseen documents; the server groups them into length
buckets, pads to one fixed batch size (one compiled executable per bucket
width — the jit cache is enumerable, see the report) and runs the E-step
through the configured backend (``pallas`` = the fused fixed-point kernel,
the production path).

Examples:
  PYTHONPATH=src python -m repro.launch.serve_lda --corpus small \
      --requests 64 --batch 32 --backend gather
  PYTHONPATH=src python -m repro.launch.serve_lda --ckpt ckpts/run1 \
      --backend pallas
  # Arxiv-scale serving dry-run (lowering + memory, no weights needed):
  PYTHONPATH=src python -m repro.launch.serve_lda --dryrun
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

# Arxiv (Table 1): V=141,927 padded /16, K=100 → 128 lanes.
ARXIV = dict(vocab=141_952, topics=128)
ARXIV_WIDTHS = (32, 64, 128)            # serving bucket widths at L=128


def run_serve_dryrun(batch: int = 256, widths=ARXIV_WIDTHS,
                     backend: str = "pallas") -> dict:
    """Lower the per-bucket serving step at Arxiv scale, per width.

    No weights are materialised (ShapeDtypeStructs only): this checks the
    serving program compiles at the production shape and reports its
    device-memory needs — the serving analogue of ``dryrun_lda --mode ivi``.
    """
    from repro.core.types import LDAConfig
    from repro.lda.infer import _posterior_batch

    v, k = ARXIV["vocab"], ARXIV["topics"]
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=50,
                    estep_backend=backend, estep_stream_dtype="bfloat16")
    out = {"arch": "lda-serve-arxiv", "mode": "serve", "backend": backend,
           "shape": f"b{batch}", "widths": list(widths)}
    t0 = time.time()
    try:
        sds = jax.ShapeDtypeStruct
        per_width = {}
        for w in widths:
            lowered = _posterior_batch.lower(
                cfg, sds((v, k), jnp.float32),
                sds((batch, w), jnp.int32), sds((batch, w), jnp.float32))
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            per_width[w] = {
                "temp_gb": mem.temp_size_in_bytes / 1e9,
                "argument_gb": mem.argument_size_in_bytes / 1e9,
            }
        out["compile_s"] = round(time.time() - t0, 1)
        out["memory"] = per_width
        out["jit_cache_entries"] = len(widths)
        out["ok"] = True
    except Exception as e:  # noqa: BLE001
        out["ok"] = False
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-1500:]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="LDA checkpoint (manifest dir or legacy .npz); "
                         "omit to train a quick model on --corpus")
    ap.add_argument("--corpus", default="small")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--topics", type=int, default=50)
    ap.add_argument("--estep-iters", type=int, default=50)
    ap.add_argument("--backend", default=None,
                    choices=[None, "gather", "dense", "pallas"],
                    help="serving E-step backend (default: the config's)")
    ap.add_argument("--batch", type=int, default=32,
                    help="request batch size (also the jit pad width)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--layout", default=None, choices=[None, "padded", "csr"],
                    help="serving batch layout: padded width buckets or "
                         "the flat CSR token stream (one jit entry total); "
                         "default: the estimator's training layout")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="with --layout csr: flat slots per batch")
    ap.add_argument("--ragged", action="store_true",
                    help="serve ragged requests through posterior_docs "
                         "(no padded Corpus; double-buffered by default)")
    ap.add_argument("--no-double-buffer", action="store_true",
                    help="with --ragged: the synchronous reference path")
    ap.add_argument("--warm-epochs", type=int, default=1,
                    help="quick-train epochs when no --ckpt is given")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dryrun", action="store_true",
                    help="Arxiv-scale serving lowering, no weights")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a repro.obs span trace of the serving run "
                         "and write it as JSONL here (docs/observability.md)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the serving metrics-registry snapshot here")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.dryrun:
        res = run_serve_dryrun(batch=args.batch,
                               backend=args.backend or "pallas")
        if res["ok"]:
            worst = max(m["temp_gb"] for m in res["memory"].values())
            print(f"[OK ] lda-serve arxiv  compile={res['compile_s']}s "
                  f"widths={res['widths']} max_temp={worst:.2f}GB "
                  f"jit_entries={res['jit_cache_entries']}")
        else:
            print(f"[FAIL] lda-serve: {res['error'][:200]}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")
        return

    from repro.data import PAPER_CORPORA, make_corpus
    from repro.lda import LDA
    from repro.obs import MetricsRegistry, Telemetry

    # the metrics registry IS the latency accounting now (real histogram
    # percentiles replaced the old ad-hoc list); the full bundle (with a
    # span recorder) is only built when a telemetry flag asks for it
    tel = Telemetry() if (args.trace or args.metrics_json) else None
    reg = tel.metrics if tel is not None else MetricsRegistry()

    spec = PAPER_CORPORA[args.corpus]
    test = make_corpus(spec, split="test", seed=args.seed, scale=args.scale)
    if args.ckpt:
        lda = LDA.load(args.ckpt)
        print(f"topics from {args.ckpt}: V={lda.cfg.vocab_size} "
              f"K={lda.cfg.num_topics}")
    else:
        train = make_corpus(spec, split="train", seed=args.seed,
                            scale=args.scale)
        lda = LDA(num_topics=args.topics, vocab_size=spec.vocab_size,
                  estep_max_iters=args.estep_iters, algo="ivi",
                  seed=args.seed)
        lda.fit(train, epochs=args.warm_epochs)
        print(f"quick-trained ivi on {args.corpus}: "
              f"{args.warm_epochs} epoch(s), docs_seen={lda.docs_seen}")

    inf = lda.inferencer(backend=args.backend, batch_size=args.batch,
                         layout=args.layout, token_budget=args.token_budget,
                         telemetry=tel)
    rng = np.random.default_rng(args.seed)

    if args.ragged:
        # ragged request traffic — no padded Corpus built per request; the
        # double-buffered pipeline packs batch t+1 while batch t runs
        from repro.data.stream import CorpusDocStream
        ragged_docs = list(CorpusDocStream(test).iter_from(0))
        serve = lambda docs: inf.posterior_docs(   # noqa: E731
            docs, double_buffer=not args.no_double_buffer)
        request = lambda rows: serve([ragged_docs[r] for r in rows])  # noqa: E731
    else:
        request = lambda rows: inf.posterior(      # noqa: E731
            test.take(jnp.asarray(rows)))

    # warmup: serve the whole test corpus once — every bucket width
    # compiles here, so the timed loop measures steady-state latency
    if args.requests:
        request(np.arange(test.num_docs))

    # the timed loop only — warmup latencies (compiles) stay out of the
    # histogram, preserving the old steady-state report semantics
    t0 = time.perf_counter()
    for _ in range(args.requests):
        rows = rng.choice(test.num_docs, size=args.batch, replace=False)
        t1 = time.perf_counter()
        gamma = request(rows)
        reg.observe("serve.request_ms", (time.perf_counter() - t1) * 1e3)
        assert gamma.shape == (args.batch, lda.cfg.num_topics)
    wall = time.perf_counter() - t0

    pct = reg.percentiles("serve.request_ms")   # NaNs on an empty run
    lat = reg.histogram_values("serve.request_ms")
    docs = args.requests * args.batch
    mode = ("ragged" + ("" if args.no_double_buffer else "+double-buffer")
            if args.ragged else "corpus")
    mode = f"{inf.layout}/{mode}"
    if lat:
        print(f"served {args.requests} requests × {args.batch} docs "
              f"backend={inf.cfg.estep_backend} [{mode}]: "
              f"{docs / wall:.1f} docs/s")
        print(f"latency ms: p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
              f"p99={pct['p99']:.1f} max={max(lat):.1f}")
        pad = inf.padding_stats()
        print(f"padding: frac={pad['pad_frac']:.3f} "
              f"wasted={pad['wasted_token_bytes'] / 1e3:.1f}kB staged "
              f"({pad['padded_slots'] - pad['live_slots']} of "
              f"{pad['padded_slots']} slots dead)")
    else:
        print("served 0 requests — skipping the latency report")
    cache = inf.cache_info()
    print(f"jit cache: {cache['jit_entries']} compiled widths "
          f"{cache['compiled_widths']} "
          f"(batches per width: {cache['batches_per_width']})")
    if args.trace:
        n = tel.trace.dump_jsonl(args.trace)
        print(f"trace: wrote {n} records to {args.trace}")
    if args.metrics_json:
        reg.dump_json(args.metrics_json)
        print(f"metrics: wrote {args.metrics_json}")
    if args.out:
        rec = {"mode": "serve", "backend": inf.cfg.estep_backend,
               "serve_mode": mode,
               "batch": args.batch, "requests": args.requests,
               "docs_per_s": docs / wall if lat else 0.0,
               "latency_ms": pct,
               "jit_widths": cache["compiled_widths"],
               "batches_per_width": cache["batches_per_width"],
               "layout": inf.layout,
               "padding": inf.padding_stats(), "ok": True}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
