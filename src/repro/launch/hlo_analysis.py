"""Structural analysis of compiled (SPMD, per-device) HLO text.

``jax``'s ``compiled.cost_analysis()`` counts each ``while`` body **once**,
but every ``lax.scan`` (layer stacks, attention q-chunks, SSD chunk scans)
lowers to a while loop — so raw cost_analysis under-counts FLOPs by the trip
counts. This module parses the HLO text instead:

* builds the computation call graph (while bodies/conditions, fusions,
  calls) and recovers each while loop's **trip count** from the constant in
  its condition's compare;
* multiplies instruction costs by the product of enclosing trip counts;
* FLOPs: every ``dot`` = 2 × numel(result) × Π contracting dims (the MXU
  term — elementwise FLOPs are ignored, they are bandwidth-bound anyway);
* collective bytes: Σ max(result, operand) bytes per all-gather/all-reduce/
  reduce-scatter/all-to-all/collective-permute, trip-multiplied — the
  per-device ICI traffic proxy;
* HBM bytes: Σ (unique operand bytes + result bytes) over dot instructions
  plus entry parameter bytes — a structural upper-ish bound on HBM traffic
  (fusion reuse is invisible in text form; documented in EXPERIMENTS.md).

All quantities are **per device** (SPMD HLO is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# header like: %wide.region_3 (param: (s32[], bf16[...])) -> (...) {
# params may contain nested parens (tuple types) — match only the name.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape text like 'f32[8,128]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    text: str
    comp: str


@dataclasses.dataclass
class HLOModule:
    comps: Dict[str, List[Instr]]
    entry: str
    defs: Dict[str, str]          # instruction name → result shape text


def parse_module(text: str) -> HLOModule:
    comps: Dict[str, List[Instr]] = {}
    defs: Dict[str, str] = {}
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.startswith("HloModule"):
            continue
        if "->" in stripped and stripped.endswith("{") \
                and not _INSTR_RE.match(stripped):
            m = _COMP_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if stripped.startswith("ENTRY"):
                    entry = cur
            continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(stripped)
        if mi:
            rhs = mi.group(2)
            opm = re.search(r"\}?\s*([a-z][\w\-]*)\(", rhs)
            op = opm.group(1) if opm else ""
            name = mi.group(1)
            comps[cur].append(Instr(name, op, stripped, cur))
            sm = _SHAPE_RE.search(rhs)
            if sm:
                # result shape text up to the op token (covers tuples too)
                cut = rhs.find(" " + op + "(") if op else -1
                defs[name] = rhs[:cut] if cut > 0 else sm.group(0)
    return HLOModule(comps=comps, entry=entry, defs=defs)


def _called_comps(instr: Instr) -> List[str]:
    """Computations referenced by this instruction (body/cond/calls/fusion)."""
    out = []
    for key in ("body", "condition", "to_apply", "calls", "branch_computations"):
        for m in re.finditer(key + r"=\{?%?([\w\.\-]+)", instr.text):
            out.append(m.group(1))
        for m in re.finditer(key + r"=\{([^}]*)\}", instr.text):
            out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


def _while_trip_count(mod: HLOModule, cond_name: str) -> int:
    """Recover trip count from the condition's compare-with-constant.

    XLA may wrap the compare in a fused computation (``wrapped_compare``);
    the loop-bound constant stays in the condition computation itself, so the
    robust recovery is: largest positive integer constant reachable from the
    condition (conditions are tiny — counter, bound, compare).
    """
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        name = stack.pop()
        if name in seen or name not in mod.comps:
            continue
        seen.add(name)
        for ins in mod.comps[name]:
            m = re.search(r"constant\((\d+)\)", ins.text)
            if m:
                best = max(best, int(m.group(1)))
            stack.extend(_called_comps(ins))
    return best


def _edges(mod: HLOModule) -> Dict[str, List[Tuple[str, float]]]:
    """caller → [(callee, weight)]; while bodies weighted by trip count."""
    out: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for comp, instrs in mod.comps.items():
        for ins in instrs:
            if ins.op == "while":
                bodym = re.search(r"body=%?([\w\.\-]+)", ins.text)
                condm = re.search(r"condition=%?([\w\.\-]+)", ins.text)
                trip = _while_trip_count(mod, condm.group(1)) if condm else 1
                if bodym:
                    out[comp].append((bodym.group(1), float(trip)))
                if condm:
                    out[comp].append((condm.group(1), float(trip + 1)))
                continue
            for callee in _called_comps(ins):
                if callee in mod.comps:
                    out[comp].append((callee, 1.0))
    return out


def _multipliers(mod: HLOModule) -> Dict[str, float]:
    """Effective execution multiplier per computation.

    The call graph is a DAG; propagate trip-count products in topological
    order (Kahn) so computations with several callers accumulate fully
    before their own callees are visited.
    """
    edges = _edges(mod)
    indeg: Dict[str, int] = defaultdict(int)
    for comp, outs in edges.items():
        for callee, _ in outs:
            indeg[callee] += 1
    mult: Dict[str, float] = defaultdict(float)
    mult[mod.entry] = 1.0
    queue = [c for c in mod.comps if indeg[c] == 0]
    while queue:
        comp = queue.pop()
        for callee, w in edges.get(comp, []):
            mult[callee] += mult[comp] * w
            indeg[callee] -= 1
            if indeg[callee] == 0:
                queue.append(callee)
    return dict(mult)


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start")

_LOOP_PRIMS = ("while", "scan")


# jnp-side *compute* on a (B, L, K)-rank array — data staging (gather /
# pad / broadcast / reshape / transpose) feeding a kernel is excluded: XLA
# fuses it into the operand read, and the issue is arithmetic round-trips.
_ARITH_PRIMS = frozenset({
    "add", "sub", "mul", "div", "pow", "integer_pow", "exp", "log", "neg",
    "max", "min", "select_n", "rsqrt", "sqrt", "tanh", "logistic",
    "reduce_sum", "reduce_max", "dot_general",
})


def pallas_call_sites(fn, *args, **kwargs) -> Dict[str, int]:
    """Count Pallas kernel-launch sites in ``fn``'s jaxpr.

    Returns ``{"total": n, "under_loop": m, "blk_intermediates": i}``:
    ``under_loop`` counts sites nested inside a ``while``/``scan`` — a
    kernel there launches once per trip (the pre-fusion E-step paid one
    launch per fixed-point sweep; the fused path must report 0) — and
    ``blk_intermediates`` counts rank-≥3 *arithmetic* results outside any
    kernel (the (B, L, K) jnp intermediates the fused memo correction
    eliminates; kernel-internal VMEM math is not walked).

    Structure is counted at jaxpr level rather than in compiled HLO
    because interpret-mode Pallas (CPU CI) inlines kernels into plain HLO
    ops; on TPU each site lowers to exactly one Mosaic custom-call, so the
    count equals the compiled launch-site count there.
    """
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs) if callable(fn) else fn
    counts = {"total": 0, "under_loop": 0, "blk_intermediates": 0}

    def sub_jaxprs(eqn):
        for v in eqn.params.values():
            if isinstance(v, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                yield v
            elif isinstance(v, (tuple, list)):
                for x in v:
                    if isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                        yield x

    def walk(jx, in_loop):
        if isinstance(jx, jax.core.ClosedJaxpr):
            jx = jx.jaxpr
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "pallas_call":
                counts["total"] += 1
                if in_loop:
                    counts["under_loop"] += 1
                continue              # kernel-internal math lives in VMEM
            if name in _ARITH_PRIMS and any(
                    getattr(ov.aval, "ndim", 0) >= 3 for ov in eqn.outvars):
                counts["blk_intermediates"] += 1
            for sub in sub_jaxprs(eqn):
                walk(sub, in_loop or name in _LOOP_PRIMS)

    walk(jaxpr, False)
    return counts


def dense_vocab_cubes(fn, vocab_size: int, *args, **kwargs) -> int:
    """Count rank-≥3 jaxpr values carrying a vocab-sized axis.

    The one-hot ``memo_delta`` emitted (nb, V, K) scatter partials — rank-3
    arrays with a (padded) vocab axis that exist only to be reduced. The
    segment-sum path must produce **zero** such values: its (V, K) masses
    are rank 2 and its only rank-3 arrays are (B, L, K) token cubes. An
    axis counts as vocab-sized only inside the lane-padding window
    ``[V, round_up(V, 128)]`` — the extent a vocab axis can actually take
    in the launch structure — NOT for any axis ≥ V, or a long token axis
    (L ≥ V is routine for small-vocab shapes) would trip the guard.
    """
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs) if callable(fn) else fn
    vocab_pad = ((vocab_size + 127) // 128) * 128
    count = 0

    def sub_jaxprs(eqn):
        for v in eqn.params.values():
            if isinstance(v, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                yield v
            elif isinstance(v, (tuple, list)):
                for x in v:
                    if isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)):
                        yield x

    def walk(jx):
        nonlocal count
        if isinstance(jx, jax.core.ClosedJaxpr):
            jx = jx.jaxpr
        for eqn in jx.eqns:
            for ov in eqn.outvars:
                shape = getattr(ov.aval, "shape", ())
                if len(shape) >= 3 and any(vocab_size <= d <= vocab_pad
                                           for d in shape):
                    count += 1
            for sub in sub_jaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    return count


def _dot_flops(ins: Instr, defs: Dict[str, str]) -> float:
    """2 × numel(result) × contraction size for a dot instruction.

    Compiled HLO references operands by name only, so the lhs shape is
    resolved through the module-wide symbol table ``defs``.
    """
    lhs_c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.text)
    shape_part = ins.text.split("=", 1)[1]
    _, res_dims = _shape_dims(shape_part)
    argm = re.search(r"dot\(([^)]*)\)", ins.text)
    if not argm:
        return 0.0
    arg_txt = argm.group(1)
    if _SHAPE_RE.search(arg_txt):
        # operands carry inline shapes (xla in jax<=0.4): first shape = lhs
        lhs_txt = arg_txt
    else:
        # name-only operands: resolve through the module symbol table
        lhs_txt = defs.get(arg_txt.split(",")[0].strip().lstrip("%"), "")
    cdim = 1
    if lhs_c and lhs_txt:
        _, lhs_dims = _shape_dims(lhs_txt)
        for ci in lhs_c.group(1).split(","):
            if ci != "" and int(ci) < len(lhs_dims):
                cdim *= lhs_dims[int(ci)]
    res_n = 1
    for d in res_dims:
        res_n *= d
    return 2.0 * res_n * cdim


def analyze(text: str, top_k: int = 0) -> Dict[str, object]:
    """Roofline inputs from per-device SPMD HLO text.

    ``top_k`` > 0 additionally returns the heaviest individual collectives
    and dots (multiplier-weighted) for bottleneck hunting.
    """
    mod = parse_module(text)
    mult = _multipliers(mod)
    flops = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    dot_bytes = 0.0
    param_bytes = 0.0
    top_coll: List[Tuple[float, str]] = []
    top_dot: List[Tuple[float, str]] = []
    for comp, instrs in mod.comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        for ins in instrs:
            if ins.op == "dot":
                fl = m * _dot_flops(ins, mod.defs)
                flops += fl
                dot_bytes += m * _shape_bytes(ins.text)
                if top_k:
                    top_dot.append((fl, f"x{m:g} {ins.text[:140]}"))
            elif ins.op in _COLLECTIVES:
                key = ins.op.replace("-start", "")
                by = m * _shape_bytes(ins.text.split("=", 1)[1])
                coll_bytes[key] += by
                if top_k:
                    top_coll.append((by, f"x{m:g} {ins.text[:140]}"))
            elif ins.op == "parameter" and comp == mod.entry:
                param_bytes += _shape_bytes(ins.text.split("=", 1)[1])
    out: Dict[str, object] = {
        "dot_flops": flops,
        "dot_bytes": dot_bytes,
        "param_bytes": param_bytes,
        "collective_bytes": sum(coll_bytes.values()),
        **{f"coll_{k}": v for k, v in sorted(coll_bytes.items())},
    }
    if top_k:
        out["top_collectives"] = sorted(top_coll, reverse=True)[:top_k]
        out["top_dots"] = sorted(top_dot, reverse=True)[:top_k]
    return out
