import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's own workload at the Arxiv corpus scale of
Table 1 (V=141,927; K=100 padded to 128; 782k documents).

Three modes:

* ``divi`` — one D-IVI global round on the production mesh: λ / ⟨m_vk⟩
  model-sharded on V (DESIGN.md §5); per-worker memo stores and the
  streamed (W, S, B, L) batch slabs data-sharded — no corpus is device
  state. Reports memory + roofline terms like the transformer dry-run.
* ``ivi`` — the single-host IVI hot step (`engines.incremental_update`)
  lowered with the fused Pallas E-step backend, plus the MemoStore
  footprint math: the device program only ever sees one mini-batch of the
  memo (the store lives in host RAM), and the bf16 chunked store holds the
  full Arxiv memo under the 40 GB single-host budget. Also reports the
  kernel-launch structure (one fused ``pallas_call`` per fixed point, none
  under a loop — docs/estep.md).
* ``serve`` — the ``LDA.transform`` serving step (`repro.lda.infer`)
  lowered per bucket width at Arxiv V with the fused backend: the
  per-width jit cache the `launch/serve_lda.py` request loop runs on.

Usage: python -m repro.launch.dryrun_lda [--mode divi|ivi|serve|all]
       [--mesh single|multi|both] [--batch 1024] [--staleness 1]
       [--out results/lda.jsonl]
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.memo import memo_footprint_bytes
from repro.core.types import GlobalState, LDAConfig
from repro.dist.divi import (DIVIConfig, DIVIState, WorkerShard,
                             make_divi_round)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

# Arxiv (Table 1): 782,385 train docs, V=141,927, avg 116 words/doc.
ARXIV = dict(num_docs=782_384, vocab=141_952,       # padded: /16 divisible
             max_unique=128, topics=128)            # K=100 → 128 lanes


def lower_round(mesh, batch: int, staleness: int):
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_workers = 1
    for a in data_axes:
        n_workers *= mesh.shape[a]
    docs_per_worker = ARXIV["num_docs"] // n_workers
    v, k, L = ARXIV["vocab"], ARXIV["topics"], ARXIV["max_unique"]

    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=100)
    dcfg = DIVIConfig(num_workers=n_workers, batch_size=batch,
                      staleness=staleness)
    rnd = make_divi_round(cfg, dcfg, mesh, data_axes)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    dspec = P(data_axes)
    state = DIVIState(
        lam=sds((v, k), jnp.float32, P("model", None)),
        m_vk=sds((v, k), jnp.float32, P("model", None)),
        init_mass=sds((v, k), jnp.float32, P("model", None)),
        init_frac=sds((), jnp.float32, P()),
        t=sds((), jnp.int32, P()),
    )
    from repro.core.memo import DenseMemoStore
    shard = WorkerShard(
        memo=DenseMemoStore(
            pi=sds((n_workers, docs_per_worker, L, k), jnp.float32,
                   P(data_axes, None, None, None)),
            visited=sds((n_workers, docs_per_worker), jnp.bool_,
                        P(data_axes, None))),
    )
    # per-round streamed batches — the argument footprint is (W, S, B, L)
    # slabs pulled by each worker's ingest, not a resident corpus
    ids = sds((n_workers, staleness, batch, L), jnp.int32,
              P(data_axes, None, None, None))
    cnts = sds((n_workers, staleness, batch, L), jnp.float32,
               P(data_axes, None, None, None))
    idx = sds((n_workers, staleness, batch), jnp.int32,
              P(data_axes, None, None))
    delay = sds((n_workers, staleness), jnp.bool_, P(data_axes, None))
    nw = sds((), jnp.float32, P())
    return rnd.lower(state, shard, ids, cnts, idx, delay, nw), n_workers


def run(mesh_kind: str, batch: int, staleness: int):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    out = {"arch": "lda-divi-arxiv", "shape": f"b{batch}_s{staleness}",
           "mesh": mesh_kind, "chips": mesh.devices.size}
    t0 = time.time()
    try:
        lowered, n_workers = lower_round(mesh, batch, staleness)
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        out["memory"] = {"temp_gb": mem.temp_size_in_bytes / 1e9,
                         "argument_gb": mem.argument_size_in_bytes / 1e9}
        hlo = hlo_analysis.analyze(compiled.as_text())
        out["hlo"] = hlo
        out["roofline"] = {
            "compute_s": hlo["dot_flops"] / HW["peak_flops"],
            "memory_s": max(hlo["dot_bytes"], hlo["param_bytes"])
            / HW["hbm_bw"],
            "collective_s": hlo["collective_bytes"] / HW["ici_bw"],
        }
        out["workers"] = n_workers
        out["ok"] = True
    except Exception as e:  # noqa: BLE001
        out["ok"] = False
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-1500:]
    return out


def run_ivi(batch: int, estep_iters: int = 50):
    """Lower the single-host IVI hot step at Arxiv scale, fused backend."""
    from repro.core.engines import incremental_update

    v, k, L, D = (ARXIV["vocab"], ARXIV["topics"], ARXIV["max_unique"],
                  ARXIV["num_docs"])
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=estep_iters,
                    estep_backend="pallas", estep_stream_dtype="bfloat16")
    out = {"arch": "lda-ivi-arxiv", "shape": f"b{batch}", "mode": "ivi",
           "memo_store": "chunked-bf16"}
    t0 = time.time()
    try:
        sds = jax.ShapeDtypeStruct
        state = GlobalState(lam=sds((v, k), jnp.float32),
                            m_vk=sds((v, k), jnp.float32),
                            init_mass=sds((v, k), jnp.float32),
                            init_frac=sds((), jnp.float32),
                            t=sds((), jnp.int32))
        args = (state, sds((batch, L), jnp.int32),
                sds((batch, L), jnp.float32),
                sds((batch, L, k), jnp.float32),       # π_old from the store
                sds((batch,), jnp.bool_), sds((), jnp.float32),
                "bfloat16")                  # the chunked store's wire dtype
        out["kernel_sites"] = hlo_analysis.pallas_call_sites(
            lambda *a: incremental_update(cfg, False, *a, "bfloat16"),
            *args[:-1])
        lowered = incremental_update.lower(cfg, False, *args)
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        out["memory"] = {"temp_gb": mem.temp_size_in_bytes / 1e9,
                         "argument_gb": mem.argument_size_in_bytes / 1e9}
        # the memo itself never enters the device program — footprint math:
        out["memo_gb"] = {
            kind: memo_footprint_bytes(kind, D, L, k, vocab_size=v) / 1e9
            for kind in ("dense", "chunked", "gamma")}
        out["memo_under_40gb"] = out["memo_gb"]["chunked"] < 40.0
        out["ok"] = True
    except Exception as e:  # noqa: BLE001
        out["ok"] = False
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-1500:]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="all",
                    choices=["divi", "ivi", "serve", "all"])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    results = []
    if args.mode in ("divi", "all"):
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for mk in meshes:
            res = run(mk, args.batch, args.staleness)
            if res["ok"]:
                rf = res["roofline"]
                print(f"[OK ] lda-divi × {mk}  compile={res['compile_s']}s "
                      f"temp={res['memory']['temp_gb']:.2f}GB "
                      f"compute={rf['compute_s']:.2e}s "
                      f"coll={rf['collective_s']:.2e}s")
            else:
                print(f"[FAIL] lda-divi × {mk}: {res['error'][:200]}")
            results.append(res)
    if args.mode in ("ivi", "all"):
        res = run_ivi(args.batch)
        if res["ok"]:
            ks = res["kernel_sites"]
            mg = res["memo_gb"]
            print(f"[OK ] lda-ivi single-host  compile={res['compile_s']}s "
                  f"kernels={ks['total']} under_loop={ks['under_loop']} "
                  f"blk_jnp={ks['blk_intermediates']} "
                  f"memo dense={mg['dense']:.1f}GB "
                  f"chunked={mg['chunked']:.1f}GB "
                  f"gamma={mg['gamma']:.2f}GB "
                  f"(<40GB: {res['memo_under_40gb']})")
        else:
            print(f"[FAIL] lda-ivi: {res['error'][:200]}")
        results.append(res)
    if args.mode in ("serve", "all"):
        from repro.launch.serve_lda import run_serve_dryrun
        res = run_serve_dryrun(batch=min(args.batch, 256))
        if res["ok"]:
            worst = max(m["temp_gb"] for m in res["memory"].values())
            print(f"[OK ] lda-serve single-host  compile={res['compile_s']}s "
                  f"widths={res['widths']} max_temp={worst:.2f}GB "
                  f"jit_entries={res['jit_cache_entries']}")
        else:
            print(f"[FAIL] lda-serve: {res['error'][:200]}")
        results.append(res)
    if args.out:
        with open(args.out, "a") as f:
            for res in results:
                f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
