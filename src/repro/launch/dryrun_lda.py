import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's own workload: one D-IVI global round on the
production mesh, at the Arxiv corpus scale of Table 1 (V=141,927; K=100
padded to 128; 782k documents sharded over the data axes).

λ / ⟨m_vk⟩ are model-sharded on V (DESIGN.md §5); per-worker corpus shards
and memos are data-sharded. Reports memory + roofline terms like the
transformer dry-run.

Usage: python -m repro.launch.dryrun_lda [--mesh single|multi|both]
       [--batch 1024] [--staleness 1] [--out results/lda.jsonl]
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.types import LDAConfig
from repro.dist.divi import (DIVIConfig, DIVIState, WorkerShard,
                             make_divi_round)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}

# Arxiv (Table 1): 782,385 train docs, V=141,927, avg 116 words/doc.
ARXIV = dict(num_docs=782_384, vocab=141_952,       # padded: /16 divisible
             max_unique=128, topics=128)            # K=100 → 128 lanes


def lower_round(mesh, batch: int, staleness: int):
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_workers = 1
    for a in data_axes:
        n_workers *= mesh.shape[a]
    docs_per_worker = ARXIV["num_docs"] // n_workers
    v, k, L = ARXIV["vocab"], ARXIV["topics"], ARXIV["max_unique"]

    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=100)
    dcfg = DIVIConfig(num_workers=n_workers, batch_size=batch,
                      staleness=staleness)
    rnd = make_divi_round(cfg, dcfg, mesh, data_axes)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    dspec = P(data_axes)
    state = DIVIState(
        lam=sds((v, k), jnp.float32, P("model", None)),
        m_vk=sds((v, k), jnp.float32, P("model", None)),
        init_mass=sds((v, k), jnp.float32, P("model", None)),
        init_frac=sds((), jnp.float32, P()),
        t=sds((), jnp.int32, P()),
    )
    shard = WorkerShard(
        token_ids=sds((n_workers, docs_per_worker, L), jnp.int32,
                      P(data_axes, None, None)),
        counts=sds((n_workers, docs_per_worker, L), jnp.float32,
                   P(data_axes, None, None)),
        pi=sds((n_workers, docs_per_worker, L, k), jnp.float32,
               P(data_axes, None, None, None)),
        visited=sds((n_workers, docs_per_worker), jnp.bool_,
                    P(data_axes, None)),
    )
    idx = sds((n_workers, staleness, batch), jnp.int32,
              P(data_axes, None, None))
    delay = sds((n_workers, staleness), jnp.bool_, P(data_axes, None))
    nw = sds((), jnp.float32, P())
    return rnd.lower(state, shard, idx, delay, nw), n_workers


def run(mesh_kind: str, batch: int, staleness: int):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    out = {"arch": "lda-divi-arxiv", "shape": f"b{batch}_s{staleness}",
           "mesh": mesh_kind, "chips": mesh.devices.size}
    t0 = time.time()
    try:
        lowered, n_workers = lower_round(mesh, batch, staleness)
        compiled = lowered.compile()
        out["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        out["memory"] = {"temp_gb": mem.temp_size_in_bytes / 1e9,
                         "argument_gb": mem.argument_size_in_bytes / 1e9}
        hlo = hlo_analysis.analyze(compiled.as_text())
        out["hlo"] = hlo
        out["roofline"] = {
            "compute_s": hlo["dot_flops"] / HW["peak_flops"],
            "memory_s": max(hlo["dot_bytes"], hlo["param_bytes"])
            / HW["hbm_bw"],
            "collective_s": hlo["collective_bytes"] / HW["ici_bw"],
        }
        out["workers"] = n_workers
        out["ok"] = True
    except Exception as e:  # noqa: BLE001
        out["ok"] = False
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-1500:]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        res = run(mk, args.batch, args.staleness)
        if res["ok"]:
            rf = res["roofline"]
            print(f"[OK ] lda-divi × {mk}  compile={res['compile_s']}s "
                  f"temp={res['memory']['temp_gb']:.2f}GB "
                  f"compute={rf['compute_s']:.2e}s "
                  f"coll={rf['collective_s']:.2e}s")
        else:
            print(f"[FAIL] lda-divi × {mk}: {res['error'][:200]}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
