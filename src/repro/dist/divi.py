"""D-IVI production path: one global round as a ``shard_map`` program.

Sharding layout on a ``("data", "model")`` (optionally ``("pod", "data",
"model")``) mesh — DESIGN mirrors the paper's master/worker message flow:

* **master state** λ / ⟨m_vk⟩ / init_mass: model-sharded on V
  (``P("model", None)``) — the master is itself distributed over the model
  axis; scalars (init_frac, t) replicated;
* **worker state** (the π-memo shards) and the per-round inputs (the
  streamed token_ids/counts batches, idx, delay): data-sharded on the
  leading worker axis. The corpus is NOT device state — each worker's
  ``WorkerIngest`` streams one ``(S, B, L)`` slab of documents into the
  round, so the argument footprint is per-round batches, not a resident
  ``(W, D_w, L)`` corpus;
* each sub-round reduces the (V, K) corrections with **one psum over the
  data axes** — the same single message the paper's workers send to the
  master — and the λ fetch is one all-gather of the model-sharded rows.

The worker E-step runs on the *full* mini-batch of each worker (replicated
across the model axis). This is deliberate: the E-step's fixed-point stop
criterion couples the documents of a batch, so splitting a worker's batch
over the model axis would change its numerics — and bit-parity with the
single-device vmap simulation (``repro.dist.protocol.divi_round``) is the
correctness contract validated by ``tests/test_divi.py``. The two paths
share ``worker_correction`` / ``master_update`` verbatim; the only
difference is *where* the worker loop runs (vmap axis vs. data-mesh axis)
and how the corrections are reduced (``sum`` vs. ``psum``).
"""
from __future__ import annotations

import math
from functools import partial

import jax

try:                                      # jax >= 0.6: out of experimental
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.math import exp_dirichlet_expectation
from repro.core.memo import DenseMemoStore
from repro.core.types import LDAConfig
from repro.dist.protocol import (DIVIConfig, DIVIState, WorkerShard,
                                 divi_round, master_update,
                                 worker_correction)

__all__ = ["DIVIConfig", "DIVIState", "WorkerShard", "divi_round",
           "make_divi_round"]


def make_divi_round(cfg: LDAConfig, dcfg: DIVIConfig, mesh,
                    data_axes) -> jax.stages.Wrapped:
    """Build the jitted shard_map round for ``mesh``.

    Returns a callable/lowerable ``round(state, shard, token_ids, counts,
    idx, delay, num_words_total) -> (state, shard)`` with

      state: DIVIState — (V, K) leaves sharded ``P("model", None)``;
      shard: WorkerShard — leading worker axis sharded over ``data_axes``;
      token_ids/counts: (W, S, B, L) streamed batches, idx: (W, S, B)
      int32, delay: (W, S) bool — all data-sharded on the worker axis;
      num_words_total: () float32, replicated.
    """
    data_axes = tuple(data_axes)
    model = "model" if "model" in mesh.axis_names else None
    n_data = math.prod(int(mesh.shape[a]) for a in data_axes)
    if dcfg.num_workers % n_data:
        raise ValueError(
            f"num_workers={dcfg.num_workers} not divisible by the data-mesh "
            f"size {n_data} ({data_axes})")
    if model and cfg.vocab_size % int(mesh.shape[model]):
        raise ValueError(
            f"vocab_size={cfg.vocab_size} not divisible by the model axis "
            f"({int(mesh.shape[model])}) — pad V")

    mrow = P(model, None)
    state_specs = DIVIState(lam=mrow, m_vk=mrow, init_mass=mrow,
                            init_frac=P(), t=P())
    shard_specs = WorkerShard(
        memo=DenseMemoStore(pi=P(data_axes, None, None, None),
                            visited=P(data_axes, None)))
    in_specs = (state_specs, shard_specs,
                P(data_axes, None, None, None),      # token_ids (W, S, B, L)
                P(data_axes, None, None, None),      # counts    (W, S, B, L)
                P(data_axes, None, None),            # idx       (W, S, B)
                P(data_axes, None),                  # delay     (W, S)
                P())
    out_specs = (state_specs, shard_specs)

    def round_body(state, shard, token_ids, counts, idx, delay,
                   num_words_total):
        # "fetch λ from the master": all-gather the model-sharded rows, then
        # compute exp(E[ln φ]) exactly as the simulation does on the full λ.
        lam_full = (jax.lax.all_gather(state.lam, model, axis=0, tiled=True)
                    if model else state.lam)
        eb = exp_dirichlet_expectation(lam_full, axis=0)
        v_local = state.lam.shape[0]
        row0 = (jax.lax.axis_index(model) * v_local) if model else 0

        def substep(carry, xs):
            st, memo = carry
            ids_s, cnts_s, idx_s, delay_s = xs   # (W_loc, B, L) ×2, (W_loc,
            corr_w, words_w, memo = jax.vmap(    # B), (W_loc,)
                partial(worker_correction, cfg, eb))(
                    ids_s, cnts_s, memo, idx_s, delay_s)
            # "send the correction to the master": the round's one message.
            corr = corr_w.sum(0)
            words = words_w.sum()
            if data_axes:
                corr = jax.lax.psum(corr, data_axes)
                words = jax.lax.psum(words, data_axes)
            corr = jax.lax.dynamic_slice_in_dim(corr, row0, v_local, axis=0) \
                if model else corr
            st = master_update(cfg, st, corr, words, num_words_total)
            return (st, memo), None

        (state, memo), _ = jax.lax.scan(
            substep, (state, shard.memo),
            (token_ids.swapaxes(0, 1), counts.swapaxes(0, 1),
             idx.swapaxes(0, 1), delay.swapaxes(0, 1)))
        return state, WorkerShard(memo=memo)

    fn = shard_map(round_body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(0, 1))
