"""Host driver for D-IVI: corpus sharding, round sampling, path selection.

The engine owns everything that is host-side in the paper's system — the
assignment of documents to workers, the per-round mini-batch sampling and
the Bernoulli sleep/drop coin flips — and hands the resulting index arrays
to the jitted round. Both execution paths (single-device vmap simulation
and mesh shard_map) therefore consume bit-identical inputs from the same
seeded generator, which is what makes them comparable array-for-array.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.engines import init_engine_state
from repro.core.memo import DenseMemoStore
from repro.core.types import Corpus, LDAConfig
from repro.dist.divi import make_divi_round
from repro.dist.protocol import (DIVIConfig, DIVIState, WorkerShard,
                                 divi_round)
from repro.obs import as_telemetry


def shard_corpus(corpus: Corpus, num_workers: int,
                 num_topics: int) -> Tuple[WorkerShard, int]:
    """Split the corpus into ``num_workers`` contiguous document shards.

    The trailing ``num_docs % num_workers`` documents are dropped (every
    worker must hold the same shard shape for vmap/shard_map); with one
    worker the shard is the corpus in its original order, which is what
    makes the P=1 engine comparable to the single-host S-IVI step.
    """
    d = corpus.num_docs
    dw = d // num_workers
    if dw == 0:
        raise ValueError(f"corpus of {d} docs cannot feed "
                         f"{num_workers} workers")
    n = num_workers * dw
    ids = jnp.asarray(np.asarray(corpus.token_ids)[:n], jnp.int32)
    cnts = jnp.asarray(np.asarray(corpus.counts)[:n], jnp.float32)
    l = corpus.max_unique
    shard = WorkerShard(
        token_ids=ids.reshape(num_workers, dw, l),
        counts=cnts.reshape(num_workers, dw, l),
        # per-worker MemoStore shards: the dense device store with a
        # leading worker axis (vmap/shard_map peel it off)
        memo=DenseMemoStore(
            pi=jnp.zeros((num_workers, dw, l, num_topics), jnp.float32),
            visited=jnp.zeros((num_workers, dw), bool)),
    )
    return shard, dw


class DIVIEngine:
    """Paper §4 driver: P workers, staleness S, Bernoulli round-dropping.

    ``mesh=None`` runs the single-device vmap simulation; passing a mesh
    with a data axis (and optionally a ``"model"`` axis sharding V) runs the
    shard_map production path — same protocol, same numbers.
    """

    def __init__(self, cfg: LDAConfig, dcfg: DIVIConfig, corpus: Corpus, *,
                 seed: int = 0, mesh=None,
                 data_axes: Optional[Tuple[str, ...]] = None,
                 telemetry=None):
        self.cfg, self.dcfg = cfg, dcfg
        self.tel = as_telemetry(telemetry)
        self.rng = np.random.default_rng(seed)
        self.shard, self.docs_per_worker = shard_corpus(
            corpus, dcfg.num_workers, cfg.num_topics)
        if dcfg.batch_size > self.docs_per_worker:
            # sampling with replacement would put a document into a batch
            # twice, double-applying its memo delta — refuse instead
            raise ValueError(
                f"batch_size={dcfg.batch_size} exceeds the "
                f"{self.docs_per_worker} documents each of the "
                f"{dcfg.num_workers} workers holds; shrink the batch or the "
                f"worker count")
        # identical λ₀ to the single-host engines at the same seed —
        # DIVIState IS the canonical GlobalState, one constructor for both
        self.state = init_engine_state(cfg, jax.random.key(seed))
        # retire init mass against the sharded corpus' word total so the
        # retirement completes exactly after every shard is visited
        self.num_words_total = jnp.asarray(
            float(np.asarray(self.shard.counts).sum()), jnp.float32)
        self.mesh = mesh
        if mesh is None:
            self._round = jax.jit(partial(divi_round, cfg, dcfg),
                                  donate_argnums=(0, 1))
        else:
            if data_axes is None:
                data_axes = tuple(a for a in mesh.axis_names if a != "model")
            self._round = make_divi_round(cfg, dcfg, mesh, data_axes)
            model = "model" if "model" in mesh.axis_names else None
            mrow = NamedSharding(mesh, P(model, None))
            rep = NamedSharding(mesh, P())
            self.state = DIVIState(
                lam=jax.device_put(self.state.lam, mrow),
                m_vk=jax.device_put(self.state.m_vk, mrow),
                init_mass=jax.device_put(self.state.init_mass, mrow),
                init_frac=jax.device_put(self.state.init_frac, rep),
                t=jax.device_put(self.state.t, rep))
            dsh = lambda *rest: NamedSharding(mesh, P(tuple(data_axes), *rest))
            self.shard = WorkerShard(
                token_ids=jax.device_put(self.shard.token_ids,
                                         dsh(None, None)),
                counts=jax.device_put(self.shard.counts, dsh(None, None)),
                memo=DenseMemoStore(
                    pi=jax.device_put(self.shard.pi, dsh(None, None, None)),
                    visited=jax.device_put(self.shard.visited, dsh(None))))
        self.docs_seen = 0

    # -- rounds ------------------------------------------------------------
    def _sample_round(self) -> Tuple[np.ndarray, np.ndarray]:
        w, s, b = (self.dcfg.num_workers, self.dcfg.staleness,
                   self.dcfg.batch_size)
        dw = self.docs_per_worker
        idx = np.empty((w, s, b), np.int64)
        for i in range(w):
            for j in range(s):
                idx[i, j] = self.rng.choice(dw, size=b, replace=False)
        delay = self.rng.random((w, s)) < self.dcfg.delay_prob
        return idx, delay

    def run_round(self) -> None:
        """One global round: S sub-rounds of P concurrent worker batches."""
        tel = self.tel
        sp = tel.trace.begin("divi/round", workers=self.dcfg.num_workers,
                             staleness=self.dcfg.staleness) \
            if tel.enabled else None
        idx, delay = self._sample_round()
        self.state, self.shard = self._round(
            self.state, self.shard, jnp.asarray(idx, jnp.int32),
            jnp.asarray(delay), self.num_words_total)
        docs = int(self.dcfg.batch_size * (~delay).sum())
        self.docs_seen += docs
        if sp is not None:
            tel.trace.end(sp, sync=self.state.lam)
            m = tel.metrics
            m.inc("divi.rounds")
            m.inc("divi.docs", docs)
            m.inc("divi.dropped_batches", float(delay.sum()))

    # -- views -------------------------------------------------------------
    @property
    def lam(self) -> jax.Array:
        return self.state.lam
