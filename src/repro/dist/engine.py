"""Host driver for D-IVI: stream sharding, round ingest, path selection.

The engine owns everything that is host-side in the paper's system — the
assignment of documents to workers (`data.stream.ShardedDocStream`: each
worker owns a shard VIEW of the corpus ``DocStream``, never a resident
corpus slice), the per-round batch pulling/packing through each worker's
``WorkerIngest``, and the Bernoulli sleep/drop coin flips — and hands the
resulting batch arrays to the jitted round. Both execution paths
(single-device vmap simulation and mesh shard_map) therefore consume
bit-identical inputs from the same seeded generator and the same shard
cursors, which is what makes them comparable array-for-array. For the same
reason a stream-fed engine is bit-equal to one fed the materialized corpus:
packing is bit-transparent and the shard assignment is a pure function of
``(num_docs, num_workers, partitioner, seed)``.
"""
from __future__ import annotations

from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.engines import init_engine_state
from repro.core.memo import DenseMemoStore
from repro.core.types import LDAConfig
from repro.data.stream import ShardedDocStream, as_doc_stream
from repro.dist.divi import make_divi_round
from repro.dist.protocol import (DIVIConfig, DIVIState, WorkerIngest,
                                 WorkerShard, divi_round)
from repro.obs import as_telemetry


class DIVIEngine:
    """Paper §4 driver: P workers, staleness S, Bernoulli round-dropping.

    ``data`` is anything ``as_doc_stream`` accepts — a padded ``Corpus``,
    any ``DocStream`` (lazy UCI files included: beyond-host-RAM corpora
    stream straight into the distributed path), or a pre-built
    ``ShardedDocStream`` whose shard count must equal ``num_workers``.

    ``mesh=None`` runs the single-device vmap simulation; passing a mesh
    with a data axis (and optionally a ``"model"`` axis sharding V) runs the
    shard_map production path — same protocol, same numbers.
    """

    def __init__(self, cfg: LDAConfig, dcfg: DIVIConfig, data, *,
                 seed: int = 0, mesh=None,
                 data_axes: Optional[Tuple[str, ...]] = None,
                 telemetry=None):
        self.cfg, self.dcfg = cfg, dcfg
        self.tel = as_telemetry(telemetry)
        self.rng = np.random.default_rng(seed)
        if isinstance(data, ShardedDocStream):
            if data.num_shards != dcfg.num_workers:
                raise ValueError(
                    f"ShardedDocStream deals {data.num_shards} shards but "
                    f"DIVIConfig asks for {dcfg.num_workers} workers — the "
                    "assignment must be one shard per worker")
            self.sharded = data
        else:
            self.sharded = ShardedDocStream(
                as_doc_stream(data), dcfg.num_workers,
                partitioner=dcfg.partitioner, seed=dcfg.partition_seed)
        metrics = self.tel.metrics if self.tel.enabled else None
        self.ingest: List[WorkerIngest] = [
            WorkerIngest(self.sharded.shard(w), dcfg.batch_size,
                         metrics=metrics)
            for w in range(dcfg.num_workers)]
        sizes = self.sharded.shard_sizes
        if dcfg.batch_size > min(sizes):
            # a batch wider than its shard would wrap the cyclic shard
            # stream onto itself and put a document into the batch twice,
            # double-applying its memo delta — refuse instead
            raise ValueError(
                f"batch_size={dcfg.batch_size} exceeds the {min(sizes)} "
                f"documents the smallest of the {dcfg.num_workers} worker "
                "shards holds; shrink the batch or the worker count")
        self.max_unique = int(self.sharded.max_unique)
        # memo rows = the LARGEST shard (shards differ by at most one doc;
        # smaller shards never touch their trailing row) — no document is
        # dropped to equalize worker shapes
        self.docs_per_worker = max(sizes)
        # identical λ₀ to the single-host engines at the same seed —
        # DIVIState IS the canonical GlobalState, one constructor for both
        self.state = init_engine_state(cfg, jax.random.key(seed))
        self.shard = WorkerShard(memo=DenseMemoStore(
            pi=jnp.zeros((dcfg.num_workers, self.docs_per_worker,
                          self.max_unique, cfg.num_topics), jnp.float32),
            visited=jnp.zeros((dcfg.num_workers, self.docs_per_worker),
                              bool)))
        # retire init mass against the FULL stream's word total — every
        # document lands in exactly one shard, so retirement completes
        # exactly when every shard is covered
        self.num_words_total = jnp.asarray(float(self.sharded.base.num_words),
                                           jnp.float32)
        self.mesh = mesh
        if mesh is None:
            self._round = jax.jit(partial(divi_round, cfg, dcfg),
                                  donate_argnums=(0, 1))
        else:
            if data_axes is None:
                data_axes = tuple(a for a in mesh.axis_names if a != "model")
            self._round = make_divi_round(cfg, dcfg, mesh, data_axes)
            model = "model" if "model" in mesh.axis_names else None
            mrow = NamedSharding(mesh, P(model, None))
            rep = NamedSharding(mesh, P())
            self.state = DIVIState(
                lam=jax.device_put(self.state.lam, mrow),
                m_vk=jax.device_put(self.state.m_vk, mrow),
                init_mass=jax.device_put(self.state.init_mass, mrow),
                init_frac=jax.device_put(self.state.init_frac, rep),
                t=jax.device_put(self.state.t, rep))
            dsh = lambda *rest: NamedSharding(mesh, P(tuple(data_axes), *rest))
            self.shard = WorkerShard(memo=DenseMemoStore(
                pi=jax.device_put(self.shard.pi, dsh(None, None, None)),
                visited=jax.device_put(self.shard.visited, dsh(None))))
        self.docs_seen = 0

    # -- rounds ------------------------------------------------------------
    def _ingest_round(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
        """Flip the drop coins, then pull one packed batch per LIVE
        (worker, sub-round) slot from the worker's shard stream —
        sub-round-major, so worker w's batches arrive in its own stream
        order. Dropped slots stay zero-filled (an exact no-op in the
        round: zero counts contribute zero to every reduction and the
        masked memo write-back restores the gathered rows)."""
        w, s, b = (self.dcfg.num_workers, self.dcfg.staleness,
                   self.dcfg.batch_size)
        l = self.max_unique
        delay = self.rng.random((w, s)) < self.dcfg.delay_prob
        ids = np.zeros((w, s, b, l), np.int32)
        cnts = np.zeros((w, s, b, l), np.float32)
        idx = np.zeros((w, s, b), np.int64)
        for j in range(s):
            for i in range(w):
                if delay[i, j]:
                    continue      # a sleeping worker pulls nothing
                batch = self.ingest[i].next_batch()
                ids[i, j], cnts[i, j] = batch.token_ids, batch.counts
                idx[i, j] = batch.rows
        return ids, cnts, idx, delay

    def run_round(self) -> None:
        """One global round: S sub-rounds of P concurrent worker batches."""
        tel = self.tel
        sp = tel.trace.begin("divi/round", workers=self.dcfg.num_workers,
                             staleness=self.dcfg.staleness) \
            if tel.enabled else None
        ids, cnts, idx, delay = self._ingest_round()
        self.state, self.shard = self._round(
            self.state, self.shard, jnp.asarray(ids), jnp.asarray(cnts),
            jnp.asarray(idx, jnp.int32), jnp.asarray(delay),
            self.num_words_total)
        docs = int(self.dcfg.batch_size * (~delay).sum())
        self.docs_seen += docs
        if sp is not None:
            tel.trace.end(sp, sync=self.state.lam)
            m = tel.metrics
            m.inc("divi.rounds")
            m.inc("divi.docs", docs)
            m.inc("divi.dropped_batches", float(delay.sum()))

    # -- views -------------------------------------------------------------
    @property
    def lam(self) -> jax.Array:
        return self.state.lam
