"""Asynchronous distributed incremental variational inference (paper §4).

Two bit-comparable execution paths for the same master/worker protocol:

* ``repro.dist.protocol`` — round semantics, per-worker stream ingest
  (``WorkerIngest``) + the single-device vmap-over-workers simulation
  (delay/staleness experiments, tests);
* ``repro.dist.divi`` — the shard_map production path on a
  ``("data", "model")`` device mesh;
* ``repro.dist.engine`` — the host driver (stream sharding, round ingest,
  drop sampling, timing).

Documents reach workers as shard views of one ``DocStream``
(``repro.data.stream.ShardedDocStream``) — there is no materialize-then-
slice step. See ``docs/divi.md`` for the protocol write-up.
"""
from repro.dist.protocol import (DIVIConfig, DIVIState, WorkerIngest,
                                 WorkerShard, divi_round, master_update,
                                 worker_correction)
from repro.dist.divi import make_divi_round
from repro.dist.engine import DIVIEngine

__all__ = ["DIVIConfig", "DIVIState", "WorkerIngest", "WorkerShard",
           "DIVIEngine", "divi_round", "make_divi_round", "master_update",
           "worker_correction"]
