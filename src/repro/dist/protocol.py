"""D-IVI master/worker round semantics (paper §4), shared by both paths.

The paper's asynchronous distributed algorithm: *P* workers each own a
disjoint shard of the corpus and its π-memo; the master owns the global
state (λ, ⟨m_vk⟩, the un-retired random-init mass). A worker repeatedly

  1. fetches (possibly stale) topics λ from the master,
  2. runs the partial E-step on a mini-batch of *its own* documents,
     warm-starting γ from its memo (Alg. 1 lines 4–7),
  3. sends the subtract-old/add-new correction Σ_d cnt·(π_new − π_memo)
     back to the master — one (V, K) message.

Because the corrections are exact memo deltas they commute: the master can
fold them in *in any order and at any lag* and ⟨m_vk⟩ stays a faithful
(if slightly stale) accumulator — this is what makes the asynchronous
protocol correct where gradient-based schemes need care. The master folds
each reduced correction into the S-IVI Robbins–Monro update (eq. 5).

Workers go through the same two interfaces as the single-host engines:
the E-step via ``repro.core.estep`` backends (`memo_correction`) and the
π-memo via a ``MemoStore`` shard — each worker owns a ``DenseMemoStore``
whose pure ``gather``/``updated`` trace under vmap (simulation) and
shard_map (production) alike.

Round structure used here (identical in the vmap simulation and the
shard_map production path, see ``repro.dist.divi``):

* one *global round* = ``staleness`` sub-rounds;
* every worker runs all ``staleness`` mini-batches against the **round-
  start** λ, while the master's state advances one S-IVI update per
  sub-round — so corrections arrive at parameter lag 0..staleness−1,
  the paper's sleep/μ staleness model;
* each worker independently *drops* a sub-round with probability
  ``delay_prob`` (the paper's Fig. 5 sleep experiments): a dropped worker
  contributes no correction and leaves its memo untouched;
* a worker's own memo is never stale — workers own their documents, only
  the master parameters lag.

Host-side sampling (mini-batch indices, drop coin-flips) lives in
``DIVIEngine`` and is passed in as arrays, so the two execution paths are
driven by bit-identical inputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engines import (memo_correction, retire_init_frac,
                                sivi_global_update)
from repro.core.math import exp_dirichlet_expectation
from repro.core.memo import DenseMemoStore
from repro.core.types import GlobalState, LDAConfig


@dataclasses.dataclass(frozen=True)
class DIVIConfig:
    """Distribution hyper-parameters (hashable: usable as a jit static)."""

    num_workers: int = 4
    batch_size: int = 64
    delay_prob: float = 0.0   # P(worker drops a sub-round) — Fig. 5
    staleness: int = 1        # sub-rounds per global round (parameter lag)


# The master state IS the canonical engine state — one constructor set for
# single-host and distributed (``types.init_global_state``). In the
# shard_map path the (V, K) leaves hold this device's model-axis rows; the
# scalar leaves are replicated.
DIVIState = GlobalState


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WorkerShard:
    """Per-worker corpus shards and memo stores, leading axis = worker.

    ``memo`` is a ``DenseMemoStore`` whose leaves carry a leading (W,)
    worker axis — vmap/shard_map peel it off, so inside a worker the store
    methods see the plain per-worker (D_w, L, K) layout.
    """

    token_ids: jax.Array        # (W, D_w, L) int32 padded unique-token ids
    counts: jax.Array           # (W, D_w, L) float32 counts, 0 on padding
    memo: DenseMemoStore        # pi (W, D_w, L, K), visited (W, D_w)

    @property
    def pi(self) -> jax.Array:
        return self.memo.pi

    @property
    def visited(self) -> jax.Array:
        return self.memo.visited


def worker_correction(cfg: LDAConfig, eb: jax.Array, token_ids: jax.Array,
                      counts: jax.Array, memo: DenseMemoStore,
                      idx: jax.Array, delayed: jax.Array):
    """One worker, one mini-batch, against stale topics ``eb``.

    Args:
      eb: (V, K) exp(E[ln φ]) computed from the *round-start* λ.
      token_ids/counts/memo: this worker's full shard (no W axis).
      idx: (B,) local document indices into the shard — duplicate-free
        (a document appearing twice would double-apply its memo delta;
        ``DIVIEngine`` enforces batch_size ≤ docs-per-worker for this).
      delayed: () bool — this worker dropped the sub-round: it contributes
        nothing and its memo stays untouched (paper's sleep model).

    Returns (correction (V, K), first-visit word count, new memo store).
    """
    ids, cnts = token_ids[idx], counts[idx]
    old_pi, visited_rows = memo.gather(idx)
    corr, words, res = memo_correction(cfg, eb, ids, cnts, old_pi,
                                       visited_rows)

    live = ~delayed
    corr = jnp.where(live, corr, 0.0)
    words = jnp.where(live, words, 0.0)
    memo = memo.updated(idx, jnp.where(live, res.pi, old_pi),
                        visited_mask=jnp.broadcast_to(live, idx.shape))
    return corr, words, memo


def master_update(cfg: LDAConfig, state: DIVIState, corr: jax.Array,
                  words_retired: jax.Array,
                  num_words_total: jax.Array) -> DIVIState:
    """Fold the reduced correction into the S-IVI master step (eq. 5).

    ``corr`` and the (V, K) state leaves may be the local model-axis rows —
    the update is elementwise in V, so the sharded and replicated layouts
    share the exact single-host code path (and its float behaviour).
    """
    frac = retire_init_frac(state.init_frac, words_retired, num_words_total)
    lam, m_vk = sivi_global_update(cfg, state, corr, frac)
    return DIVIState(lam=lam, m_vk=m_vk, init_mass=state.init_mass,
                     init_frac=frac, t=state.t + 1)


def divi_round(cfg: LDAConfig, dcfg: DIVIConfig, state: DIVIState,
               shard: WorkerShard, idx: jax.Array, delay: jax.Array,
               num_words_total: jax.Array) -> Tuple[DIVIState, WorkerShard]:
    """One D-IVI global round — single-device vmap-over-workers simulation.

    Args:
      idx: (W, S, B) int32 per-worker local document indices.
      delay: (W, S) bool dropped-sub-round flags.

    All workers' E-steps use the round-start λ (``eb`` below); the master
    state advances one S-IVI update per sub-round, so sub-round *s* folds in
    corrections computed at parameter lag *s* — the staleness model.
    """
    eb = exp_dirichlet_expectation(state.lam, axis=0)

    def substep(carry, xs):
        st, memo = carry
        idx_s, delay_s = xs                                  # (W, B), (W,)
        corr_w, words_w, memo = jax.vmap(
            partial(worker_correction, cfg, eb))(
                shard.token_ids, shard.counts, memo, idx_s, delay_s)
        st = master_update(cfg, st, corr_w.sum(0), words_w.sum(),
                           num_words_total)
        return (st, memo), None

    (state, memo), _ = jax.lax.scan(
        substep, (state, shard.memo),
        (idx.swapaxes(0, 1), delay.swapaxes(0, 1)))
    return state, WorkerShard(token_ids=shard.token_ids, counts=shard.counts,
                              memo=memo)
