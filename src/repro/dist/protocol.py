"""D-IVI master/worker round semantics (paper §4), shared by both paths.

The paper's asynchronous distributed algorithm: *P* workers each own a
disjoint shard of the corpus and its π-memo; the master owns the global
state (λ, ⟨m_vk⟩, the un-retired random-init mass). A worker repeatedly

  1. fetches (possibly stale) topics λ from the master,
  2. runs the partial E-step on a mini-batch of *its own* documents,
     warm-starting γ from its memo (Alg. 1 lines 4–7),
  3. sends the subtract-old/add-new correction Σ_d cnt·(π_new − π_memo)
     back to the master — one (V, K) message.

Because the corrections are exact memo deltas they commute: the master can
fold them in *in any order and at any lag* and ⟨m_vk⟩ stays a faithful
(if slightly stale) accumulator — this is what makes the asynchronous
protocol correct where gradient-based schemes need care. The master folds
each reduced correction into the S-IVI Robbins–Monro update (eq. 5).

Worker state splits host/device along the streaming-ingest line:

* ``WorkerIngest`` (host) — one worker's shard view of the corpus
  ``DocStream`` (`data.stream.ShardedDocStream`), its single-rung
  ``BatchPacker`` and its pass cursor. Documents are pulled and packed
  per sub-round; no worker ever holds its corpus slice as a resident
  array. Cursor + open packer docs are the checkpointable ingest state.
* ``WorkerShard`` (device) — the per-worker π-memo shards only: a
  ``DenseMemoStore`` with a leading (W,) worker axis whose pure
  ``gather``/``updated`` trace under vmap (simulation) and shard_map
  (production) alike. Memo rows are shard-local document positions — the
  same positions the ingest stamps on packed batches.

Round structure used here (identical in the vmap simulation and the
shard_map production path, see ``repro.dist.divi``):

* one *global round* = ``staleness`` sub-rounds;
* every worker runs all ``staleness`` mini-batches against the **round-
  start** λ, while the master's state advances one S-IVI update per
  sub-round — so corrections arrive at parameter lag 0..staleness−1,
  the paper's sleep/μ staleness model;
* each worker independently *drops* a sub-round with probability
  ``delay_prob`` (the paper's Fig. 5 sleep experiments): a dropped worker
  pulls no documents, contributes no correction and leaves its memo
  untouched (its batch slot is zero-filled — zero counts contribute exact
  zeros to every reduction, and the masked memo write-back is a no-op);
* a worker's own memo is never stale — workers own their documents, only
  the master parameters lag.

Host-side work (batch pulling/packing, drop coin-flips) lives in
``DIVIEngine`` and is passed in as arrays, so the two execution paths are
driven by bit-identical inputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import (memo_correction, retire_init_frac,
                                sivi_global_update)
from repro.core.math import exp_dirichlet_expectation
from repro.core.memo import DenseMemoStore
from repro.core.types import GlobalState, LDAConfig
from repro.data.stream import BatchPacker, PackedBatch, ShardDocStream


@dataclasses.dataclass(frozen=True)
class DIVIConfig:
    """Distribution hyper-parameters (hashable: usable as a jit static).

    ``partitioner`` / ``partition_seed`` select how the corpus stream is
    dealt to workers (`data.stream.ShardedDocStream`): ``"range"`` =
    contiguous position blocks, ``"hash"`` = seeded round-robin by hashed
    position. They matter only when the engine builds the sharding itself
    (passing a pre-built ``ShardedDocStream`` overrides them).
    """

    num_workers: int = 4
    batch_size: int = 64
    delay_prob: float = 0.0   # P(worker drops a sub-round) — Fig. 5
    staleness: int = 1        # sub-rounds per global round (parameter lag)
    partitioner: str = "range"
    partition_seed: int = 0


# The master state IS the canonical engine state — one constructor set for
# single-host and distributed (``types.init_global_state``). In the
# shard_map path the (V, K) leaves hold this device's model-axis rows; the
# scalar leaves are replicated.
DIVIState = GlobalState


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WorkerShard:
    """Per-worker π-memo stores, leading axis = worker.

    ``memo`` is a ``DenseMemoStore`` whose leaves carry a leading (W,)
    worker axis — vmap/shard_map peel it off, so inside a worker the store
    methods see the plain per-worker (D_w, L, K) layout. Rows are
    shard-LOCAL document positions (``WorkerIngest`` batch rows); workers
    whose shard is smaller than the common D_w simply never touch the
    trailing rows. The corpus itself is not device state any more — it
    streams through ``WorkerIngest`` one mini-batch at a time.
    """

    memo: DenseMemoStore        # pi (W, D_w, L, K), visited (W, D_w)

    @property
    def pi(self) -> jax.Array:
        return self.memo.pi

    @property
    def visited(self) -> jax.Array:
        return self.memo.visited


class WorkerIngest:
    """Host-side ingest state of ONE worker: shard stream + packer + cursor.

    The packer is single-rung (``boundaries=()`` → one width = the memo's
    L): every emitted batch is a full ``(batch_size, L)`` ``PackedBatch``,
    which is what lets the W workers' batches stack into the uniform
    ``(W, S, B, L)`` arrays the vmap/shard_map round consumes. Emission is
    therefore exactly one batch per ``batch_size`` documents pulled, in
    shard-stream order; at shard exhaustion the cursor wraps (``passes``
    increments) and the packer keeps filling across the boundary — a batch
    never contains the same document twice as long as
    ``batch_size <= shard.num_docs`` (the engine enforces this).

    ``capture()``/``restore()`` persist the cursor, the pass counter and
    the open (not-yet-emitted) packer documents — the full mid-pass ingest
    state, mirroring the single-host stream checkpoint contract.
    """

    def __init__(self, stream: ShardDocStream, batch_size: int, *,
                 metrics=None):
        self.stream = stream
        self.batch_size = int(batch_size)
        self.width = int(stream.max_unique)
        self.cursor = 0             # documents pulled in the current pass
        self.passes = 0
        self.docs_pulled = 0        # lifetime counters (telemetry/bench)
        self.tokens_pulled = 0.0
        self._metrics = metrics
        self._packer = self._make_packer()
        self._iter = None

    def _make_packer(self) -> BatchPacker:
        return self.stream.make_packer(self.batch_size, boundaries=(),
                                       metrics=self._metrics)

    # -- pulling ---------------------------------------------------------
    def pull_doc(self) -> Optional[PackedBatch]:
        """Pull ONE document from the shard into the packer; returns the
        emitted batch when this document completes one, else None."""
        if self._iter is None:
            self._iter = self.stream.iter_from(self.cursor)
        try:
            ids, cnts = next(self._iter)
        except StopIteration:
            # pass boundary: the distributed round samples forever, so the
            # shard cycles — next pass revisits from local position 0
            self.cursor = 0
            self.passes += 1
            self._iter = self.stream.iter_from(0)
            ids, cnts = next(self._iter)
        pos = self.cursor
        self.cursor += 1
        self.docs_pulled += 1
        self.tokens_pulled += float(np.sum(cnts))
        return self._packer.add(pos, ids, cnts)

    def next_batch(self) -> PackedBatch:
        """Pull documents until one ``(batch_size, L)`` batch emits."""
        while True:
            batch = self.pull_doc()
            if batch is not None:
                return batch

    # -- checkpointing ---------------------------------------------------
    def capture(self) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
        """(json-able meta, ragged pending arrays) — everything needed to
        reconstruct this exact ingest state."""
        pend = self._packer.pending_docs()
        meta: Dict[str, Any] = {
            "cursor": int(self.cursor),
            "passes": int(self.passes),
            "docs_pulled": int(self.docs_pulled),
            "tokens_pulled": float(self.tokens_pulled),
            "pending_pos": [int(p) for p, _, _ in pend],
        }
        arrays: Dict[str, np.ndarray] = {}
        for i, (_pos, ids, cnts) in enumerate(pend):
            arrays[f"pend_{i:05d}_ids"] = np.asarray(ids, np.int32)
            arrays[f"pend_{i:05d}_cnts"] = np.asarray(cnts, np.float32)
        return meta, arrays

    def restore(self, meta: Dict[str, Any],
                arrays: Dict[str, np.ndarray]) -> None:
        packer = self._make_packer()
        packer.load_pending([
            (pos, arrays[f"pend_{i:05d}_ids"], arrays[f"pend_{i:05d}_cnts"])
            for i, pos in enumerate(meta["pending_pos"])])
        self._packer = packer
        self.cursor = int(meta["cursor"])
        self.passes = int(meta["passes"])
        self.docs_pulled = int(meta["docs_pulled"])
        self.tokens_pulled = float(meta["tokens_pulled"])
        self._iter = None            # re-seated lazily at the cursor


def worker_correction(cfg: LDAConfig, eb: jax.Array, token_ids: jax.Array,
                      counts: jax.Array, memo: DenseMemoStore,
                      idx: jax.Array, delayed: jax.Array):
    """One worker, one mini-batch, against stale topics ``eb``.

    Args:
      eb: (V, K) exp(E[ln φ]) computed from the *round-start* λ.
      token_ids/counts: (B, L) the worker's packed mini-batch (streamed in
        by ``WorkerIngest`` — the corpus is not device state).
      memo: this worker's memo shard (no W axis).
      idx: (B,) shard-local document positions of the batch rows —
        duplicate-free (a document appearing twice would double-apply its
        memo delta; ``DIVIEngine`` enforces batch_size <= shard size, which
        bounds any batch to one wrap of the cyclic shard stream).
      delayed: () bool — this worker dropped the sub-round: it contributes
        nothing and its memo stays untouched (paper's sleep model; the
        zero-filled placeholder batch makes the masked write-back exact).

    Returns (correction (V, K), first-visit word count, new memo store).
    """
    old_pi, visited_rows = memo.gather(idx)
    corr, words, res = memo_correction(cfg, eb, token_ids, counts, old_pi,
                                       visited_rows)

    live = ~delayed
    corr = jnp.where(live, corr, 0.0)
    words = jnp.where(live, words, 0.0)
    memo = memo.updated(idx, jnp.where(live, res.pi, old_pi),
                        visited_mask=jnp.broadcast_to(live, idx.shape))
    return corr, words, memo


def master_update(cfg: LDAConfig, state: DIVIState, corr: jax.Array,
                  words_retired: jax.Array,
                  num_words_total: jax.Array) -> DIVIState:
    """Fold the reduced correction into the S-IVI master step (eq. 5).

    ``corr`` and the (V, K) state leaves may be the local model-axis rows —
    the update is elementwise in V, so the sharded and replicated layouts
    share the exact single-host code path (and its float behaviour).
    """
    frac = retire_init_frac(state.init_frac, words_retired, num_words_total)
    lam, m_vk = sivi_global_update(cfg, state, corr, frac)
    return DIVIState(lam=lam, m_vk=m_vk, init_mass=state.init_mass,
                     init_frac=frac, t=state.t + 1)


def divi_round(cfg: LDAConfig, dcfg: DIVIConfig, state: DIVIState,
               shard: WorkerShard, token_ids: jax.Array, counts: jax.Array,
               idx: jax.Array, delay: jax.Array,
               num_words_total: jax.Array) -> Tuple[DIVIState, WorkerShard]:
    """One D-IVI global round — single-device vmap-over-workers simulation.

    Args:
      token_ids/counts: (W, S, B, L) the round's streamed worker batches
        (zero-filled in dropped (w, s) slots).
      idx: (W, S, B) int32 shard-local document positions per batch row.
      delay: (W, S) bool dropped-sub-round flags.

    All workers' E-steps use the round-start λ (``eb`` below); the master
    state advances one S-IVI update per sub-round, so sub-round *s* folds in
    corrections computed at parameter lag *s* — the staleness model.
    """
    eb = exp_dirichlet_expectation(state.lam, axis=0)

    def substep(carry, xs):
        st, memo = carry
        ids_s, cnts_s, idx_s, delay_s = xs       # (W, B, L) ×2, (W, B), (W,)
        corr_w, words_w, memo = jax.vmap(
            partial(worker_correction, cfg, eb))(
                ids_s, cnts_s, memo, idx_s, delay_s)
        st = master_update(cfg, st, corr_w.sum(0), words_w.sum(),
                           num_words_total)
        return (st, memo), None

    (state, memo), _ = jax.lax.scan(
        substep, (state, shard.memo),
        (token_ids.swapaxes(0, 1), counts.swapaxes(0, 1),
         idx.swapaxes(0, 1), delay.swapaxes(0, 1)))
    return state, WorkerShard(memo=memo)
