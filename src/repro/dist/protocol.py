"""D-IVI master/worker round semantics (paper §4), shared by both paths.

The paper's asynchronous distributed algorithm: *P* workers each own a
disjoint shard of the corpus and its π-memo; the master owns the global
state (λ, ⟨m_vk⟩, the un-retired random-init mass). A worker repeatedly

  1. fetches (possibly stale) topics λ from the master,
  2. runs the partial E-step on a mini-batch of *its own* documents,
     warm-starting γ from its memo (Alg. 1 lines 4–7),
  3. sends the subtract-old/add-new correction Σ_d cnt·(π_new − π_memo)
     back to the master — one (V, K) message.

Because the corrections are exact memo deltas they commute: the master can
fold them in *in any order and at any lag* and ⟨m_vk⟩ stays a faithful
(if slightly stale) accumulator — this is what makes the asynchronous
protocol correct where gradient-based schemes need care. The master folds
each reduced correction into the S-IVI Robbins–Monro update (eq. 5).

Round structure used here (identical in the vmap simulation and the
shard_map production path, see ``repro.dist.divi``):

* one *global round* = ``staleness`` sub-rounds;
* every worker runs all ``staleness`` mini-batches against the **round-
  start** λ, while the master's state advances one S-IVI update per
  sub-round — so corrections arrive at parameter lag 0..staleness−1,
  the paper's sleep/μ staleness model;
* each worker independently *drops* a sub-round with probability
  ``delay_prob`` (the paper's Fig. 5 sleep experiments): a dropped worker
  contributes no correction and leaves its memo untouched;
* a worker's own memo is never stale — workers own their documents, only
  the master parameters lag.

Host-side sampling (mini-batch indices, drop coin-flips) lives in
``DIVIEngine`` and is passed in as arrays, so the two execution paths are
driven by bit-identical inputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.engines import (memo_correction, retire_init_frac,
                                sivi_global_update)
from repro.core.math import exp_dirichlet_expectation
from repro.core.types import LDAConfig


@dataclasses.dataclass(frozen=True)
class DIVIConfig:
    """Distribution hyper-parameters (hashable: usable as a jit static)."""

    num_workers: int = 4
    batch_size: int = 64
    delay_prob: float = 0.0   # P(worker drops a sub-round) — Fig. 5
    staleness: int = 1        # sub-rounds per global round (parameter lag)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DIVIState:
    """Master variational state — mirrors ``EngineState`` field-for-field.

    In the shard_map path the (V, K) leaves hold this device's model-axis
    rows; the scalar leaves are replicated.
    """

    lam: jax.Array         # (V, K) topic-word Dirichlet parameter
    m_vk: jax.Array        # (V, K) incremental accumulator ⟨m_vk⟩
    init_mass: jax.Array   # (V, K) un-attributed random-init mass
    init_frac: jax.Array   # () share of init_mass still live in λ
    t: jax.Array           # () int32 master update counter (drives ρ_t)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WorkerShard:
    """Per-worker corpus shards and π-memos, leading axis = worker."""

    token_ids: jax.Array   # (W, D_w, L) int32 padded unique-token ids
    counts: jax.Array      # (W, D_w, L) float32 counts, 0 on padding
    pi: jax.Array          # (W, D_w, L, K) memoized responsibilities
    visited: jax.Array     # (W, D_w) bool — memo rows that are live


def worker_correction(cfg: LDAConfig, eb: jax.Array, token_ids: jax.Array,
                      counts: jax.Array, pi: jax.Array, visited: jax.Array,
                      idx: jax.Array, delayed: jax.Array):
    """One worker, one mini-batch, against stale topics ``eb``.

    Args:
      eb: (V, K) exp(E[ln φ]) computed from the *round-start* λ.
      token_ids/counts/pi/visited: this worker's full shard (no W axis).
      idx: (B,) local document indices into the shard — duplicate-free
        (a document appearing twice would double-apply its memo delta;
        ``DIVIEngine`` enforces batch_size ≤ docs-per-worker for this).
      delayed: () bool — this worker dropped the sub-round: it contributes
        nothing and its memo stays untouched (paper's sleep model).

    Returns (correction (V, K), first-visit word count, new pi, new visited).
    """
    ids, cnts = token_ids[idx], counts[idx]
    old_pi = pi[idx]                                         # (B, L, K)
    corr, words, res = memo_correction(cfg, eb, ids, cnts, old_pi,
                                       visited[idx])

    live = ~delayed
    corr = jnp.where(live, corr, 0.0)
    words = jnp.where(live, words, 0.0)
    pi = pi.at[idx].set(jnp.where(live, res.pi, old_pi))
    visited = visited.at[idx].set(visited[idx] | live)
    return corr, words, pi, visited


def master_update(cfg: LDAConfig, state: DIVIState, corr: jax.Array,
                  words_retired: jax.Array,
                  num_words_total: jax.Array) -> DIVIState:
    """Fold the reduced correction into the S-IVI master step (eq. 5).

    ``corr`` and the (V, K) state leaves may be the local model-axis rows —
    the update is elementwise in V, so the sharded and replicated layouts
    share the exact single-host code path (and its float behaviour).
    """
    frac = retire_init_frac(state.init_frac, words_retired, num_words_total)
    lam, m_vk = sivi_global_update(cfg, state, corr, frac)
    return DIVIState(lam=lam, m_vk=m_vk, init_mass=state.init_mass,
                     init_frac=frac, t=state.t + 1)


def divi_round(cfg: LDAConfig, dcfg: DIVIConfig, state: DIVIState,
               shard: WorkerShard, idx: jax.Array, delay: jax.Array,
               num_words_total: jax.Array) -> Tuple[DIVIState, WorkerShard]:
    """One D-IVI global round — single-device vmap-over-workers simulation.

    Args:
      idx: (W, S, B) int32 per-worker local document indices.
      delay: (W, S) bool dropped-sub-round flags.

    All workers' E-steps use the round-start λ (``eb`` below); the master
    state advances one S-IVI update per sub-round, so sub-round *s* folds in
    corrections computed at parameter lag *s* — the staleness model.
    """
    eb = exp_dirichlet_expectation(state.lam, axis=0)

    def substep(carry, xs):
        st, pi, vis = carry
        idx_s, delay_s = xs                                  # (W, B), (W,)
        corr_w, words_w, pi, vis = jax.vmap(
            partial(worker_correction, cfg, eb))(
                shard.token_ids, shard.counts, pi, vis, idx_s, delay_s)
        st = master_update(cfg, st, corr_w.sum(0), words_w.sum(),
                           num_words_total)
        return (st, pi, vis), None

    (state, pi, vis), _ = jax.lax.scan(
        substep, (state, shard.pi, shard.visited),
        (idx.swapaxes(0, 1), delay.swapaxes(0, 1)))
    return state, WorkerShard(token_ids=shard.token_ids, counts=shard.counts,
                              pi=pi, visited=vis)
