"""GQA self-attention: training (q-chunked causal) and KV-cache decode.

Features required by the assigned architectures: grouped-query attention,
rotary or no positions, sliding windows (gemma2 local layers and the
long-context variant), attention-logit softcaps (gemma2), QK-RMSNorm
(qwen3), QKV biases (qwen2/internvl), custom query scale (gemma2).

Training attention is computed in query chunks (``cfg.attn_chunk``) with a
``lax.scan`` so the (chunk, S) score tile is the only materialised score
buffer — flash-attention-style memory behaviour in pure JAX/XLA. Decode uses
a ring-buffer cache: ``slot_pos`` tracks the absolute position in each slot,
which makes the sliding-window mask implicit (overwritten slots simply fall
out of the window).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, apply_norm, rope, softcap, \
    truncated_normal

NEG_INF = -2.0 ** 30  # large-but-finite: keeps padded rows NaN-free


def attn_init(cfg: ModelConfig, key) -> Params:
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": truncated_normal(ks[0], (d, h, hd), d ** -0.5),
        "wk": truncated_normal(ks[1], (d, kv, hd), d ** -0.5),
        "wv": truncated_normal(ks[2], (d, kv, hd), d ** -0.5),
        "wo": truncated_normal(ks[3], (h, hd, d), (h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd))
        p["bk"] = jnp.zeros((kv, hd))
        p["bv"] = jnp.zeros((kv, hd))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dgk->btgk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dgk->btgk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = _rms(q) * p["q_norm"].astype(dt)
        k = _rms(k) * p["k_norm"].astype(dt)
    return q, k, v


def _rms(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
    return y.astype(x.dtype)


def _scale(cfg: ModelConfig) -> float:
    return (cfg.query_scale if cfg.query_scale is not None
            else cfg.resolved_head_dim ** -0.5)


# ---------------------------------------------------------------------------
# training path — q-chunked causal attention
# ---------------------------------------------------------------------------

def attention_train(cfg: ModelConfig, p: Params, x: jax.Array,
                    window: Optional[int] = None,
                    positions: Optional[jax.Array] = None) -> jax.Array:
    """Causal (optionally sliding-window) self-attention over full sequences.

    x: (B, S, D) → (B, S, D). S must be divisible by cfg.attn_chunk (callers
    pad); positions default to arange(S).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = h // kv
    q, k, v = _qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(s)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = q * _scale(cfg)

    # pad queries to the chunk grid; padded rows are sliced off afterwards
    # and padded keys are masked out by the causal test (their positions
    # exceed every real query position).
    c = min(cfg.attn_chunk, s)
    s_pad = ((s + c - 1) // c) * c
    kpos = jnp.broadcast_to(positions, (s,))
    qpos_all = jnp.concatenate(
        [kpos, kpos[-1] + 1 + jnp.arange(s_pad - s)]) if s_pad != s else kpos
    qg = q.reshape(b, s, kv, rep, hd)
    if s_pad != s:
        qg = jnp.pad(qg, ((0, 0), (0, s_pad - s), (0, 0), (0, 0), (0, 0)))
    nc = s_pad // c
    qc = qg.reshape(b, nc, c, kv, rep, hd).transpose(1, 0, 2, 3, 4, 5)
    pc = qpos_all.reshape(nc, c)

    # checkpointed: the (c, S) score tile is recomputed in the backward pass
    # instead of being saved per chunk — flash-attention memory behaviour.
    @jax.checkpoint
    def chunk(_, inp):
        qi, qpos = inp                                    # (B,c,kv,rep,hd),(c,)
        logits = jnp.einsum("bqgrk,bsgk->bgrqs", qi, k)   # (B,kv,rep,c,S)
        logits = softcap(logits, cfg.attn_logit_softcap)
        mask = qpos[:, None] >= kpos[None, :]             # causal (c, S)
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None, None], logits.astype(jnp.float32),
                           NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bgrqs,bsgk->bqgrk", w, v)       # (B,c,kv,rep,hd)
        return None, out

    _, outs = jax.lax.scan(chunk, None, (qc, pc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s_pad, h, hd)[:, :s]
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode path — ring-buffer KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, W, kv, hd) — rope already applied
    v: jax.Array          # (B, W, kv, hd)
    slot_pos: jax.Array   # (B, W) int32 absolute position per slot (−1 empty)


def init_cache(cfg: ModelConfig, batch: int, window: int,
               dtype=jnp.bfloat16) -> KVCache:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, window, kv, hd), dtype),
        v=jnp.zeros((batch, window, kv, hd), dtype),
        slot_pos=jnp.full((batch, window), -1, jnp.int32),
    )


def attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                     cache: KVCache, pos: jax.Array,
                     window: Optional[int] = None
                     ) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, D); pos: (B,) absolute positions.

    The new token's K/V overwrite slot ``pos % W`` (ring). Attention runs
    over the updated cache; masking = slot occupied ∧ (window if given).
    """
    b, _, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = h // kv
    w_slots = cache.k.shape[1]

    q, k, v = _qkv(cfg, p, x)                      # q (B,1,h,hd), k/v (B,1,kv,hd)
    if cfg.use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    q = q * _scale(cfg)

    slot = (pos % w_slots).astype(jnp.int32)       # (B,)
    bidx = jnp.arange(b)
    new_k = cache.k.at[bidx, slot].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[bidx, slot].set(v[:, 0].astype(cache.v.dtype))
    new_sp = cache.slot_pos.at[bidx, slot].set(pos.astype(jnp.int32))

    qg = q.reshape(b, kv, rep, hd)
    logits = jnp.einsum("bgrk,bsgk->bgrs", qg, new_k.astype(q.dtype))
    logits = softcap(logits, cfg.attn_logit_softcap)
    valid = new_sp >= 0                            # (B, W)
    valid &= new_sp <= pos[:, None]
    if window is not None:
        valid &= new_sp > (pos[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits.astype(jnp.float32),
                       NEG_INF)
    wgt = jax.nn.softmax(logits, axis=-1).astype(new_v.dtype)
    out = jnp.einsum("bgrs,bsgk->bgrk", wgt, new_v).reshape(b, 1, h, hd)
    y = jnp.einsum("bthk,hkd->btd", out.astype(x.dtype), p["wo"].astype(x.dtype))
    return y, KVCache(new_k, new_v, new_sp)
