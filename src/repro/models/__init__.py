from repro.models import attention, layers, moe, recurrent, transformer
