"""Generic decoder assembly for all assigned architectures.

The per-layer ``layer_pattern`` is segmented into *stages*: maximal
``(cycle, reps)`` chunks where the same cycle of block kinds repeats.
Parameters of a stage are stacked on a leading ``reps`` dim and applied with
``lax.scan`` — compile time scales with the number of distinct stages
(≤ 3 for every assigned arch), not with depth.

Supports: training forward (full sequence), single-token decode with
per-layer caches (KV ring buffers / recurrent states), MoE blocks via
shard_map islands (see ``repro.models.moe``), the zamba2 shared attention
block (one parameter set applied at many depths), VLM patch-embedding
prefixes and MusicGen multi-codebook embedding/readout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_PARALLEL, MAMBA2,
                                MAMBA2_SHARED, MLSTM, MOE, SLSTM,
                                ModelConfig, effective_window)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.layers import (Params, apply_mlp, apply_norm, mlp_init,
                                 norm_init, rope, sinusoidal, softcap,
                                 truncated_normal)
from repro.models.moe import MeshCtx

AuxDict = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# pattern segmentation
# ---------------------------------------------------------------------------

def segment_pattern(pattern: Sequence[str],
                    max_cycle: int = 8) -> List[Tuple[Tuple[str, ...], int]]:
    """Greedy left-to-right factorisation into (cycle, reps) stages."""
    segs: List[Tuple[Tuple[str, ...], int]] = []
    i, L = 0, len(pattern)
    while i < L:
        best_p, best_r = 1, 1
        for p in range(1, max_cycle + 1):
            if i + p > L:
                break
            r = 1
            while (i + p * (r + 1) <= L
                   and tuple(pattern[i + p * r: i + p * (r + 1)])
                   == tuple(pattern[i: i + p])):
                r += 1
            # only multi-layer cycles that actually repeat are worth a
            # stage; otherwise emit single layers (keeps stacked params
            # homogeneous instead of bundling unrelated kinds)
            if r >= 2 and p * r > best_p * best_r:
                best_p, best_r = p, r
        segs.append((tuple(pattern[i: i + best_p]), best_r))
        i += best_p * best_r
    # merge adjacent single-kind stages of the same kind
    merged: List[Tuple[Tuple[str, ...], int]] = []
    for cyc, reps in segs:
        if merged and merged[-1][0] == cyc:
            merged[-1] = (cyc, merged[-1][1] + reps)
        else:
            merged.append((cyc, reps))
    return merged


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _attn_layer_init(cfg: ModelConfig, key, moe: bool) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": norm_init(cfg, cfg.d_model),
                 "attn": attn_mod.attn_init(cfg, ks[0]),
                 "norm2": norm_init(cfg, cfg.d_model)}
    if moe:
        p["moe"] = moe_mod.moe_init(cfg, ks[1])
    else:
        p["mlp"] = mlp_init(cfg, ks[1], cfg.d_model,
                            cfg.dense_d_ff or cfg.d_ff, gated=cfg.mlp_gated)
    if cfg.post_block_norm:
        p["norm1_post"] = norm_init(cfg, cfg.d_model)
        p["norm2_post"] = norm_init(cfg, cfg.d_model)
    return p


def layer_init(cfg: ModelConfig, kind: str, key) -> Params:
    if kind in (ATTN, ATTN_LOCAL):
        return _attn_layer_init(cfg, key, moe=False)
    if kind == MOE:
        return _attn_layer_init(cfg, key, moe=True)
    if kind == ATTN_PARALLEL:
        ks = jax.random.split(key, 2)
        return {"norm": norm_init(cfg, cfg.d_model),
                "attn": attn_mod.attn_init(cfg, ks[0]),
                "mlp": mlp_init(cfg, ks[1], cfg.d_model, cfg.d_ff,
                                gated=cfg.mlp_gated)}
    if kind in (MAMBA2, MAMBA2_SHARED):
        return {"norm": norm_init(cfg, cfg.d_model),
                "mamba": rec_mod.mamba2_init(cfg, key)}
    if kind == MLSTM:
        return {"norm": norm_init(cfg, cfg.d_model),
                "cell": rec_mod.mlstm_init(cfg, key)}
    if kind == SLSTM:
        return {"norm": norm_init(cfg, cfg.d_model),
                "cell": rec_mod.slstm_init(cfg, key)}
    raise ValueError(kind)


def shared_attn_init(cfg: ModelConfig, key) -> Params:
    """Zamba2 shared block: consumes concat(x, emb0) (2D → D) then attn+MLP."""
    ks = jax.random.split(key, 3)
    return {"norm_in": norm_init(cfg, 2 * cfg.d_model),
            "in_proj": truncated_normal(ks[0], (2 * cfg.d_model, cfg.d_model),
                                        (2 * cfg.d_model) ** -0.5),
            "attn": attn_mod.attn_init(cfg, ks[1]),
            "norm2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(cfg, ks[2], cfg.d_model, cfg.d_ff)}


def _zero_aux(cfg: ModelConfig) -> AuxDict:
    return {"lb_loss": jnp.zeros(()),
            "counts": jnp.zeros((max(cfg.num_experts, 1),)),
            "dropped": jnp.zeros(())}


def _acc_aux(a: AuxDict, b: AuxDict) -> AuxDict:
    return {k: a[k] + b[k] for k in a}


def _moe_block(cfg: ModelConfig, p: Params, x: jax.Array,
               ctx: Optional[MeshCtx]) -> Tuple[jax.Array, AuxDict]:
    if ctx is None:
        return moe_mod.moe_ffn(cfg, p, x, None)
    especs = {"router": P(None, None),
              "w_gate": P("model", None, None),
              "w_up": P("model", None, None),
              "w_down": P("model", None, None)}
    if cfg.num_shared_experts:
        especs["shared"] = {"w_gate": P(None, "model"),
                            "w_up": P(None, "model"),
                            "w_down": P("model", None)}
    n_data = 1
    for a in ctx.data_axes:
        n_data *= ctx.mesh.shape[a]
    # B=1 decode (long-context) cannot shard the token batch over the data
    # axes: replicate it instead (each data rank redundantly computes the
    # single token — negligible — and no data-psum is needed).
    data_sharded = x.shape[0] % n_data == 0
    dp = P(ctx.data_axes, None, None) if data_sharded else P(None, None, None)

    def inner(pp, xx):
        y, aux = moe_mod.moe_ffn(cfg, pp, xx, ctx)
        if data_sharded:
            # reduce stats over data so outputs are fully replicated scalars
            aux = {"lb_loss": jax.lax.psum(aux["lb_loss"],
                                           ctx.data_axes) / n_data,
                   "counts": jax.lax.psum(aux["counts"], ctx.data_axes),
                   "dropped": jax.lax.psum(aux["dropped"], ctx.data_axes)}
        return y, aux

    fn = jax.shard_map(inner, mesh=ctx.mesh, in_specs=(especs, dp),
                       out_specs=(dp, P()), check_vma=False)
    return fn(p, x)


def apply_layer(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                emb0: Optional[jax.Array], shared: Optional[Params],
                ctx: Optional[MeshCtx],
                positions: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, AuxDict]:
    """Training-time application of one block. x: (B, S, D)."""
    aux = _zero_aux(cfg)
    if kind in (ATTN, ATTN_LOCAL, MOE):
        window = effective_window(cfg, kind)
        h = attn_mod.attention_train(cfg, p["attn"],
                                     apply_norm(cfg, p["norm1"], x),
                                     window=window, positions=positions)
        if cfg.post_block_norm:
            h = apply_norm(cfg, p["norm1_post"], h)
        x = x + h
        hin = apply_norm(cfg, p["norm2"], x)
        if kind == MOE:
            h, aux = _moe_block(cfg, p["moe"], hin, ctx)
        else:
            h = apply_mlp(cfg, p["mlp"], hin)
        if cfg.post_block_norm:
            h = apply_norm(cfg, p["norm2_post"], h)
        return x + h, aux
    if kind == ATTN_PARALLEL:
        n = apply_norm(cfg, p["norm"], x)
        return (x + attn_mod.attention_train(
                    cfg, p["attn"], n, window=effective_window(cfg, kind),
                    positions=positions)
                + apply_mlp(cfg, p["mlp"], n)), aux
    if kind in (MAMBA2, MAMBA2_SHARED):
        x = x + rec_mod.mamba2_train(cfg, p["mamba"],
                                     apply_norm(cfg, p["norm"], x))
        if kind == MAMBA2_SHARED:
            assert shared is not None and emb0 is not None
            cat = jnp.concatenate([x, emb0], axis=-1)
            h = apply_norm(cfg, shared["norm_in"], cat) \
                @ shared["in_proj"].astype(x.dtype)
            x = x + attn_mod.attention_train(cfg, shared["attn"], h,
                                             positions=positions)
            x = x + apply_mlp(cfg, shared["mlp"],
                              apply_norm(cfg, shared["norm2"], x))
        return x, aux
    if kind == MLSTM:
        return x + rec_mod.mlstm_train(cfg, p["cell"],
                                       apply_norm(cfg, p["norm"], x)), aux
    if kind == SLSTM:
        return x + rec_mod.slstm_train(cfg, p["cell"],
                                       apply_norm(cfg, p["norm"], x)), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    stages = segment_pattern(cfg.pattern)
    ks = jax.random.split(key, len(stages) + 4)
    params: Params = {}
    d, v = cfg.d_model, cfg.vocab_size
    if cfg.modality == "audio":
        params["embed"] = truncated_normal(ks[0], (cfg.num_codebooks, v, d),
                                           d ** -0.5)
        params["heads"] = truncated_normal(ks[1], (cfg.num_codebooks, d, v),
                                           d ** -0.5)
    else:
        params["embed"] = truncated_normal(ks[0], (v, d), d ** -0.5)
        if not cfg.tie_embeddings:
            params["lm_head"] = truncated_normal(ks[1], (d, v), d ** -0.5)
    params["final_norm"] = norm_init(cfg, d)
    if MAMBA2_SHARED in cfg.pattern:
        params["shared_attn"] = shared_attn_init(cfg, ks[2])

    stage_params = []
    for si, (cycle, reps) in enumerate(stages):
        rep_keys = jax.random.split(ks[3 + si], reps)
        per_pos = []
        for pos, kind in enumerate(cycle):
            plist = [layer_init(cfg, kind, jax.random.fold_in(rk, pos))
                     for rk in rep_keys]
            per_pos.append(jax.tree.map(lambda *xs: jnp.stack(xs), *plist))
        stage_params.append(tuple(per_pos))
    params["stages"] = tuple(stage_params)
    return params


def stage_layout(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    return segment_pattern(cfg.pattern)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
           dtype) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B, S, D), positions (S,))."""
    tokens = batch["tokens"]
    if cfg.modality == "audio":
        # tokens: (B, S, C) — sum the codebook embeddings
        emb = params["embed"].astype(dtype)                  # (C, V, D)
        x = sum(emb[c][tokens[..., c]] for c in range(cfg.num_codebooks))
    else:
        x = params["embed"].astype(dtype)[tokens]            # (B, S, D)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if cfg.modality == "vision" and "vision_embeds" in batch:
        x = jnp.concatenate([batch["vision_embeds"].astype(dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)
    if not cfg.use_rope and cfg.modality == "audio":
        x = x + sinusoidal(positions, cfg.d_model).astype(dtype)[None]
    return x, positions


def _readout(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.modality == "audio":
        logits = jnp.einsum("bsd,cdv->bscv", x, params["heads"].astype(dt))
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dt))
    else:
        logits = x @ params["lm_head"].astype(dt)
    logits = logits * cfg.logit_scale
    return softcap(logits, cfg.final_logit_softcap)


def forward_hidden(cfg: ModelConfig, params: Params,
                   batch: Dict[str, jax.Array],
                   ctx: Optional[MeshCtx] = None
                   ) -> Tuple[jax.Array, AuxDict]:
    """Full-sequence forward up to (but not including) the readout."""
    dtype = jnp.dtype(cfg.dtype)
    x, positions = _embed(cfg, params, batch, dtype)
    x = _shard(x, ctx, P(None, None, None), batch_axes=True)
    emb0 = x if MAMBA2_SHARED in cfg.pattern else None
    shared = params.get("shared_attn")
    aux = _zero_aux(cfg)
    stages = stage_layout(cfg)
    for (cycle, reps), sp in zip(stages, params["stages"]):
        def body(carry, xs):
            xx, ax = carry
            for i, kind in enumerate(cycle):
                xx, ai = apply_layer(cfg, kind, xs[i], xx, emb0, shared, ctx,
                                     positions)
                ax = _acc_aux(ax, ai)
            xx = _shard(xx, ctx, P(None, None, None), batch_axes=True)
            return (xx, ax), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), sp)
    return x, aux


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            ctx: Optional[MeshCtx] = None) -> Tuple[jax.Array, AuxDict]:
    """Full-sequence forward. Returns (logits, aux)."""
    x, aux = forward_hidden(cfg, params, batch, ctx)
    return _readout(cfg, params, x), aux


def _shard(x: jax.Array, ctx: Optional[MeshCtx], spec: P,
           batch_axes: bool = False, force_rep: bool = False):
    if ctx is None:
        return x
    if batch_axes:
        if not force_rep and ctx.seq_shard and x.ndim == 3 \
                and x.shape[1] > 1 \
                and x.shape[1] % ctx.mesh.shape[ctx.model_axis] == 0:
            # sequence parallelism: the residual stream (and thus every
            # scan-saved remat carry) is S-sharded over the model axis
            spec = P(ctx.data_axes, ctx.model_axis, None)
        else:
            spec = P(ctx.data_axes, *spec[1:])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            ctx: Optional[MeshCtx] = None, lb_coef: float = 0.01,
            loss_chunk: int = 1024) -> Tuple[jax.Array, AuxDict]:
    """Next-token cross entropy (labels pre-shifted; −1 = masked).

    The readout + softmax is computed in sequence chunks under
    ``jax.checkpoint`` so the (B, S, V) fp32 logits are never materialised —
    for 150k–256k vocabularies that one buffer would otherwise dominate HBM.
    """
    hidden, aux = forward_hidden(cfg, params, batch, ctx)
    labels = batch["labels"]
    b, s = hidden.shape[:2]
    c = min(loss_chunk, s)
    s_pad = ((s + c - 1) // c) * c
    if s_pad != s:
        hidden = jnp.pad(hidden, ((0, 0), (0, s_pad - s)) + ((0, 0),))
        pad_lab = ((0, 0), (0, s_pad - s)) + ((0, 0),) * (labels.ndim - 2)
        labels = jnp.pad(labels, pad_lab, constant_values=-1)
    nc = s_pad // c
    hs = jnp.moveaxis(hidden.reshape(b, nc, c, -1), 1, 0)
    ls = jnp.moveaxis(labels.reshape((b, nc, c) + labels.shape[2:]), 1, 0)

    @jax.checkpoint
    def chunk_ce(carry, inp):
        h, lab = inp
        logits = _readout(cfg, params, h)
        m = (lab >= 0).astype(jnp.float32)
        lb = jnp.maximum(lab, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lb[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + (nll * m).sum(), cnt + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk_ce, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls))
    ce = tot / jnp.maximum(cnt, 1.0)
    n_moe = sum(1 for k in cfg.pattern if k == MOE)
    total = ce + (lb_coef * aux["lb_loss"] / max(n_moe, 1) if n_moe else 0.0)
    metrics = {"loss": total, "ce": ce, **aux}
    return total, metrics


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch_size: int, cache_len: int,
                dtype=jnp.bfloat16) -> Tuple:
    """Per-stage caches mirroring params['stages'] (leading reps dim)."""
    def one(kind, window):
        if kind in (ATTN, ATTN_LOCAL, ATTN_PARALLEL, MOE):
            w = effective_window(cfg, kind)
            return attn_mod.init_cache(cfg, batch_size,
                                       min(w or cache_len, cache_len), dtype)
        if kind == MAMBA2:
            return rec_mod.mamba2_init_cache(cfg, batch_size)
        if kind == MAMBA2_SHARED:
            return (rec_mod.mamba2_init_cache(cfg, batch_size),
                    attn_mod.init_cache(cfg, batch_size, cache_len, dtype))
        if kind == MLSTM:
            return rec_mod.mlstm_init_cache(cfg, batch_size)
        if kind == SLSTM:
            return rec_mod.slstm_init_cache(cfg, batch_size)
        raise ValueError(kind)

    caches = []
    for cycle, reps in stage_layout(cfg):
        per_pos = []
        for kind in cycle:
            c = one(kind, cfg.sliding_window)
            per_pos.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), c))
        caches.append(tuple(per_pos))
    return tuple(caches)


def apply_layer_decode(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                       cache, pos: jax.Array, emb0, shared,
                       ctx: Optional[MeshCtx]):
    """x: (B, 1, D); pos: (B,) absolute positions."""
    if kind in (ATTN, ATTN_LOCAL, ATTN_PARALLEL, MOE):
        window = effective_window(cfg, kind)
        if kind == ATTN_PARALLEL:
            n = apply_norm(cfg, p["norm"], x)
            h, cache = attn_mod.attention_decode(cfg, p["attn"], n, cache,
                                                 pos, window)
            return x + h + apply_mlp(cfg, p["mlp"], n), cache
        h, cache = attn_mod.attention_decode(
            cfg, p["attn"], apply_norm(cfg, p["norm1"], x), cache, pos,
            window)
        if cfg.post_block_norm:
            h = apply_norm(cfg, p["norm1_post"], h)
        x = x + h
        hin = apply_norm(cfg, p["norm2"], x)
        if kind == MOE:
            h, _ = _moe_block(cfg, p["moe"], hin, ctx)
        else:
            h = apply_mlp(cfg, p["mlp"], hin)
        if cfg.post_block_norm:
            h = apply_norm(cfg, p["norm2_post"], h)
        return x + h, cache
    if kind in (MAMBA2, MAMBA2_SHARED):
        mcache = cache[0] if kind == MAMBA2_SHARED else cache
        h, mcache = rec_mod.mamba2_step(cfg, p["mamba"],
                                        apply_norm(cfg, p["norm"], x), mcache)
        x = x + h
        if kind == MAMBA2_SHARED:
            cat = jnp.concatenate([x, emb0], axis=-1)
            hin = apply_norm(cfg, shared["norm_in"], cat) \
                @ shared["in_proj"].astype(x.dtype)
            h, acache = attn_mod.attention_decode(cfg, shared["attn"], hin,
                                                  cache[1], pos, None)
            x = x + h
            x = x + apply_mlp(cfg, shared["mlp"],
                              apply_norm(cfg, shared["norm2"], x))
            return x, (mcache, acache)
        return x, mcache
    if kind == MLSTM:
        h, cache = rec_mod.mlstm_step(cfg, p["cell"],
                                      apply_norm(cfg, p["norm"], x), cache)
        return x + h, cache
    if kind == SLSTM:
        h, cache = rec_mod.slstm_step(cfg, p["cell"],
                                      apply_norm(cfg, p["norm"], x), cache)
        return x + h, cache
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params: Params, caches,
                tokens: jax.Array, pos: jax.Array,
                ctx: Optional[MeshCtx] = None):
    """One-token decode. tokens: (B,) (or (B, C) audio); pos: (B,).

    Returns (logits (B, V) or (B, C, V), new caches).
    """
    dtype = jnp.dtype(cfg.dtype)
    if cfg.modality == "audio":
        emb = params["embed"].astype(dtype)
        x = sum(emb[c][tokens[:, c]] for c in range(cfg.num_codebooks))
        x = x[:, None]
    else:
        x = params["embed"].astype(dtype)[tokens][:, None]   # (B, 1, D)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if not cfg.use_rope and cfg.modality == "audio":
        x = x + jax.vmap(lambda p_: sinusoidal(p_[None], cfg.d_model)[0]
                         )(pos).astype(dtype)[:, None]
    x = _shard(x, ctx, P(None, None, None), batch_axes=True)
    emb0 = x if MAMBA2_SHARED in cfg.pattern else None
    shared = params.get("shared_attn")

    new_caches = []
    for (cycle, reps), sp, sc in zip(stage_layout(cfg), params["stages"],
                                     caches):
        def body(xx, xs):
            pp, cc = xs
            ncs = []
            for i, kind in enumerate(cycle):
                xx, nc = apply_layer_decode(cfg, kind, pp[i], xx, cc[i], pos,
                                            emb0, shared, ctx)
                ncs.append(nc)
            return xx, tuple(ncs)
        x, nc = jax.lax.scan(body, x, (sp, sc))
        new_caches.append(nc)
    logits = _readout(cfg, params, x)[:, 0]
    return logits, tuple(new_caches)
