"""Recurrent blocks: Mamba2 (SSD), xLSTM mLSTM / sLSTM.

One chunked scalar-decay linear-recurrence core serves both Mamba2 and the
mLSTM: both obey

    S_t = a_t · S_{t−1} + i_t · k_t ⊗ v_t          (state (N, P) per head)
    y_t = q_t · S_t  [ / normalizer for mLSTM ]

with per-step scalar decay a_t. Mamba2 is the unstabilised case
(a = exp(Δ·A) ∈ (0,1), i = Δ folded into v); the mLSTM uses an exponential
input gate and therefore carries the xLSTM stabiliser m with the state.
Training runs chunk-parallel (intra-chunk (L,L) matmuls on the MXU,
inter-chunk lax.scan) — the TPU-native adaptation of the CUDA scan kernels
(DESIGN.md §2); decode is the O(1) recurrence.

The sLSTM has a true hidden-to-hidden recurrence (block-diagonal R), so its
training path is an honest lax.scan over time — the xLSTM paper accelerates
it with a fused CUDA kernel; on TPU it stays sequential (DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, apply_norm, truncated_normal

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked scalar-decay linear recurrence (shared core)
# ---------------------------------------------------------------------------

class RecurrentState(NamedTuple):
    c: jax.Array        # (B, H, N, P) (stabilised for mLSTM)
    n: jax.Array        # (B, H, N) normaliser (zeros when unused)
    m: jax.Array        # (B, H) stabiliser (zeros when unused)


def init_state(b: int, h: int, n: int, p: int,
               dtype=jnp.float32) -> RecurrentState:
    return RecurrentState(jnp.zeros((b, h, n, p), dtype),
                          jnp.zeros((b, h, n), dtype),
                          jnp.zeros((b, h), dtype))


def chunked_scan(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array,
                 log_i: Optional[jax.Array], state: RecurrentState,
                 chunk: int, stabilize: bool
                 ) -> Tuple[jax.Array, RecurrentState]:
    """Chunk-parallel linear recurrence.

    q, k: (B, T, H, N); v: (B, T, H, P); log_a, log_i: (B, T, H).
    Returns y (B, T, H, P) and the final state. T must divide by ``chunk``.
    """
    b, t, h, n = q.shape
    p = v.shape[-1]
    L = min(chunk, t)
    assert t % L == 0, (t, L)
    nc = t // L

    def to_chunks(x, feat):
        x = x.reshape((b, nc, L, h) + ((feat,) if feat else ()))
        return jnp.moveaxis(x, 3, 2)            # (B, nc, H, L[, feat])

    qc, kc, vc = to_chunks(q, n), to_chunks(k, n), to_chunks(v, p)
    lac = to_chunks(log_a, 0)
    lic = to_chunks(log_i, 0) if log_i is not None else jnp.zeros_like(lac)
    qc, kc, vc, lac, lic = (jnp.moveaxis(x, 1, 0)
                            for x in (qc, kc, vc, lac, lic))  # (nc, B, H, ...)

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]       # j ≥ i

    def body(carry: RecurrentState, inp):
        qi, ki, vi, la, li = inp                # (B,H,L,N/P), (B,H,L)
        laf = la.astype(jnp.float32)
        lif = li.astype(jnp.float32)
        f = jnp.cumsum(laf, axis=-1)            # F_j (B,H,L)
        # decay from step i to j (i ≤ j): F_j − F_i + li_i
        g = f[..., :, None] - f[..., None, :] + lif[..., None, :]
        g = jnp.where(causal, g, NEG_INF)       # (B,H,L,L)
        binit = f + carry.m[..., None]          # init-state decay (B,H,L)
        if stabilize:
            mj = jnp.maximum(g.max(-1), binit)  # (B,H,L)
        else:
            mj = jnp.zeros_like(binit)
        w = jnp.exp(g - mj[..., None])          # (B,H,L,L)
        scores = jnp.einsum("bhjn,bhin->bhji", qi, ki)
        ws = jnp.where(causal, w * scores.astype(jnp.float32), 0.0)
        num = jnp.einsum("bhji,bhip->bhjp", ws.astype(vi.dtype), vi)
        einit = jnp.exp(binit - mj)             # (B,H,L)
        num = num + einit[..., None].astype(vi.dtype) * jnp.einsum(
            "bhjn,bhnp->bhjp", qi, carry.c.astype(qi.dtype))
        if stabilize:
            den = ws.sum(-1) + einit * jnp.einsum(
                "bhjn,bhn->bhj", qi, carry.n.astype(qi.dtype)
            ).astype(jnp.float32)
            den = jnp.maximum(jnp.abs(den), jnp.exp(-mj)) + 1e-6
            y = num / den[..., None].astype(num.dtype)
        else:
            y = num
        # ---- state update -------------------------------------------------
        ftot = f[..., -1]                       # F_L (B,H)
        gstate = ftot[..., None] - f + lif      # F_L − F_i + li_i (B,H,L)
        bstate = ftot + carry.m                 # F_L + m_prev (B,H)
        if stabilize:
            mnew = jnp.maximum(gstate.max(-1), bstate)
        else:
            mnew = jnp.zeros_like(bstate)
        wst = jnp.exp(gstate - mnew[..., None])  # (B,H,L)
        est = jnp.exp(bstate - mnew)
        c_new = (est[..., None, None] * carry.c.astype(jnp.float32)
                 + jnp.einsum("bhl,bhln,bhlp->bhnp", wst,
                              ki.astype(jnp.float32), vi.astype(jnp.float32)))
        n_new = (est[..., None] * carry.n
                 + jnp.einsum("bhl,bhln->bhn", wst, ki.astype(jnp.float32)))
        return RecurrentState(c_new, n_new, mnew), y

    final, ys = jax.lax.scan(body, state, (qc, kc, vc, lac, lic))
    y = jnp.moveaxis(ys, 0, 1)                  # (B, nc, H, L, P)
    y = jnp.moveaxis(y, 2, 3).reshape(b, t, h, p)
    return y, final


def recurrence_step(q: jax.Array, k: jax.Array, v: jax.Array,
                    log_a: jax.Array, log_i: Optional[jax.Array],
                    state: RecurrentState, stabilize: bool
                    ) -> Tuple[jax.Array, RecurrentState]:
    """Single-token decode step. q, k: (B, H, N); v: (B, H, P); gates (B, H)."""
    laf = log_a.astype(jnp.float32)
    lif = (log_i if log_i is not None else jnp.zeros_like(log_a)
           ).astype(jnp.float32)
    if stabilize:
        mnew = jnp.maximum(laf + state.m, lif)
    else:
        mnew = jnp.zeros_like(laf)
    fz = jnp.exp(laf + state.m - mnew)          # (B, H)
    iz = jnp.exp(lif - mnew)
    c = (fz[..., None, None] * state.c
         + iz[..., None, None] * jnp.einsum("bhn,bhp->bhnp",
                                            k.astype(jnp.float32),
                                            v.astype(jnp.float32)))
    nvec = fz[..., None] * state.n + iz[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), c)
    if stabilize:
        den = jnp.einsum("bhn,bhn->bh", q.astype(jnp.float32), nvec)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-mnew)) + 1e-6
        y = num / den[..., None]
    else:
        y = num
    return y.astype(v.dtype), RecurrentState(c, nvec, mnew)


# ---------------------------------------------------------------------------
# causal depthwise conv1d (+ decode ring state)
# ---------------------------------------------------------------------------

def conv1d_train(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, T, C); w: (K, C) depthwise causal; returns (B, T, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    t = x.shape[1]
    out = sum(xp[:, i:i + t] * w[i] for i in range(k))
    return out + b


def conv1d_step(x: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, C); conv_state: (B, K−1, C) of previous inputs (oldest first).

    Compute in the activation dtype; the returned state keeps the cache
    dtype so scan carries stay type-stable.
    """
    full = jnp.concatenate([conv_state.astype(x.dtype), x[:, None]], axis=1)
    y = jnp.einsum("bkc,kc->bc", full, w) + b
    return y, full[:, 1:].astype(conv_state.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_state


def mamba2_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    d_in, nh, ns = mamba2_dims(cfg)
    conv_c = d_in + 2 * ns
    ks = jax.random.split(key, 4)
    return {
        "in_proj": truncated_normal(ks[0], (d, 2 * d_in + 2 * ns + nh),
                                    d ** -0.5),
        "conv_w": truncated_normal(ks[1], (cfg.ssm_conv, conv_c), 0.2),
        "conv_b": jnp.zeros((conv_c,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,)) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,)),
        "norm_scale": jnp.ones((d_in,)),
        "out_proj": truncated_normal(ks[3], (d_in, d), d_in ** -0.5),
    }


class Mamba2Cache(NamedTuple):
    conv: jax.Array          # (B, K−1, d_in + 2N)
    ssm: RecurrentState


def mamba2_init_cache(cfg: ModelConfig, batch: int) -> Mamba2Cache:
    d_in, nh, ns = mamba2_dims(cfg)
    return Mamba2Cache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * ns)),
        ssm=init_state(batch, nh, ns, cfg.ssm_head_dim))


def _mamba2_pre(cfg: ModelConfig, p: Params, zxbcdt: jax.Array):
    """Split in_proj output; returns (z, xbc, dt)."""
    d_in, nh, ns = mamba2_dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * ns], axis=-1)
    return z, xbc, dt


def _mamba2_core(cfg: ModelConfig, p: Params, xbc: jax.Array,
                 dt: jax.Array):
    """Common post-conv math: split conv output and build SSD operands."""
    d_in, nh, ns = mamba2_dims(cfg)
    hd = cfg.ssm_head_dim
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + ns], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (..., nh)
    a = -jnp.exp(p["a_log"])                                      # (nh,)
    log_a = dt * a                                                # (..., nh)
    return xs, bmat, cmat, dt, log_a


def mamba2_train(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """x: (B, T, D) → (B, T, D)."""
    b, t, d = x.shape
    d_in, nh, ns = mamba2_dims(cfg)
    hd = cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _mamba2_pre(cfg, p, zxbcdt)
    xbc = jax.nn.silu(conv1d_train(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    xs, bmat, cmat, dtf, log_a = _mamba2_core(cfg, p, xbc, dt)
    xh = xs.reshape(b, t, nh, hd)
    v = xh * dtf[..., None].astype(xh.dtype)                  # fold Δ into v
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, t, nh, ns))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, t, nh, ns))
    y, _ = chunked_scan(q, k, v, log_a, None,
                        init_state(b, nh, ns, hd), cfg.chunk_size,
                        stabilize=False)
    y = y + p["d_skip"].astype(x.dtype)[:, None] * xh
    y = y.reshape(b, t, d_in)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_step(cfg: ModelConfig, p: Params, x: jax.Array,
                cache: Mamba2Cache) -> Tuple[jax.Array, Mamba2Cache]:
    """x: (B, 1, D) single-token decode."""
    b = x.shape[0]
    d_in, nh, ns = mamba2_dims(cfg)
    hd = cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _mamba2_pre(cfg, p, zxbcdt)
    xbc, conv = conv1d_step(xbc, cache.conv, p["conv_w"].astype(x.dtype),
                            p["conv_b"].astype(x.dtype))
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat, dtf, log_a = _mamba2_core(cfg, p, xbc, dt)
    xh = xs.reshape(b, nh, hd)
    v = xh * dtf[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(bmat[:, None, :], (b, nh, ns))
    q = jnp.broadcast_to(cmat[:, None, :], (b, nh, ns))
    y, ssm = recurrence_step(q, k, v, log_a, None, cache.ssm,
                             stabilize=False)
    y = y + p["d_skip"].astype(x.dtype)[:, None] * xh
    y = y.reshape(b, 1, d_in)
    y = _gated_rmsnorm(y, z[:, None], p["norm_scale"])
    return y @ p["out_proj"].astype(x.dtype), Mamba2Cache(conv, ssm)


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    out = gf * jax.lax.rsqrt((gf ** 2).mean(-1, keepdims=True) + eps)
    return (out * scale).astype(y.dtype)


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = 2 * cfg.d_model            # proj_factor = 2
    heads = cfg.num_heads
    return d_in, heads, d_in // heads


def mlstm_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    d_in, h, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_up": truncated_normal(ks[0], (d, 2 * d_in), d ** -0.5),
        "conv_w": truncated_normal(ks[1], (4, d_in), 0.2),
        "conv_b": jnp.zeros((d_in,)),
        "wq": truncated_normal(ks[2], (d_in, d_in), d_in ** -0.5),
        "wk": truncated_normal(ks[3], (d_in, d_in), d_in ** -0.5),
        "w_gates": truncated_normal(ks[4], (d_in, 2 * h), d_in ** -0.5),
        "b_gates": jnp.concatenate([jnp.zeros((h,)),           # input gate
                                    jnp.linspace(3.0, 6.0, h)]),  # forget
        "skip": jnp.ones((d_in,)),
        "norm_scale": jnp.ones((d_in,)),
        "w_down": truncated_normal(ks[5], (d_in, d), d_in ** -0.5),
    }


class MLSTMCache(NamedTuple):
    conv: jax.Array           # (B, 3, d_in)
    cell: RecurrentState


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    d_in, h, hd = mlstm_dims(cfg)
    return MLSTMCache(conv=jnp.zeros((batch, 3, d_in)),
                      cell=init_state(batch, h, hd, hd))


def _mlstm_qkvg(cfg: ModelConfig, p: Params, xi: jax.Array, xc: jax.Array):
    """xi: pre-conv branch, xc: post-conv. Returns q,k,v,(log_f, log_i)."""
    d_in, h, hd = mlstm_dims(cfg)
    shp = xi.shape[:-1]
    q = (xc @ p["wq"].astype(xc.dtype)).reshape(shp + (h, hd)) * hd ** -0.5
    k = (xc @ p["wk"].astype(xc.dtype)).reshape(shp + (h, hd)) * hd ** -0.5
    v = xi.reshape(shp + (h, hd))
    gates = xi @ p["w_gates"].astype(xi.dtype) + p["b_gates"].astype(xi.dtype)
    log_i, f_raw = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    return q, k, v, log_f, log_i


def mlstm_train(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    b, t, d = x.shape
    d_in, h, hd = mlstm_dims(cfg)
    up = x @ p["w_up"].astype(x.dtype)
    xi, zg = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(conv1d_train(xi, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype)))
    q, k, v, log_f, log_i = _mlstm_qkvg(cfg, p, xi, xc)
    y, _ = chunked_scan(q, k, v, log_f, log_i, init_state(b, h, hd, hd),
                        cfg.chunk_size, stabilize=True)
    y = _headwise_rmsnorm(y, p["norm_scale"]).reshape(b, t, d_in)
    y = y + p["skip"].astype(x.dtype) * xc
    y = y * jax.nn.silu(zg)
    return y @ p["w_down"].astype(x.dtype)


def mlstm_step(cfg: ModelConfig, p: Params, x: jax.Array,
               cache: MLSTMCache) -> Tuple[jax.Array, MLSTMCache]:
    b = x.shape[0]
    d_in, h, hd = mlstm_dims(cfg)
    up = x[:, 0] @ p["w_up"].astype(x.dtype)
    xi, zg = jnp.split(up, 2, axis=-1)
    xc, conv = conv1d_step(xi, cache.conv, p["conv_w"].astype(x.dtype),
                           p["conv_b"].astype(x.dtype))
    xc = jax.nn.silu(xc)
    q, k, v, log_f, log_i = _mlstm_qkvg(cfg, p, xi, xc)
    y, cell = recurrence_step(q, k, v, log_f, log_i, cache.cell,
                              stabilize=True)
    y = _headwise_rmsnorm(y[:, None], p["norm_scale"])[:, 0]
    y = y.reshape(b, d_in) + p["skip"].astype(x.dtype) * xc
    y = y * jax.nn.silu(zg)
    return (y @ p["w_down"].astype(x.dtype))[:, None], MLSTMCache(conv, cell)


def _headwise_rmsnorm(y: jax.Array, scale: jax.Array,
                      eps: float = 1e-6) -> jax.Array:
    """y: (..., H, hd) — RMS per head, then flatten and scale."""
    yf = y.astype(jnp.float32)
    yn = yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + eps)
    flat = yn.reshape(y.shape[:-2] + (-1,))
    return (flat * scale).astype(y.dtype)


# ---------------------------------------------------------------------------
# sLSTM fused-sequence cell with custom VJP
# ---------------------------------------------------------------------------
#
# A naive jax.grad through the time scan reduces the recurrent-weight
# gradient dR across the (sharded) batch at EVERY timestep — T×L all-reduces
# of |R| bytes dominate the xlstm roofline (§Perf xlstm iterations 1–2).
# This custom VJP does what fused CUDA LSTM kernels do: the forward scan
# saves per-step activations, the backward scan only propagates (gc, gn, gh)
# and emits per-step gate deltas; dR and the input cotangents are then ONE
# time-batched einsum outside the scan — a single gradient reduction.
# The stabiliser m is treated as a constant in the backward pass (standard
# for xLSTM: gradients do not flow through max-stabilisers).

from functools import partial as _partial


def _slstm_gates(r, wxb, xc, h, state, heads):
    """Shared forward-step math. Returns new state + residuals."""
    b, d = h.shape
    hd = d // heads
    c, n, m = state
    hh = h.reshape(b, heads, hd)
    rz, ri, rf, ro = (jnp.einsum("bhj,hjk->bhk", hh, r[g]).reshape(b, d)
                      for g in range(4))
    zr, ir, fr, orr = jnp.split(wxb, 4, axis=-1)
    z = jnp.tanh(zr + rz)
    log_i = ir + xc + ri
    pre_f = fr + xc + rf
    log_f = jax.nn.log_sigmoid(pre_f)
    sig_f = jnp.exp(log_f)
    o = jax.nn.sigmoid(orr + ro)
    m_new = jnp.maximum(log_f + m, log_i)
    iz = jnp.exp(log_i - m_new)
    fz = jnp.exp(log_f + m - m_new)
    c_new = fz * c + iz * z
    n_new = fz * n + iz
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), (z, iz, fz, o, sig_f)


@_partial(jax.custom_vjp, nondiff_argnums=(0,))
def slstm_seq(heads: int, r, wxb, xc):
    """hs (B,T,D) from pre-activations wxb (B,T,4D) + conv branch xc (B,T,D).

    All in float32 (caller casts); r: (4, H, hd, hd).
    """
    hs, _ = _slstm_seq_fwd(heads, r, wxb, xc)
    return hs


def _slstm_seq_fwd(heads, r, wxb, xc):
    b, t, d4 = wxb.shape
    d = d4 // 4

    def step(carry, inp):
        c, n, m, h = carry
        wxb_t, xc_t = inp
        (c, n, m, h), res = _slstm_gates(r, wxb_t, xc_t, h, (c, n, m), heads)
        return (c, n, m, h), (h, c, n) + res

    z0 = jnp.zeros((b, d), jnp.float32)
    _, ys = jax.lax.scan(step, (z0, z0, z0, z0),
                         (jnp.moveaxis(wxb, 1, 0), jnp.moveaxis(xc, 1, 0)))
    h_seq, c_seq, n_seq, z, iz, fz, o, sig_f = ys      # each (T, B, D)
    hs = jnp.moveaxis(h_seq, 0, 1)
    return hs, (r, h_seq, c_seq, n_seq, z, iz, fz, o, sig_f)


def _slstm_seq_bwd(heads, res, ghs):
    r, h_seq, c_seq, n_seq, z, iz, fz, o, sig_f = res
    t, b, d = h_seq.shape
    hd = d // heads
    # shifted (t−1) sequences; step 0 sees the zero initial state
    shift = lambda x: jnp.concatenate([jnp.zeros((1, b, d), x.dtype), x[:-1]])
    h_prev, c_prev, n_prev = shift(h_seq), shift(c_seq), shift(n_seq)
    gh_out = jnp.moveaxis(ghs.astype(jnp.float32), 1, 0)   # (T, B, D)

    def step(carry, inp):
        gc, gn, gh_rec = carry
        (gho, cp, np_, ct, nt, zt, izt, fzt, ot, sft) = inp
        gh = gho + gh_rec
        nhat = jnp.maximum(nt, 1e-6)
        do = gh * ct / nhat
        dc = gc + gh * ot / nhat
        dn = gn - jnp.where(nt >= 1e-6, gh * ot * ct / (nhat * nhat), 0.0)
        dz = dc * izt
        dlog_i = (dc * zt + dn) * izt
        dlog_f = (dc * cp + dn * np_) * fzt
        gc_prev = dc * fzt
        gn_prev = dn * fzt
        d_z = dz * (1.0 - zt * zt)
        d_i = dlog_i
        d_f = dlog_f * (1.0 - sft)
        d_o = do * ot * (1.0 - ot)
        # recurrent cotangent: δg · R_gᵀ per head
        def back(delta, rg):
            dh = delta.reshape(b, heads, hd)
            return jnp.einsum("bhk,hjk->bhj", dh, rg).reshape(b, d)
        gh_prev = (back(d_z, r[0]) + back(d_i, r[1])
                   + back(d_f, r[2]) + back(d_o, r[3]))
        return (gc_prev, gn_prev, gh_prev), (d_z, d_i, d_f, d_o)

    init = (jnp.zeros((b, d)), jnp.zeros((b, d)), jnp.zeros((b, d)))
    _, deltas = jax.lax.scan(
        step, init,
        (gh_out, c_prev, n_prev, c_seq, n_seq, z, iz, fz, o, sig_f),
        reverse=True)
    d_z, d_i, d_f, d_o = deltas                         # (T, B, D) each
    # ONE time-batched weight-gradient einsum per gate (single reduction)
    hp = h_prev.reshape(t, b, heads, hd)

    def dr(delta):
        return jnp.einsum("tbhj,tbhk->hjk", hp, delta.reshape(t, b, heads, hd))

    d_r = jnp.stack([dr(d_z), dr(d_i), dr(d_f), dr(d_o)])
    d_wxb = jnp.moveaxis(jnp.concatenate([d_z, d_i, d_f, d_o], -1), 0, 1)
    d_xc = jnp.moveaxis(d_i + d_f, 0, 1)
    return d_r, d_wxb, d_xc


slstm_seq.defvjp(_slstm_seq_fwd, _slstm_seq_bwd)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — honest sequential scan
# ---------------------------------------------------------------------------

def slstm_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    f_up = int(d * 4 / 3)
    ks = jax.random.split(key, 4)
    return {
        "conv_w": truncated_normal(ks[0], (4, d), 0.2),
        "conv_b": jnp.zeros((d,)),
        "w_in": truncated_normal(ks[1], (d, 4 * d), d ** -0.5),   # z,i,f,o
        "r": truncated_normal(ks[2], (4, h, hd, hd), hd ** -0.5),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.repeat(jnp.linspace(3.0, 6.0, h), hd),
                              jnp.zeros((d,))]),
        "norm_scale": jnp.ones((d,)),
        "w_up": truncated_normal(ks[3], (d, f_up), d ** -0.5),
        "w_down": truncated_normal(jax.random.fold_in(key, 9), (f_up, d),
                                   f_up ** -0.5),
    }


class SLSTMCache(NamedTuple):
    conv: jax.Array        # (B, 3, D)
    c: jax.Array           # (B, D)
    n: jax.Array           # (B, D)
    h: jax.Array           # (B, D)
    m: jax.Array           # (B, D)


def slstm_init_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    d = cfg.d_model
    z = jnp.zeros((batch, d))
    return SLSTMCache(conv=jnp.zeros((batch, 3, d)), c=z, n=z, h=z, m=z)


def slstm_train(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    b, t, d = x.shape
    xc = jax.nn.silu(conv1d_train(x, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype)))
    wxb = x @ p["w_in"].astype(x.dtype) + p["b"].astype(x.dtype)
    y = slstm_seq(cfg.num_heads, p["r"].astype(jnp.float32),
                  wxb.astype(jnp.float32), xc.astype(jnp.float32))
    y = y.astype(x.dtype)                                    # (B, T, D)
    y = _headwise_rmsnorm(y.reshape(b, t, cfg.num_heads, -1),
                          p["norm_scale"])
    y = jax.nn.gelu(y @ p["w_up"].astype(x.dtype))
    return y @ p["w_down"].astype(x.dtype)


def slstm_step(cfg: ModelConfig, p: Params, x: jax.Array,
               cache: SLSTMCache) -> Tuple[jax.Array, SLSTMCache]:
    b = x.shape[0]
    xt = x[:, 0]
    xc, conv = conv1d_step(xt, cache.conv, p["conv_w"].astype(x.dtype),
                           p["conv_b"].astype(x.dtype))
    xc = jax.nn.silu(xc)
    wxb = xt @ p["w_in"].astype(x.dtype) + p["b"].astype(x.dtype)
    (c, n, m, hid), _ = _slstm_gates(
        p["r"].astype(jnp.float32), wxb.astype(jnp.float32),
        xc.astype(jnp.float32), cache.h, (cache.c, cache.n, cache.m),
        cfg.num_heads)
    y = _headwise_rmsnorm(hid.astype(x.dtype).reshape(b, 1, cfg.num_heads, -1),
                          p["norm_scale"])[:, 0]
    y = jax.nn.gelu(y @ p["w_up"].astype(x.dtype))
    y = y @ p["w_down"].astype(x.dtype)
    return y[:, None], SLSTMCache(conv, c, n, hid, m)
