"""Mixture-of-Experts FFN with TPU-native expert parallelism.

Dispatch scheme (DESIGN.md §5): activations are data-sharded and replicated
over the ``model`` axis, experts are sharded over ``model``. Every model
rank therefore already holds all of its data-shard's tokens; it sorts them
by routed expert (stable argsort), slices the *contiguous* segment belonging
to its local experts (one dynamic_slice, static capacity bound), runs the
expert FFNs with ``jax.lax.ragged_dot`` (dropless up to the capacity bound),
scatters back, and a single psum over ``model`` combines expert partial
sums — the same collective a tensor-parallel dense FFN would need, with no
all-to-all and no (tokens × experts × capacity) dispatch tensor.

Paper tie-in (DESIGN.md §4): expert-load statistics are *expected counts*
exactly like LDA's ⟨m_vk⟩. The layer returns per-expert counts; the training
loop maintains them with the paper's incremental/decaying update (S-IVI
eq. 5 applied to router counts) and they feed the load-balance loss.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, truncated_normal


class MeshCtx(NamedTuple):
    """Axis names for shard_map sub-regions (None → single-device math)."""

    mesh: object                  # jax.sharding.Mesh
    data_axes: Tuple[str, ...]    # e.g. ("pod", "data")
    model_axis: str               # "model"
    seq_shard: bool = False       # sequence-parallel residual stream (SP)


def moe_init(cfg: ModelConfig, key) -> Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": truncated_normal(ks[0], (d, e), d ** -0.5),
        "w_gate": truncated_normal(ks[1], (e, d, f), d ** -0.5),
        "w_up": truncated_normal(ks[2], (e, d, f), d ** -0.5),
        "w_down": truncated_normal(ks[3], (e, f, d), f ** -0.5),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": truncated_normal(kk[0], (d, fs), d ** -0.5),
            "w_up": truncated_normal(kk[1], (d, fs), d ** -0.5),
            "w_down": truncated_normal(kk[2], (fs, d), fs ** -0.5),
        }
    return p


def _capacity(cfg: ModelConfig, n_tokens: int, m_size: int) -> int:
    """Static per-rank token-slot capacity."""
    rows = n_tokens * cfg.num_experts_per_tok
    cap = int(rows * cfg.moe_capacity_factor / m_size) + 8
    cap = max(cap, 8 * cfg.num_experts_per_tok)
    cap = min(cap, rows)
    return ((cap + 7) // 8) * 8 if cap >= 8 else cap


def moe_ffn_local(cfg: ModelConfig, p: Params, x: jax.Array,
                  rank: jax.Array, m_size: int
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Routed-expert FFN for one model rank's expert shard.

    x: (N, D) local tokens (replicated across model ranks);
    p["w_*"]: local expert shard (E/m, D|F, F|D); p["router"]: replicated.
    Returns the *partial* output (to be psum'd over model) and aux stats.
    """
    n, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    el = e // m_size
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                      # (N, k)
    if cfg.norm_topk_prob:
        top_p = top_p / (top_p.sum(-1, keepdims=True) + 1e-20)

    e_flat = top_i.reshape(-1)                                  # (N·k,)
    w_flat = top_p.reshape(-1)
    order = jnp.argsort(e_flat)                                 # stable
    counts = jnp.bincount(e_flat, length=e)                     # (E,)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)])             # (E+1,)

    cap = _capacity(cfg, n, m_size)
    lo = offsets[rank * el]
    hi = offsets[rank * el + el]
    # pad so the slice never clamps its start (dynamic_slice clamps when
    # lo + cap > len, silently misaligning the group offsets); padded
    # entries point at row 0 and are neutralised by the `live` mask below
    order_padded = jnp.concatenate([order, jnp.zeros((cap,), order.dtype)])
    seg_idx = jax.lax.dynamic_slice_in_dim(order_padded, lo, cap)  # (cap,)
    seg_tok = seg_idx // k
    xs = x[seg_tok]                                             # (cap, D)
    ws = w_flat[seg_idx]                                        # (cap,)
    live = jnp.arange(cap) < (hi - lo)                          # capacity mask

    # group sizes for my experts, clipped to the slice and capacity
    cum = jnp.clip(jax.lax.dynamic_slice_in_dim(offsets, rank * el, el + 1)
                   - lo, 0, cap)
    gs = jnp.diff(cum).astype(jnp.int32)
    gs = gs.at[-1].add(cap - gs.sum())          # absorb padding rows

    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["w_gate"].astype(dt), gs)) \
        * jax.lax.ragged_dot(xs, p["w_up"].astype(dt), gs)
    out_seg = jax.lax.ragged_dot(h, p["w_down"].astype(dt), gs)  # (cap, D)
    out_seg = out_seg * (ws * live)[:, None].astype(dt)

    y = jnp.zeros_like(x).at[seg_tok].add(out_seg)              # (N, D)

    if cfg.num_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"].astype(dt)) * (x @ sp["w_up"].astype(dt))
        y = y + hs @ sp["w_down"].astype(dt)

    # router statistics: expected counts (the LDA ⟨m_vk⟩ analogue) + switch
    # load-balance ingredients (batch fraction f_e, mean prob p_e)
    aux = {
        "counts": counts.astype(jnp.float32),
        "lb_loss": e * jnp.sum((counts / (n * k)) * probs.mean(0)),
        "dropped": jnp.maximum((hi - lo) - cap, 0).astype(jnp.float32),
    }
    return y, aux


def moe_ffn(cfg: ModelConfig, p: Params, x: jax.Array,
            ctx: Optional[MeshCtx]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) → (B, S, D). Caller wraps in shard_map when ctx given;
    here ctx only tells us the model-axis name for rank/psum."""
    b, s, d = x.shape
    flat = x.reshape(-1, d)
    if ctx is None:
        y, aux = moe_ffn_local(cfg, p, flat, jnp.asarray(0, jnp.int32), 1)
    else:
        m_size = ctx.mesh.shape[ctx.model_axis]
        rank = jax.lax.axis_index(ctx.model_axis)
        y, aux = moe_ffn_local(cfg, p, flat, rank, m_size)
        y = jax.lax.psum(y, ctx.model_axis)
        aux = {k2: jax.lax.psum(v, ctx.model_axis) / m_size
               for k2, v in aux.items()}
    return y.reshape(b, s, d), aux
