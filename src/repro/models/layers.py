"""Shared building blocks: norms, MLPs, embeddings, rotary/sinusoidal pos."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict


def truncated_normal(key, shape, std: float, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * std


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.zeros((d,)) if cfg.norm == "rmsnorm_gemma"
            else jnp.ones((d,))}


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:
        y = xf * jax.lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps)
        w = (1.0 + p["scale"]) if cfg.norm == "rmsnorm_gemma" else p["scale"]
        y = y * w
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x) if cfg.act == "gelu" else jax.nn.silu(x)


def mlp_init(cfg: ModelConfig, key, d: int, f: int,
             gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": truncated_normal(ks[0], (d, f), d ** -0.5),
         "w_down": truncated_normal(ks[1], (f, d), f ** -0.5)}
    if gated:
        p["w_gate"] = truncated_normal(ks[2], (d, f), d ** -0.5)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = x @ p["w_up"].astype(dt)
    if "w_gate" in p:
        h = _act(cfg, x @ p["w_gate"].astype(dt)) * h
    else:
        h = _act(cfg, h)
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., T, H, hd); positions: (T,) or (B, T)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., T, half)
    if ang.ndim == 2:                                          # (T, half)
        ang = ang[None, :, None, :]                            # (1, T, 1, half)
    else:                                                      # (B, T, half)
        ang = ang[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    c, s = jnp.cos(ang).astype(x.dtype), jnp.sin(ang).astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """(T,) → (T, d) fixed sinusoidal table (musicgen)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / half)
    ang = positions[:, None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
