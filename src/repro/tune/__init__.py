"""repro.tune — autotuned kernel policies for the LDA E-step stack.

The policy space (``KernelPolicy``, defined in ``repro.core.types`` so a
tuned config can ride on the frozen, jit-static ``LDAConfig``):

* fused padded fixed point: ``block_b`` / ``block_v``;
* memo_delta scatter pair: ``delta_block_b`` / ``delta_block_v`` /
  ``pi_block_l`` / ``scatter_block_t``;
* CSR flat-token path: ``block_t``;
* memo wire dtype and the serving double-buffer depth.

Winners live in a versioned on-disk store (``PolicyStore``) keyed on
``(backend, layout, B_or_T, V, K, W, device_kind)``; engines and the
serving path resolve them through a ``PolicyResolver`` (telemetry:
``tune.cache`` hit/miss counters, ``tune/lookup`` spans). With no store
configured everything resolves to the built-in defaults and the whole
stack is bit-identical to the pre-autotune behaviour.

Search (``repro.tune.search``) is deliberately imported lazily — it
pulls in the kernels; the store/resolve layer is dependency-light so
engines can import it at construction. CLI: ``python -m repro.tune``
(tune / show / clear); benchmark: ``benchmarks/tune_bench.py`` →
``BENCH_tune.json``. docs/tuning.md has the full story, including the
measured-vs-modeled honesty rules.
"""
from __future__ import annotations

from repro.core.types import DEFAULT_KERNEL_POLICY, KernelPolicy

from .resolve import PolicyResolver
from .store import (
    STORE_FORMAT,
    STORE_VERSION,
    PolicyKey,
    PolicyStore,
    TuneStoreWarning,
    as_store,
    current_device_kind,
    policy_from_dict,
    policy_to_dict,
)

__all__ = [
    "KernelPolicy", "DEFAULT_KERNEL_POLICY",
    "PolicyKey", "PolicyStore", "PolicyResolver", "TuneStoreWarning",
    "STORE_FORMAT", "STORE_VERSION",
    "as_store", "current_device_kind",
    "policy_from_dict", "policy_to_dict",
]
