"""Budgeted search over the kernel-policy lattice.

The search is **measurement-first with an honest fallback**:

* on a real accelerator (``jax.default_backend() == "tpu"`` — the only
  backend the kernels compile natively for) every candidate is timed by
  running the actual ``pallas_call`` pipeline under a
  ``repro.obs.SpanRecorder(device_sync=True)`` span, whose ``end(...,
  sync=out)`` blocks until the device work is done before timestamping;
* anywhere else the kernels only run in interpret mode, whose wall time
  says nothing about TPU behaviour — the objective falls back to the
  structural HBM model (``repro.tune.model``) and every record carries
  ``proxy_regime: true``. Interpret timings are never used as an
  objective.

**Eligibility is gated on correctness, not just cost**: before a
candidate may win, its γ / memo-correction / π outputs are compared
against the default-config oracle on real probe inputs — bit-equal for
same-wire candidates, within the documented bf16-wire tolerance when
the candidate flips ``wire_dtype``. Tile knobs that regroup partial-sum
accumulation (a non-resident ``block_v``, the scatter token tile, the
CSR token tile) can legitimately fail this gate; the gate is the filter
that keeps "faster" from meaning "different".

Probe shapes: verifying at the full target shape can be prohibitively
slow in interpret mode (the Arxiv vocabulary is 141k rows), so the gate
runs at a scaled-down probe that PRESERVES the residency regime of the
target (resident stays resident, streaming stays streaming — the only
structural branch the kernels take on shape). The probe shape is
recorded in the result, never hidden.

Search procedure (``tune_shape``): seeded random sampling over the
VMEM-guard-pruned lattice, then neighborhood refinement (±1 lattice step
per knob around the incumbent), then the equality gate on the
best-first-ranked candidates. If nothing both passes the gate and beats
the default, the default wins — a tuned store never regresses.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import DEFAULT_KERNEL_POLICY, KernelPolicy

from . import model as tune_model
from .store import PolicyKey, PolicyStore, current_device_kind

# knob -> ordered lattice values (None entries mean "defer to the
# kernel's own VMEM policy"); neighborhood refinement moves ±1 step here
PADDED_LATTICE: Dict[str, Sequence] = {
    "block_b": (64, 128, 256),
    "block_v": (256, 512, 1024, 2048, 4096),
    "delta_block_b": (8, 16, 32, 64),
    "delta_block_v": (None, 1024, 2048, 4096, 8192),
    "pi_block_l": (128, 256, 512, 1024),
    "scatter_block_t": (128, 256),
}
CSR_LATTICE: Dict[str, Sequence] = {
    "block_t": (256, 512, 1024, 2048),
    "delta_block_v": (None, 1024, 2048, 4096, 8192),
    "pi_block_l": (256, 512, 1024),
    "scatter_block_t": (128, 256),
}

# fused fixed point: C tile + Eφ tile + γ/Eθ/γ0 triple, double-buffered
_FUSED_VMEM_BUDGET = 12 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class TuneShape:
    """The problem identity one tune run targets (mirrors PolicyKey)."""

    task: str                   # "padded" | "csr"
    b_or_t: int                 # batch size (padded) / token budget (csr)
    v: int
    k: int
    w: Optional[int] = None     # padded token width; None on csr
    num_docs: Optional[int] = None   # csr doc rows (defaults to 64)
    backend: str = "pallas"
    layout: str = "padded"

    def key(self, device_kind: Optional[str] = None) -> PolicyKey:
        return PolicyKey(backend=self.backend, layout=self.layout,
                         b_or_t=self.b_or_t, v=self.v, k=self.k, w=self.w,
                         device_kind=device_kind or current_device_kind())


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_ok(shape: TuneShape, policy: KernelPolicy,
            stream_bytes: int = 4) -> bool:
    """Prune candidates whose tiles blow a kernel's VMEM step budget.

    The π kernel self-guards (``pi_tile_shape`` halves its B tile), but
    the fused fixed point and an *explicit* scatter V-chunk do not — an
    oversized tile is an XLA OOM at trace time on real hardware.
    """
    from repro.kernels import lda_estep, ops

    kp = _round_up(shape.k, 128)
    if shape.task == "padded":
        _, bv, _ = ops.effective_fixed_point_blocks(
            shape.b_or_t, shape.v, shape.k, block_b=policy.block_b,
            block_v=policy.block_v, stream_bytes=stream_bytes)
        bv = min(bv, _round_up(shape.v, 128))
        fused = (policy.block_b * bv * stream_bytes      # C tile
                 + bv * kp * stream_bytes                # Eφ tile
                 + 3 * policy.block_b * kp * 4)          # γ/Eθ/γ0
        if fused > _FUSED_VMEM_BUDGET:
            return False
    else:
        bt = ops.csr_effective_block_t(shape.b_or_t, shape.k, stream_bytes,
                                       policy.block_t)
        if bt * kp * stream_bytes > ops._V_RESIDENT_BYTES:
            return False
    if policy.delta_block_v is not None:
        vc = min(policy.delta_block_v, _round_up(shape.v, 128))
        nacc = 2
        step = (vc * policy.scatter_block_t
                + nacc * (vc * shape.k + policy.scatter_block_t * shape.k)
                ) * 4
        if step > lda_estep._SEG_VMEM_BUDGET:
            return False
    return True


def _lattice(shape: TuneShape) -> Dict[str, Sequence]:
    return PADDED_LATTICE if shape.task == "padded" else CSR_LATTICE


def _sample_candidates(shape: TuneShape, budget: int, seed: int,
                       allow_wire: bool,
                       stream_bytes: int) -> List[KernelPolicy]:
    """Seeded random VMEM-valid candidates (default always included)."""
    rng = random.Random(seed)
    lattice = dict(_lattice(shape))
    if allow_wire:
        lattice["wire_dtype"] = (None, "bfloat16")
    out = [DEFAULT_KERNEL_POLICY]
    seen = {DEFAULT_KERNEL_POLICY}
    attempts = 0
    while len(out) < budget + 1 and attempts < budget * 20:
        attempts += 1
        fields = {knob: rng.choice(vals) for knob, vals in lattice.items()}
        cand = dataclasses.replace(DEFAULT_KERNEL_POLICY, **fields)
        if cand in seen or not vmem_ok(shape, cand, stream_bytes):
            continue
        seen.add(cand)
        out.append(cand)
    return out


def _deviations(policy: KernelPolicy) -> int:
    """How many knobs differ from the default policy."""
    return sum(getattr(policy, f.name)
               != getattr(DEFAULT_KERNEL_POLICY, f.name)
               for f in dataclasses.fields(KernelPolicy))


def _simplify(shape: TuneShape, policy: KernelPolicy, cost_fn, scored,
              stream_bytes: int) -> KernelPolicy:
    """Revert every knob whose reversion to the default is free.

    Random sampling draws all knobs at once, so an incumbent usually
    carries changed knobs that contribute NOTHING to its cost — including
    accumulation-regrouping ones (non-resident ``block_v``, the scatter
    token tile) that would fail the bit-equality gate for no win. The
    minimal-deviation form of the incumbent is both likelier to gate and
    more legible in the store.
    """
    cur = policy
    for f in dataclasses.fields(KernelPolicy):
        dv = getattr(DEFAULT_KERNEL_POLICY, f.name)
        if getattr(cur, f.name) == dv:
            continue
        cand = dataclasses.replace(cur, **{f.name: dv})
        if not vmem_ok(shape, cand, stream_bytes):
            continue
        if cand not in scored:
            scored[cand] = cost_fn(cand)
        if scored[cand] <= scored[cur]:
            cur = cand
    return cur


def _neighbors(shape: TuneShape, policy: KernelPolicy,
               allow_wire: bool) -> List[KernelPolicy]:
    """±1 lattice step per knob around ``policy``."""
    lattice = dict(_lattice(shape))
    if allow_wire:
        lattice["wire_dtype"] = (None, "bfloat16")
    out = []
    for knob, vals in lattice.items():
        vals = list(vals)
        cur = getattr(policy, knob)
        idx = vals.index(cur) if cur in vals else 0
        for j in (idx - 1, idx + 1):
            if 0 <= j < len(vals):
                out.append(dataclasses.replace(policy, **{knob: vals[j]}))
    return out


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------

def measurement_available() -> bool:
    """True iff the kernels compile natively (a real TPU): only then do
    wall timings describe the kernels rather than the interpreter."""
    import jax

    return jax.default_backend() == "tpu"


def _modeled_cost(shape: TuneShape, policy: KernelPolicy, iters: int,
                  stream_bytes: int) -> float:
    return tune_model.modeled_cost_seconds(
        shape.task if shape.task in ("padded", "csr") else "padded",
        policy=policy, b_or_t=shape.b_or_t, v=shape.v, k=shape.k,
        w=shape.w, iters=iters, stream_bytes=stream_bytes,
        num_docs=shape.num_docs)


def _measured_cost(run, policy: KernelPolicy, *, reps: int = 5) -> float:
    """Min-of-reps wall seconds of the real kernel pipeline, timed under
    device-synced ``repro.obs`` spans."""
    from repro.obs import SpanRecorder

    rec = SpanRecorder(device_sync=True)
    run(policy)                                     # compile + warm
    for _ in range(reps):
        tok = rec.begin("tune/measure")
        out = run(policy)
        rec.end(tok, sync=out)
    return min(r["dur_us"] for r in rec.records
               if r.get("name") == "tune/measure") / 1e6


# ---------------------------------------------------------------------------
# probe inputs + the bit-equality gate
# ---------------------------------------------------------------------------

def probe_shape(shape: TuneShape, stream_bytes: int = 4) -> dict:
    """A scaled-down shape preserving the target's residency regime."""
    from repro.kernels import ops

    kp = _round_up(shape.k, 128)
    if shape.task == "padded":
        _, _, resident = ops.effective_fixed_point_blocks(
            shape.b_or_t, shape.v, shape.k, stream_bytes=stream_bytes)
        if resident:
            v = min(shape.v, 2048)
        else:
            # smallest lane-aligned V still over the residency budget
            v = _round_up(ops._V_RESIDENT_BYTES // (kp * stream_bytes), 128)
            v += 128
        return {"b": min(shape.b_or_t, 32), "v": v, "k": shape.k,
                "l": min(shape.w or 32, 32)}
    t_res = ops.csr_effective_block_t(shape.b_or_t, shape.k, stream_bytes)
    if t_res >= shape.b_or_t:                        # T-resident target
        t = min(shape.b_or_t, 1024)
    else:
        t = _round_up(ops._V_RESIDENT_BYTES // (kp * stream_bytes), 128)
        t += 128
    return {"t": t, "b": min(shape.num_docs or 64, 32),
            "v": min(shape.v, 2048), "k": shape.k}


def _probe_inputs(shape: TuneShape, probe: dict, seed: int = 0):
    """Real-statistics inputs + a small-iteration cfg for the gate."""
    import jax
    import jax.numpy as jnp

    from repro.core.math import exp_dirichlet_expectation
    from repro.core.types import LDAConfig

    rng = np.random.default_rng(seed)
    k, v = probe["k"], probe["v"]
    lam = jax.random.gamma(jax.random.key(seed), 100.0, (v, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=6,
                    estep_backend=shape.backend)
    if shape.task == "padded":
        b, l = probe["b"], probe["l"]
        ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
        cnts = jnp.asarray((rng.poisson(1.5, (b, l)) + 1).astype(np.float32))
        old_pi = jnp.asarray(rng.dirichlet(np.ones(k), (b, l))
                             .astype(np.float32))
        visited = jnp.asarray((np.arange(b) % 2).astype(bool))
        return cfg, (eb, ids, cnts, old_pi, visited)
    t, b = probe["t"], probe["b"]
    lens = np.minimum(rng.zipf(1.5, b), max(1, t // b)).astype(int)
    segs_l, ids_l, cnts_l = [], [], []
    for d, n in enumerate(lens):
        n = int(min(n, v))
        segs_l += [d] * n
        ids_l += list(rng.choice(v, size=n, replace=False))
        cnts_l += list(1.0 + rng.poisson(1.0, n))
    pad = t - len(ids_l)
    ids = jnp.asarray(np.asarray(ids_l + [0] * pad, np.int32))
    cnts = jnp.asarray(np.asarray(cnts_l + [0.0] * pad, np.float32))
    segs = jnp.asarray(np.asarray(segs_l + [0] * pad, np.int32))
    old_pi = jnp.asarray(rng.dirichlet(np.ones(k), t).astype(np.float32))
    visited = jnp.asarray((np.arange(b) % 2).astype(bool))
    return cfg, (eb, ids, cnts, segs, old_pi, visited)


def _gate_runner(shape: TuneShape, cfg, inputs):
    """A ``run(policy) -> (corr, gamma, pi)`` closure over probe inputs."""
    from repro.kernels import ops

    if shape.task == "padded":
        eb, ids, cnts, old_pi, visited = inputs

        def run(policy: KernelPolicy):
            corr, _, res = ops.memo_correction_pallas(
                cfg, eb, ids, cnts, old_pi, visited,
                pi_dtype=policy.wire_dtype or "float32", policy=policy)
            return corr, res.gamma, res.pi
    else:
        eb, ids, cnts, segs, old_pi, visited = inputs

        def run(policy: KernelPolicy):
            corr, _, res = ops.memo_correction_pallas_csr(
                cfg, eb, ids, cnts, segs, old_pi, visited,
                pi_dtype=policy.wire_dtype or "float32", policy=policy)
            return corr, res.gamma, res.pi
    return run


# documented bf16-wire tolerance (docs/tuning.md): flipping the memo wire
# re-rounds π through bfloat16, a ~2^-8 relative step on each element
BF16_WIRE_ATOL = 2e-2


def equality_check(run, default_out, policy: KernelPolicy
                   ) -> Tuple[bool, str, float]:
    """Gate one candidate against the default-config oracle outputs.

    Returns ``(ok, mode, max_abs_err)`` with mode ``"bitwise"`` for
    same-wire candidates and ``"bf16-wire"`` (tolerance compare) when
    the candidate changes ``wire_dtype``.
    """
    import jax.numpy as jnp

    got = run(policy)
    bitwise = policy.wire_dtype in (None, "float32")
    max_err = max(float(jnp.abs(jnp.asarray(g, jnp.float32)
                                - jnp.asarray(d, jnp.float32)).max())
                  for g, d in zip(got, default_out))
    if bitwise:
        ok = all(bool(jnp.array_equal(g, d))
                 for g, d in zip(got, default_out))
        return ok, "bitwise", max_err
    scale = max(float(jnp.abs(d).max()) for d in default_out) or 1.0
    return max_err <= BF16_WIRE_ATOL * scale, "bf16-wire", max_err


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TuneResult:
    shape: TuneShape
    policy: KernelPolicy              # the winner (default if nothing won)
    default_cost: float
    tuned_cost: float
    objective: str                    # "measured_seconds"|"modeled_seconds"
    proxy_regime: bool
    equality: dict
    effective: dict
    trials: int
    improvement: float                # default_cost / tuned_cost


def effective_record(shape: TuneShape, policy: KernelPolicy,
                     stream_bytes: int = 4) -> dict:
    """The tiles that actually run under ``policy`` (promotions applied)."""
    from repro.kernels import lda_estep, ops

    vc, tb = lda_estep.segment_scatter_blocks(
        shape.k, shape.v, True, block_v=policy.delta_block_v,
        block_t=policy.scatter_block_t)
    rec = {"delta_block_v": vc, "scatter_block_t": tb}
    if shape.task == "padded":
        bb, bv, resident = ops.effective_fixed_point_blocks(
            shape.b_or_t, shape.v, shape.k, block_b=policy.block_b,
            block_v=policy.block_v, stream_bytes=stream_bytes)
        rec.update(block_b=bb, block_v=bv, v_resident=resident)
    else:
        bt = ops.csr_effective_block_t(shape.b_or_t, shape.k, stream_bytes,
                                       policy.block_t)
        rec.update(block_t=bt, t_resident=bt >= shape.b_or_t)
    return rec


def tune_shape(shape: TuneShape, *, budget: int = 16, seed: int = 0,
               refine_rounds: int = 2, gate_candidates: int = 4,
               iters: int = 20, allow_bf16_wire: bool = False,
               stream_bytes: int = 4, verbose: bool = False) -> TuneResult:
    """Search the policy lattice for one problem shape.

    ``budget`` random VMEM-valid candidates + ``refine_rounds`` of ±1
    neighborhood refinement are ranked by the objective; the best
    ``gate_candidates`` are then bit-equality-gated (cheapest-first)
    and the first passer that beats the default wins.
    """
    measured = measurement_available()
    cands = _sample_candidates(shape, budget, seed, allow_bf16_wire,
                               stream_bytes)

    probe = probe_shape(shape, stream_bytes)
    cfg, inputs = _probe_inputs(shape, probe, seed)
    run = _gate_runner(shape, cfg, inputs)

    if measured:
        # time the real kernels at the TARGET shape (the gate still runs
        # at the probe shape — correctness transfers, wall time doesn't)
        if shape.task == "padded":
            target = {"b": shape.b_or_t, "v": shape.v, "k": shape.k,
                      "l": shape.w or 32}
        else:
            target = {"t": shape.b_or_t, "b": shape.num_docs or 64,
                      "v": shape.v, "k": shape.k}
        cfg_t, inputs_t = _probe_inputs(shape, target, seed)
        meas_run = _gate_runner(shape, cfg_t, inputs_t)

        def cost(p):
            return _measured_cost(meas_run, p)
        objective = "measured_seconds"
    else:
        def cost(p):
            return _modeled_cost(shape, p, iters, stream_bytes)
        objective = "modeled_seconds"

    scored = {p: cost(p) for p in cands}
    for _ in range(refine_rounds):
        best = min(scored, key=scored.get)
        fresh = [n for n in _neighbors(shape, best, allow_bf16_wire)
                 if n not in scored and vmem_ok(shape, n, stream_bytes)]
        for n in fresh:
            scored[n] = cost(n)
        if verbose and fresh:
            print(f"  refine: +{len(fresh)} neighbors around "
                  f"cost={scored[best]:.3e}")

    # canonicalize the incumbent before gating, then rank equal costs
    # toward fewest knob deviations — a cost tier is usually full of
    # candidates dragging gate-hostile knobs along for free
    _simplify(shape, min(scored, key=scored.get), cost, scored,
              stream_bytes)
    default_cost = scored[DEFAULT_KERNEL_POLICY]
    default_out = run(DEFAULT_KERNEL_POLICY)
    ranked = sorted(scored, key=lambda p: (scored[p], _deviations(p)))
    winner, eq_rec = DEFAULT_KERNEL_POLICY, {
        "checked": True, "mode": "bitwise", "max_abs_err": 0.0,
        "probe_shape": probe}
    gated = 0
    for cand in ranked:
        if cand == DEFAULT_KERNEL_POLICY or scored[cand] >= default_cost:
            break                       # nothing cheaper left to gate
        if gated >= gate_candidates:
            break
        gated += 1
        ok, mode, err = equality_check(run, default_out, cand)
        if verbose:
            print(f"  gate[{gated}] cost={scored[cand]:.3e} {mode} "
                  f"err={err:.2e} -> {'PASS' if ok else 'reject'}")
        if ok:
            winner = cand
            eq_rec = {"checked": True, "mode": mode, "max_abs_err": err,
                      "probe_shape": probe}
            break

    tuned_cost = scored[winner]
    return TuneResult(
        shape=shape, policy=winner, default_cost=default_cost,
        tuned_cost=tuned_cost, objective=objective,
        proxy_regime=not measured, equality=eq_rec,
        effective=effective_record(shape, winner, stream_bytes),
        trials=len(scored),
        improvement=default_cost / tuned_cost if tuned_cost else 1.0)


def tune_and_store(store: PolicyStore, shape: TuneShape,
                   **kwargs) -> TuneResult:
    """``tune_shape`` + persist the winner under the shape's key."""
    res = tune_shape(shape, **kwargs)
    store.put(
        shape.key(), res.policy,
        objective={"kind": res.objective,
                   "default_cost": res.default_cost,
                   "tuned_cost": res.tuned_cost,
                   "improvement": res.improvement,
                   "proxy_regime": res.proxy_regime,
                   "trials": res.trials},
        effective=res.effective,
        equality=res.equality)
    return res
