"""Versioned on-disk store for tuned kernel policies.

One JSON file holds every tuned record, keyed on the full problem
identity ``(backend, layout, B_or_T, V, K, W, device_kind)``::

    {
      "format": "repro.tune",
      "version": 1,
      "entries": {
        "pallas/padded/B64/V4096/K128/W64/cpu:cpu": {
          "key": {...},            # the key fields, for validation
          "policy": {...},         # KernelPolicy fields
          "objective": {...},      # default vs tuned cost + proxy_regime
          "effective": {...},      # the tiles that actually run
          "equality": {...},       # how bit-equality was established
        }
      }
    }

Same discipline as the PR-3 checkpoint manifest: schema-validated
round-trip, atomic writes (tmp file + ``os.replace`` in the same
directory, so concurrent writers can race but never torn-write), and a
hard rule that a *store problem is never a training problem*: corrupted,
stale-version or foreign-format files are ignored with a warning and the
engines fall back to the built-in default policy.

``device_kind`` is part of the key AND revalidated from the stored
record, so an entry tuned on one accelerator is never served on another
(a TPU-tuned tile set can be VMEM-invalid or just slow elsewhere).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from typing import Dict, Optional

from repro.core.types import KernelPolicy

STORE_FORMAT = "repro.tune"
STORE_VERSION = 1

_POLICY_FIELDS = {f.name for f in dataclasses.fields(KernelPolicy)}


class TuneStoreWarning(UserWarning):
    """A policy store was unreadable/invalid and is being ignored."""


@dataclasses.dataclass(frozen=True)
class PolicyKey:
    """The full problem identity a tuned policy is valid for.

    ``w`` is the padded batch width (``None`` for width-free entries:
    the CSR flat-token path, or a padded entry meant to serve any
    width). ``b_or_t`` is the batch size on the padded path and the
    token budget T on the CSR path.
    """

    backend: str
    layout: str
    b_or_t: int
    v: int
    k: int
    w: Optional[int]
    device_kind: str

    def path(self) -> str:
        w = "W*" if self.w is None else f"W{self.w}"
        return (f"{self.backend}/{self.layout}/B{self.b_or_t}/V{self.v}/"
                f"K{self.k}/{w}/{self.device_kind}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def current_device_kind() -> str:
    """A stable id for the accelerator policies are tuned on.

    ``platform:device_kind`` lowercased (e.g. ``cpu:cpu``,
    ``tpu:tpu-v4``) — the store never serves an entry across kinds.
    """
    import jax

    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "") or dev.platform
    return f"{dev.platform}:{kind}".replace(" ", "-").lower()


def policy_to_dict(policy: KernelPolicy) -> dict:
    return dataclasses.asdict(policy)


def policy_from_dict(d: dict) -> KernelPolicy:
    """Decode a stored policy dict; raises ``ValueError`` on junk."""
    if not isinstance(d, dict):
        raise ValueError(f"policy record must be a dict, got {type(d)}")
    unknown = set(d) - _POLICY_FIELDS
    if unknown:
        raise ValueError(f"unknown policy fields: {sorted(unknown)}")
    pol = KernelPolicy(**d)
    for f in ("block_b", "block_v", "delta_block_b", "pi_block_l",
              "scatter_block_t", "block_t", "double_buffer_depth"):
        val = getattr(pol, f)
        if not isinstance(val, int) or val <= 0:
            raise ValueError(f"policy field {f} must be a positive int, "
                             f"got {val!r}")
    if pol.delta_block_v is not None and (
            not isinstance(pol.delta_block_v, int) or pol.delta_block_v <= 0):
        raise ValueError(f"delta_block_v must be None or a positive int, "
                         f"got {pol.delta_block_v!r}")
    if pol.wire_dtype not in (None, "float32", "bfloat16"):
        raise ValueError(f"wire_dtype must be None|float32|bfloat16, "
                         f"got {pol.wire_dtype!r}")
    return pol


class PolicyStore:
    """Read/write access to one policy-store JSON file.

    Reads never raise on a bad file — they warn and behave as empty.
    Writes are read-modify-write with an atomic same-directory
    tmp+rename, so a reader never observes a torn file and concurrent
    writers at worst lose the race entry-wise, not byte-wise.
    """

    def __init__(self, path: str):
        self.path = os.fspath(path)

    # -- reading ---------------------------------------------------------
    def _read_entries(self) -> Dict[str, dict]:
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            return {}
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            warnings.warn(
                f"ignoring unreadable tune store {self.path!r}: {e}",
                TuneStoreWarning, stacklevel=3)
            return {}
        if not isinstance(doc, dict) or doc.get("format") != STORE_FORMAT:
            warnings.warn(
                f"ignoring tune store {self.path!r}: not a "
                f"{STORE_FORMAT} file", TuneStoreWarning, stacklevel=3)
            return {}
        if doc.get("version") != STORE_VERSION:
            warnings.warn(
                f"ignoring tune store {self.path!r}: version "
                f"{doc.get('version')!r} != {STORE_VERSION} (stale store — "
                f"re-run `python -m repro.tune tune`)",
                TuneStoreWarning, stacklevel=3)
            return {}
        entries = doc.get("entries")
        if not isinstance(entries, dict):
            warnings.warn(
                f"ignoring tune store {self.path!r}: no entries table",
                TuneStoreWarning, stacklevel=3)
            return {}
        return entries

    def entries(self) -> Dict[str, dict]:
        """Every stored record, keyed by its key path string."""
        return self._read_entries()

    def get(self, key: PolicyKey) -> Optional[dict]:
        """The raw record for ``key``, or None (miss OR invalid entry)."""
        rec = self._read_entries().get(key.path())
        if rec is None:
            return None
        stored_key = rec.get("key", {})
        # revalidate the identity fields from the record body: a renamed
        # or tampered entry must not smuggle a foreign-device policy in
        for field in ("backend", "layout", "device_kind"):
            if stored_key.get(field) != getattr(key, field):
                warnings.warn(
                    f"ignoring tune entry {key.path()!r}: stored "
                    f"{field}={stored_key.get(field)!r} does not match "
                    f"requested {getattr(key, field)!r}",
                    TuneStoreWarning, stacklevel=3)
                return None
        try:
            policy_from_dict(rec.get("policy", {}))
        except ValueError as e:
            warnings.warn(
                f"ignoring tune entry {key.path()!r}: bad policy ({e})",
                TuneStoreWarning, stacklevel=3)
            return None
        return rec

    def get_policy(self, key: PolicyKey) -> Optional[KernelPolicy]:
        rec = self.get(key)
        if rec is None:
            return None
        return policy_from_dict(rec["policy"])

    # -- writing ---------------------------------------------------------
    def _write_doc(self, entries: Dict[str, dict]) -> None:
        doc = {"format": STORE_FORMAT, "version": STORE_VERSION,
               "entries": entries}
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tune-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)  # atomic on POSIX: never torn
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def put(self, key: PolicyKey, policy: KernelPolicy, *,
            objective: Optional[dict] = None,
            effective: Optional[dict] = None,
            equality: Optional[dict] = None) -> dict:
        """Insert/overwrite the record for ``key``; returns the record."""
        policy_from_dict(policy_to_dict(policy))   # round-trip sanity
        rec = {"key": key.to_dict(), "policy": policy_to_dict(policy)}
        if objective is not None:
            rec["objective"] = objective
        if effective is not None:
            rec["effective"] = effective
        if equality is not None:
            rec["equality"] = equality
        entries = self._read_entries()
        entries[key.path()] = rec
        self._write_doc(entries)
        return rec

    def clear(self, prefix: Optional[str] = None) -> int:
        """Drop entries whose key path starts with ``prefix`` (all when
        None); returns how many were removed."""
        entries = self._read_entries()
        if prefix is None:
            removed = len(entries)
            kept: Dict[str, dict] = {}
        else:
            kept = {p: r for p, r in entries.items()
                    if not p.startswith(prefix)}
            removed = len(entries) - len(kept)
        self._write_doc(kept)
        return removed


def as_store(store) -> Optional[PolicyStore]:
    """Coerce a user-facing ``tune_store=`` argument.

    ``None`` stays None (no store: built-in defaults, bit-identical to
    the pre-autotune stack); a path becomes a :class:`PolicyStore`; a
    store passes through.
    """
    if store is None:
        return None
    if isinstance(store, PolicyStore):
        return store
    if isinstance(store, (str, os.PathLike)):
        return PolicyStore(store)
    raise TypeError("tune_store must be None, a path, or a "
                    f"repro.tune.PolicyStore, got {type(store).__name__}")
