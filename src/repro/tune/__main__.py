"""CLI for the kernel-policy autotuner.

::

    # search one shape and cache the winner
    python -m repro.tune tune --store tune_store.json \
        --task padded --batch 64 --vocab 4096 --topics 128 --width 64 \
        --budget 16

    # CSR: --batch is the token budget T
    python -m repro.tune tune --store tune_store.json --task csr \
        --batch 4096 --vocab 8192 --topics 128 --docs 64

    # inspect / clear
    python -m repro.tune show --store tune_store.json
    python -m repro.tune clear --store tune_store.json [--prefix pallas/]
"""
from __future__ import annotations

import argparse
import json
import sys

from .store import PolicyStore, current_device_kind


def _cmd_tune(args) -> int:
    from .search import TuneShape, tune_and_store

    backend = "csr" if args.task == "csr" else "pallas"
    layout = "csr" if args.task == "csr" else "padded"
    shape = TuneShape(task=args.task, b_or_t=args.batch, v=args.vocab,
                      k=args.topics, w=args.width, num_docs=args.docs,
                      backend=backend, layout=layout)
    store = PolicyStore(args.store)
    res = tune_and_store(store, shape, budget=args.budget, seed=args.seed,
                         iters=args.iters,
                         allow_bf16_wire=args.allow_bf16_wire,
                         verbose=args.verbose)
    kind = "measured" if not res.proxy_regime else "modeled (proxy_regime)"
    print(f"tuned {shape.task} B_or_T={shape.b_or_t} V={shape.v} "
          f"K={shape.k} W={shape.w} on {current_device_kind()}")
    print(f"  objective : {kind}")
    print(f"  default   : {res.default_cost:.3e} s")
    print(f"  tuned     : {res.tuned_cost:.3e} s "
          f"({res.improvement:.2f}x, {res.trials} trials)")
    print(f"  equality  : {res.equality['mode']} "
          f"(max|err| {res.equality['max_abs_err']:.1e}) at probe "
          f"{res.equality['probe_shape']}")
    print(f"  effective : {res.effective}")
    print(f"  policy    : {res.policy}")
    print(f"  -> {args.store} [{shape.key().path()}]")
    return 0


def _cmd_show(args) -> int:
    store = PolicyStore(args.store)
    entries = store.entries()
    if not entries:
        print(f"{args.store}: no tuned entries")
        return 0
    if args.json:
        json.dump(entries, sys.stdout, indent=2, sort_keys=True)
        print()
        return 0
    print(f"{args.store}: {len(entries)} tuned entr"
          f"{'y' if len(entries) == 1 else 'ies'}")
    for path, rec in sorted(entries.items()):
        obj = rec.get("objective", {})
        imp = obj.get("improvement")
        tag = " [proxy_regime]" if obj.get("proxy_regime") else ""
        imp_s = f" {imp:.2f}x" if isinstance(imp, (int, float)) else ""
        print(f"  {path}{imp_s}{tag}")
        if args.verbose:
            print(f"    policy={rec.get('policy')}")
            print(f"    effective={rec.get('effective')}")
            print(f"    equality={rec.get('equality')}")
    return 0


def _cmd_clear(args) -> int:
    removed = PolicyStore(args.store).clear(args.prefix)
    what = f"prefix {args.prefix!r}" if args.prefix else "all entries"
    print(f"{args.store}: removed {removed} ({what})")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("tune", help="search one shape, cache the winner")
    t.add_argument("--store", required=True, help="policy store JSON path")
    t.add_argument("--task", choices=["padded", "csr"], default="padded")
    t.add_argument("--batch", type=int, required=True,
                   help="batch size (padded) / token budget T (csr)")
    t.add_argument("--vocab", type=int, required=True)
    t.add_argument("--topics", type=int, required=True)
    t.add_argument("--width", type=int, default=None,
                   help="padded token width W (omit for a W* entry)")
    t.add_argument("--docs", type=int, default=None,
                   help="csr doc rows per batch")
    t.add_argument("--budget", type=int, default=16,
                   help="random candidates before refinement")
    t.add_argument("--iters", type=int, default=20,
                   help="fixed-point sweeps priced by the model")
    t.add_argument("--seed", type=int, default=0)
    t.add_argument("--allow-bf16-wire", action="store_true",
                   help="let the search flip the memo wire to bf16 "
                        "(tolerance-gated, docs/tuning.md)")
    t.add_argument("--verbose", action="store_true")
    t.set_defaults(fn=_cmd_tune)

    s = sub.add_parser("show", help="list tuned entries")
    s.add_argument("--store", required=True)
    s.add_argument("--json", action="store_true")
    s.add_argument("--verbose", action="store_true")
    s.set_defaults(fn=_cmd_show)

    c = sub.add_parser("clear", help="drop tuned entries")
    c.add_argument("--store", required=True)
    c.add_argument("--prefix", default=None,
                   help="only entries whose key path starts with this")
    c.set_defaults(fn=_cmd_clear)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
