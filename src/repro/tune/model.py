"""Policy-aware structural HBM cost model for the E-step kernels.

The same counting rules as ``benchmarks/kernel_bench.py`` (a block is
re-fetched only when its index-map output changes between consecutive
grid steps; jnp intermediates cost one write + one read), generalised so
every :class:`KernelPolicy` knob the search can move is priced:

* ``block_b`` — the fused fixed point re-streams Eφ once per B-tile per
  sweep in the non-resident regime, so fewer B-tiles mean fewer Eφ bytes;
* ``block_v`` — only matters through whole-V residency promotion, which
  is applied here via ``ops.effective_fixed_point_blocks`` (the
  satellite fix: the model prices the tile that actually runs);
* ``delta_block_b`` / ``pi_block_l`` — row/L padding of the (B, L, K)
  π cubes the memo pair streams;
* ``delta_block_v`` — the scatter's V-chunk count: token rows are
  re-streamed once per chunk;
* ``scatter_block_t`` — enters through the chunk-size VMEM policy
  (``segment_scatter_blocks``) and row-tile padding;
* ``wire_dtype`` — a bf16 memo wire halves the π/old_pi stream bytes of
  the scatter;
* ``block_t`` — CSR token-cube residency (``csr_effective_block_t``).

This is the *fallback* objective (tagged ``proxy_regime=True``) when no
real accelerator is present to time; on a TPU the search times the real
``pallas_call`` executions instead. Modeled seconds divide bytes by the
``repro.obs.roofline`` HW table's HBM bandwidth — the same convention as
every BENCH_*.json.
"""
from __future__ import annotations

from typing import Optional

from repro.core.types import DEFAULT_KERNEL_POLICY, KernelPolicy


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _wire_bytes(policy: KernelPolicy) -> int:
    return 2 if policy.wire_dtype == "bfloat16" else 4


def modeled_fused_hbm_bytes(b: int, v: int, k: int, l: int, iters: int, *,
                            policy: Optional[KernelPolicy] = None,
                            stream_bytes: int = 4) -> int:
    """HBM bytes of one padded E-step + memo correction under ``policy``."""
    from repro.kernels import lda_estep, ops

    pol = policy or DEFAULT_KERNEL_POLICY
    block_b, block_v, _ = ops.effective_fixed_point_blocks(
        b, v, k, block_b=pol.block_b, block_v=pol.block_v,
        stream_bytes=stream_bytes)
    nb = -(-b // block_b)
    nv = -(-_round_up(v, 128) // block_v)
    bk = b * k * 4
    if nv == 1:
        c_elems, eb_elems = b * v, v * k              # fetched once
    else:
        c_elems = iters * b * v                       # re-streamed per sweep
        eb_elems = iters * nb * v * k
    fixed_point = (c_elems + eb_elems) * stream_bytes + 3 * bk

    bp = _round_up(b, pol.delta_block_b)              # padded B (ops wrapper)
    _, bl = lda_estep.pi_tile_shape(bp, l, k, block_b=pol.delta_block_b,
                                    block_l=pol.pi_block_l)
    lp = _round_up(l, bl)                             # padded token axis
    cube = bp * lp * k * 4
    wire = _wire_bytes(pol)
    pi_rows = bp * lp * (k * wire)                    # π / old_pi wire rows
    vc, _ = lda_estep.segment_scatter_blocks(
        k, v, True, block_v=pol.delta_block_v, block_t=pol.scatter_block_t)
    nvc = -(-v // vc)
    delta = (2 * bp * lp * 4 + 2 * cube + bk          # token-π kernel
             + nvc * (2 * pi_rows + 2 * bp * lp * 4)  # per-chunk re-streams
             + 2 * v * k * 4)                         # S_new/S_old out
    return fixed_point + delta


def modeled_csr_hbm_bytes(t: int, b: int, v: int, k: int, iters: int, *,
                          policy: Optional[KernelPolicy] = None,
                          stream_bytes: int = 4) -> int:
    """HBM bytes of one CSR flat-token E-step + memo correction."""
    from repro.kernels import lda_estep, ops

    pol = policy or DEFAULT_KERNEL_POLICY
    kp = _round_up(k, 128)
    bp = _round_up(b, 8)
    bt = ops.csr_effective_block_t(t, k, stream_bytes, pol.block_t)
    tp = _round_up(t, bt)
    resident = tp == bt                               # one (T, Kp) tile
    bk = bp * k * 4
    gather = v * k * 4 + tp * 4 + tp * kp * stream_bytes
    tok_fetch = tp * (4 + 4) + tp * kp * stream_bytes
    fixed_point = (1 if resident else iters) * tok_fetch + 3 * bp * kp * 4
    wire = _wire_bytes(pol)
    vc, _ = lda_estep.segment_scatter_blocks(
        k, v, True, block_v=pol.delta_block_v, block_t=pol.scatter_block_t)
    nvc = -(-v // vc)
    delta = (tp * (4 + 4) + tp * k * stream_bytes + bk + tp * k * 4
             + nvc * (tp * (4 + 4) + 2 * tp * k * wire)  # per-chunk re-streams
             + 2 * v * k * 4)                            # S_new/S_old out
    return gather + fixed_point + delta


def modeled_cost_seconds(task: str, *, policy: Optional[KernelPolicy],
                         b_or_t: int, v: int, k: int, w: Optional[int],
                         iters: int, stream_bytes: int = 4,
                         num_docs: Optional[int] = None) -> float:
    """Modeled wall seconds of one E-step: HBM bytes / roofline HBM BW.

    ``task`` is ``"padded"`` (``b_or_t`` = batch, ``w`` = token width) or
    ``"csr"`` (``b_or_t`` = token budget T, ``num_docs`` = doc rows).
    """
    from repro.obs.roofline import HW

    if task == "padded":
        if w is None:
            raise ValueError("padded task needs a token width w")
        bytes_ = modeled_fused_hbm_bytes(b_or_t, v, k, w, iters,
                                         policy=policy,
                                         stream_bytes=stream_bytes)
    elif task == "csr":
        bytes_ = modeled_csr_hbm_bytes(b_or_t, num_docs or 64, v, k, iters,
                                       policy=policy,
                                       stream_bytes=stream_bytes)
    else:
        raise ValueError(f"unknown tune task {task!r}")
    return bytes_ / HW["hbm_bw"]
