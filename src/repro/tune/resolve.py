"""Store → :class:`KernelPolicy` resolution for engines and serving.

A :class:`PolicyResolver` wraps a :class:`~repro.tune.store.PolicyStore`
with:

* an in-memory memo (serving resolves one policy per batch width — the
  disk file is read once per distinct shape, not per batch);
* telemetry: every resolution runs under a ``tune/lookup`` span and
  bumps the ``tune.cache`` counter with ``result="hit"|"miss"`` — a
  traced run shows exactly which policies came from the store and which
  defaulted;
* a width-wildcard fallback: an exact ``(…, W, …)`` key is tried first,
  then the ``W*`` entry (written by width-free tunes, e.g. CSR), so one
  tuned record can serve every padded width of the same (B, V, K).

A resolver with no store resolves everything to ``None`` (counted as
misses): callers then leave ``cfg.kernel_policy`` unset, which is
bit-identical to the pre-autotune defaults.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.types import KernelPolicy

from .store import PolicyKey, PolicyStore, as_store, current_device_kind


class PolicyResolver:
    def __init__(self, store=None, telemetry=None,
                 device_kind: Optional[str] = None):
        from repro.obs import NULL_TELEMETRY, as_telemetry

        self.store: Optional[PolicyStore] = as_store(store)
        self.telemetry = (NULL_TELEMETRY if telemetry is None
                          else as_telemetry(telemetry))
        self.device_kind = device_kind or current_device_kind()
        self._memo: Dict[Tuple, Optional[KernelPolicy]] = {}

    def key(self, *, backend: str, layout: str, b_or_t: int, v: int,
            k: int, w: Optional[int] = None) -> PolicyKey:
        return PolicyKey(backend=backend, layout=layout, b_or_t=b_or_t,
                         v=v, k=k, w=w, device_kind=self.device_kind)

    def resolve(self, *, backend: str, layout: str, b_or_t: int, v: int,
                k: int, w: Optional[int] = None) -> Optional[KernelPolicy]:
        """The tuned policy for this shape, or None (→ defaults)."""
        memo_key = (backend, layout, b_or_t, v, k, w)
        if memo_key in self._memo:
            return self._memo[memo_key]
        key = self.key(backend=backend, layout=layout, b_or_t=b_or_t,
                       v=v, k=k, w=w)
        tel = self.telemetry
        tok = (tel.trace.begin("tune/lookup", key=key.path())
               if tel.enabled else None)
        policy = None
        if self.store is not None:
            policy = self.store.get_policy(key)
            if policy is None and w is not None:
                # width-wildcard fallback: a width-free tune of the same
                # (backend, layout, B, V, K) serves every padded width
                wild = self.key(backend=backend, layout=layout,
                                b_or_t=b_or_t, v=v, k=k, w=None)
                policy = self.store.get_policy(wild)
        if tel.enabled:
            tel.metrics.inc("tune.cache",
                            result="hit" if policy is not None else "miss")
            tel.trace.end(tok)
        self._memo[memo_key] = policy
        return policy
