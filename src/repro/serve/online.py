"""Online learning from served traffic — the paper's headline, closed.

IVI is the natural online learner: no learning rate to schedule, and a
monotone memoized bound to watchdog. ``OnlineLearner`` runs it against
the documents a ``ServingService`` is serving:

* served documents append to a ``repro.data.stream.QueueDocStream``
  (capacity-bounded; stable positions keep the π-memo bookkeeping exact
  across revisits of a growing window);
* on a background cadence the learner runs one full training pass over
  everything appended so far (``Trainer.run_pass`` — the IVI unit whose
  bound guarantee holds) and publishes the new λ through a
  ``SnapshotStore`` — an atomic versioned swap, so **inference never
  blocks on training**;
* the ELBO watchdog guards monotonicity across swaps, with one honest
  subtlety: the memoized bound is only comparable between two passes
  over the SAME document set (appends change the objective), and only
  after the init mass has retired. The learner therefore arms its
  watchdog readings exactly when ``init_frac == 0`` **and** no document
  arrived since the previous reading — the steady-state/drain passes
  where the paper's guarantee is actually in force. Unarmed readings
  are still recorded (they are the convergence trace).

The learner binds its engine lazily at the first update with traffic —
a ``DocStream`` engine reads ``num_words`` once at bind to retire the
init mass, so binding before any document exists would divide by zero;
binding late merely retires the carried mass early
(``retire_init_frac`` clamps at 0, `docs/serving.md`).

Warm start: pass the serving λ as ``lam0`` and the learner starts from
the served model via ``LDA.warm_start`` (init-mass carry — monotone-safe)
instead of a random init.
"""
from __future__ import annotations

import threading
from typing import List, Optional

from repro.core.types import LDAConfig
from repro.data.stream import QueueDocStream
from repro.obs import ElboWatchdog
from repro.serve.snapshot import SnapshotStore


class OnlineLearner:
    """Background ``partial_fit`` + atomic λ publication (see module doc).

    Args:
      cfg: the model config (must match the serving inferencer's (V, K)).
      store: the ``SnapshotStore`` to publish through.
      lam0: optional warm-start λ (the serving model); None = random init.
      capacity: online window size — documents beyond it are dropped
        (counted on ``stream.dropped``).
      max_unique: per-document unique-token cap (memo width).
      batch_size: training mini-batch size.
      cadence_s: background-thread update period.
      min_new_docs: don't start a pass until this many NEW documents
        arrived since the last one (the first bind also waits for it).
      watchdog: an ``ElboWatchdog`` (default: a fresh ``warn`` one).
      seed: engine seed.
    """

    def __init__(self, cfg: LDAConfig, store: SnapshotStore, *,
                 lam0=None, capacity: int = 4096, max_unique: int = 256,
                 batch_size: int = 64, cadence_s: float = 0.25,
                 min_new_docs: int = 8,
                 watchdog: Optional[ElboWatchdog] = None, seed: int = 0):
        self.cfg = cfg
        self.store = store
        self.stream = QueueDocStream(cfg.vocab_size, capacity=capacity,
                                     max_unique=max_unique)
        self.watchdog = watchdog or ElboWatchdog(policy="warn")
        self.cadence_s = cadence_s
        self.min_new_docs = max(int(min_new_docs), 1)
        self._lam0 = lam0
        self._batch_size = batch_size
        self._seed = seed
        self._lda = None
        self._docs_at_last_update = 0
        self._docs_at_prev_bound: Optional[int] = None
        self.updates = 0
        self.armed_observations = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- intake (called from the serving loop) ---------------------------
    def observe(self, docs) -> int:
        """Append served documents to the online window; returns how many
        were retained (the rest were dropped at capacity). Non-blocking —
        a list append per doc, no device work, no training."""
        kept = 0
        for doc in docs:
            if self.stream.append(doc) is not None:
                kept += 1
        return kept

    # -- training --------------------------------------------------------
    def _bind(self) -> None:
        from repro.lda import LDA
        lda = LDA(self.cfg, algo="ivi", batch_size=self._batch_size,
                  seed=self._seed)
        lda.fit(self.stream, epochs=0)           # bind without training
        if self._lam0 is not None:
            lda.warm_start(self._lam0)
        self._lda = lda

    @property
    def docs_trained(self) -> int:
        return 0 if self._lda is None else self._lda.docs_seen

    @property
    def model(self):
        """The live estimator (None before the first update)."""
        return self._lda

    def update_once(self, *, force: bool = False) -> Optional[int]:
        """One training pass over the current window + publish.

        Skips (returns None) while fewer than ``min_new_docs`` documents
        arrived since the last pass — unless ``force``, which runs a pass
        whenever ANY document exists (the drain path: repeated forced
        passes over a quiet window are exactly the armed-watchdog
        steady-state). Returns the published model version.
        """
        appended = self.stream.appended
        new = appended - self._docs_at_last_update
        if appended == 0 or self.stream.num_words <= 0:
            return None
        if not force and new < self.min_new_docs:
            return None
        if self._lda is None:
            self._bind()
        self._docs_at_last_update = appended
        tr = self._lda.trainer
        tr.run_pass()
        self.updates += 1
        bound = tr.full_bound()
        eng = tr.eng
        # armed iff the objective is comparable to the previous reading:
        # same document set before AND after the pass, init mass retired
        armed = (eng._watchdog_armed()
                 and self._docs_at_prev_bound == appended
                 and self.stream.appended == appended)
        self.armed_observations += int(armed)
        self.watchdog.observe(bound, step=self.updates, armed=armed)
        self._docs_at_prev_bound = appended
        snap = self.store.publish(self._lda.lam,
                                  docs_trained=self._lda.docs_seen)
        return snap.version

    def drain(self, passes: int = 2) -> List[int]:
        """Synchronous steady-state passes over the final window (no new
        traffic) — the armed-watchdog monotonicity readings. Returns the
        published versions."""
        out = []
        for _ in range(passes):
            v = self.update_once(force=True)
            if v is not None:
                out.append(v)
        return out

    # -- background cadence ----------------------------------------------
    def start(self) -> "OnlineLearner":
        """Run ``update_once`` on the background cadence until ``stop``."""
        if self._thread is not None:
            raise ValueError("learner already started")
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.cadence_s):
                self.update_once()

        self._thread = threading.Thread(target=loop, name="online-learner",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the background thread (idempotent; joins it)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "OnlineLearner":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
