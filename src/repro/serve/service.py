"""The serving loop: an open request stream under latency SLOs.

``ServingService`` drives one serving replica end to end:

    requests ──► admission control ──► BatchPacker batches ──► E-step
                  (shed / file / flush)        (`repro.serve.admission`)
                                                 │
                          OnlineLearner ◄── served documents
                          (background partial_fit, publishes λ
                           via atomic snapshot swap — `online.py`)

The loop is **open-loop real time**: requests carry scheduled arrival
times (`repro.serve.traffic`), the service sleeps until the next arrival
or the next admission-flush horizon, whichever is earlier, and a
response's latency is completion − *scheduled* arrival — queueing delay
included, the honest client-side number. Batches run through
``TopicInferencer.posterior_packed`` and block per batch, so the latency
histogram measures real device completion, not dispatch.

Every OK response records the ``model_version`` of the snapshot that
served it; under an ``OnlineLearner`` the version advances mid-stream
while in-flight batches complete on the snapshot they started with
(`docs/serving.md` on the swap semantics).

``slo_report`` summarises a run against the config's SLO targets in a
schema-versioned record (``repro.serve.slo/v1``); ``validate_slo_report``
is the schema gate the CI smoke step runs.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs import MetricsRegistry, as_telemetry
from repro.serve.admission import AdmissionController, Request, Response

SLO_SCHEMA = "repro.serve.slo/v1"


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving-loop policy knobs.

    ``slo_ms`` maps percentile names (``"p50"``/``"p95"``/``"p99"``) to
    latency targets in ms; targets are *reported* against (SLO
    attainment in ``slo_report``), never enforced in the loop.
    """

    flush_timeout_s: float = 0.05
    shed_margin_s: float = 0.0
    deadline_headroom_s: float = 0.0
    slo_ms: Optional[Dict[str, float]] = None


class ServingService:
    """One serving replica over an open request stream (see module doc).

    Args:
      inferencer: the snapshot-aware ``TopicInferencer`` to serve with —
        batch formation copies its ``packer_kwargs()``, so served batches
        are bit-equal to ``posterior_docs`` on the same admitted
        sequence.
      config: a ``ServiceConfig``.
      learner: optional ``repro.serve.online.OnlineLearner`` — every
        served document is fed to it (non-blocking append; training and
        λ publication happen on the learner's own cadence/thread).
      telemetry: ``repro.obs`` bundle. The service ALWAYS keeps a
        metrics registry (latency accounting is the product here, not
        optional observability): the bundle's when enabled, a private one
        otherwise.
      clock/sleep: injectable time sources (tests).
    """

    def __init__(self, inferencer, *, config: Optional[ServiceConfig] = None,
                 learner=None, telemetry=None,
                 clock: Callable[[], float] = time.perf_counter,
                 sleep: Callable[[float], None] = time.sleep):
        self.inf = inferencer
        self.config = config or ServiceConfig()
        self.learner = learner
        self.tel = as_telemetry(telemetry)
        self.metrics = (self.tel.metrics if self.tel.enabled
                        else MetricsRegistry())
        self._clock, self._sleep = clock, sleep
        self.admission = AdmissionController(
            inferencer.packer_kwargs(),
            flush_timeout_s=self.config.flush_timeout_s,
            shed_margin_s=self.config.shed_margin_s,
            deadline_headroom_s=self.config.deadline_headroom_s,
            metrics=self.metrics)
        self.responses: List[Response] = []
        self._t0: Optional[float] = None
        self._last_done = 0.0

    # -- the loop --------------------------------------------------------
    def _now(self) -> float:
        return self._clock() - self._t0

    def run(self, requests: Sequence[Request]) -> List[Response]:
        """Serve a scheduled request stream to completion.

        ``requests`` must be sorted by ``arrival_s`` (the traffic
        generators emit them sorted). The call blocks for the schedule's
        real duration; at stream end every open bucket is flushed and
        served (the stream is closed — no further traffic justifies
        holding a partial batch). Returns the responses, completion
        order; they accumulate on ``self.responses`` across runs.
        """
        if self._t0 is None:
            self._t0 = self._clock()
        out_start = len(self.responses)
        for req in requests:
            # sleep toward the arrival, waking for due partial flushes
            while True:
                now = self._now()
                if now >= req.arrival_s:
                    break
                due = self.admission.next_due(now)
                if due is not None and due < req.arrival_s:
                    if due > now:
                        self._sleep(due - now)
                    self._poll_flushes()
                else:
                    self._sleep(req.arrival_s - now)
            now = self._now()
            admitted, batch = self.admission.offer(req, now)
            if not admitted:
                self.responses.append(Response(
                    rid=req.rid, status="shed", gamma=None,
                    model_version=None, arrival_s=req.arrival_s,
                    done_s=now))
                self.metrics.inc("serve.shed")
            if batch is not None:
                self._serve_batch(batch)
            self._poll_flushes()
        for batch in self.admission.close(self._now()):
            self._serve_batch(batch)
        return self.responses[out_start:]

    def _poll_flushes(self) -> None:
        for batch in self.admission.poll(self._now()):
            self._serve_batch(batch)

    def _serve_batch(self, batch) -> None:
        tel = self.tel
        reqs = self.admission.take(batch.rows, self._now())
        sp = tel.trace.begin("serve/request_batch",
                             docs=len(reqs)) if tel.enabled else None
        _, gamma, n, version = self.inf.posterior_packed(batch)
        gamma.block_until_ready()          # honest completion time
        if sp is not None:
            tel.trace.end(sp)
        done = self._now()
        self._last_done = max(self._last_done, done)
        g = np.asarray(gamma[:n])
        for i, req in enumerate(reqs):
            self.responses.append(Response(
                rid=req.rid, status="ok", gamma=g[i],
                model_version=version, arrival_s=req.arrival_s,
                done_s=done))
            self.metrics.observe("serve.latency_ms",
                                 (done - req.arrival_s) * 1e3)
        self.metrics.inc("serve.batches")
        self.metrics.inc("serve.docs", len(reqs))
        if self.learner is not None:
            self.learner.observe([(r.ids, r.cnts) for r in reqs])

    # -- reporting -------------------------------------------------------
    def slo_report(self) -> dict:
        """The run summary: counts, latency percentiles, throughput,
        model-version coverage, SLO attainment (``repro.serve.slo/v1``)."""
        ok = [r for r in self.responses if r.ok]
        shed = [r for r in self.responses if r.status == "shed"]
        pct = self.metrics.percentiles("serve.latency_ms",
                                       ps=(50, 95, 99))
        lat = self.metrics.histogram_values("serve.latency_ms")
        wall = max(self._last_done, 1e-9)
        versions = sorted({r.model_version for r in ok})
        report = {
            "schema": SLO_SCHEMA,
            "offered": self.admission.offered,
            "served": len(ok),
            "shed": len(shed),
            "pending": self.admission.pending,
            "conservation_ok": (self.admission.offered
                                == len(ok) + len(shed)
                                + self.admission.pending),
            "latency_ms": {"p50": pct["p50"], "p95": pct["p95"],
                           "p99": pct["p99"],
                           "max": max(lat) if lat else float("nan")},
            "throughput_docs_s": len(ok) / wall,
            "wall_s": wall,
            "model_versions": versions,
            "every_response_versioned": all(
                r.model_version is not None for r in ok),
            "slo": {},
        }
        if self.config.slo_ms:
            for name, target in sorted(self.config.slo_ms.items()):
                got = report["latency_ms"].get(name, float("nan"))
                report["slo"][name] = {
                    "target_ms": float(target), "observed_ms": got,
                    "attained": bool(got <= target) if not math.isnan(got)
                    else False,
                }
        return report


def validate_slo_report(report: dict) -> dict:
    """Schema gate for ``slo_report`` output (the CI smoke runs this) —
    raises ``ValueError`` on any shape violation, returns the report."""
    if not isinstance(report, dict):
        raise ValueError("SLO report must be a dict")
    if report.get("schema") != SLO_SCHEMA:
        raise ValueError(f"unknown SLO report schema "
                         f"{report.get('schema')!r} (want {SLO_SCHEMA})")
    for key, typ in (("offered", int), ("served", int), ("shed", int),
                     ("pending", int), ("conservation_ok", bool),
                     ("latency_ms", dict), ("throughput_docs_s", float),
                     ("wall_s", float), ("model_versions", list),
                     ("every_response_versioned", bool), ("slo", dict)):
        if key not in report:
            raise ValueError(f"SLO report missing {key!r}")
        if not isinstance(report[key], typ):
            raise ValueError(f"SLO report field {key!r} must be "
                             f"{typ.__name__}, got "
                             f"{type(report[key]).__name__}")
    for p in ("p50", "p95", "p99", "max"):
        if p not in report["latency_ms"]:
            raise ValueError(f"latency_ms missing {p!r}")
        v = report["latency_ms"][p]
        if not isinstance(v, float) or (not math.isnan(v) and v < 0):
            raise ValueError(f"latency_ms[{p!r}] must be a non-negative "
                             f"float or NaN, got {v!r}")
    if not report["conservation_ok"]:
        raise ValueError(
            f"request conservation violated: offered={report['offered']} "
            f"!= served={report['served']} + shed={report['shed']} + "
            f"pending={report['pending']}")
    for name, slo in report["slo"].items():
        for k in ("target_ms", "observed_ms", "attained"):
            if k not in slo:
                raise ValueError(f"slo[{name!r}] missing {k!r}")
    return report
