"""Versioned model snapshots: the publish side of online serving.

``TopicInferencer`` holds its topics as one atomic ``(version,
exp_elog_beta)`` tuple (`repro.lda.infer.TopicInferencer.swap_model`);
this module is the other half of the contract — the PUBLISHER the online
learner drives:

* ``ModelSnapshot`` is the immutable record of one publication (version,
  the λ it came from, how many documents trained it, when it went live);
* ``SnapshotStore`` owns the expensive part of a swap — preprocessing λ
  to exp(E[ln φ]) and materialising it on device — OUTSIDE the serving
  swap window, then publishes to every attached inferencer with one
  ``swap_model`` call each and **measures the swap stall** (the wall time
  a concurrent request could contend on). That measured window is the
  ``serve.swap_stall_ms`` histogram ``benchmarks/service_bench.py``
  asserts a bound on: inference never blocks on training beyond it.

The store is thread-safe: one learner publishing while any number of
serving threads read is the designed case; multiple publishers serialise
on the store lock.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core.math import exp_dirichlet_expectation


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """One published model version (immutable)."""

    version: int
    exp_elog_beta: object           # (V, K) device array, ready to serve
    docs_trained: int               # documents the publisher had consumed
    published_s: float              # store-clock time publish() returned
    swap_stall_s: float             # measured swap window (see module doc)


class SnapshotStore:
    """Atomic λ publication to attached inferencers (see module docstring).

    Args:
      inferencer: a ``TopicInferencer`` to publish to (more via
        ``attach`` — e.g. one per serving replica; every attached
        inferencer receives the same version number).
      metrics: optional ``repro.obs`` ``MetricsRegistry`` — each publish
        observes ``serve.swap_stall_ms`` and bumps ``serve.publishes``.
      clock: injectable monotonic clock (tests).
    """

    def __init__(self, inferencer=None, *, metrics=None,
                 clock: Callable[[], float] = time.perf_counter):
        self._infs = [inferencer] if inferencer is not None else []
        self.metrics = metrics
        self._clock = clock
        self._lock = threading.Lock()
        self.history: List[ModelSnapshot] = []

    def attach(self, inferencer) -> None:
        """Add a serving replica; it picks up the NEXT publish (its
        current snapshot is whatever it was constructed with)."""
        with self._lock:
            self._infs.append(inferencer)

    @property
    def current(self) -> Optional[ModelSnapshot]:
        return self.history[-1] if self.history else None

    def publish(self, lam, *, docs_trained: int = 0) -> ModelSnapshot:
        """Preprocess λ and swap it into every attached inferencer.

        The preprocessing (exp(E[ln φ]) + device materialisation via
        ``block_until_ready``) happens on THIS thread before the swap
        window opens, so a serving thread never waits on an
        unmaterialised snapshot; the measured ``swap_stall_s`` covers
        only the ``swap_model`` reference assignments.
        """
        eb = exp_dirichlet_expectation(jnp.asarray(lam), axis=0)
        eb.block_until_ready()
        with self._lock:
            if not self._infs:
                raise ValueError("no inferencer attached — publish() has "
                                 "nowhere to swap the snapshot into")
            t0 = self._clock()
            version = None
            for inf in self._infs:
                v = inf.swap_model(exp_elog_beta=eb)
                version = v if version is None else version
            stall = self._clock() - t0
            snap = ModelSnapshot(version=version, exp_elog_beta=eb,
                                 docs_trained=int(docs_trained),
                                 published_s=self._clock(),
                                 swap_stall_s=stall)
            self.history.append(snap)
        if self.metrics is not None:
            self.metrics.inc("serve.publishes")
            self.metrics.observe("serve.swap_stall_ms", stall * 1e3)
        return snap

    def swap_stalls_ms(self) -> List[float]:
        """Measured swap windows of every publish, in ms (the bench's
        bounded-stall assertion reads this)."""
        return [s.swap_stall_s * 1e3 for s in self.history]
