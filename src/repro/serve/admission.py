"""Admission control: deadline- and size-aware batch formation.

The serving loop cannot hand single documents to the device — E-step
throughput comes from batching — but an open request stream never
obligingly arrives ``batch_size`` at a time. The admission controller is
the policy in between:

* **size-aware formation**: admitted requests file into a
  ``repro.data.stream.BatchPacker`` built from the serving inferencer's
  own ``packer_kwargs()`` — the SAME width ladder / CSR token budget the
  offline path uses, so a batch formed here is bit-identical to the one
  ``posterior_docs`` would have packed from the same document sequence
  (the served-vs-offline equality tests ride on this). A bucket that
  reaches ``batch_size`` emits immediately;
* **deadline-aware shedding**: a request whose remaining budget is
  already inside ``shed_margin_s`` at offer time is refused outright —
  serving it would burn device time on a response the client has given
  up on;
* **timeout-based partial flush**: ``poll(now)`` emits every open bucket
  once the oldest pending request has waited ``flush_timeout_s``, or
  once any pending deadline is within ``deadline_headroom_s`` — partial
  batches cost padding, unbounded waits cost SLOs.

Every method takes an explicit ``now`` (seconds on the caller's clock):
the controller owns no clock, which is what makes the edge cases
deterministic to test. The service layer (`repro.serve.service`) drives
it in real time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.stream import BatchPacker


@dataclasses.dataclass
class Request:
    """One inference request: a ragged document with an arrival time and
    an absolute deadline (both in seconds on the schedule clock)."""

    rid: int
    ids: np.ndarray                 # (n,) int32 unique token ids
    cnts: np.ndarray                # (n,) float32 counts
    arrival_s: float = 0.0
    deadline_s: float = math.inf


@dataclasses.dataclass
class Response:
    """The service's answer to one request.

    ``status`` is ``"ok"`` (γ present, ``model_version`` identifies the
    snapshot that served it) or ``"shed"`` (refused at admission; γ and
    version are None). ``latency_s`` is completion − scheduled arrival —
    open-loop latency, queueing included.
    """

    rid: int
    status: str
    gamma: Optional[np.ndarray]
    model_version: Optional[int]
    arrival_s: float
    done_s: float

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class AdmissionController:
    """Deadline/size-aware batch formation (see module docstring).

    Args:
      packer_kwargs: ``TopicInferencer.packer_kwargs()`` — batch size,
        vocab, layout and token budget of the serving path. The ladder is
        open-ended (``max_width=None``), exactly like serving's own
        packer.
      flush_timeout_s: max time the oldest pending request may wait
        before every open bucket flushes.
      shed_margin_s: refuse a request whose ``deadline_s − now`` is
        ≤ this margin at offer time (0 = shed only already-expired).
      deadline_headroom_s: flush open buckets early when any pending
        deadline is within this headroom (default 0 = deadline-driven
        flush only at expiry; the timeout trigger usually fires first).
      metrics: optional ``MetricsRegistry`` (``admit.*`` counters and the
        queue-wait histogram).
    """

    def __init__(self, packer_kwargs: Dict[str, object], *,
                 flush_timeout_s: float = 0.05,
                 shed_margin_s: float = 0.0,
                 deadline_headroom_s: float = 0.0,
                 metrics=None):
        if flush_timeout_s < 0:
            raise ValueError("flush_timeout_s must be >= 0")
        self.packer = BatchPacker(packer_kwargs["batch_size"],
                                  vocab_size=packer_kwargs.get("vocab_size"),
                                  layout=packer_kwargs.get("layout", "padded"),
                                  token_budget=packer_kwargs.get(
                                      "token_budget"),
                                  metrics=metrics)
        self.flush_timeout_s = flush_timeout_s
        self.shed_margin_s = shed_margin_s
        self.deadline_headroom_s = deadline_headroom_s
        self.metrics = metrics
        self._pos = 0                                   # packer positions
        # pos → (request, admit time); insertion order = admit order
        self._pending: Dict[int, Tuple[Request, float]] = {}
        self.shed: List[Request] = []
        self.offered = 0

    # -- intake ----------------------------------------------------------
    def offer(self, req: Request, now: float):
        """Admit or shed one request at time ``now``.

        Returns ``(admitted, batch)``: ``admitted`` False means the
        request was shed (recorded in ``self.shed``); ``batch`` is the
        ``PackedBatch``/``CSRBatch`` this admission completed, or None.
        """
        self.offered += 1
        if req.deadline_s - now <= self.shed_margin_s:
            self.shed.append(req)
            if self.metrics is not None:
                self.metrics.inc("admit.shed")
            return False, None
        pos = self._pos
        self._pos += 1
        self._pending[pos] = (req, now)
        if self.metrics is not None:
            self.metrics.inc("admit.admitted")
        batch = self.packer.add(pos, req.ids, req.cnts)
        return True, batch

    def take(self, rows: np.ndarray, now: float) -> List[Request]:
        """Pop the requests of an emitted batch, in row order — the
        service maps γ rows back to requests through this."""
        out = []
        for pos in np.asarray(rows, np.int64):
            req, admit_t = self._pending.pop(int(pos))
            if self.metrics is not None:
                self.metrics.observe("admit.queue_wait_ms",
                                     (now - admit_t) * 1e3)
            out.append(req)
        return out

    # -- flush policy ----------------------------------------------------
    def _oldest_admit(self) -> Optional[float]:
        for _, (_, t) in self._pending.items():
            return t
        return None

    def _min_deadline(self) -> float:
        return min((r.deadline_s for r, _ in self._pending.values()),
                   default=math.inf)

    def poll(self, now: float) -> List:
        """Emit every open bucket if a flush trigger is due at ``now``;
        an empty window (nothing pending) never flushes."""
        if not self._pending:
            return []
        oldest = self._oldest_admit()
        due = (now - oldest >= self.flush_timeout_s
               or self._min_deadline() - now <= self.deadline_headroom_s)
        if not due:
            return []
        batches = self.packer.flush()
        if batches and self.metrics is not None:
            self.metrics.inc("admit.partial_flushes", len(batches))
        return batches

    def next_due(self, now: float) -> Optional[float]:
        """The earliest future time a flush trigger fires (None when
        nothing is pending) — the service's sleep horizon."""
        if not self._pending:
            return None
        t = self._oldest_admit() + self.flush_timeout_s
        dl = self._min_deadline()
        if dl < math.inf:
            t = min(t, dl - self.deadline_headroom_s)
        return max(t, now)

    def close(self, now: float) -> List:
        """Final flush: emit everything still open (stream end)."""
        del now
        return self.packer.flush()

    # -- introspection ---------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._pending)
