"""repro.serve — the online serving service.

An open request stream served under latency SLOs while the paper's
incremental update trains on the served traffic in the background:

* `repro.serve.traffic` — seeded Poisson / bursty ON-OFF / replay
  arrival schedules;
* `repro.serve.admission` — deadline- and size-aware batch formation
  over the ragged-pipeline ``BatchPacker`` (shedding, timeout-based
  partial flush);
* `repro.serve.service` — the real-time serving loop + SLO reporting
  (``repro.serve.slo/v1`` schema);
* `repro.serve.snapshot` — atomic versioned λ publication with a
  measured swap-stall window;
* `repro.serve.online` — the background IVI learner feeding it.

See ``docs/serving.md`` for the architecture and semantics;
``benchmarks/service_bench.py`` emits ``BENCH_service.json``.
"""
from repro.serve.admission import AdmissionController, Request, Response
from repro.serve.online import OnlineLearner
from repro.serve.service import (
    SLO_SCHEMA,
    ServiceConfig,
    ServingService,
    validate_slo_report,
)
from repro.serve.snapshot import ModelSnapshot, SnapshotStore
from repro.serve.traffic import (
    onoff_arrivals,
    poisson_arrivals,
    replay_arrivals,
    requests_from_docs,
)

__all__ = [
    "Request", "Response", "AdmissionController",
    "ServiceConfig", "ServingService", "SLO_SCHEMA", "validate_slo_report",
    "ModelSnapshot", "SnapshotStore", "OnlineLearner",
    "poisson_arrivals", "onoff_arrivals", "replay_arrivals",
    "requests_from_docs",
]
