"""Synthetic request-arrival processes — seeded, reproducible.

The serving loop (`repro.serve.service`) consumes requests with
*scheduled* arrival times; this module generates the schedules:

* ``poisson_arrivals`` — the classic open-loop load model: exponential
  inter-arrival gaps at a constant ``rate``;
* ``onoff_arrivals`` — bursty traffic as an ON/OFF (interrupted Poisson)
  process: arrivals stream at ``rate`` during ``on_s``-long bursts
  separated by ``off_s``-long silences. Same mean in-burst rate, much
  heavier tail behaviour at the batcher — the shape that stresses
  timeout-based partial flushes;
* ``replay_arrivals`` — the launcher's fixed-replay mode as a schedule:
  ``n`` arrivals evenly spaced at ``rate`` (or all at t=0 — the
  closed-loop burst the old ``serve_lda --requests`` behaviour maps to).

All generators take an explicit ``seed`` and return absolute arrival
times in seconds from the schedule origin, non-decreasing. Pair a
schedule with documents via ``requests_from_docs``.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.admission import Request


def poisson_arrivals(n: int, rate: float, *, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    """``n`` absolute arrival times of a Poisson process at ``rate``/s."""
    if n < 0:
        raise ValueError("n must be >= 0")
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = np.random.default_rng(seed)
    return t0 + np.cumsum(rng.exponential(1.0 / rate, size=n))


def onoff_arrivals(n: int, rate: float, *, on_s: float, off_s: float,
                   seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """``n`` arrivals of an ON/OFF (interrupted Poisson) process.

    Arrivals are generated as a rate-``rate`` Poisson process in *busy
    time*, then mapped onto the wall clock by inserting an ``off_s``
    silence after every ``on_s`` of busy time — bursts of in-rate
    traffic separated by dead air, with the same seeded reproducibility
    as ``poisson_arrivals``.
    """
    if on_s <= 0 or off_s < 0:
        raise ValueError("need on_s > 0 and off_s >= 0")
    busy = poisson_arrivals(n, rate, seed=seed)        # busy-time stamps
    return t0 + busy + np.floor(busy / on_s) * off_s


def replay_arrivals(n: int, rate: Optional[float] = None, *,
                    t0: float = 0.0) -> np.ndarray:
    """Fixed-replay schedule: ``n`` arrivals evenly spaced at ``rate``/s,
    or ALL at ``t0`` when ``rate`` is None (the burst replay the legacy
    ``serve_lda --requests N`` loop corresponds to)."""
    if n < 0:
        raise ValueError("n must be >= 0")
    if rate is None:
        return np.full(n, t0)
    if rate <= 0:
        raise ValueError("rate must be positive")
    return t0 + np.arange(n) / rate


def requests_from_docs(docs: Sequence, arrivals: np.ndarray, *,
                       deadline_s: float = math.inf,
                       start_id: int = 0) -> List[Request]:
    """Zip documents with an arrival schedule into ``Request`` objects.

    ``docs``: ragged documents (anything ``as_ragged_doc`` accepts);
    cycled if shorter than the schedule. ``deadline_s`` is a per-request
    latency budget — each request's absolute deadline is its arrival plus
    the budget (inf = never sheddable).
    """
    from repro.data.stream import as_ragged_doc
    if len(docs) == 0 and len(arrivals):
        raise ValueError("no documents to build requests from")
    out = []
    for i, t in enumerate(np.asarray(arrivals, np.float64)):
        ids, cnts = as_ragged_doc(docs[i % len(docs)])
        out.append(Request(rid=start_id + i, ids=ids, cnts=cnts,
                           arrival_s=float(t),
                           deadline_s=float(t) + deadline_s))
    return out
