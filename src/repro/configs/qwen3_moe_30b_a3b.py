"""Qwen3-30B-A3B — 128-expert top-8 MoE, QK-norm [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import MOE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                       # every FFN is MoE
    moe_d_ff=768,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    norm_topk_prob=True,
    qk_norm=True,
    rope_theta=1_000_000.0,
    layer_pattern=(MOE,) * 48,
    source="[hf:Qwen/Qwen3-30B-A3B]",
)
