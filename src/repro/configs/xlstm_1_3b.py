"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                      # xLSTM blocks carry their own projections
    vocab_size=50304,
    layer_pattern=(MLSTM, SLSTM) * 24,
    norm="layernorm",
    act="gelu",
    use_rope=False,              # xLSTM is recurrent; no positional encoding
    chunk_size=256,
    source="[arXiv:2405.04517]",
)
