"""InternVL2-1B — InternViT frontend (stubbed) + Qwen2-0.5B language model
[arXiv:2404.16821].

Per the assignment, the vision encoder + projector are a stub:
``input_specs()`` supplies precomputed patch embeddings of shape
(batch, num_patches, d_model), which the decoder prepends to the token
embeddings. Only the language transformer is implemented here.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    modality="vision",
    num_patches=256,
    source="[arXiv:2404.16821]",
)
