"""DeepSeekMoE-16B — 2 shared + 64 routed top-6 fine-grained experts,
first layer dense [arXiv:2401.06066]."""
from repro.configs.base import ATTN, MOE, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                  # the single leading dense FFN
    dense_d_ff=10944,
    moe_d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    norm_topk_prob=False,        # deepseek-moe does not renormalise top-k
    layer_pattern=(ATTN,) + (MOE,) * 27,
    source="[arXiv:2401.06066]",
)
