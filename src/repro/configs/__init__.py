"""Config registry: 10 assigned architectures + the paper's LDA setups."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_PARALLEL, INPUT_SHAPES,
                                MAMBA2, MAMBA2_SHARED, MLSTM, MOE, SLSTM,
                                InputShape, ModelConfig)

from repro.configs import (command_r_35b, deepseek_moe_16b, gemma2_27b,
                           internvl2_1b, musicgen_medium, qwen2_5_3b,
                           qwen3_moe_30b_a3b, xlstm_1_3b, yi_9b, zamba2_1_2b)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (xlstm_1_3b, gemma2_27b, qwen3_moe_30b_a3b, internvl2_1b,
              qwen2_5_3b, musicgen_medium, command_r_35b, zamba2_1_2b,
              deepseek_moe_16b, yi_9b)
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]
