"""Zamba2-1.2B — Mamba2 backbone + shared attention block
[arXiv:2411.15242].

The shared transformer block (attention + MLP with a single set of weights)
is applied at every 6th layer on top of the Mamba2 block, re-using the same
parameters at each application — the paper's parameter-sharing scheme.
"""
from repro.configs.base import MAMBA2, MAMBA2_SHARED, ModelConfig

_pattern = tuple(
    MAMBA2_SHARED if (i % 6) == 5 else MAMBA2 for i in range(38))

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,                   # shared block MLP
    vocab_size=32000,
    layer_pattern=_pattern,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    source="[arXiv:2411.15242]",
)
