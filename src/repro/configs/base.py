"""Model / run configuration system.

Every assigned architecture is described by a ``ModelConfig``; layer
heterogeneity (gemma2 local/global alternation, zamba2 shared attention,
deepseek-moe first-dense-layer, xLSTM mLSTM/sLSTM mix) is expressed with a
``layer_pattern`` of block kinds that the transformer assembles into
homogeneous scan groups (compile time stays O(#kinds), not O(#layers)).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds understood by repro.models.transformer
ATTN = "attn"                  # global self-attention + MLP
ATTN_LOCAL = "attn_local"      # sliding-window self-attention + MLP
ATTN_PARALLEL = "attn_parallel"  # parallel-residual attention‖MLP (command-r)
MOE = "moe"                    # self-attention + MoE FFN
MAMBA2 = "mamba2"              # Mamba2 (SSD) block
MAMBA2_SHARED = "mamba2_shared"  # Mamba2 + the shared attention block (zamba2)
MLSTM = "mlstm"                # xLSTM matrix-memory block
SLSTM = "slstm"                # xLSTM scalar-memory block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // num_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False             # qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None        # used by *_local blocks
    attn_logit_softcap: Optional[float] = None  # gemma2
    final_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None         # override 1/sqrt(head_dim)
    attn_chunk: int = 512             # q-chunk for memory-bounded attention
    force_local: bool = False         # long-context variant: window everywhere

    # norm / act / misc
    norm: str = "rmsnorm"             # rmsnorm | rmsnorm_gemma | layernorm
    act: str = "silu"                 # silu | gelu
    tie_embeddings: bool = False
    mlp_gated: bool = True            # SwiGLU/GeGLU vs plain 2-layer MLP
    post_block_norm: bool = False     # gemma2 sandwich norms
    logit_scale: float = 1.0          # command-r
    use_rope: bool = True             # musicgen uses sinusoidal positions
    scale_embeddings: bool = False    # gemma2 multiplies embeddings by √d

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden size
    dense_d_ff: int = 0               # hidden size of leading dense layers
    norm_topk_prob: bool = True
    moe_capacity_factor: float = 1.25

    # SSM / recurrent
    ssm_state: int = 0                # Mamba2 d_state
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    chunk_size: int = 256             # SSD / chunkwise-mLSTM chunk

    # modality frontend stubs (vlm / audio)
    modality: Optional[str] = None    # None | "vision" | "audio"
    num_patches: int = 256            # vision embeddings prepended per sample
    num_codebooks: int = 1            # musicgen parallel codebooks

    # explicit per-layer pattern; None → all ATTN (or MOE if num_experts)
    layer_pattern: Optional[Tuple[str, ...]] = None

    # training
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True

    # citation for the config ([arXiv:...] / [hf:...])
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.num_layers
            return self.layer_pattern
        kind = MOE if self.num_experts else ATTN
        return (kind,) * self.num_layers

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                seq_len_hint: int = 128) -> "ModelConfig":
        """CPU-sized variant of the same family for smoke tests.

        ≤ 2 layers, d_model ≤ 512, ≤ 4 experts, same block kinds.
        """
        scale = d_model / self.d_model
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(self.num_kv_heads, heads))
        pat = None
        if self.layer_pattern is not None:
            # keep the *variety* of the pattern: first kinds, cycle-preserving
            pat = tuple(self.pattern[i % len(self.pattern)]
                        for i in range(num_layers))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=max(64, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            dense_d_ff=min(self.dense_d_ff, 256) if self.dense_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            chunk_size=min(self.chunk_size, max(16, seq_len_hint // 4)),
            sliding_window=(min(self.sliding_window, seq_len_hint // 2)
                            if self.sliding_window else None),
            num_patches=min(self.num_patches, 16),
            attn_chunk=64,
            layer_pattern=pat,
            remat=False,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def effective_window(cfg: ModelConfig, kind: str):
    """Window for an attention-bearing block.

    ``force_local=True`` is the documented long-context *variant* for pure
    full-attention archs (DESIGN.md §4): every attention block becomes
    sliding-window so the 500k decode cache stays bounded. gemma2's native
    local/global split is preserved (its global layers keep the full cache).
    """
    if kind == ATTN_LOCAL:
        return cfg.sliding_window
    if cfg.force_local:
        return cfg.sliding_window or 4096
    return None


def shape_variant(cfg: ModelConfig, shape: InputShape):
    """Adapt a config to an input shape; returns (cfg, note)."""
    import dataclasses as _dc
    if shape.name != "long_500k":
        return cfg, ""
    recurrent = any(k in (MAMBA2, MAMBA2_SHARED, MLSTM, SLSTM)
                    for k in cfg.pattern)
    if recurrent:
        return cfg, "native recurrent (O(1)-state) long-context decode"
    if ATTN_LOCAL in cfg.pattern:
        return cfg, "native local/global: local layers windowed, global full"
    note = ("sliding-window VARIANT (window=4096): the upstream model is "
            "pure full-attention and does not claim 500k support")
    return _dc.replace(cfg, force_local=True,
                       sliding_window=cfg.sliding_window or 4096), note
