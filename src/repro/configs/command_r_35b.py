"""Command-R 35B — parallel-residual blocks, no biases
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ATTN_PARALLEL, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    layer_pattern=(ATTN_PARALLEL,) * 40,
    norm="layernorm",
    logit_scale=0.0625,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    source="[hf:CohereForAI/c4ai-command-r-v01]",
)
