"""Gemma-2 27B — local+global alternating attention, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ATTN, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    layer_pattern=(ATTN_LOCAL, ATTN) * 23,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=144.0 ** -0.5,    # query_pre_attn_scalar = d_model / heads
    norm="rmsnorm_gemma",
    post_block_norm=True,
    scale_embeddings=True,
    act="gelu",
    tie_embeddings=True,
    source="[arXiv:2408.00118]",
)
