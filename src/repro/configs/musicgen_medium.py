"""MusicGen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

The EnCodec tokenizer / mel front-end is a stub per the assignment:
``input_specs()`` provides the (batch, seq, num_codebooks) discrete token
grid directly. The decoder embeds and sums the 4 codebooks (delay pattern
is a data-layout concern handled by the pipeline) and predicts all 4
codebooks per step through parallel output heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    use_rope=False,              # sinusoidal positions, as in the paper
    modality="audio",
    num_codebooks=4,
    source="[arXiv:2306.05284]",
)
