"""End-to-end driver: asynchronous distributed IVI (D-IVI, paper §4).

Simulates the paper's master/worker protocol exactly (vmap-bit-exact with
the shard_map production path) through the ``repro.lda.LDA`` facade:
P workers with stale parameters, dropped rounds, and the subtract-old/
add-new corrections — then compares quality across P, reproducing the
paper's central Table 2 claim: LPP is flat in P while throughput scales.

Run:  PYTHONPATH=src python examples/distributed_lda.py
"""
import time

from repro.data import PAPER_CORPORA, make_corpus
from repro.dist import DIVIConfig
from repro.lda import LDA


def main() -> None:
    spec = PAPER_CORPORA["small"]
    train = make_corpus(spec, split="train", seed=0)
    test = make_corpus(spec, split="test", seed=0)

    total_rounds = 32
    print(f"{'P':>3} {'rounds':>7} {'docs':>7} {'LPP':>9} {'wall s':>8}")
    for p in (1, 2, 4, 8):
        lda = LDA(num_topics=50, vocab_size=spec.vocab_size,
                  estep_max_iters=40, algo="divi",
                  distributed=DIVIConfig(num_workers=p, batch_size=16),
                  seed=0)
        rounds = max(total_rounds // p, 2)
        t0 = time.perf_counter()
        lda.fit(train, rounds=rounds)
        wall = time.perf_counter() - t0
        print(f"{p:>3} {rounds:>7} {lda.docs_seen:>7} "
              f"{lda.score(test):>9.4f} {wall:>8.2f}")

    print("\nWith 50% dropped rounds (paper Fig. 5):")
    lda = LDA(num_topics=50, vocab_size=spec.vocab_size, estep_max_iters=40,
              algo="divi",
              distributed=DIVIConfig(num_workers=4, batch_size=16,
                                     delay_prob=0.5), seed=0)
    lda.fit(train, rounds=16)
    print("LPP:", lda.score(test), "(still converges)")


if __name__ == "__main__":
    main()
