"""End-to-end driver: asynchronous distributed IVI (D-IVI, paper §4).

Simulates the paper's master/worker protocol exactly (vmap-bit-exact with
the shard_map production path): P workers with stale parameters, dropped
rounds, and the subtract-old/add-new corrections — then compares quality
across P, reproducing the paper's central Table 2 claim: LPP is flat in P
while throughput scales.

Run:  PYTHONPATH=src python examples/distributed_lda.py
"""
import time

from repro.core import LDAConfig, log_predictive, split_heldout
from repro.data import PAPER_CORPORA, make_corpus
from repro.dist import DIVIConfig, DIVIEngine


def main() -> None:
    spec = PAPER_CORPORA["small"]
    train = make_corpus(spec, split="train", seed=0)
    test = make_corpus(spec, split="test", seed=0)
    cfg = LDAConfig(num_topics=50, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    obs, held = split_heldout(test, seed=0)

    total_rounds = 32
    print(f"{'P':>3} {'rounds':>7} {'docs':>7} {'LPP':>9} {'wall s':>8}")
    for p in (1, 2, 4, 8):
        eng = DIVIEngine(cfg, DIVIConfig(num_workers=p, batch_size=16),
                         train, seed=0)
        t0 = time.perf_counter()
        for _ in range(max(total_rounds // p, 2)):
            eng.run_round()
        wall = time.perf_counter() - t0
        lpp = float(log_predictive(cfg, eng.lam, obs, held))
        print(f"{p:>3} {max(total_rounds // p, 2):>7} {eng.docs_seen:>7} "
              f"{lpp:>9.4f} {wall:>8.2f}")

    print("\nWith 50% dropped rounds (paper Fig. 5):")
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=4, batch_size=16,
                                     delay_prob=0.5), train, seed=0)
    for _ in range(16):
        eng.run_round()
    print("LPP:", float(log_predictive(cfg, eng.lam, obs, held)),
          "(still converges)")


if __name__ == "__main__":
    main()
