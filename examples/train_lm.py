"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the yi-9b family at a 100M reduction on a real (synthetic-text) next-
token objective, with the IAG optimizer option demonstrating the paper's
incremental-statistics idea carried over to gradient training
(DESIGN.md §4).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.optim import adamw, cosine_schedule
from repro.training import TrainState, make_train_step


def synthetic_text(rng, vocab, batch, seq):
    """Zipfian token stream with local repetition structure (so the loss
    actually falls below the uniform baseline)."""
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
    # inject bigram structure: 30% of positions copy 2 steps back
    mask = rng.random((batch, seq + 1)) < 0.3
    toks[:, 2:][mask[:, 2:]] = toks[:, :-2][mask[:, 2:]]
    return toks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    base = get_config("yi-9b")
    cfg = dataclasses.replace(
        base.reduced(num_layers=2, d_model=512, seq_len_hint=args.seq),
        vocab_size=8192, num_layers=4,
        layer_pattern=None)
    # ~4 layers × d512 ≈ 100M with the 8k vocab embedding
    params = T.init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name} reduction: {n / 1e6:.1f}M params, "
          f"{args.steps} steps")

    opt = adamw(cosine_schedule(3e-4, 20, args.steps))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg, opt))
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    losses = []
    for s in range(args.steps):
        toks = synthetic_text(rng, cfg.vocab_size, args.batch, args.seq)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        state, metrics = step(state, batch)
        losses.append(float(metrics["ce"]))
        if (s + 1) % 20 == 0:
            dt = time.perf_counter() - t0
            tps = (s + 1) * args.batch * args.seq / dt
            print(f"step={s + 1:4d} ce={losses[-1]:.4f} tokens/s={tps:.0f}")
    uniform = np.log(cfg.vocab_size)
    print(f"\nfinal ce={losses[-1]:.3f} vs uniform {uniform:.3f} — "
          f"learned structure: {losses[-1] < uniform - 1.0}")


if __name__ == "__main__":
    main()
