"""Quickstart: incremental variational inference for LDA in ~40 lines.

Trains IVI through the ``repro.lda.LDA`` facade on a synthetic
paper-shaped corpus, shows the monotone bound and held-out predictive
likelihood, contrasts with SVI, and round-trips a checkpoint.

Run:  PYTHONPATH=src python examples/quickstart.py [--corpus tiny|small]
"""
import argparse

from repro.data import PAPER_CORPORA, make_corpus
from repro.lda import LDA


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", default="small", choices=sorted(PAPER_CORPORA),
                    help="tiny is the CI smoke size")
    args = ap.parse_args()
    spec = PAPER_CORPORA[args.corpus]
    train = make_corpus(spec, split="train", seed=0)
    test = make_corpus(spec, split="test", seed=0)
    topics = min(50, spec.vocab_size // 4)

    print("== IVI (the paper's algorithm: no learning rate) ==")
    ivi = LDA(num_topics=topics, vocab_size=spec.vocab_size, algo="ivi",
              batch_size=32, seed=0)
    ivi.fit(train, test_corpus=test)   # first pass retires random-init mass
    print(f"after 1 epoch: lpp={ivi.evaluate()['lpp']:.4f}")
    prev = ivi.bound()
    for _ in range(10):
        ivi.partial_fit(steps=1)
        cur = ivi.bound()
        assert cur >= prev - 1e-2, "IVI must increase the bound monotonically"
        prev = cur
    print(f"10 incremental updates, bound increased monotonically "
          f"to {prev:.1f}")
    ivi.fit(epochs=3)
    print(f"final: lpp={ivi.evaluate()['lpp']:.4f}")

    print("\n== SVI baseline (needs a learning rate; no monotonicity) ==")
    svi = LDA(num_topics=topics, vocab_size=spec.vocab_size, algo="svi",
              batch_size=32, seed=0)
    svi.fit(train, epochs=4, test_corpus=test)
    print(f"final: lpp={svi.evaluate()['lpp']:.4f}")
    print(f"\nIVI {ivi.history.lpp[-1]:.4f} vs SVI {svi.history.lpp[-1]:.4f} "
          f"(paper Fig. 1; see EXPERIMENTS.md §Paper-validation for the "
          f"synthetic-corpus caveat)")

    print("\n== save → load → serve ==")
    ivi.save("/tmp/lda_quickstart_ckpt")
    theta = LDA.load("/tmp/lda_quickstart_ckpt").transform(test)
    print(f"topic posterior for {theta.shape[0]} unseen docs, "
          f"K={theta.shape[1]} (resume with LDA.load(...).resume(train))")


if __name__ == "__main__":
    main()
