"""Quickstart: incremental variational inference for LDA in ~40 lines.

Trains IVI through the ``repro.lda.LDA`` facade on a synthetic
paper-shaped corpus, shows the monotone bound and held-out predictive
likelihood, contrasts with SVI, and round-trips a checkpoint. The IVI
run records `repro.obs` telemetry (spans + metrics + a warn-policy ELBO
watchdog) and ends with a one-screen run summary.

Run:  PYTHONPATH=src python examples/quickstart.py [--corpus tiny|small]
                                                   [--trace PATH]
"""
import argparse

from repro.data import PAPER_CORPORA, make_corpus
from repro.lda import LDA
from repro.obs import ElboWatchdog, Telemetry, spans_by_name


def telemetry_summary(tel: Telemetry) -> None:
    """One-screen run report from the telemetry bundle (docs/observability.md)."""
    spans = spans_by_name(tel.trace.records)
    upd = spans.get("train/update", {"count": 0, "total_s": 0.0})
    tokens = tel.metrics.total("train.tokens")
    wd = tel.watchdog.status()
    print("\n== telemetry summary (repro.obs) ==")
    print(f"updates : {upd['count']} batches, "
          f"{tel.metrics.total('train.docs'):.0f} docs, {tokens:.0f} tokens "
          f"in {upd['total_s']:.2f}s of update spans "
          f"-> {tokens / max(upd['total_s'], 1e-9):.0f} tokens/s")
    tail = ", ".join(f"{b:.1f}" for b in tel.watchdog.bound_tail(4))
    print(f"bound   : tail [{tail}] (watchdog: {wd['checks']} checks, "
          f"{wd['armed_checks']} armed, {wd['violations']} violations -> "
          f"{'OK' if wd['ok'] else 'VIOLATED'})")
    print(f"topics  : {tel.metrics.value('train.effective_topics'):.1f} "
          f"effective (memo resident "
          f"{tel.metrics.value('train.memo_resident_bytes') / 1e6:.1f} MB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", default="small", choices=sorted(PAPER_CORPORA),
                    help="tiny is the CI smoke size")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also dump the IVI run's span trace as JSONL "
                         "(view via python -m repro.obs.trace --chrome)")
    args = ap.parse_args()
    spec = PAPER_CORPORA[args.corpus]
    train = make_corpus(spec, split="train", seed=0)
    test = make_corpus(spec, split="test", seed=0)
    topics = min(50, spec.vocab_size // 4)

    print("== IVI (the paper's algorithm: no learning rate) ==")
    tel = Telemetry(watchdog=ElboWatchdog(policy="warn", check_every=0))
    ivi = LDA(num_topics=topics, vocab_size=spec.vocab_size, algo="ivi",
              batch_size=32, seed=0, telemetry=tel)
    ivi.fit(train, test_corpus=test)   # first pass retires random-init mass
    print(f"after 1 epoch: lpp={ivi.evaluate()['lpp']:.4f}")
    prev = ivi.bound()
    for _ in range(10):
        ivi.partial_fit(steps=1)
        cur = ivi.bound()
        assert cur >= prev - 1e-2, "IVI must increase the bound monotonically"
        prev = cur
    print(f"10 incremental updates, bound increased monotonically "
          f"to {prev:.1f}")
    ivi.fit(epochs=3)
    print(f"final: lpp={ivi.evaluate()['lpp']:.4f}")

    print("\n== SVI baseline (needs a learning rate; no monotonicity) ==")
    svi = LDA(num_topics=topics, vocab_size=spec.vocab_size, algo="svi",
              batch_size=32, seed=0)
    svi.fit(train, epochs=4, test_corpus=test)
    print(f"final: lpp={svi.evaluate()['lpp']:.4f}")
    print(f"\nIVI {ivi.history.lpp[-1]:.4f} vs SVI {svi.history.lpp[-1]:.4f} "
          f"(paper Fig. 1; see EXPERIMENTS.md §Paper-validation for the "
          f"synthetic-corpus caveat)")

    print("\n== save → load → serve ==")
    ivi.save("/tmp/lda_quickstart_ckpt")
    theta = LDA.load("/tmp/lda_quickstart_ckpt").transform(test)
    print(f"topic posterior for {theta.shape[0]} unseen docs, "
          f"K={theta.shape[1]} (resume with LDA.load(...).resume(train))")

    telemetry_summary(tel)
    if args.trace:
        n = tel.trace.dump_jsonl(args.trace)
        print(f"trace   : {n} span records -> {args.trace}")


if __name__ == "__main__":
    main()
