"""Quickstart: incremental variational inference for LDA in ~40 lines.

Trains IVI on a synthetic paper-shaped corpus, shows the monotone bound and
held-out predictive likelihood, and contrasts with SVI.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import LDAConfig, LDAEngine
from repro.data import PAPER_CORPORA, make_corpus


def main() -> None:
    spec = PAPER_CORPORA["small"]
    train = make_corpus(spec, split="train", seed=0)
    test = make_corpus(spec, split="test", seed=0)
    cfg = LDAConfig(num_topics=50, vocab_size=spec.vocab_size)

    print("== IVI (the paper's algorithm: no learning rate) ==")
    ivi = LDAEngine(cfg, train, algo="ivi", batch_size=32, seed=0,
                    test_corpus=test)
    ivi.run_epoch()          # first pass retires the random-init mass
    print(f"after 1 epoch: lpp={ivi.evaluate()['lpp']:.4f}")
    prev = ivi.full_bound()
    for i in range(10):
        ivi.run_minibatch()
        cur = ivi.full_bound()
        assert cur >= prev - 1e-2, "IVI must increase the bound monotonically"
        prev = cur
    print(f"10 incremental updates, bound increased monotonically "
          f"to {prev:.1f}")
    for _ in range(3):
        ivi.run_epoch()
    print(f"final: lpp={ivi.evaluate()['lpp']:.4f}")

    print("\n== SVI baseline (needs a learning rate; no monotonicity) ==")
    svi = LDAEngine(cfg, train, algo="svi", batch_size=32, seed=0,
                    test_corpus=test)
    for _ in range(4):
        svi.run_epoch()
    print(f"final: lpp={svi.evaluate()['lpp']:.4f}")
    print(f"\nIVI {ivi.history.lpp[-1]:.4f} vs SVI {svi.history.lpp[-1]:.4f} "
          f"(paper Fig. 1; see EXPERIMENTS.md §Paper-validation for the "
          f"synthetic-corpus caveat)")


if __name__ == "__main__":
    main()
