"""Serving example: batched autoregressive decode with KV/recurrent caches.

Loads a reduced model per --arch (default zamba2 — hybrid Mamba2+attention,
the interesting cache case), prefills a prompt batch, then decodes with the
production serve_step. Works for every assigned arch id.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.training import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced(seq_len_hint=args.prompt_len)
    params = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    b, pl = args.batch, args.prompt_len
    cache_len = pl + args.new_tokens

    tok_shape = (b, pl, cfg.num_codebooks) if cfg.modality == "audio" \
        else (b, pl)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, tok_shape))

    caches = T.init_caches(cfg, b, cache_len, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))

    # prefill by teacher-forcing the prompt through serve_step (exercises
    # the same cache path the decode loop uses)
    t0 = time.perf_counter()
    tok = prompt[:, 0]
    for t in range(pl):
        tok = prompt[:, t]
        nxt, logits, caches = serve(params, caches, tok,
                                    jnp.full((b,), t, jnp.int32))
    print(f"prefilled {pl} tokens in {time.perf_counter() - t0:.2f}s")

    # decode
    outs = []
    t0 = time.perf_counter()
    cur = nxt
    for t in range(pl, pl + args.new_tokens):
        cur, logits, caches = serve(params, caches, cur,
                                    jnp.full((b,), t, jnp.int32))
        outs.append(np.asarray(cur))
    dt = time.perf_counter() - t0
    gen = np.stack(outs, axis=1)
    print(f"decoded {args.new_tokens} tokens × {b} seqs in {dt:.2f}s "
          f"({args.new_tokens * b / dt:.1f} tok/s)")
    print("sample:", gen[0].tolist()[:16], "…")
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
