"""`repro.tune`: store robustness, resolver telemetry, the equality gate,
and the facade/engine/serving threading of tuned kernel policies.

The acceptance bars of ISSUE 10:

* a store problem is NEVER a training problem — corrupted, stale-version
  or foreign-format store files are ignored with a ``TuneStoreWarning``
  and the run falls back to the built-in defaults;
* concurrent writers can race entry-wise but never torn-write the file
  (atomic same-directory tmp+rename);
* an entry tuned on one ``device_kind`` is never served on another, even
  if the file is renamed/tampered to claim otherwise;
* every policy the search can return is bit-equal to the default-config
  oracle on fresh inputs (or within the documented bf16-wire tolerance
  when it flips ``wire_dtype``);
* no store ⇒ bit-identical trajectories to the pre-autotune stack;
* a store hit rides ``cfg.kernel_policy`` through engine, checkpoint and
  serving (per-width) resolution.
"""
import dataclasses
import json
import threading
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import DEFAULT_KERNEL_POLICY, KernelPolicy, LDAConfig
from repro.kernels import ops
from repro.tune import search as tsearch
from repro.tune.resolve import PolicyResolver
from repro.tune.store import (STORE_FORMAT, STORE_VERSION, PolicyKey,
                              PolicyStore, TuneStoreWarning,
                              current_device_kind, policy_from_dict,
                              policy_to_dict)


def _key(**kw) -> PolicyKey:
    base = dict(backend="pallas", layout="padded", b_or_t=8, v=256, k=8,
                w=8, device_kind=current_device_kind())
    base.update(kw)
    return PolicyKey(**base)


_POL = KernelPolicy(block_b=64, delta_block_b=8)
_META = dict(objective={"kind": "modeled_seconds", "proxy_regime": True,
                        "default_cost": 1.0, "tuned_cost": 0.5,
                        "improvement": 2.0},
             effective={}, equality={"mode": "bitwise", "max_abs_err": 0.0,
                                     "probe_shape": {}})


# ---------------------------------------------------------------------------
# store robustness
# ---------------------------------------------------------------------------

def test_store_round_trip(tmp_path):
    store = PolicyStore(tmp_path / "t.json")
    key = _key()
    store.put(key, _POL, **_META)
    assert store.get_policy(key) == _POL
    rec = store.get(key)
    assert rec["objective"]["proxy_regime"] is True
    assert rec["equality"]["mode"] == "bitwise"
    # the on-disk document is schema-complete
    doc = json.loads((tmp_path / "t.json").read_text())
    assert doc["format"] == STORE_FORMAT
    assert doc["version"] == STORE_VERSION
    assert key.path() in doc["entries"]


def test_missing_store_is_a_silent_miss(tmp_path):
    store = PolicyStore(tmp_path / "absent.json")
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # a missing file is NOT junk
        assert store.get_policy(_key()) is None
        assert store.entries() == {}


@pytest.mark.parametrize("content", [
    "{not json",                                        # corrupted
    json.dumps({"format": "something.else", "version": 1, "entries": {}}),
    json.dumps({"format": STORE_FORMAT, "version": 999, "entries": {}}),
    json.dumps({"format": STORE_FORMAT, "version": STORE_VERSION,
                "entries": "not-a-table"}),
])
def test_bad_store_warns_and_is_empty(tmp_path, content):
    p = tmp_path / "bad.json"
    p.write_text(content)
    store = PolicyStore(p)
    with pytest.warns(TuneStoreWarning):
        assert store.entries() == {}
    with pytest.warns(TuneStoreWarning):
        assert store.get_policy(_key()) is None


def test_bad_policy_entry_is_ignored(tmp_path):
    store = PolicyStore(tmp_path / "t.json")
    key = _key()
    store.put(key, _POL, **_META)
    doc = json.loads(store.path and open(store.path).read())
    doc["entries"][key.path()]["policy"]["block_b"] = -4
    with open(store.path, "w") as f:
        json.dump(doc, f)
    with pytest.warns(TuneStoreWarning, match="bad policy"):
        assert store.get_policy(key) is None


def test_device_kind_mismatch_never_served(tmp_path):
    store = PolicyStore(tmp_path / "t.json")
    here, foreign = _key(), _key(device_kind="tpu:tpu-v4")
    store.put(foreign, _POL, **_META)
    # honest path: different device_kind → different key path → plain miss
    assert store.get_policy(here) is None
    # tampered path: rename the foreign entry onto this device's key path
    # — the record-body revalidation must still refuse it
    doc = json.loads(open(store.path).read())
    doc["entries"][here.path()] = doc["entries"].pop(foreign.path())
    with open(store.path, "w") as f:
        json.dump(doc, f)
    with pytest.warns(TuneStoreWarning, match="device_kind"):
        assert store.get_policy(here) is None


def test_concurrent_writers_never_tear_the_file(tmp_path):
    p = tmp_path / "t.json"
    errs = []

    def writer(i):
        try:
            store = PolicyStore(p)
            for j in range(5):
                store.put(_key(b_or_t=8 * (i + 1), v=128 * (j + 1)),
                          _POL, **_META)
        except BaseException as e:          # noqa: BLE001 — reported below
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    # racing writers may lose entries (last-writer-wins read-modify-write)
    # but the FILE must always be a valid, schema-complete document whose
    # every surviving policy decodes
    doc = json.loads(p.read_text())
    assert doc["format"] == STORE_FORMAT
    assert doc["entries"]
    for rec in doc["entries"].values():
        policy_from_dict(rec["policy"])


def test_clear_prefix(tmp_path):
    store = PolicyStore(tmp_path / "t.json")
    store.put(_key(), _POL, **_META)
    store.put(_key(backend="csr", layout="csr", w=None), _POL, **_META)
    assert store.clear("pallas/") == 1
    assert len(store.entries()) == 1
    assert store.clear() == 1
    assert store.entries() == {}


def test_policy_dict_round_trip_is_strict():
    assert policy_from_dict(policy_to_dict(_POL)) == _POL
    with pytest.raises(ValueError, match="unknown policy fields"):
        policy_from_dict({"block_b": 64, "warp_speed": 9})
    with pytest.raises(ValueError, match="positive int"):
        policy_from_dict({"block_b": 0})
    with pytest.raises(ValueError, match="wire_dtype"):
        policy_from_dict({"wire_dtype": "float16"})


# ---------------------------------------------------------------------------
# resolver: telemetry + wildcard + memo
# ---------------------------------------------------------------------------

def _tel():
    from repro.obs import as_telemetry
    return as_telemetry(True)


def test_resolver_counters_and_span(tmp_path):
    store = PolicyStore(tmp_path / "t.json")
    store.put(_key(), _POL, **_META)
    tel = _tel()
    r = PolicyResolver(store, telemetry=tel)
    hit = r.resolve(backend="pallas", layout="padded", b_or_t=8, v=256,
                    k=8, w=8)
    miss = r.resolve(backend="pallas", layout="padded", b_or_t=9999, v=256,
                     k=8, w=8)
    assert hit == _POL and miss is None
    snap = tel.metrics.snapshot()
    counts = {tuple(sorted(c["labels"].items())): c["value"]
              for c in snap["counters"] if c["name"] == "tune.cache"}
    assert counts[(("result", "hit"),)] == 1
    assert counts[(("result", "miss"),)] == 1
    lookups = [s for s in tel.trace.records if s["name"] == "tune/lookup"]
    assert len(lookups) == 2
    assert all("dur_us" in s for s in lookups)


def test_resolver_width_wildcard_fallback(tmp_path):
    store = PolicyStore(tmp_path / "t.json")
    store.put(_key(w=None), _POL, **_META)
    r = PolicyResolver(store)
    assert r.resolve(backend="pallas", layout="padded", b_or_t=8, v=256,
                     k=8, w=64) == _POL


def test_resolver_memoizes_disk_reads(tmp_path):
    p = tmp_path / "t.json"
    store = PolicyStore(p)
    store.put(_key(), _POL, **_META)
    r = PolicyResolver(store)
    kw = dict(backend="pallas", layout="padded", b_or_t=8, v=256, k=8, w=8)
    assert r.resolve(**kw) == _POL
    p.unlink()                      # a second resolve must not re-read
    assert r.resolve(**kw) == _POL


def test_resolver_without_store_resolves_none():
    assert PolicyResolver(None).resolve(backend="pallas", layout="padded",
                                        b_or_t=8, v=256, k=8, w=8) is None


# ---------------------------------------------------------------------------
# effective tiles (the no-longer-silent V-residency promotion) + VMEM guard
# ---------------------------------------------------------------------------

def test_effective_fixed_point_blocks_resident_promotion():
    # (V, K) under the residency budget: ONE V tile, flag raised
    bb, bv, resident = ops.effective_fixed_point_blocks(32, 1024, 8)
    assert resident and bb == 128
    assert bv == 1024              # promoted to the lane-aligned vocab


def test_effective_fixed_point_blocks_streaming_passthrough():
    bb, bv, resident = ops.effective_fixed_point_blocks(256, 141_952, 128)
    assert not resident and (bb, bv) == (128, 512)   # defaults untouched


def test_vmem_ok_prunes_oversized_tiles():
    arxiv = tsearch.TuneShape(task="padded", b_or_t=256, v=141_952, k=128,
                              w=128)
    assert tsearch.vmem_ok(arxiv, DEFAULT_KERNEL_POLICY)
    assert tsearch.vmem_ok(arxiv, KernelPolicy(block_b=256, block_v=4096))
    # C tile + Eφ tile alone exceed the fused 12 MB step budget
    assert not tsearch.vmem_ok(arxiv,
                               KernelPolicy(block_b=256, block_v=8192))
    # explicit scatter V-chunk whose step blows the segment budget
    assert not tsearch.vmem_ok(
        arxiv, KernelPolicy(delta_block_v=8192, scatter_block_t=256))


def test_sampled_candidates_are_vmem_valid_and_include_default():
    shape = tsearch.TuneShape(task="padded", b_or_t=256, v=141_952, k=128,
                              w=128)
    cands = tsearch._sample_candidates(shape, budget=12, seed=3,
                                       allow_wire=True, stream_bytes=4)
    assert cands[0] == DEFAULT_KERNEL_POLICY
    assert len(set(cands)) == len(cands)
    assert all(tsearch.vmem_ok(shape, c) for c in cands)


# ---------------------------------------------------------------------------
# the equality gate (one compiled probe, shared across tests)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gate_probe():
    """One small V-resident probe: (shape, run, default outputs)."""
    shape = tsearch.TuneShape(task="padded", b_or_t=16, v=512, k=8, w=16)
    probe = tsearch.probe_shape(shape)
    cfg, inputs = tsearch._probe_inputs(shape, probe, seed=0)
    run = tsearch._gate_runner(shape, cfg, inputs)
    return shape, run, run(DEFAULT_KERNEL_POLICY)


def test_policy_none_is_bit_identical_to_default_policy(gate_probe):
    # the no-store regression at the kernel layer: policy=None and the
    # explicit default policy take the exact same tile path
    _, run, default_out = gate_probe
    ok, mode, err = tsearch.equality_check(run, default_out,
                                           DEFAULT_KERNEL_POLICY)
    assert ok and mode == "bitwise" and err == 0.0


def test_block_b_variant_is_bit_equal(gate_probe):
    _, run, default_out = gate_probe
    ok, mode, _ = tsearch.equality_check(run, default_out,
                                         KernelPolicy(block_b=64))
    assert ok and mode == "bitwise"


def test_bf16_wire_within_documented_tolerance(gate_probe):
    _, run, default_out = gate_probe
    ok, mode, err = tsearch.equality_check(
        run, default_out, KernelPolicy(wire_dtype="bfloat16"))
    assert mode == "bf16-wire" and ok
    assert 0.0 < err                      # it IS a different wire...
    scale = max(float(jnp.abs(d).max()) for d in default_out)
    assert err <= tsearch.BF16_WIRE_ATOL * scale


def test_search_winner_bit_equal_on_fresh_inputs(gate_probe):
    """Property: whatever tune_shape returns must reproduce the default
    trajectory on inputs the gate never saw."""
    shape, _, _ = gate_probe
    res = tsearch.tune_shape(shape, budget=4, seed=1, gate_candidates=2,
                             refine_rounds=1)
    assert res.tuned_cost <= res.default_cost
    assert res.equality["checked"]
    probe = tsearch.probe_shape(shape)
    cfg, inputs = tsearch._probe_inputs(shape, probe, seed=12345)
    fresh = tsearch._gate_runner(shape, cfg, inputs)
    ok, mode, _ = tsearch.equality_check(fresh, fresh(DEFAULT_KERNEL_POLICY),
                                         res.policy)
    assert ok, f"search winner {res.policy} diverged on fresh inputs ({mode})"


def test_probe_preserves_residency_regime():
    res_shape = tsearch.TuneShape(task="padded", b_or_t=64, v=2048, k=8,
                                  w=32)
    stream_shape = tsearch.TuneShape(task="padded", b_or_t=256, v=141_952,
                                     k=128, w=128)
    p_res = tsearch.probe_shape(res_shape)
    p_str = tsearch.probe_shape(stream_shape)
    assert ops.effective_fixed_point_blocks(
        p_res["b"], p_res["v"], p_res["k"])[2]
    assert not ops.effective_fixed_point_blocks(
        p_str["b"], p_str["v"], p_str["k"])[2]


# ---------------------------------------------------------------------------
# facade / engine / checkpoint / serving threading
# ---------------------------------------------------------------------------

def _facade(spec, tmp_path=None, *, store=None, **kw):
    from repro.lda import LDA
    cfg = LDAConfig(num_topics=4, vocab_size=spec.vocab_size,
                    estep_max_iters=8, estep_backend="pallas")
    return LDA(cfg, algo="ivi", batch_size=16, seed=3, tune_store=store,
               **kw)


def test_facade_no_store_is_bit_identical(tiny_corpus, tmp_path):
    train, _, spec = tiny_corpus
    a = _facade(spec).fit(train, epochs=1)
    # a configured-but-empty store resolves to a miss — same trajectory
    b = _facade(spec, store=str(tmp_path / "empty.json")).fit(train,
                                                              epochs=1)
    assert a.cfg.kernel_policy is None and b.cfg.kernel_policy is None
    np.testing.assert_array_equal(np.asarray(a.lam), np.asarray(b.lam))


def test_facade_store_hit_rides_cfg_and_checkpoint(tiny_corpus, tmp_path):
    train, _, spec = tiny_corpus
    store = PolicyStore(tmp_path / "t.json")
    pol = KernelPolicy(block_b=64, delta_block_b=8)
    store.put(PolicyKey(backend="pallas", layout="padded", b_or_t=16,
                        v=spec.vocab_size, k=4, w=train.max_unique,
                        device_kind=current_device_kind()), pol, **_META)
    from repro.lda import LDA
    lda = _facade(spec, store=store).partial_fit(train, steps=2)
    assert lda.cfg.kernel_policy == pol
    assert lda.trainer.eng.cfg.kernel_policy == pol
    ck = str(tmp_path / "ck")
    lda.save(ck)
    loaded = LDA.load(ck)
    # the checkpoint carries the ACTIVE policy as a real KernelPolicy
    # (hashable: cfg is a jit static arg) — resumed runs replay the tuned
    # trajectory without needing the store
    assert loaded.cfg.kernel_policy == pol
    assert isinstance(loaded.cfg.kernel_policy, KernelPolicy)
    hash(loaded.cfg)
    loaded.resume(train)
    loaded.partial_fit(steps=1)
    lda.partial_fit(steps=1)
    np.testing.assert_array_equal(np.asarray(lda.lam),
                                  np.asarray(loaded.lam))


def test_inferencer_resolves_per_width(tiny_corpus, tmp_path):
    from repro.lda.infer import TopicInferencer
    _, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=4, vocab_size=spec.vocab_size,
                    estep_max_iters=8, estep_backend="pallas")
    pol = KernelPolicy(block_b=64)
    store = PolicyStore(tmp_path / "t.json")
    store.put(PolicyKey(backend="pallas", layout="padded", b_or_t=8,
                        v=spec.vocab_size, k=4, w=16,
                        device_kind=current_device_kind()), pol, **_META)
    lam = jnp.ones((spec.vocab_size, 4), jnp.float32)
    tel = _tel()
    inf = TopicInferencer(cfg, lam, batch_size=8, tune_store=store,
                          telemetry=tel)
    assert inf._cfg_for_width(16).kernel_policy == pol      # tuned width
    assert inf._cfg_for_width(32).kernel_policy is None     # miss → default
    assert inf._cfg_for_width(16).kernel_policy == pol      # memoized
    counts = {tuple(sorted(c["labels"].items())): c["value"]
              for c in tel.metrics.snapshot()["counters"]
              if c["name"] == "tune.cache"}
    assert counts[(("result", "hit"),)] == 1
    assert counts[(("result", "miss"),)] == 1


def test_inferencer_buffer_depth_from_policy(tiny_corpus):
    from repro.lda.infer import TopicInferencer
    _, _, spec = tiny_corpus
    lam = jnp.ones((spec.vocab_size, 4), jnp.float32)
    base = LDAConfig(num_topics=4, vocab_size=spec.vocab_size)
    assert TopicInferencer(base, lam)._buffer_depth() == 2
    deep = dataclasses.replace(
        base, kernel_policy=KernelPolicy(double_buffer_depth=4))
    assert TopicInferencer(deep, lam)._buffer_depth() == 4


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_tune_show_clear(tmp_path, capsys):
    from repro.tune.__main__ import main
    p = str(tmp_path / "t.json")
    rc = main(["tune", "--store", p, "--task", "padded", "--batch", "8",
               "--vocab", "256", "--topics", "8", "--width", "8",
               "--budget", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "objective" in out and "equality" in out
    store = PolicyStore(p)
    assert len(store.entries()) == 1
    rec = next(iter(store.entries().values()))
    assert rec["objective"]["proxy_regime"] is \
        (not tsearch.measurement_available())
    assert main(["show", "--store", p]) == 0
    assert "tuned entr" in capsys.readouterr().out
    assert main(["clear", "--store", p]) == 0
    assert store.entries() == {}
