"""EStepBackend / MemoStore contracts (the E-step/memo refactor).

* backend equivalence: gather / dense / pallas (interpret mode) produce
  the same EStepResult and the same memo correction on random ragged
  batches;
* MemoStore oracle: the dense store keeps the full-pass identity
  ⟨m_vk⟩ == Σ_d s_d exactly, the bf16 chunked store keeps it within bf16
  tolerance, and the γ-only store reconstructs π faithfully right after a
  write;
* epoch coverage: the D % batch_size tail is visited (init_frac retires
  to exact zero — the eq. 4 exactness precondition);
* fused-kernel structure: one pallas_call per fixed point (none under a
  loop) and no (B, L, K) jnp arithmetic in the correction jaxpr.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, LDAEngine
from repro.core.estep import (BowBatch, estep_gather, get_backend,
                              quantize_pi, scatter_sstats, warm_start_gamma)
from repro.core.math import exp_dirichlet_expectation
from repro.core.memo import make_memo_store, memo_footprint_bytes
from repro.core.types import Corpus
from repro.data.bow import bucket_corpus, bucket_padding_stats, corpus_from_docs
from repro.launch.hlo_analysis import dense_vocab_cubes, pallas_call_sites

BACKENDS = ("gather", "dense", "pallas", "csr")


def _ragged_batch(seed, b=12, vocab=200, k=7, mean_len=25):
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, vocab, size=max(2, int(rng.poisson(mean_len))))
            for _ in range(b)]
    corpus = corpus_from_docs(docs, vocab)
    cfg = LDAConfig(num_topics=k, vocab_size=vocab, estep_max_iters=50)
    lam = jax.random.gamma(jax.random.key(seed), 100.0, (vocab, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    return cfg, corpus, eb


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [0, 3])
def test_backend_equivalence_solve(backend, seed):
    """All backends return the same (γ, π, sstats) on ragged batches."""
    cfg, corpus, eb = _ragged_batch(seed)
    batch = BowBatch(corpus.token_ids, corpus.counts)
    want = get_backend("gather").solve(cfg, eb, batch)
    got = get_backend(backend).solve(cfg, eb, batch)
    np.testing.assert_allclose(got.gamma, want.gamma, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(got.pi, want.pi, rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(got.sstats, want.sstats, rtol=1e-2, atol=2e-3)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_equivalence_correction(backend):
    """solve_correction agrees across backends (memo warm start + delta)."""
    cfg, corpus, eb = _ragged_batch(1)
    batch = BowBatch(corpus.token_ids, corpus.counts)
    rng = np.random.default_rng(1)
    base = get_backend("gather").solve(cfg, eb, batch)
    visited = jnp.asarray(rng.random(corpus.num_docs) < 0.5)
    old_pi = jnp.where(visited[:, None, None], base.pi, 0.0)
    want = get_backend("gather").solve_correction(cfg, eb, batch, old_pi,
                                                  visited)
    got = get_backend(backend).solve_correction(cfg, eb, batch, old_pi,
                                                visited)
    np.testing.assert_allclose(got[0], want[0], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(got[1]), float(want[1]), rtol=1e-6)
    np.testing.assert_allclose(got[2].pi, want[2].pi, rtol=2e-3, atol=1e-4)


def _mass_identity_gap(eng):
    """max |⟨m_vk⟩ − Σ_d scatter(cnt·π_store)| over the corpus."""
    pi, _ = eng.memo.gather(np.arange(eng.corpus.num_docs))
    rebuilt = scatter_sstats(eng.corpus.token_ids,
                             eng.corpus.counts[:, :, None] * pi,
                             eng.cfg.vocab_size)
    return float(jnp.abs(eng.state.m_vk - rebuilt).max())


@pytest.mark.parametrize("store,tol", [("dense", 5e-4), ("chunked", 2e-3)])
def test_memo_store_mass_identity(store, tol, tiny_corpus):
    """Full-pass ⟨m_vk⟩ == Σ_d s_d, for the dense AND the bf16 store.

    The bf16 store stays tight because π is rounded through the wire dtype
    *before* the add-new scatter (estep.quantize_pi): the accumulator adds
    exactly what the store holds, so low precision shrinks no invariant —
    only the memo footprint."""
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=50)
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0,
                    memo_store=store, chunk_docs=40)
    eng.run_epoch()
    for _ in range(4):
        eng.run_minibatch()
    assert float(eng.state.init_frac) == 0.0
    gap = _mass_identity_gap(eng)
    assert gap < tol, gap
    if store == "dense":
        # eq. 4 exactness: λ = β₀ + ⟨m_vk⟩ after the covering pass
        np.testing.assert_allclose(np.asarray(eng.state.lam),
                                   cfg.beta0 + np.asarray(eng.state.m_vk),
                                   rtol=1e-5, atol=1e-5)


def test_gamma_store_reconstructs_pi(tiny_corpus):
    """Right after a write the γ-only store reproduces the dense store's π
    (same λ-epoch), and S-IVI still trains through it."""
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=50)
    dense = LDAEngine(cfg, train, algo="sivi", batch_size=16, seed=0)
    gamma = LDAEngine(cfg, train, algo="sivi", batch_size=16, seed=0,
                      memo_store="gamma", chunk_docs=train.num_docs)
    rows = np.arange(16)
    dense.run_minibatch(rows)
    gamma.run_minibatch(rows)
    pi_d, vis_d = dense.memo.gather(rows)
    pi_g, vis_g = gamma.memo.gather(rows)
    np.testing.assert_array_equal(np.asarray(vis_d), np.asarray(vis_g))
    np.testing.assert_allclose(np.asarray(pi_g), np.asarray(pi_d),
                               rtol=2e-2, atol=2e-2)   # bf16 snapshot
    assert gamma.memo.footprint_bytes() < dense.memo.footprint_bytes()


def test_gamma_store_rejected_for_exact_ivi(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    with pytest.raises(ValueError, match="eq. 4"):
        LDAEngine(cfg, train, algo="ivi", batch_size=16, memo_store="gamma")


def test_epoch_tail_documents_are_visited():
    """D % batch_size tail docs must be visited: init_frac retires to an
    exact 0 after ONE epoch and λ = β₀ + ⟨m_vk⟩ holds (the old epoch order
    dropped the tail and the eq. 4 exactness never arrived)."""
    rng = np.random.default_rng(7)
    docs = [rng.integers(0, 150, size=rng.integers(5, 40))
            for _ in range(37)]                      # 37 % 8 = 5 tail docs
    corpus = corpus_from_docs(docs, 150)
    cfg = LDAConfig(num_topics=5, vocab_size=150, estep_max_iters=50)
    eng = LDAEngine(cfg, corpus, algo="ivi", batch_size=8, seed=0)
    eng.run_epoch()
    assert eng.docs_seen == 37
    assert bool(eng.memo.visited.all())
    assert float(eng.state.init_frac) == 0.0
    np.testing.assert_allclose(np.asarray(eng.state.lam),
                               cfg.beta0 + np.asarray(eng.state.m_vk),
                               rtol=1e-5, atol=1e-5)


def test_bucketed_epoch_covers_and_shrinks_padding(tiny_corpus):
    train, test, spec = tiny_corpus
    buckets = bucket_corpus(train)
    covered = np.sort(np.concatenate(buckets.doc_idx))
    np.testing.assert_array_equal(covered, np.arange(train.num_docs))
    stats = bucket_padding_stats(train, buckets)
    assert stats["slot_ratio"] <= 1.0
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0,
                    bucket_by_length=True)
    eng.run_epoch()
    assert eng.docs_seen == train.num_docs
    assert bool(eng.memo.visited.all())
    assert float(eng.state.init_frac) == 0.0


def test_fused_pallas_launch_structure():
    """One pallas_call per fixed point plus the memo_delta pair: no kernel
    under a while/scan, no (B, L, K) jnp arithmetic, and ZERO dense
    vocab-sized rank-3 values (the (nb, V, K) one-hot partials the
    segment-sum scatter eliminates) in the fused correction jaxpr."""
    cfg, corpus, eb = _ragged_batch(2)
    batch = BowBatch(corpus.token_ids, corpus.counts)
    old_pi = jnp.zeros(corpus.token_ids.shape + (cfg.num_topics,))
    visited = jnp.zeros((corpus.num_docs,), bool)

    def fused_corr():
        return get_backend("pallas").solve_correction(cfg, eb, batch,
                                                      old_pi, visited)

    fused = pallas_call_sites(fused_corr)
    # fixed point + token-π + segment scatter
    assert fused["total"] == 3, fused
    assert fused["under_loop"] == 0, fused
    assert fused["blk_intermediates"] == 0, fused
    assert dense_vocab_cubes(fused_corr, cfg.vocab_size) == 0

    # the retired one-hot baseline DOES allocate the dense partials — the
    # guard must be able to see them, or the zero above proves nothing
    from repro.kernels import lda_estep
    eb_tok = eb[corpus.token_ids]
    et = jnp.ones((corpus.num_docs, cfg.num_topics), jnp.float32)
    assert dense_vocab_cubes(
        lambda: lda_estep.memo_delta_onehot(
            corpus.token_ids, corpus.counts, eb_tok, et, cfg.vocab_size,
            old_pi=old_pi, block_b=4),
        cfg.vocab_size) > 0

    from repro.kernels.ops import estep_pallas_sweeps
    legacy = pallas_call_sites(
        lambda: estep_pallas_sweeps(cfg, eb, corpus.token_ids,
                                    corpus.counts))
    assert legacy["under_loop"] >= 1            # the old one-launch-per-sweep


def test_pallas_correction_long_token_axis():
    """L=8192 — far past the one-hot path's ~4k VMEM cap — must match the
    jnp backend at fp32 tolerance (the L grid axis acceptance bar)."""
    b, l, vocab, k = 4, 8192, 300, 8
    rng = np.random.default_rng(11)
    ids = jnp.asarray(rng.integers(0, vocab, (b, l)).astype(np.int32))
    cnts = jnp.asarray((rng.poisson(0.8, (b, l))).astype(np.float32))
    cfg = LDAConfig(num_topics=k, vocab_size=vocab, estep_max_iters=15,
                    estep_backend="pallas")
    lam = jax.random.gamma(jax.random.key(4), 100.0, (vocab, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    batch = BowBatch(ids, cnts)
    visited = jnp.asarray(rng.random(b) < 0.5)
    base = get_backend("gather").solve(cfg, eb, batch)
    old_pi = jnp.where(visited[:, None, None], base.pi, 0.0)
    want = get_backend("gather").solve_correction(cfg, eb, batch, old_pi,
                                                  visited)
    got = get_backend("pallas").solve_correction(cfg, eb, batch, old_pi,
                                                 visited)
    np.testing.assert_allclose(got[0], want[0], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(got[2].pi, want[2].pi, rtol=2e-3, atol=1e-4)
    # L >= V here: the dense-partial guard must not mistake the (B, L, K)
    # token cubes' long L axis for a vocab axis
    assert dense_vocab_cubes(
        lambda: get_backend("pallas").solve_correction(cfg, eb, batch,
                                                       old_pi, visited),
        cfg.vocab_size) == 0


def test_pallas_correction_non_resident_vocab():
    """A non-lane-multiple vocab large enough to need several V chunks
    (forced via a small block_v) must match the jnp backend — the
    non-V-resident acceptance shape, run in interpret mode."""
    from repro.kernels import ops as kops
    rng = np.random.default_rng(12)
    b, l, vocab, k = 8, 40, 4999, 12
    ids = jnp.asarray(rng.integers(0, vocab, (b, l)).astype(np.int32))
    cnts = jnp.asarray((rng.poisson(1.0, (b, l)) + 1).astype(np.float32))
    cfg = LDAConfig(num_topics=k, vocab_size=vocab, estep_max_iters=20,
                    estep_backend="pallas")
    lam = jax.random.gamma(jax.random.key(5), 100.0, (vocab, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    batch = BowBatch(ids, cnts)
    old_pi = jnp.zeros((b, l, k), jnp.float32)
    visited = jnp.zeros((b,), bool)
    want = get_backend("gather").solve_correction(cfg, eb, batch, old_pi,
                                                  visited)
    got = kops.memo_correction_pallas(cfg, eb, ids, cnts, old_pi, visited,
                                      delta_block_v=512)   # 10 V chunks
    np.testing.assert_allclose(got[0], want[0], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(got[2].sstats, want[2].sstats,
                               rtol=1e-2, atol=2e-3)


def test_pallas_correction_bf16_wire_segment_parity():
    """Under a bf16 memo wire the segment-sum path must return the SAME
    rounded π as the jnp backend and masses consistent with scattering
    exactly those rounded values (the store invariant across the new
    scatter)."""
    cfg, corpus, eb = _ragged_batch(5)
    batch = BowBatch(corpus.token_ids, corpus.counts)
    rng = np.random.default_rng(5)
    base = get_backend("gather").solve(cfg, eb, batch)
    visited = jnp.asarray(rng.random(corpus.num_docs) < 0.5)
    old_pi = jnp.where(visited[:, None, None],
                       quantize_pi(base.pi, "bfloat16"), 0.0)
    want = get_backend("gather").solve_correction(cfg, eb, batch, old_pi,
                                                  visited,
                                                  pi_dtype="bfloat16")
    got = get_backend("pallas").solve_correction(cfg, eb, batch, old_pi,
                                                 visited,
                                                 pi_dtype="bfloat16")
    # the rounded π must be bf16-representable and agree across backends
    pi = np.asarray(got[2].pi)
    np.testing.assert_array_equal(
        pi, np.asarray(quantize_pi(jnp.asarray(pi), "bfloat16")))
    np.testing.assert_allclose(pi, np.asarray(want[2].pi),
                               rtol=2e-3, atol=2e-3)
    # and the masses are the scatter of exactly those rounded rows
    rebuilt = scatter_sstats(corpus.token_ids,
                             corpus.counts[:, :, None] * got[2].pi,
                             cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(got[2].sstats),
                               np.asarray(rebuilt), rtol=1e-4, atol=1e-4)


def test_engine_end_to_end_pallas_backend(tiny_corpus):
    """The whole IVI engine (store + backend interfaces) on the fused
    kernels — the CI guard requested for estep_backend='pallas'."""
    train, test, spec = tiny_corpus
    base = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                     estep_max_iters=40)
    res = {}
    for backend in ("dense", "pallas"):
        cfg = dataclasses.replace(base, estep_backend=backend)
        eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0,
                        test_corpus=test)
        eng.run_epoch()
        res[backend] = (np.asarray(eng.state.lam), eng.evaluate()["lpp"])
    np.testing.assert_allclose(res["dense"][0], res["pallas"][0],
                               rtol=2e-2, atol=2e-2)
    assert abs(res["dense"][1] - res["pallas"][1]) < 0.1


def test_memo_footprint_math():
    """The dry-run memo math: Arxiv scale, chunked under the 40 GB bar."""
    d, l, k, v = 782_384, 128, 128, 141_952
    dense = memo_footprint_bytes("dense", d, l, k)
    chunked = memo_footprint_bytes("chunked", d, l, k)
    gamma = memo_footprint_bytes("gamma", d, l, k, vocab_size=v)
    assert dense / 1e9 > 40.0                   # the wall the issue names
    assert chunked / 1e9 < 40.0
    assert gamma < chunked < dense
    # footprint math must match what a real (small) store allocates
    cfg = LDAConfig(num_topics=4, vocab_size=60)
    store = make_memo_store("chunked", cfg, 100, 12, chunk_docs=32)
    assert store.footprint_bytes() == memo_footprint_bytes(
        "chunked", 100, 12, 4)
