"""D-IVI protocol-level guarantees, beyond the quality checks in
test_divi.py: determinism, exact reduction to the single-host S-IVI step,
delay/staleness bookkeeping invariants, shard-stream ingest order."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LDAConfig
from repro.core.engines import init_engine_state, sivi_step
from repro.core.types import Memo
from repro.data import PAPER_CORPORA, ShardedDocStream, make_corpus
from repro.data.stream import CorpusDocStream
from repro.dist import DIVIConfig, DIVIEngine


def _cfg(spec):
    return LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                     estep_max_iters=40)


def test_divi_deterministic_across_runs(tiny_corpus):
    """Same seed ⇒ identical λ, memo and doc counter across two engines."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    dcfg = DIVIConfig(num_workers=2, batch_size=16, delay_prob=0.3,
                      staleness=2)
    e1 = DIVIEngine(cfg, dcfg, train, seed=7)
    e2 = DIVIEngine(cfg, dcfg, train, seed=7)
    for _ in range(4):
        e1.run_round()
        e2.run_round()
    assert e1.docs_seen == e2.docs_seen
    np.testing.assert_array_equal(np.asarray(e1.lam), np.asarray(e2.lam))
    np.testing.assert_array_equal(np.asarray(e1.shard.pi),
                                  np.asarray(e2.shard.pi))


def test_divi_single_worker_round_equals_sivi_step(tiny_corpus):
    """One round with P=1, delay_prob=0, S=1 IS the single-host S-IVI step
    on the same mini-batch (the protocol's base case). With one worker the
    range partitioner owns the whole corpus in order, so the worker's
    first streamed batch is exactly documents 0..B-1."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=1, batch_size=16), train,
                     seed=0)
    eng.run_round()

    ref = init_engine_state(cfg, jax.random.key(0))
    memo = Memo(pi=jnp.zeros((train.num_docs, train.max_unique,
                              cfg.num_topics), jnp.float32),
                visited=jnp.zeros((train.num_docs,), bool))
    rows = jnp.arange(16)
    nw = jnp.asarray(float(np.asarray(train.counts).sum()))
    ref, memo = sivi_step(cfg, ref, memo, train.token_ids[rows],
                          train.counts[rows], rows, nw)
    np.testing.assert_allclose(np.asarray(eng.state.lam),
                               np.asarray(ref.lam), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(eng.state.m_vk),
                               np.asarray(ref.m_vk), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(eng.shard.pi[0][rows]),
                               np.asarray(memo.pi[rows]),
                               rtol=1e-6, atol=1e-6)
    assert int(eng.state.t) == int(ref.t) == 1


def test_divi_fully_delayed_round_is_identity(tiny_corpus):
    """If every worker drops every sub-round, λ moves only by the
    Robbins–Monro decay toward β₀ + ⟨m_vk⟩, the memo stays untouched —
    and the workers' stream cursors do not advance (a sleeping worker
    pulls nothing)."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=2, batch_size=8,
                                     staleness=2, delay_prob=1.0),
                     train, seed=0)
    m_vk0 = np.asarray(eng.state.m_vk).copy()   # the round donates its args
    eng.run_round()
    # no corrections folded in, no documents visited, no mass retired
    np.testing.assert_array_equal(np.asarray(eng.state.m_vk), m_vk0)
    assert not bool(eng.shard.visited.any())
    assert float(eng.state.init_frac) == 1.0
    assert int(eng.state.t) == 2  # the master clock still ticks per sub-round
    assert all(ing.cursor == 0 and ing.docs_pulled == 0
               for ing in eng.ingest)
    assert eng.docs_seen == 0


def test_divi_staleness_processes_s_batches_per_round(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=2, batch_size=8,
                                     staleness=3), train, seed=0)
    eng.run_round()
    assert int(eng.state.t) == 3           # one master update per sub-round
    assert eng.docs_seen == 2 * 3 * 8      # P × S × B (no delays)
    # each live worker pulled S batches from its own shard stream, in order
    assert all(ing.docs_pulled == 3 * 8 for ing in eng.ingest)


def test_range_partition_covers_corpus_in_order(tiny_corpus):
    """The range partitioner deals contiguous position blocks: worker
    shards concatenate back to 0..D-1, and the engine's memo rows line up
    with shard-local document order."""
    train, _, spec = tiny_corpus
    sharded = ShardedDocStream(CorpusDocStream(train), 4)
    pos = np.concatenate([sharded.positions(w) for w in range(4)])
    np.testing.assert_array_equal(pos, np.arange(train.num_docs))
    assert sharded.shard_sizes == [24, 24, 24, 24]
    # shard 1's first document is global document 24
    ids, cnts = next(sharded.shard(1).iter_from(0))
    row = np.asarray(train.token_ids)[24]
    live = np.asarray(train.counts)[24] > 0
    np.testing.assert_array_equal(ids, row[live])


def test_divi_init_mass_fully_retired_after_cover(tiny_corpus):
    """Once every document has been visited, λ = β₀+⟨m_vk⟩ exactly at the
    λ̂ level: init_frac snaps to exact zero (the eq. 4 invariant)."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    eng = DIVIEngine(cfg, DIVIConfig(num_workers=4, batch_size=24), train,
                     seed=0)
    for _ in range(8):   # 96 docs / (4×24 per round) — covered many times
        eng.run_round()
    assert bool(eng.shard.visited.all())
    assert float(eng.state.init_frac) == 0.0
