"""`repro.lda` facade: parity, durable checkpoints, serving, API surface.

The acceptance bars of ISSUE 3:

* facade trajectories are BIT-equal to driving the engines directly
  (same seed) for all four single-host algos and for D-IVI;
* save → load → resume is bit-equal to an uninterrupted run — *including*
  a save taken mid-epoch, for the dense / bf16-chunked / γ-only memo
  stores (the memo, the rng stream and the unvisited epoch remainder all
  round-trip through the manifest);
* ``LDA.transform`` on held-out docs matches the E-step
  ``predictive.log_predictive`` runs, to fp32 tolerance, via the Pallas
  backend;
* the legacy bare-λ flat-npz checkpoints still load (serve-only, with a
  ``DeprecationWarning``) — the old ``train.py`` save path silently
  produced non-resumable IVI runs;
* the public API surface (``repro.lda.__all__``) is guarded, and the old
  entry points stay importable.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LDAConfig, LDAEngine
from repro.core.estep import estep_gather
from repro.core.math import safe_normalize
from repro.core.predictive import split_heldout
from repro.dist import DIVIConfig, DIVIEngine
from repro.lda import LDA


def _cfg(spec, **kw):
    kw.setdefault("estep_max_iters", 20)
    return LDAConfig(num_topics=4, vocab_size=spec.vocab_size, **kw)


def _same(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# parity: facade == direct engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["mvi", "svi", "ivi", "sivi"])
def test_facade_parity_single_host(tiny_corpus, algo):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    lda = LDA(cfg, algo=algo, batch_size=16, seed=3).fit(train, epochs=2)
    eng = LDAEngine(cfg, train, algo=algo, batch_size=16, seed=3)
    eng.run_epoch()
    eng.run_epoch()
    _same(lda.lam, eng.state.lam)
    _same(lda.state.m_vk, eng.state.m_vk)
    assert lda.docs_seen == eng.docs_seen


def test_facade_parity_divi(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    dcfg = DIVIConfig(num_workers=2, batch_size=8)
    lda = LDA(cfg, algo="divi", distributed=dcfg, seed=0).fit(train, rounds=3)
    eng = DIVIEngine(cfg, dcfg, train, seed=0)
    for _ in range(3):
        eng.run_round()
    _same(lda.lam, eng.lam)
    assert lda.docs_seen == eng.docs_seen


# ---------------------------------------------------------------------------
# durable checkpoints: save mid-epoch → restore → bit-equal continuation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("store,algo,bucketed", [
    ("dense", "ivi", False),
    ("chunked", "ivi", False),
    ("gamma", "sivi", False),
    ("dense", "ivi", True),
])
def test_checkpoint_roundtrip_mid_epoch(tiny_corpus, tmp_path, store, algo,
                                        bucketed):
    """Save after 3 minibatches (mid-epoch), resume, run 2 more: λ and
    ⟨m_vk⟩ must be bit-equal to the run that never stopped — for every
    memo-store representation, including the bf16 wire."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    kw = dict(algo=algo, batch_size=16, seed=7, memo_store=store,
              chunk_docs=16, bucket_by_length=bucketed)
    path = os.path.join(tmp_path, "ck")

    a = LDA(cfg, **kw).partial_fit(train, steps=3)
    assert a.trainer.pending_batches > 0      # genuinely mid-epoch
    a.save(path)
    a.partial_fit(steps=2)

    b = LDA.load(path).resume(train)
    assert b.trainer.pending_batches > 0      # the remainder round-tripped
    b.partial_fit(steps=2)

    _same(a.lam, b.lam)
    _same(a.state.m_vk, b.state.m_vk)
    _same(a.state.init_frac, b.state.init_frac)
    # the memo itself is bit-equal too (in its own wire dtype)
    sa, sb = a.trainer.eng.memo.state_dict(), b.trainer.eng.memo.state_dict()
    assert sorted(sa) == sorted(sb)
    for k in sa:
        _same(sa[k], sb[k])


def test_checkpoint_roundtrip_mvi(tiny_corpus, tmp_path):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    path = os.path.join(tmp_path, "ck")
    a = LDA(cfg, algo="mvi", batch_size=16, seed=1).fit(train, epochs=1)
    a.save(path)
    a.fit(epochs=1)
    b = LDA.load(path).resume(train).fit(epochs=1)
    _same(a.lam, b.lam)   # needs the γ warm-start buffer in the manifest


def test_checkpoint_roundtrip_divi(tiny_corpus, tmp_path):
    train, _, spec = tiny_corpus
    cfg = _cfg(spec)
    path = os.path.join(tmp_path, "ck")
    dcfg = DIVIConfig(num_workers=2, batch_size=8, staleness=2)
    a = LDA(cfg, algo="divi", distributed=dcfg, seed=0).fit(train, rounds=2)
    a.save(path)
    a.partial_fit(steps=2)
    b = LDA.load(path).resume(train)
    assert b.distributed == dcfg              # DIVIConfig round-trips
    b.partial_fit(steps=2)
    _same(a.lam, b.lam)
    _same(a.state.m_vk, b.state.m_vk)


def test_fit_on_unresumed_checkpoint_refuses(tiny_corpus, tmp_path):
    """fit() on a loaded-but-not-resumed estimator must not silently
    retrain from scratch while the checkpoint payload sits unused."""
    train, _, spec = tiny_corpus
    path = os.path.join(tmp_path, "ck")
    LDA(_cfg(spec), algo="ivi", batch_size=16).partial_fit(
        train, steps=1).save(path)
    loaded = LDA.load(path)
    with pytest.raises(ValueError, match="resume"):
        loaded.fit(train, epochs=1)
    loaded.resume(train).fit(epochs=1)       # the blessed path still works


def test_resave_to_same_path(tiny_corpus, tmp_path):
    """Periodic checkpointing to one directory: the reload must see the
    newest generation, not a mix."""
    train, _, spec = tiny_corpus
    path = os.path.join(tmp_path, "ck")
    a = LDA(_cfg(spec), algo="ivi", batch_size=16, seed=5)
    a.partial_fit(train, steps=2).save(path)
    a.partial_fit(steps=2).save(path)        # overwrite in place
    b = LDA.load(path).resume(train)
    _same(a.lam, b.lam)
    _same(a.state.m_vk, b.state.m_vk)


def test_resume_with_wrong_corpus_refuses(tiny_corpus, tmp_path):
    """A checkpoint carries no corpus, but restoring into a different-sized
    one must fail loudly, not gather out-of-range memo rows silently."""
    train, test, spec = tiny_corpus          # train: 96 docs, test: 32
    path = os.path.join(tmp_path, "ck")
    LDA(_cfg(spec), algo="ivi", batch_size=16).partial_fit(
        train, steps=1).save(path)
    with pytest.raises(ValueError, match="checkpoint"):
        LDA.load(path).resume(test)


def test_late_test_corpus_rebinds(tiny_corpus):
    """test_corpus passed after the first bind must take effect."""
    train, test, spec = tiny_corpus
    lda = LDA(_cfg(spec), algo="ivi", batch_size=16).fit(train, epochs=1)
    lda.fit(epochs=1, test_corpus=test)
    assert "lpp" in lda.evaluate()


def test_wrong_store_on_resume_refuses(tiny_corpus, tmp_path):
    """The memo is algorithm state: restoring it into a different store
    kind silently changes the wire dtype — must refuse instead."""
    train, _, spec = tiny_corpus
    path = os.path.join(tmp_path, "ck")
    a = LDA(_cfg(spec), algo="ivi", batch_size=16,
            memo_store="chunked").partial_fit(train, steps=1)
    a.save(path)
    b = LDA.load(path)
    b.memo_store = "dense"                    # simulate a mismatched rebuild
    with pytest.raises(ValueError, match="memo store"):
        b.resume(train)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_transform_matches_predictive_estep_pallas(tiny_corpus):
    """``LDA.transform`` (fused Pallas backend, bucketed + padded batches)
    must match the plain token-gather E-step that ``log_predictive`` fits
    on observed halves — fp32 tolerance (the backends share the fixed
    point but not the float op order)."""
    train, test, spec = tiny_corpus
    # converge the fixed point hard so the comparison tests float agreement,
    # not where each backend's while_loop happened to stop on the plateau
    cfg = _cfg(spec, estep_max_iters=100, estep_tol=1e-6)
    lda = LDA(cfg, algo="ivi", batch_size=16, seed=0).fit(train, epochs=1)
    obs, _ = split_heldout(test, seed=0)

    eb = jnp.exp(jax.scipy.special.digamma(lda.lam)
                 - jax.scipy.special.digamma(lda.lam.sum(0)))
    want = estep_gather(cfg, eb, obs.token_ids, obs.counts)
    theta_want = np.asarray(safe_normalize(want.gamma, axis=-1))

    theta = lda.transform(obs, backend="pallas", batch_size=8)
    np.testing.assert_allclose(theta, theta_want, rtol=2e-3, atol=2e-3)

    gamma = lda.posterior(obs, backend="gather", batch_size=8)
    np.testing.assert_allclose(gamma, np.asarray(want.gamma),
                               rtol=2e-3, atol=2e-3)


def test_serve_from_loaded_checkpoint_without_corpus(tiny_corpus, tmp_path):
    train, test, spec = tiny_corpus
    path = os.path.join(tmp_path, "ck")
    lda = LDA(_cfg(spec), algo="ivi", batch_size=16).fit(train, epochs=1)
    lda.save(path)
    served = LDA.load(path)                  # no resume, no corpus
    theta = served.transform(test)
    assert theta.shape == (test.num_docs, 4)
    np.testing.assert_allclose(theta.sum(-1), 1.0, atol=1e-5)
    assert served.top_words(3).shape == (4, 3)
    assert np.isfinite(served.score(test))


# ---------------------------------------------------------------------------
# legacy checkpoints + evaluate() History hygiene
# ---------------------------------------------------------------------------

def test_legacy_bare_lambda_checkpoint(tiny_corpus, tmp_path):
    from repro.checkpoint import save_checkpoint
    train, test, spec = tiny_corpus
    cfg = _cfg(spec)
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0)
    eng.run_epoch()
    path = os.path.join(tmp_path, "legacy.npz")
    save_checkpoint(path, eng.state)

    with pytest.warns(DeprecationWarning, match="CANNOT resume"):
        lda = LDA.load(path)
    _same(lda.lam, eng.state.lam)            # serving state intact
    assert lda.transform(test).shape == (test.num_docs, cfg.num_topics)
    with pytest.raises(ValueError, match="resume"):
        lda.resume(train)                    # but training cannot continue
    with pytest.raises(ValueError, match="serve-only"):
        lda.fit(train, epochs=1)             # ...not even from scratch


def test_evaluate_without_test_corpus_records_bound(tiny_corpus):
    """No test corpus → no lpp=nan rows; the memoized bound is recorded."""
    train, _, spec = tiny_corpus
    eng = LDAEngine(_cfg(spec), train, algo="ivi", batch_size=16, seed=0)
    eng.run_epoch()
    out = eng.evaluate()
    assert "lpp" not in out and "elbo" in out
    assert eng.history.lpp == []             # never padded with nan
    assert len(eng.history.elbo) == 1
    assert np.isfinite(eng.history.elbo[0])
    # and the recorded value is the memoized bound
    assert out["elbo"] == pytest.approx(eng.full_bound())


# ---------------------------------------------------------------------------
# public API surface
# ---------------------------------------------------------------------------

def test_public_api_surface():
    """``repro.lda.__all__`` is the public contract: additions are fine,
    removals/renames are breaking — keep this list in sync deliberately."""
    import repro.lda as lda_pkg

    expected = {
        "LDA", "Trainer", "SingleHostTrainer", "DIVITrainer",
        "make_trainer", "TopicInferencer", "topic_posterior",
        "save_lda_checkpoint", "load_lda_checkpoint", "SCHEMA_VERSION",
    }
    assert expected.issubset(set(lda_pkg.__all__))
    for name in lda_pkg.__all__:
        assert getattr(lda_pkg, name) is not None


def test_old_entry_points_still_importable():
    """The facade wraps — it does not replace — the historical surface."""
    from repro.core import (LDAEngine, incremental_update, ivi_step,  # noqa
                            sivi_step, svi_step)
    from repro.dist import DIVIConfig, DIVIEngine                     # noqa
    from repro.checkpoint import (restore_checkpoint,                 # noqa
                                  save_checkpoint)
    import repro.launch.train                                         # noqa
    import repro.launch.serve_lda                                     # noqa


def test_constructor_validation(tiny_corpus):
    _, _, spec = tiny_corpus
    with pytest.raises(ValueError, match="incompatible"):
        LDA(num_topics=4, vocab_size=spec.vocab_size, algo="ivi",
            distributed=DIVIConfig())
    with pytest.raises(TypeError, match="not both"):
        LDA(_cfg(spec), num_topics=8)
    with pytest.raises(ValueError, match="unknown algo"):
        LDA(num_topics=4, vocab_size=spec.vocab_size, algo="vb")
    # divi shorthand implies a default DIVIConfig
    assert LDA(_cfg(spec), algo="divi").distributed is not None
