"""Extended components: CVB0, topic metrics, hyperparameter learning,
flash-attention kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CVB0Engine, LDAConfig, LDAEngine, effective_topics,
                        log_predictive, npmi_coherence, split_heldout,
                        top_words, update_alpha0, update_beta0)
from repro.data import PAPER_CORPORA, make_corpus
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_mha
from repro.kernels.ref import mha_ref


# ---------------------------------------------------------------------------
# CVB0
# ---------------------------------------------------------------------------

def test_cvb0_improves_lpp(tiny_corpus):
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    obs, held = split_heldout(test)
    eng = CVB0Engine(cfg, train, batch_size=16, seed=0)
    first = float(log_predictive(cfg, eng.lam, obs, held))
    for _ in range(5):
        eng.run_epoch()
    last = float(log_predictive(cfg, eng.lam, obs, held))
    assert last > first + 0.3


def test_cvb0_count_conservation(tiny_corpus):
    """Σ_vk N_vk must equal the corpus word count at all times."""
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    eng = CVB0Engine(cfg, train, batch_size=16, seed=0)
    total = float(train.num_words)
    for _ in range(6):
        eng.run_minibatch()
        np.testing.assert_allclose(float(eng.state.n_vk.sum()), total,
                                   rtol=1e-4)


def test_cvb0_competitive_with_ivi(tiny_corpus):
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    obs, held = split_heldout(test)
    cvb = CVB0Engine(cfg, train, batch_size=16, seed=0)
    ivi = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0)
    for _ in range(6):
        cvb.run_epoch()
        ivi.run_epoch()
    l_cvb = float(log_predictive(cfg, cvb.lam, obs, held))
    l_ivi = float(log_predictive(cfg, ivi.state.lam, obs, held))
    assert abs(l_cvb - l_ivi) < 0.4, (l_cvb, l_ivi)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_topic_metrics(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0)
    for _ in range(5):
        eng.run_epoch()
    tw = top_words(eng.state.lam, k=5)
    assert tw.shape == (8, 5)
    coh_trained = npmi_coherence(eng.state.lam, train, k=5)
    lam_rand = jax.random.gamma(jax.random.key(3), 100.0,
                                (spec.vocab_size, 8)) * 0.01
    coh_rand = npmi_coherence(lam_rand, train, k=5)
    assert coh_trained > coh_rand, (coh_trained, coh_rand)
    eff = effective_topics(eng.state.lam)
    assert 1.0 <= eff <= 8.0


# ---------------------------------------------------------------------------
# hyperparameter learning
# ---------------------------------------------------------------------------

def test_minka_recovers_concentration():
    """Fit symmetric α from Dirichlet-posterior-like samples."""
    rng = np.random.default_rng(0)
    true_a = 0.7
    k, n = 10, 4000
    # posterior params = prior + counts from docs of length ~50
    theta = rng.dirichlet([true_a] * k, size=n)
    counts = np.stack([rng.multinomial(50, t) for t in theta])
    post = jnp.asarray(true_a + counts, jnp.float32)
    a_hat = update_alpha0(0.1, post, iters=50)
    assert abs(a_hat - true_a) < 0.25, a_hat


def test_update_beta0_runs(tiny_corpus):
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0)
    eng.run_epoch()
    b = update_beta0(cfg.beta0, eng.state.lam)
    assert 0 < b < 10


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

FA_SHAPES = [
    (2, 256, 64, 128, 128, True),
    (2, 256, 64, 64, 128, False),
    (4, 512, 128, 128, 64, True),
    (1, 128, 32, 128, 128, True),
    (3, 384, 64, 128, 128, True),
]


@pytest.mark.parametrize("bh,s,hd,bq,bk,causal", FA_SHAPES)
def test_flash_attention_matches_ref(bh, s, hd, bq, bk, causal, rng):
    q = jnp.asarray(rng.normal(0, 1, (bh, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (bh, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (bh, s, hd)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (2, 256, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (2, 256, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (2, 256, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True)
    want = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), s=st.sampled_from([128, 256]),
       hd=st.sampled_from([32, 64]))
def test_flash_attention_property(seed, s, hd):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (2, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (2, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (2, s, hd)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, block_q=min(128, s),
                          block_k=min(128, s))
    want = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_flash_mha_gqa_and_padding(rng):
    """GQA repeat + non-128-multiple sequence (pad/unpad) path."""
    q = jnp.asarray(rng.normal(0, 1, (2, 70, 8, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (2, 70, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (2, 70, 2, 32)).astype(np.float32))
    got = flash_mha(q, k, v)
    kf, vf = jnp.repeat(k, 4, 2), jnp.repeat(v, 4, 2)
    fl = lambda x: x.transpose(0, 2, 1, 3).reshape(16, 70, 32)
    want = mha_ref(fl(q), fl(kf), fl(vf)).reshape(2, 8, 70, 32) \
        .transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Pallas backend end-to-end (engine runs its E-step through the kernels)
# ---------------------------------------------------------------------------

def test_engine_with_pallas_backend_matches_dense(tiny_corpus):
    """IVI engine run end-to-end through the Pallas kernels.

    One update must match the jnp dense backend tightly; over two epochs
    the trajectories may diverge chaotically (the fixed-point iteration
    count is tolerance-dependent), so the long-horizon check is on quality.
    """
    import dataclasses
    train, test, spec = tiny_corpus
    base = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                     estep_max_iters=40)
    res = {}
    for backend in ("dense", "pallas"):
        cfg = dataclasses.replace(base, estep_backend=backend)
        eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0,
                        test_corpus=test)
        eng.run_minibatch(rows=np.arange(16))
        lam1 = np.asarray(eng.state.lam)
        for _ in range(2):
            eng.run_epoch()
        res[backend] = (lam1, eng.evaluate()["lpp"])
    np.testing.assert_allclose(res["dense"][0], res["pallas"][0],
                               rtol=2e-2, atol=2e-2)
    assert np.isfinite(res["pallas"][1])
    assert abs(res["dense"][1] - res["pallas"][1]) < 0.1


def test_sivi_robbins_monro_blend(tiny_corpus):
    """S-IVI eq. (5): λ_t must be the exact Robbins–Monro blend of λ_{t−1}
    and β₀ + ⟨m_vk⟩ after the incremental correction."""
    import jax
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    eng = LDAEngine(cfg, train, algo="sivi", batch_size=16, seed=0)
    eng.run_epoch()
    lam_prev = np.asarray(eng.state.lam)
    t_prev = int(eng.state.t)
    eng.run_minibatch()
    rho = (t_prev + 1 + cfg.tau) ** (-cfg.kappa)
    lam_hat = cfg.beta0 + np.asarray(eng.state.m_vk) \
        + float(eng.state.init_frac) * np.asarray(eng.state.init_mass)
    want = (1 - rho) * lam_prev + rho * lam_hat
    np.testing.assert_allclose(np.asarray(eng.state.lam), want,
                               rtol=1e-4, atol=1e-4)
