"""Optimizers (incl. the IAG paper-bridge), microbatching, checkpoint IO."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ARCHS
from repro.models import transformer as T
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         cosine_schedule, iag, sgd)
from repro.training import TrainState, make_train_step


def _quadratic(theta):
    return jnp.sum((theta - 3.0) ** 2)


@pytest.mark.parametrize("make", [lambda: adamw(0.1), lambda: sgd(0.05)])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    theta = jnp.zeros((4,))
    state = opt.init(theta)
    for _ in range(200):
        g = jax.grad(_quadratic)(theta)
        upd, state = opt.update(g, state, theta)
        theta = apply_updates(theta, upd)
    assert float(_quadratic(theta)) < 1e-2


def test_iag_incremental_aggregate_semantics():
    """IAG == full-gradient descent once every shard is memoized (the IVI
    eq.-4 property transplanted to gradients)."""
    num_shards = 4
    data = jnp.arange(1.0, 5.0)          # shard s has target data[s]

    def loss_shard(theta, s):
        return 0.5 * (theta - data[s]) ** 2

    opt = iag(0.3, num_shards)
    theta = jnp.zeros(())
    state = opt.init(theta)
    for step in range(80):
        s = step % num_shards
        g = jax.grad(loss_shard)(theta, s)
        upd, state = opt.update(g, state, theta, shard=s)
        theta = apply_updates(theta, upd)
    # optimum of the average loss = mean(data)
    assert abs(float(theta) - float(data.mean())) < 1e-2
    # the aggregate equals the sum of memoized shard gradients (exactness)
    agg = state["agg"]
    memo_sum = state["memo"].sum()
    np.testing.assert_allclose(float(agg), float(memo_sum), rtol=1e-5,
                               atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4
    assert float(norm) > 20


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5
    assert float(lr(5)) < float(lr(10))


def test_microbatched_train_step_matches_full(rng):
    """microbatches=N must give the same update as one big batch (for a
    deterministic model: no dropout, mean-reduced loss)."""
    cfg = ARCHS["yi-9b"].reduced(seq_len_hint=32)
    params = T.init_params(cfg, jax.random.key(0))
    opt = adamw(1e-3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))
    batch = {"tokens": tokens, "labels": labels}

    s1 = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    s2 = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step1 = jax.jit(make_train_step(cfg, opt))
    step2 = jax.jit(make_train_step(cfg, opt, microbatches=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    # per-microbatch means averaged == full-batch mean when mb sizes equal
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 5e-3
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
    assert d < 5e-3, d


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = ARCHS["qwen2.5-3b"].reduced(seq_len_hint=16)
    params = T.init_params(cfg, jax.random.key(1))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, step=7)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    restored = restore_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_lda_state(tmp_path):
    from repro.core import LDAConfig, LDAEngine
    from repro.data import PAPER_CORPORA, make_corpus
    spec = PAPER_CORPORA["tiny"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=4, vocab_size=spec.vocab_size,
                    estep_max_iters=20)
    eng = LDAEngine(cfg, corpus, algo="ivi", batch_size=16, seed=0)
    eng.run_epoch()
    path = os.path.join(tmp_path, "lda.npz")
    save_checkpoint(path, eng.state)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), eng.state)
    restored = restore_checkpoint(path, like)
    np.testing.assert_array_equal(np.asarray(eng.state.lam),
                                  np.asarray(restored.lam))
