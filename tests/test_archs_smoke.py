"""Deliverable (f): per-architecture REDUCED smoke tests.

Each assigned arch instantiates a reduced variant of the same family
(≤2 layers, d_model ≤ 512, ≤4 experts) and runs one forward + one real
train step (grads + AdamW update) on CPU, asserting output shapes and
finiteness. Decode smoke: one serve_step against a fresh cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.optim import adamw
from repro.training import TrainState, make_serve_step, make_train_step

S = 64
B = 2


def _batch(cfg, rng):
    tok_shape = ((B, S, cfg.num_codebooks) if cfg.modality == "audio"
                 else (B, S))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, tok_shape))}
    lab_len = S + (cfg.num_patches if cfg.modality == "vision" else 0)
    lab_shape = ((B, lab_len, cfg.num_codebooks) if cfg.modality == "audio"
                 else (B, lab_len))
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, lab_shape))
    if cfg.modality == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_patches, cfg.d_model))
            .astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_shapes_and_finite(arch, rng):
    cfg = ARCHS[arch].reduced(seq_len_hint=S)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = T.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: T.forward(cfg, p, b))(params, batch)
    s_total = S + (cfg.num_patches if cfg.modality == "vision" else 0)
    if cfg.modality == "audio":
        assert logits.shape == (B, s_total, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, s_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch, rng):
    cfg = ARCHS[arch].reduced(seq_len_hint=S)
    params = T.init_params(cfg, jax.random.key(0))
    opt = adamw(1e-3)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, rng)
    state, metrics = step(state, batch)
    state, metrics2 = step(state, batch)
    assert np.isfinite(float(metrics2["loss"]))
    # one step on the same batch should not increase the loss
    assert float(metrics2["loss"]) < float(metrics["loss"]) + 0.5


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_decode_step(arch, rng):
    cfg = ARCHS[arch].reduced(seq_len_hint=S)
    params = T.init_params(cfg, jax.random.key(0))
    caches = T.init_caches(cfg, B, 32, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    toks = jnp.asarray(rng.integers(
        0, cfg.vocab_size,
        (B, cfg.num_codebooks) if cfg.modality == "audio" else (B,)))
    pos = jnp.zeros((B,), jnp.int32)
    nxt, logits, caches = serve(params, caches, toks, pos)
    if cfg.modality == "audio":
        assert logits.shape == (B, cfg.num_codebooks, cfg.vocab_size)
        assert nxt.shape == (B, cfg.num_codebooks)
    else:
        assert logits.shape == (B, cfg.vocab_size)
        assert nxt.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


def test_exact_full_configs_match_assignment():
    """Pin the full configs to the assigned spec table."""
    spec = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 10944, 102400),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = ARCHS[name]
        assert cfg.num_layers == L, name
        assert cfg.d_model == d, name
        assert cfg.num_heads == h, name
        assert cfg.num_kv_heads == kv, name
        assert cfg.d_ff == ff, name
        assert cfg.vocab_size == v, name
    assert ARCHS["qwen3-moe-30b-a3b"].num_experts == 128
    assert ARCHS["qwen3-moe-30b-a3b"].num_experts_per_tok == 8
    assert ARCHS["deepseek-moe-16b"].num_experts == 64
    assert ARCHS["deepseek-moe-16b"].num_experts_per_tok == 6
    assert ARCHS["deepseek-moe-16b"].num_shared_experts == 2
    assert ARCHS["zamba2-1.2b"].ssm_state == 64


@pytest.mark.parametrize("arch", ["yi-9b", "gemma2-27b", "musicgen-medium",
                                  "internvl2-1b"])
def test_prefill_step_matches_forward_last_token(arch, rng):
    """Serving prefill (last-token logits) must equal the full forward's
    final position."""
    from repro.training import make_prefill_step
    cfg = ARCHS[arch].reduced(seq_len_hint=S)
    params = T.init_params(cfg, jax.random.key(0))
    batch = {k: v for k, v in _batch(cfg, rng).items() if k != "labels"}
    logits_full, _ = jax.jit(lambda p, b: T.forward(cfg, p, b))(params, batch)
    pre = jax.jit(make_prefill_step(cfg))(params, batch)
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(pre), rtol=2e-4, atol=2e-4)
