"""Core LDA inference: E-step equivalences, engine behaviour, predictive."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Corpus, LDAConfig, LDAEngine, elbo_collapsed,
                        elbo_memoized, estep_dense, estep_gather,
                        log_predictive, split_heldout)
from repro.core.math import exp_dirichlet_expectation
from repro.data import PAPER_CORPORA, make_corpus


def _setup(k=8, v=250):
    spec = PAPER_CORPORA["tiny"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=k, vocab_size=v, estep_max_iters=60)
    lam = jax.random.gamma(jax.random.key(0), 100.0, (v, k)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    return cfg, corpus, lam, eb


def test_estep_gather_dense_agree():
    cfg, corpus, lam, eb = _setup()
    ids, cnts = corpus.token_ids[:16], corpus.counts[:16]
    r1 = estep_gather(cfg, eb, ids, cnts)
    r2 = estep_dense(cfg, eb, ids, cnts)
    np.testing.assert_allclose(r1.gamma, r2.gamma, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(r1.sstats, r2.sstats, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(r1.pi, r2.pi, rtol=2e-3, atol=1e-5)


def test_estep_gamma_fixed_point():
    """Converged γ satisfies γ = α₀ + Σ_l cnt·π (Alg. 1 line 6)."""
    cfg, corpus, lam, eb = _setup()
    cfg = dataclasses.replace(cfg, estep_tol=1e-7, estep_max_iters=500)
    ids, cnts = corpus.token_ids[:8], corpus.counts[:8]
    r = estep_gather(cfg, eb, ids, cnts)
    gamma_from_pi = cfg.alpha0 + jnp.einsum("blk,bl->bk", r.pi, cnts)
    np.testing.assert_allclose(r.gamma, gamma_from_pi, rtol=1e-3, atol=1e-3)


def test_estep_pi_normalized():
    cfg, corpus, lam, eb = _setup()
    ids, cnts = corpus.token_ids[:8], corpus.counts[:8]
    r = estep_gather(cfg, eb, ids, cnts)
    sums = np.asarray(r.pi.sum(-1))
    live = np.asarray(cnts) > 0
    np.testing.assert_allclose(sums[live], 1.0, rtol=1e-5)
    assert (sums[~live] == 0).all()


def test_sstats_total_mass():
    """Σ_vk sstats == total word count of the batch."""
    cfg, corpus, lam, eb = _setup()
    ids, cnts = corpus.token_ids[:8], corpus.counts[:8]
    r = estep_gather(cfg, eb, ids, cnts)
    np.testing.assert_allclose(float(r.sstats.sum()), float(cnts.sum()),
                               rtol=1e-5)


@pytest.mark.parametrize("algo", ["mvi", "svi", "ivi", "sivi"])
def test_engines_improve_lpp(algo, tiny_corpus):
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    eng = LDAEngine(cfg, train, algo=algo, batch_size=16, seed=0,
                    test_corpus=test)
    first = eng.evaluate()["lpp"]
    for _ in range(4):
        eng.run_epoch()
    last = eng.evaluate()["lpp"]
    assert np.isfinite(last)
    assert last > first + 0.05, f"{algo}: {first} → {last}"


def test_ivi_vs_mvi_speed_and_final_gap(tiny_corpus):
    """§6.1 / Fig. 1: (a) IVI is ahead of MVI at an equal *early* document
    budget (it updates λ before a full pass completes) — the speed claim,
    fully reproduced; (b) the converged LPP gap stays bounded. On synthetic
    sharply-identifiable corpora MVI's synchronized passes reach a slightly
    better basin — the documented deviation (EXPERIMENTS.md
    §Paper-validation)."""
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=60)
    mvi = LDAEngine(cfg, train, algo="mvi", batch_size=16, seed=0,
                    test_corpus=test)
    ivi = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0,
                    test_corpus=test)
    # (a) after ONE epoch's worth of documents
    mvi.run_epoch()
    ivi.run_epoch()
    early_mvi = mvi.evaluate()["lpp"]
    early_ivi = ivi.evaluate()["lpp"]
    assert early_ivi > early_mvi - 0.05, (early_ivi, early_mvi)
    # (b) bounded gap at convergence
    for _ in range(13):
        mvi.run_epoch()
        ivi.run_epoch()
    final = {"mvi": mvi.evaluate()["lpp"], "ivi": ivi.evaluate()["lpp"]}
    assert final["ivi"] > final["mvi"] - 0.5, final


def test_fullbatch_ivi_equals_mvi(tiny_corpus):
    """IVI with batch = corpus is exactly batch MVI (the strongest check of
    the incremental bookkeeping: subtract-old/add-new over the whole corpus
    must reproduce the full M-step)."""
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=60)
    mvi = LDAEngine(cfg, train, algo="mvi", batch_size=train.num_docs,
                    seed=0, test_corpus=test)
    ivi = LDAEngine(cfg, train, algo="ivi", batch_size=train.num_docs,
                    seed=0, test_corpus=test)
    for _ in range(4):
        mvi.run_epoch()
        ivi.run_minibatch(rows=np.arange(train.num_docs))
    lm, li = mvi.evaluate()["lpp"], ivi.evaluate()["lpp"]
    assert abs(lm - li) < 5e-3, (lm, li)


def test_elbo_memoized_leq_collapsed(tiny_corpus):
    """Collapsed bound (optimal π) dominates the memoized bound."""
    train, _, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size)
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0)
    eng.run_epoch()
    gamma = cfg.alpha0 + jnp.einsum("dlk,dl->dk", eng.memo.pi, train.counts)
    memo = float(elbo_memoized(cfg, train, gamma, eng.memo.pi, eng.state.lam))
    coll = float(elbo_collapsed(cfg, train, gamma, eng.state.lam))
    assert memo <= coll + 1e-2


def test_heldout_split_preserves_counts(tiny_corpus):
    _, test, _ = tiny_corpus
    obs, held = split_heldout(test, seed=0)
    np.testing.assert_allclose(np.asarray(obs.counts) + np.asarray(held.counts),
                               np.asarray(test.counts))


def test_predictive_prefers_trained_model(tiny_corpus):
    train, test, spec = tiny_corpus
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=40)
    obs, held = split_heldout(test, seed=0)
    lam0 = jax.random.gamma(jax.random.key(1), 100.0,
                            (spec.vocab_size, 8)) * 0.01
    before = float(log_predictive(cfg, lam0, obs, held))
    eng = LDAEngine(cfg, train, algo="ivi", batch_size=16, seed=0)
    for _ in range(6):
        eng.run_epoch()
    after = float(log_predictive(cfg, eng.state.lam, obs, held))
    assert after > before + 0.1
