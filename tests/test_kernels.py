"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles
(interpret mode on CPU), plus the full estep_pallas vs estep_dense path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LDAConfig
from repro.core.estep import estep_dense
from repro.core.math import exp_dirichlet_expectation
from repro.data import PAPER_CORPORA, make_corpus
from repro.kernels import lda_estep, ref
from repro.kernels.ops import estep_pallas


SHAPES = [
    # (B, V, K, block_b, block_v)
    (8, 64, 16, 8, 32),
    (16, 256, 32, 8, 64),
    (128, 512, 128, 128, 512),
    (32, 768, 100, 16, 128),
    (64, 1024, 128, 32, 256),
    (8, 512, 64, 8, 512),      # single V tile
    (128, 128, 128, 64, 64),
]


@pytest.mark.parametrize("b,v,k,bb,bv", SHAPES)
def test_sweep_kernel_matches_ref(b, v, k, bb, bv, rng):
    c = jnp.asarray(rng.poisson(0.3, (b, v)).astype(np.float32))
    et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
    eb = jnp.asarray(rng.gamma(1.0, 1.0, (v, k)).astype(np.float32))
    got = lda_estep.estep_sweep(c, et, eb, 0.5, block_b=bb, block_v=bv)
    want = ref.estep_sweep_ref(c, et, eb, 0.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,v,k,bb,bv", SHAPES)
def test_sstats_kernel_matches_ref(b, v, k, bb, bv, rng):
    c = jnp.asarray(rng.poisson(0.3, (b, v)).astype(np.float32))
    et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
    eb = jnp.asarray(rng.gamma(1.0, 1.0, (v, k)).astype(np.float32))
    got = lda_estep.sstats(c, et, eb, block_b=bb, block_v=bv)
    want = ref.sstats_ref(c, et, eb)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       b=st.sampled_from([4, 8, 16]),
       v=st.sampled_from([96, 160, 320]),
       k=st.sampled_from([8, 24, 100]))
def test_kernel_property_random_shapes(seed, b, v, k):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.poisson(0.5, (b, v)).astype(np.float32))
    et = jnp.asarray(rng.gamma(0.7, 2.0, (b, k)).astype(np.float32))
    eb = jnp.asarray(rng.gamma(0.7, 2.0, (v, k)).astype(np.float32))
    bb = b
    bv = v // 2 if v % 2 == 0 else v
    got = lda_estep.estep_sweep(c, et, eb, 0.5, block_b=bb, block_v=bv)
    want = ref.estep_sweep_ref(c, et, eb, 0.5)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)
    gs = lda_estep.sstats(c, et, eb, block_b=bb, block_v=bv)
    ws = ref.sstats_ref(c, et, eb)
    np.testing.assert_allclose(gs, ws, rtol=5e-5, atol=5e-5)


def test_estep_pallas_full_path():
    spec = PAPER_CORPORA["tiny"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=8, vocab_size=spec.vocab_size,
                    estep_max_iters=60)
    lam = jax.random.gamma(jax.random.key(0), 100.0,
                           (spec.vocab_size, 8)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    ids, cnts = corpus.token_ids[:16], corpus.counts[:16]
    r1 = estep_dense(cfg, eb, ids, cnts)
    r2 = estep_pallas(cfg, eb, ids, cnts, block_b=16, block_v=125)
    np.testing.assert_allclose(r1.gamma, r2.gamma, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(r1.sstats, r2.sstats, rtol=1e-2, atol=1e-3)
    np.testing.assert_allclose(r1.pi, r2.pi, rtol=1e-3, atol=1e-4)


def _memo_delta_refs(ids, cnts, ebt, et, v):
    p = (et[:, None, :] * ebt).sum(-1) + 1e-30
    pi = jnp.where(cnts[:, :, None] > 0,
                   et[:, None, :] * ebt / p[:, :, None], 0.0)
    flat = ids.reshape(-1)
    k = et.shape[1]
    snew = jnp.zeros((v, k)).at[flat].add(
        (cnts[:, :, None] * pi).reshape(-1, k))
    return pi, snew


@pytest.mark.parametrize("b,l,block_b", [
    (64, 32, 16),    # nb = 4: the multi-partial reduction path
    (32, 512, 32),   # VMEM guard halves block_b (32 → 4 at L=512, K=128)
])
def test_memo_delta_onehot_multi_tile_partials(b, l, block_b, rng):
    """The retired (nb, V, K) partial scheme (the benchmark baseline) must
    still match the jnp scatter with nb ≥ 2 B-tiles and when the VMEM
    guard shrinks the tile — shapes at which the old cross-tile output
    accumulation (TPU-undefined) was actually exercised."""
    v, k = 700, 128
    ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
    cnts = jnp.asarray(rng.poisson(1.0, (b, l)).astype(np.float32))
    ebt = jnp.asarray(rng.gamma(1.0, 1.0, (b, l, k)).astype(np.float32))
    et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
    opi = jnp.asarray(rng.random((b, l, k)).astype(np.float32))
    assert b // lda_estep.delta_effective_block_b(
        b, l, k, block_b=block_b) >= 2          # the shapes must fan out
    pi, snew, sold = lda_estep.memo_delta_onehot(ids, cnts, ebt, et, v,
                                                 old_pi=opi, block_b=block_b)
    pref, sref = _memo_delta_refs(ids, cnts, ebt, et, v)
    soldref = jnp.zeros((v, k)).at[ids.reshape(-1)].add(
        (cnts[:, :, None] * opi).reshape(-1, k))
    np.testing.assert_allclose(pi, pref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(snew, sref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sold, soldref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,l,v,kwargs", [
    # L grid axis: 2 L-tiles × 2 B-tiles (the old path capped L at ~4k —
    # this exercises the tiling machinery, test_estep_backend covers 8192)
    (8, 700, 300, dict(block_l=512, block_b=4)),
    # V-chunk grid axis: 6 chunks over a non-lane-multiple vocab, and a
    # row count that pads up to the T tile
    (12, 37, 700, dict(block_v=128, block_t=64)),
    # single-chunk V-resident degenerate case
    (16, 24, 200, dict()),
])
def test_memo_delta_segment_grid(b, l, v, kwargs, rng):
    """The segment-sum scatter must match the jnp scatter across the
    (B, L) tiling of the token-π kernel and the V-chunk grid of the
    accumulator — including padded L remainders and padded row tiles,
    which must stay inert (count 0)."""
    k = 128
    ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
    cnts = jnp.asarray(rng.poisson(1.0, (b, l)).astype(np.float32))
    ebt = jnp.asarray(rng.gamma(1.0, 1.0, (b, l, k)).astype(np.float32))
    et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
    opi = jnp.asarray(rng.random((b, l, k)).astype(np.float32))
    pi, snew, sold = lda_estep.memo_delta(ids, cnts, ebt, et, v,
                                          old_pi=opi, **kwargs)
    pref, sref = _memo_delta_refs(ids, cnts, ebt, et, v)
    soldref = jnp.zeros((v, k)).at[ids.reshape(-1)].add(
        (cnts[:, :, None] * opi).reshape(-1, k))
    np.testing.assert_allclose(pi, pref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(snew, sref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sold, soldref, rtol=1e-4, atol=1e-4)


def test_memo_delta_matches_onehot_baseline(rng):
    """Segment-sum and the retired one-hot baseline agree bit-for-bit in
    what they compute (π) and to fp32 summation tolerance in the masses —
    the 'measured, not asserted' bridge BENCH_estep quantifies."""
    b, l, v, k = 16, 48, 500, 64
    ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
    cnts = jnp.asarray(rng.poisson(1.0, (b, l)).astype(np.float32))
    ebt = jnp.asarray(rng.gamma(1.0, 1.0, (b, l, k)).astype(np.float32))
    et = jnp.asarray(rng.gamma(1.0, 1.0, (b, k)).astype(np.float32))
    seg = lda_estep.memo_delta(ids, cnts, ebt, et, v, quantize=True)
    one = lda_estep.memo_delta_onehot(ids, cnts, ebt, et, v, quantize=True)
    np.testing.assert_array_equal(np.asarray(seg[0]), np.asarray(one[0]))
    np.testing.assert_allclose(seg[1], one[1], rtol=1e-4, atol=1e-4)


def test_segment_scatter_blocks_policy():
    """The V-chunk policy stays lane-aligned, under budget, and V-resident
    for small vocabs."""
    f = lda_estep.segment_scatter_blocks
    vc, tb = f(128, 141_952, True)
    assert vc % 128 == 0 and vc >= 2048            # big vocabs: few chunks
    assert (vc * tb + 2 * (vc * 128 + tb * 128)) * 4 <= 8 * 1024 * 1024
    assert f(128, 700, True)[0] == 768             # V-resident, lane-aligned
    assert f(128, 4096, False)[0] == 4096
    bb, bl = lda_estep.pi_tile_shape(32, 8192, 128)
    assert bl == 512 and 2 * bb * bl * 128 * 4 <= 8 * 1024 * 1024
    assert 32 % bb == 0


def test_delta_effective_block_b_guard():
    """The VMEM guard halves the B-tile for long token axes and always
    returns a divisor of B."""
    f = lda_estep.delta_effective_block_b
    assert f(128, 64, 128) == 32           # fits at the default
    assert f(128, 128, 128) == 16          # production L halves once
    assert f(128, 512, 128) == 4
    assert f(12, 40, 16) == 12             # small batch: capped at B
    for b, l in [(96, 512), (32, 1024), (12, 512)]:
        bb = f(b, l, 128)
        assert b % bb == 0, (b, l, bb)


def test_kernel_padding_exactness():
    """Padded vocab/topic/batch slots must not leak into real outputs."""
    rng = np.random.default_rng(1)
    spec = PAPER_CORPORA["tiny"]
    corpus = make_corpus(spec, split="train", seed=0)
    cfg = LDAConfig(num_topics=5, vocab_size=spec.vocab_size,
                    estep_max_iters=30)
    lam = jax.random.gamma(jax.random.key(2), 100.0,
                           (spec.vocab_size, 5)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    ids, cnts = corpus.token_ids[:7], corpus.counts[:7]   # odd batch
    r1 = estep_dense(cfg, eb, ids, cnts)
    # blocks force padding on every axis (B→8, V→256·k, K→128)
    r2 = estep_pallas(cfg, eb, ids, cnts, block_b=8, block_v=125)
    np.testing.assert_allclose(r1.gamma, r2.gamma, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(r1.sstats, r2.sstats, rtol=1e-2, atol=1e-3)
