"""Property tests (hypothesis): the paper's §3 claims.

The central claim: after every document has been visited once, each IVI
update (partial E-step + incremental M-step) monotonically increases the
exact memoized ELBO — with NO learning rate. SVI does not have this
property; S-IVI trades it for distribution-friendliness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import LDAConfig, LDAEngine
from repro.core.types import Corpus
from repro.data.bow import corpus_from_docs


def _random_corpus(rng: np.random.Generator, n_docs: int, vocab: int,
                   mean_len: int) -> Corpus:
    docs = [rng.integers(0, vocab, size=max(2, int(rng.poisson(mean_len))))
            for _ in range(n_docs)]
    return corpus_from_docs(docs, vocab)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([3, 5, 8]),
       batch=st.sampled_from([4, 8]))
def test_ivi_monotone_bound(seed, k, batch):
    rng = np.random.default_rng(seed)
    corpus = _random_corpus(rng, n_docs=32, vocab=120, mean_len=30)
    cfg = LDAConfig(num_topics=k, vocab_size=120, estep_max_iters=100,
                    estep_tol=1e-6)
    eng = LDAEngine(cfg, corpus, algo="ivi", batch_size=batch, seed=seed)
    eng.run_epoch()                       # retire the random-init mass
    assert float(eng.state.init_frac) == 0.0
    prev = eng.full_bound()
    for _ in range(12):
        eng.run_minibatch()
        cur = eng.full_bound()
        # fp32 tolerance: the bound is a sum of ~1e4-magnitude terms, so
        # allow ~1e-6 relative rounding slack on the monotone comparison
        assert cur >= prev - max(5e-3, 2e-6 * abs(prev)), (prev, cur)
        prev = cur


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ivi_accumulator_consistency(seed):
    """⟨m_vk⟩ must equal the scatter of the memoized π at all times after
    the first pass (the subtract-old/add-new bookkeeping is exact)."""
    rng = np.random.default_rng(seed)
    corpus = _random_corpus(rng, n_docs=24, vocab=80, mean_len=20)
    cfg = LDAConfig(num_topics=4, vocab_size=80, estep_max_iters=50)
    eng = LDAEngine(cfg, corpus, algo="ivi", batch_size=8, seed=seed)
    eng.run_epoch()
    for _ in range(5):
        eng.run_minibatch()
    expected = jnp.einsum("dlk,dl->k...", eng.memo.pi, corpus.counts)
    # scatter: rebuild ⟨m_vk⟩ from the memo
    from repro.core.estep import scatter_sstats
    rebuilt = scatter_sstats(corpus.token_ids,
                             corpus.counts[:, :, None] * eng.memo.pi,
                             cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(eng.state.m_vk),
                               np.asarray(rebuilt), rtol=1e-3, atol=1e-2)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ivi_lambda_is_beta0_plus_counts(seed):
    """Eq. (4): λ = β₀ + ⟨m_vk⟩ once init mass is retired."""
    rng = np.random.default_rng(seed)
    corpus = _random_corpus(rng, n_docs=16, vocab=60, mean_len=15)
    cfg = LDAConfig(num_topics=4, vocab_size=60, estep_max_iters=50)
    eng = LDAEngine(cfg, corpus, algo="ivi", batch_size=8, seed=seed)
    eng.run_epoch()
    np.testing.assert_allclose(
        np.asarray(eng.state.lam),
        cfg.beta0 + np.asarray(eng.state.m_vk), rtol=1e-5, atol=1e-5)


def test_svi_not_required_monotone_but_converges():
    """Sanity contrast: SVI may decrease the bound between steps, yet the
    trend improves — the paper's motivation for IVI."""
    rng = np.random.default_rng(3)
    corpus = _random_corpus(rng, 32, 120, 30)
    cfg = LDAConfig(num_topics=5, vocab_size=120, estep_max_iters=60)
    eng = LDAEngine(cfg, corpus, algo="svi", batch_size=8, seed=0)
    bounds = []
    for _ in range(15):
        eng.run_minibatch()
        bounds.append(eng.full_bound())
    assert bounds[-1] > bounds[0]
