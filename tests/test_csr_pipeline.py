"""CSR ragged E-step pipeline (ISSUE 7): flat-token packing, training
equivalence, width-free serving, and the UCI O(1) resume index.

The acceptance bars:

* **CSR packer properties** — every token lands in exactly one emitted
  batch with its count intact, offsets are monotone with documents never
  split across batches, every batch is exactly ``token_budget`` slots
  with inert (segment 0, count 0) padding, and the pending/cursor
  checkpoint round-trip is bit-equal;
* **schedule-matched training equivalence** — a CSR-fed streaming run
  matches a materialized padded engine driven with the SAME deterministic
  emission schedule to fp32 tolerance, for IVI and S-IVI, and a CSR
  mid-epoch save → load → resume continues bit-equally;
* **width-free serving** — the CSR inferencer equals the padded one
  (empty and single-token documents included) while compiling exactly ONE
  jit entry for every document-length mix;
* **UCI O(1) resume** — ``iter_from(deep cursor)`` parses the same
  documents as a full scan while touching a small suffix of the file, not
  the whole prefix (the byte-offset index built by the stats scan).
"""
import importlib
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LDAConfig, LDAEngine
from repro.data import (BatchPacker, CorpusDocStream, CSRBatch,
                        UCIDocStream, corpus_from_docs, save_uci)
from repro.lda import LDA

es = importlib.import_module("repro.core.estep")


def _cfg(vocab, **kw):
    kw.setdefault("estep_max_iters", 20)
    return LDAConfig(num_topics=4, vocab_size=vocab, **kw)


def _same(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _ragged_docs(rng, n, vocab, max_len=40):
    out = []
    for _ in range(n):
        ln = int(rng.integers(0, max_len))
        ids = np.sort(rng.choice(vocab, size=ln, replace=False))
        cnts = (rng.poisson(1.0, ln) + 1).astype(np.float32)
        out.append((ids.astype(np.int32), cnts))
    return out


def _csr_schedule(docs, batch_size, token_budget, max_width=None):
    pk = BatchPacker(batch_size, max_width=max_width, layout="csr",
                     token_budget=token_budget)
    out = []
    for pos, (ids, cnts) in enumerate(docs):
        b = pk.add(pos, ids, cnts)
        if b is not None:
            out.append(b)
    return out + pk.flush(), pk


# ---------------------------------------------------------------------------
# CSR packer properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), batch=st.integers(1, 16),
       budget=st.integers(48, 300))
def test_csr_packer_every_token_exactly_once(seed, batch, budget):
    rng = np.random.default_rng(seed)
    docs = _ragged_docs(rng, int(rng.integers(1, 40)), vocab=500)
    batches, _ = _csr_schedule(docs, batch, budget)
    seen = {}
    for cb in batches:
        assert isinstance(cb, CSRBatch)
        t = cb.token_budget
        assert (len(cb.token_ids) == len(cb.counts)
                == len(cb.segments) == budget == t)
        assert cb.num_docs == len(cb.rows) <= batch
        # offsets: monotone document starts inside the flat stream
        offs = np.asarray(cb.offsets)
        assert np.all(np.diff(offs) >= 0)
        live = cb.live_tokens
        # padding tokens are inert: segment 0, count 0
        assert np.all(np.asarray(cb.counts[live:]) == 0.0)
        assert np.all(np.asarray(cb.segments[live:]) == 0)
        for d, row in enumerate(np.asarray(cb.rows)):
            sl = slice(int(offs[d]),
                       int(offs[d + 1]) if d + 1 < len(offs) else live)
            tok = np.asarray(cb.token_ids[sl])
            cnt = np.asarray(cb.counts[sl])
            assert np.all(np.asarray(cb.segments[sl]) == d)
            assert int(row) not in seen     # a doc is never split/repeated
            seen[int(row)] = (tok, cnt)
    assert sorted(seen) == list(range(len(docs)))
    for pos, (ids, cnts) in enumerate(docs):
        got_ids, got_cnts = seen[pos]
        # clipped docs keep their most frequent tokens; unclipped are exact
        if len(ids) <= budget:
            _same(got_ids, ids)
            _same(got_cnts, cnts)
        else:
            assert len(got_ids) == budget


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_csr_packer_cursor_roundtrip_bit_equal(seed):
    """pending_docs → load_pending reconstructs the exact CSR packer
    state: the remaining emission schedule is bit-equal."""
    rng = np.random.default_rng(seed)
    docs = _ragged_docs(rng, 23, vocab=300)
    a = BatchPacker(8, max_width=64, layout="csr", token_budget=128)
    for pos, (ids, cnts) in enumerate(docs):
        a.add(pos, ids, cnts)
    b = BatchPacker(8, max_width=64, layout="csr", token_budget=128)
    b.load_pending(a.pending_docs())
    fa, fb = a.flush(), b.flush()
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert x.token_budget == y.token_budget
        for f in ("rows", "token_ids", "counts", "segments", "offsets"):
            _same(getattr(x, f), getattr(y, f))


def test_csr_packer_requires_budget():
    with pytest.raises(ValueError, match="token_budget"):
        BatchPacker(8, layout="csr")


# ---------------------------------------------------------------------------
# schedule-matched training equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,store,backend", [
    ("ivi", "dense", "gather"),
    ("ivi", "chunked", "csr"),
    ("sivi", "dense", "csr"),
])
def test_csr_stream_matches_padded_schedule(tiny_corpus, algo, store,
                                            backend):
    """A CSR-fed streaming engine equals a materialized padded engine
    driven with the SAME deterministic emission schedule (the two packers
    legitimately emit different batch compositions, so the padded engine
    replays the CSR schedule batch by batch)."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec.vocab_size, estep_backend=backend)
    stream = CorpusDocStream(train, spec.vocab_size)
    budget = 256
    se = LDAEngine(cfg, stream, algo=algo, batch_size=16, seed=0,
                   memo_store=store, chunk_docs=32, layout="csr",
                   token_budget=budget)
    ce = LDAEngine(cfg, train, algo=algo, batch_size=16, seed=0,
                   memo_store=store, chunk_docs=32)
    sched, pk = _csr_schedule(list(stream.iter_from(0)), 16, budget,
                              max_width=stream.max_unique)
    for _ in range(2):
        se.run_epoch()
        for cb in sched:
            w = pk.width_for(int(cb.doc_lengths.max()) if cb.num_docs
                             else 1)
            ce.run_minibatch(cb.rows, width=w)
    assert se.docs_seen == ce.docs_seen == 2 * train.num_docs
    np.testing.assert_allclose(np.asarray(se.state.lam),
                               np.asarray(ce.state.lam),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(se.state.m_vk),
                               np.asarray(ce.state.m_vk),
                               rtol=2e-3, atol=2e-3)
    _same(se.state.init_frac, ce.state.init_frac)


def test_csr_mid_epoch_save_resume_bit_equal(tiny_corpus, tmp_path):
    """Save mid-epoch with flat batches pending, resume on a fresh stream:
    λ, ⟨m_vk⟩ and the memo bit-equal the run that never stopped."""
    train, _, spec = tiny_corpus
    path = os.path.join(tmp_path, "ck")
    kw = dict(algo="ivi", batch_size=16, seed=7, layout="csr",
              token_budget=256)

    a = LDA(_cfg(spec.vocab_size), **kw).partial_fit(
        CorpusDocStream(train, spec.vocab_size), steps=3)
    cursor = a.trainer.stream_cursor
    assert cursor > 0                                # genuinely mid-epoch
    a.save(path)
    a.partial_fit(steps=6)                           # crosses the epoch tail

    b = LDA.load(path).resume(CorpusDocStream(train, spec.vocab_size))
    assert b.trainer.stream_cursor == cursor         # cursor round-tripped
    assert b.layout == "csr"
    b.partial_fit(steps=6)

    _same(a.lam, b.lam)
    _same(a.state.m_vk, b.state.m_vk)
    _same(a.state.init_frac, b.state.init_frac)
    sa, sb = a.trainer.eng.memo.state_dict(), b.trainer.eng.memo.state_dict()
    for k in sa:
        _same(sa[k], sb[k])


def test_csr_layout_validation(tiny_corpus):
    """Corpus-fed CSR engines and csr+bucket_by_length are refused; a
    Corpus handed to the LDA facade in CSR mode is auto-wrapped."""
    train, _, spec = tiny_corpus
    cfg = _cfg(spec.vocab_size)
    with pytest.raises(ValueError, match="DocStream"):
        LDAEngine(cfg, train, algo="ivi", layout="csr")
    with pytest.raises(ValueError, match="bucket_by_length"):
        LDA(cfg, layout="csr", bucket_by_length=True)
    lda = LDA(cfg, algo="ivi", batch_size=16, seed=0, layout="csr")
    lda.partial_fit(train, steps=2)                  # auto-wrapped stream
    assert lda.trainer.eng.layout == "csr"


# ---------------------------------------------------------------------------
# width-free serving
# ---------------------------------------------------------------------------

def test_csr_serving_matches_padded_single_jit_entry(tiny_corpus):
    """CSR serving equals padded serving on a mixed-length request set —
    empty and single-token documents included — while compiling exactly
    ONE entry for the whole mix."""
    train, _, spec = tiny_corpus
    lda = LDA(_cfg(spec.vocab_size, estep_max_iters=100, estep_tol=1e-6),
              algo="ivi", batch_size=16, seed=0).fit(train, epochs=1)
    rng = np.random.default_rng(2)
    raw = [rng.integers(0, spec.vocab_size, size=int(n))
           for n in [0, 1, 3, 17, 40, 2, 55, 9, 1, 0, 30]]

    pad = lda.inferencer(batch_size=4, layout="padded")
    csr = lda.inferencer(batch_size=4, layout="csr", token_budget=128)
    g_pad = pad.posterior_docs(raw)
    g_csr = csr.posterior_docs(raw, double_buffer=True)
    np.testing.assert_allclose(g_csr, g_pad, rtol=2e-3, atol=2e-3)
    # empty docs come back at the prior
    assert np.allclose(g_csr[[0, 9]], lda.cfg.alpha0)
    assert csr.cache_info()["jit_entries"] == 1
    assert pad.cache_info()["jit_entries"] > 1
    # padding accounting exists on both layouts
    for inf in (pad, csr):
        stats = inf.padding_stats()
        assert stats["padded_slots"] >= stats["live_slots"] > 0
        assert stats["wasted_token_bytes"] >= 0


def test_csr_flat_solve_matches_gather_reference():
    """estep_csr_ref == estep_gather on the flattened batch (γ and the
    scattered sufficient statistics)."""
    rng = np.random.default_rng(5)
    docs = [rng.integers(0, 200, size=max(2, int(rng.poisson(20))))
            for _ in range(12)]
    corpus = corpus_from_docs(docs, 200)
    cfg = LDAConfig(num_topics=7, vocab_size=200, estep_max_iters=50)
    import jax
    from repro.core.math import exp_dirichlet_expectation
    lam = jax.random.gamma(jax.random.key(5), 100.0, (200, 7)) * 0.01
    eb = exp_dirichlet_expectation(lam, axis=0)
    want = es.estep_gather(cfg, eb, corpus.token_ids, corpus.counts)
    tok = es.CSRBackend.flatten(es.BowBatch(corpus.token_ids,
                                            corpus.counts))
    got = es.estep_csr_ref(cfg, eb, tok.token_ids, tok.counts,
                           tok.segments, num_docs=corpus.num_docs)
    np.testing.assert_allclose(np.asarray(got.gamma),
                               np.asarray(want.gamma), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.sstats),
                               np.asarray(want.sstats), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# UCI O(1) resume
# ---------------------------------------------------------------------------

class _CountingFile:
    def __init__(self, f, counter):
        self._f, self._c = f, counter

    def readline(self):
        line = self._f.readline()
        self._c["bytes"] += len(line)
        return line

    def seek(self, *a):
        return self._f.seek(*a)

    def tell(self):
        return self._f.tell()

    def __enter__(self):
        self._f.__enter__()
        return self

    def __exit__(self, *a):
        return self._f.__exit__(*a)


def test_uci_deep_resume_touches_o1_leading_bytes(tmp_path, monkeypatch):
    """iter_from(deep cursor) seeks to the nearest indexed docID group:
    it must parse the SAME documents as a full scan while reading a small
    tail of the file, not the whole prefix."""
    rng = np.random.default_rng(11)
    docs = [rng.integers(0, 120, size=int(rng.integers(1, 12)))
            for _ in range(240)]
    corpus = corpus_from_docs(docs, 120)
    path = os.path.join(tmp_path, "docword.txt")
    save_uci(corpus, path)
    size = os.path.getsize(path)

    stream = UCIDocStream(path, index_every=20)
    full = list(stream.iter_from(0))
    assert stream.num_words > 0          # stats scan done: index is built

    uci_mod = importlib.import_module("repro.data.uci")
    counter = {"bytes": 0}
    real_open = uci_mod._open_binary
    monkeypatch.setattr(uci_mod, "_open_binary",
                        lambda p: _CountingFile(real_open(p), counter))

    cursor = 230
    got = list(stream.iter_from(cursor))
    assert len(got) == len(full) - cursor
    for (gi, gc), (wi, wc) in zip(got, full[cursor:]):
        _same(gi, wi)
        _same(gc, wc)
    # deep resume reads O(index_every) docs of bytes, not the prefix
    assert 0 < counter["bytes"] < size // 4, (counter["bytes"], size)

    # a shallow cursor still equals the full scan through the same path
    counter["bytes"] = 0
    got1 = list(stream.iter_from(1))
    assert len(got1) == len(full) - 1
    _same(got1[0][0], full[1][0])


def test_uci_resume_index_equivalence_every_boundary(tmp_path):
    """Cursor positions straddling index boundaries (and the gap-filled
    empty-doc path) all reproduce the full scan exactly."""
    rng = np.random.default_rng(13)
    docs = [rng.integers(0, 50, size=int(rng.integers(0, 6)))
            for _ in range(103)]                     # empty docs included
    corpus = corpus_from_docs(docs, 50)
    path = os.path.join(tmp_path, "docword.txt.gz")
    save_uci(corpus, path)
    stream = UCIDocStream(path, index_every=25)
    full = list(stream.iter_from(0))
    for cursor in (0, 1, 24, 25, 26, 49, 75, 102):
        got = list(stream.iter_from(cursor))
        assert len(got) == len(full) - cursor
        for (gi, gc), (wi, wc) in zip(got, full[cursor:]):
            _same(gi, wi)
            _same(gc, wc)
